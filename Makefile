# Build-time artifact generation (optional): lowers the JAX model zoo to
# HLO text + manifests for the PJRT backend. Needs python3 with jax/numpy.
# The Rust build and tests do NOT need this — the native reference backend
# covers the hermetic path (see README.md §Backends).

.PHONY: artifacts vectors test build bench-json bench-serve bench-train clean

build:
	cargo build --release

test:
	cargo test -q

# machine-readable perf log: runs the runtime bench (train/eval step
# latency, naive-vs-tiled GEMM on resnet/vit @ batch 32, dense-vs-.geta
# inference through the f32-dequant and int8 kernels) and writes
# BENCH_runtime.json (gitignored, CI-uploaded) plus the checked-in
# BENCH_deploy.json summary at the repo root, so the deployment perf
# trajectory is diffable across PRs.
bench-json:
	cargo bench --bench bench_runtime

# serving sweep: trains mlp_tiny briefly, then drives the coalescing
# server across workers x batch-window x load (0 rps = saturation probe)
# and merges the latency/throughput rows into the checked-in
# BENCH_serve.json (see README.md §Serving).
bench-serve:
	cargo run --release -- bench-serve --model mlp_tiny --json

# training-throughput comparison: the same high-sparsity GETA run twice
# per thread count — masked-dense vs shrink-as-you-train (executor Plan
# rebuilt on the sliced subnet after every prune commit; bitwise
# identical trajectories) — merged into the checked-in BENCH_train.json
# (see README.md §Shrink-as-you-train).
bench-train:
	cargo run --release -- bench-train --model mlp_tiny --sparsity 0.85 --threads-sweep 1,4 --json

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
	cd python && python3 -m compile.vectors --out ../artifacts/quant_vectors.json

# regenerate the checked-in golden vectors (numpy only, no JAX):
# quant_vectors_small.json (quantizer math) + op_vectors_small.json
# (conv2d/layernorm/softmax forward+backward for the native interpreter).
# CI re-runs this and fails on a dirty diff (see .github/workflows/ci.yml).
vectors:
	python3 scripts/gen_quant_vectors.py

clean:
	rm -rf artifacts reports target
