//! Compression-as-a-service demo — a thin client of the `geta::serve`
//! subsystem.
//!
//! Trains `mlp_tiny` briefly, exports it to an in-memory `.geta`
//! container, loads that artifact **once** into a shared engine
//! (`serve::ModelCache`), and fronts it with `serve::Server`: bounded
//! queue, request coalescing, worker pool, latency histograms. The
//! client then serves the eval set through it and checks two things the
//! old version of this example got wrong:
//!
//! 1. **Trained weights are served.** The historical bug: each worker
//!    called `init_params(seed)` and served fresh random weights, so
//!    every reported loss was the ~ln(classes) of an untrained model.
//!    Now the served cross-entropy must beat that random baseline.
//! 2. **Serving changes nothing.** Each request's served logits must be
//!    bitwise identical to calling `engine.infer` directly — coalescing
//!    preserves per-request micro-batch boundaries by construction.
//!
//! Run: `cargo run --release --example compression_service`

use std::sync::Arc;
use std::time::Duration;

use geta::data::BatchIter;
use geta::deploy::{GetaEngine, KernelKind};
use geta::runtime::HostArray;
use geta::serve::{ModelCache, ServeConfig, ServeError, Server};

const WORKERS: usize = 2;
const REQUESTS: usize = 24;
const QUEUE_DEPTH: usize = 4; // backpressure bound
const BATCH: usize = 32; // samples per request

/// Mean softmax cross-entropy of a batch of served logits — computed
/// client-side, so it measures exactly what the service returned.
fn batch_loss(logits: &[f32], labels: &[i32], ncls: usize) -> f64 {
    let mut total = 0.0f64;
    for (i, &lab) in labels.iter().enumerate() {
        let row = &logits[i * ncls..(i + 1) * ncls];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f64 = row.iter().map(|&v| ((v - m) as f64).exp()).sum();
        total += (sum.ln() + m as f64) - row[lab as usize] as f64;
    }
    total / labels.len().max(1) as f64
}

fn main() -> anyhow::Result<()> {
    let art_dir = std::path::Path::new("artifacts");

    // ---- train + export once (this is where the weights come from) ----
    println!("training mlp_tiny (short run) and exporting a .geta container...");
    let trained = geta::report::train_export(art_dir, "mlp_tiny", 0.12, 0.5, 8.0)?;
    println!(
        "trained: acc {:.2}%  rel BOPs {:.2}%  sparsity {:.2}",
        trained.result.accuracy, trained.result.rel_bops, trained.result.group_sparsity
    );

    // ---- load ONCE into the shared cache; workers share the Arc ----
    let cache = ModelCache::new(KernelKind::Int8);
    let mut engine = GetaEngine::from_container_kernel(&trained.container, KernelKind::Int8)?;
    engine.threads = 1; // the server parallelizes across workers
    let engine = Arc::new(engine);
    cache.put("mlp_tiny", Arc::clone(&engine));
    let ncls = engine.output_per_sample();

    let server = Server::start(
        cache.get("mlp_tiny").expect("just cached"),
        ServeConfig {
            workers: WORKERS,
            queue_depth: QUEUE_DEPTH,
            batch_window: Duration::from_micros(300),
            max_batch: 4,
        },
    );

    // ---- the client: serve eval batches, keep labels for scoring ----
    let eval = &trained.trainer.eval_data;
    let mut it = BatchIter::new(eval.len(), BATCH, 5);
    let mut in_flight = Vec::new();
    let mut shed_retries = 0usize;
    for id in 0..REQUESTS {
        let idxs = it.next_batch();
        let (x, y) = eval.batch(&idxs);
        // bounded queue: a full queue sheds with a typed error; this
        // client's policy is retry-until-admitted
        let ticket = loop {
            match server.submit(x.clone()) {
                Ok(t) => break t,
                Err(ServeError::QueueFull { .. }) => {
                    shed_retries += 1;
                    std::thread::yield_now();
                }
                Err(e) => anyhow::bail!("submit failed: {e}"),
            }
        };
        in_flight.push((id, x, y, ticket));
    }

    let random_baseline = (ncls as f64).ln();
    let mut served_mean = 0.0f64;
    for (id, x, y, ticket) in in_flight {
        let reply = ticket.wait()?;
        // serving must not change results: bitwise-identical to a
        // direct engine call on the same request
        assert_eq!(reply.logits, engine.infer(&x)?, "served logits drifted");
        let HostArray::I32(labels) = &y else {
            anyhow::bail!("image task expects i32 labels")
        };
        let loss = batch_loss(&reply.logits, labels, ncls);
        served_mean += loss / REQUESTS as f64;
        println!(
            "resp {id:>3}: loss {:.4}  latency {:.2} ms",
            loss,
            reply.latency.as_secs_f64() * 1e3
        );
    }

    let report = server.shutdown();
    println!(
        "\nserved {} requests ({} batches, {} shed-retries): {}",
        report.stats.completed, report.stats.batches, shed_retries, report.histogram.summary()
    );
    println!(
        "served loss {served_mean:.4} vs random-init baseline {random_baseline:.4} (ln {ncls})"
    );
    anyhow::ensure!(
        served_mean < random_baseline,
        "served loss {served_mean:.4} does not beat the untrained baseline \
         {random_baseline:.4} — the service is not serving trained weights"
    );
    println!("OK: the service serves the trained weights, not random init");
    Ok(())
}
