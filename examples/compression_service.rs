//! Compression-as-a-service demo: a std-thread worker pool (the offline
//! substitute for a tokio runtime) serves evaluation requests against a
//! GETA-compressed model with bounded queues for backpressure.
//!
//! Layer-3 owns the event loop and process topology: a leader thread
//! accepts synthetic requests, routes them to workers over an mpsc
//! channel, each worker owns its own backend (thread-confined, no locks
//! on the hot path), and results stream back with latency stats. Works
//! against both backends: PJRT when artifacts exist, NativeEngine
//! otherwise.
//!
//! Run: `cargo run --release --example compression_service`

use std::sync::mpsc;
use std::time::Instant;

use geta::config::ExperimentConfig;
use geta::data::BatchIter;
use geta::runtime::{load_backend, Backend as _};

const WORKERS: usize = 2;
const REQUESTS: usize = 24;
const QUEUE_DEPTH: usize = 4; // backpressure bound

struct Request {
    id: usize,
    idxs: Vec<usize>,
    sent: Instant,
}

struct Response {
    id: usize,
    loss: f32,
    latency_ms: f64,
}

fn main() -> anyhow::Result<()> {
    let art = std::path::Path::new("artifacts");
    let exp = ExperimentConfig::defaults_for("mlp_tiny");
    // shared dataset (read-only)
    let (_, eval) = geta::data::SynthData::for_model(
        &load_backend(art, "mlp_tiny")?.manifest().config,
        64,
        512,
        3,
    );
    let eval = std::sync::Arc::new(eval);

    let (req_tx, req_rx) = mpsc::sync_channel::<Request>(QUEUE_DEPTH);
    let req_rx = std::sync::Arc::new(std::sync::Mutex::new(req_rx));
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();

    let mut handles = Vec::new();
    for w in 0..WORKERS {
        let rx = req_rx.clone();
        let tx = resp_tx.clone();
        let eval = eval.clone();
        let exp = exp.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
            // each worker owns its engine + weights (no shared mutable state)
            let engine = load_backend(std::path::Path::new("artifacts"), "mlp_tiny")?;
            let params = engine.init_params(exp.seed);
            let q = engine.init_qparams(&params, 8.0);
            loop {
                let req = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok(req) = req else { break };
                let (x, y) = eval.batch(&req.idxs);
                let out = engine.eval_step(&params, &q, &x, &y)?;
                tx.send(Response {
                    id: req.id,
                    loss: out.loss,
                    latency_ms: req.sent.elapsed().as_secs_f64() * 1e3,
                })
                .ok();
            }
            println!("worker {w} drained");
            Ok(())
        }));
    }
    drop(resp_tx);

    // leader: submit requests (sync_channel blocks when queue is full —
    // that IS the backpressure)
    let t0 = Instant::now();
    let mut it = BatchIter::new(eval.len(), 32, 5);
    for id in 0..REQUESTS {
        let idxs = it.next_batch();
        req_tx
            .send(Request {
                id,
                idxs,
                sent: Instant::now(),
            })
            .unwrap();
    }
    drop(req_tx);

    let mut lat: Vec<f64> = Vec::new();
    for resp in resp_rx {
        lat.push(resp.latency_ms);
        println!("resp {:>3}: loss {:.4}  latency {:.1} ms", resp.id, resp.loss, resp.latency_ms);
    }
    for h in handles {
        h.join().unwrap()?;
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total = t0.elapsed().as_secs_f64();
    println!(
        "\nserved {REQUESTS} requests in {:.2}s  ({:.1} req/s)  p50 {:.1} ms  p95 {:.1} ms",
        total,
        REQUESTS as f64 / total,
        lat[lat.len() / 2],
        lat[(lat.len() * 95 / 100).min(lat.len() - 1)]
    );
    Ok(())
}
