//! End-to-end validation driver (DESIGN.md requirement): train the
//! bert_mini transformer with the full GETA pipeline on the synthetic
//! span-extraction workload for several hundred steps, logging the loss
//! curve across all four QASSO stages, then evaluate EM/F1 and build the
//! compressed subnet. Proves the three layers compose: Pallas fake-quant
//! (L1) inside the JAX fwd/bwd (L2) driven by the Rust coordinator (L3).
//!
//! Run: `cargo run --release --example e2e_bert_squad`
//! The loss curve lands in reports/e2e_bert_loss.csv (EXPERIMENTS.md §E2E).

use geta::runtime::Backend as _;
use geta::config::ExperimentConfig;
use geta::coordinator::{GetaCompressor, Trainer};
use geta::graph;
use geta::optim::qasso::StageMask;
use geta::subnet;

fn main() -> anyhow::Result<()> {
    let art = std::path::Path::new("artifacts");
    let mut exp = ExperimentConfig::defaults_for("bert_mini");
    exp.qasso.target_group_sparsity = 0.5;
    exp.n_train = 2048;
    exp.n_eval = 512;
    // bert_mini runs on the native interpreter everywhere (PJRT is used
    // automatically when artifacts + the pjrt feature are present)
    let mut t = Trainer::new(art, exp)?;
    t.verbose = true;
    println!(
        "e2e: bert_mini ({} params) on {} synthetic QA examples, {} steps, platform {}",
        t.engine.manifest().param_count,
        t.train_data.len(),
        t.exp.total_steps(),
        t.engine.platform()
    );

    let mut geta_c = GetaCompressor::new(&t.engine, &t.exp, StageMask::default())?;
    let r = t.run(&mut geta_c)?;

    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/e2e_bert_loss.csv", r.trace.csv())?;

    println!("\n=== e2e result ===");
    println!("EM {:.2}%  F1 {:.2}%", r.em.unwrap_or(0.0), r.f1.unwrap_or(0.0));
    println!(
        "group sparsity {:.0}%  param sparsity {:.0}%  avg bits {:.2}  rel BOPs {:.2}%",
        r.group_sparsity * 100.0,
        r.param_sparsity * 100.0,
        r.avg_bits,
        r.rel_bops
    );
    println!("loss curve: reports/e2e_bert_loss.csv ({} points)", r.trace.steps.len());

    // subnet sanity: attention heads physically removed
    let space = graph::search_space_for(&t.engine.manifest().config)?;
    let params = t.engine.init_params(t.exp.seed);
    let q = t.engine.init_qparams(&params, 8.0);
    let costs = geta::metrics::layer_costs(&t.engine.manifest().config)?;
    let pruned: Vec<bool> = (0..space.groups.len()).map(|i| i % 2 == 0).collect();
    let cm = subnet::construct(&params, &space.groups, &pruned, &costs, &t.engine.site_specs(), &q);
    let wq = cm.sliced.get("block0.attn.wq.weight").unwrap();
    println!(
        "illustrative 50% slice: wq {:?} -> {:?}",
        params.get("block0.attn.wq.weight").unwrap().shape,
        wq.shape
    );
    Ok(())
}
