//! Quickstart — the paper's "Framework Usage" sketch, in Rust:
//!
//! ```python
//! geta = GETA(model); optimizer = geta.qasso()
//! optimizer.step(); geta.construct_subnet()
//! ```
//!
//! Run: `cargo run --release --example quickstart` — no artifacts needed:
//! without them the mlp_tiny pipeline runs on the native reference backend.

use geta::runtime::Backend as _;
use geta::config::ExperimentConfig;
use geta::coordinator::{GetaCompressor, Trainer};
use geta::graph;
use geta::optim::qasso::StageMask;
use geta::subnet;

fn main() -> anyhow::Result<()> {
    let art = std::path::Path::new("artifacts");

    // 1. GETA(model): load the model backend + build its QADG search space
    let mut exp = ExperimentConfig::defaults_for("mlp_tiny");
    exp.scale_steps(0.5);
    exp.qasso.target_group_sparsity = 0.4;
    let t = Trainer::new(art, exp)?;
    let space = graph::search_space_for(&t.engine.manifest().config)?;
    println!(
        "model mlp_tiny: {} params, {} prunable groups, {} quant sites",
        t.engine.manifest().param_count,
        space.groups.len(),
        t.engine.manifest().qsites.len()
    );

    // 2. optimizer = geta.qasso(); train as normal
    let mut geta_c = GetaCompressor::new(&t.engine, &t.exp, StageMask::default())?;
    let r = t.run(&mut geta_c)?;
    println!(
        "trained: acc {:.1}%  group sparsity {:.0}%  avg bits {:.1}  rel BOPs {:.2}%",
        r.accuracy,
        r.group_sparsity * 100.0,
        r.avg_bits,
        r.rel_bops
    );

    // 3. geta.construct_subnet(): physical slicing + packed quant weights
    let params = t.engine.init_params(t.exp.seed); // illustrative re-init
    let costs = geta::metrics::layer_costs(&t.engine.manifest().config)?;
    let q = t.engine.init_qparams(&params, 8.0);
    let ngroups = space.groups.len();
    let pruned = vec![false; ngroups];
    let cm = subnet::construct(&params, &space.groups, &pruned, &costs, &t.engine.site_specs(), &q);
    println!(
        "subnet: {} -> {} params, fp32 {}B -> packed {}B",
        cm.params_before, cm.params_after, cm.size_fp32_before, cm.size_after
    );
    Ok(())
}
