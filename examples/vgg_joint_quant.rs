//! Joint weight+activation quantization on VGG7-mini (the Table-4
//! scenario): GETA's white-box targets vs a DJPQ-like black-box
//! regularizer on the same substrate. Demonstrates activation-quant sites
//! flowing through the whole stack (inserted branches in the QADG, the
//! act rows of the q array, BOPs with learned activation bits).
//!
//! Run: `cargo run --release --example vgg_joint_quant`

use geta::runtime::Backend as _;
use geta::baselines;
use geta::config::ExperimentConfig;
use geta::coordinator::{GetaCompressor, Trainer};
use geta::graph;
use geta::optim::qasso::StageMask;

fn main() -> anyhow::Result<()> {
    let art = std::path::Path::new("artifacts");
    let mut exp = ExperimentConfig::defaults_for("vgg7_mini");
    exp.scale_steps(0.5);
    exp.qasso.target_group_sparsity = 0.5;
    // vgg7_mini runs on the native interpreter everywhere (PJRT is used
    // automatically when artifacts + the pjrt feature are present)
    let t = Trainer::new(art, exp)?;
    let nsites = t.engine.manifest().qsites.len();
    let nact = t
        .engine
        .manifest()
        .qsites
        .iter()
        .filter(|s| s.param.is_none())
        .count();
    println!("vgg7_mini: {nsites} quant sites ({nact} activation sites)");

    println!("\n-- GETA (explicit sparsity=0.5, bits [4,16]) --");
    let mut g = GetaCompressor::new(&t.engine, &t.exp, StageMask::default())?;
    let rg = t.run(&mut g)?;
    println!(
        "acc {:.2}%  rel BOPs {:.2}%  avg bits {:.1}  achieved sparsity {:.2}",
        rg.accuracy, rg.rel_bops, rg.avg_bits, rg.group_sparsity
    );

    println!("\n-- DJPQ-like (black-box: sparsity emerges from lambda) --");
    let space = graph::search_space_for(&t.engine.manifest().config)?;
    let params = t.engine.init_params(t.exp.seed);
    let mut d = baselines::RegularizedJoint::new(
        0.5,
        0.02,
        0.02,
        4.0,
        16.0,
        baselines::base_opt(&t.exp),
        t.exp.total_steps(),
        space.groups,
        &params,
        false,
        "DJPQ-like",
    );
    let rd = t.run(&mut d)?;
    println!(
        "acc {:.2}%  rel BOPs {:.2}%  avg bits {:.1}  achieved sparsity {:.2} (uncontrolled)",
        rd.accuracy, rd.rel_bops, rd.avg_bits, rd.group_sparsity
    );

    println!(
        "\nwhite-box vs black-box: GETA hit its 0.50 target exactly ({:.2}); \
         the regularizer landed wherever lambda took it ({:.2}).",
        rg.group_sparsity, rd.group_sparsity
    );
    Ok(())
}
