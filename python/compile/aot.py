"""AOT lowering: JAX train/eval steps -> HLO text + JSON manifests.

This is the ONLY place Python runs in the whole system, and it runs once
(`make artifacts`). For every model config in configs/models/ it lowers

    train_step(*params, q, x, y) -> (loss, *grads, qgrad, metric)
    eval_step(*params, q, x, y)  -> task-specific outputs

to HLO **text** (not serialized HloModuleProto: the xla crate's
xla_extension 0.5.1 rejects jax>=0.5 64-bit instruction ids; the text
parser reassigns ids — see /opt/xla-example/README.md) plus a manifest
describing every input/output so the Rust runtime packs literals without
any hardcoded knowledge of the model.

Usage: python -m compile.aot --out-dir ../artifacts [--models a,b,c]
"""

import argparse
import json
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import models as M

CONFIG_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "configs", "models")

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple so the Rust
    side unwraps one tuple output)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def load_configs(names=None):
    cfgs = []
    for fn in sorted(os.listdir(CONFIG_DIR)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(CONFIG_DIR, fn)) as f:
            cfg = json.load(f)
        if names is None or cfg["name"] in names:
            cfgs.append(cfg)
    return cfgs


def specs_for(model):
    (xshape, xdt), (yshape, ydt) = model.batch_shapes()
    param_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for (_, s) in model.param_specs]
    q_spec = jax.ShapeDtypeStruct((max(model.n_sites(), 1), 3), jnp.float32)
    x_spec = jax.ShapeDtypeStruct(xshape, DTYPES[xdt])
    y_spec = jax.ShapeDtypeStruct(yshape, DTYPES[ydt])
    return param_specs, q_spec, x_spec, y_spec


def eval_output_names(cfg):
    task = cfg["task"]
    if task == "image_cls":
        return ["loss", "correct"]
    if task == "span_qa":
        return ["loss", "correct", "pred_start", "pred_end"]
    if task == "lm":
        return ["loss", "correct", "mask_count"]
    raise ValueError(task)


def lower_model(cfg, out_dir):
    model = M.build(cfg)
    name = cfg["name"]
    param_specs, q_spec, x_spec, y_spec = specs_for(model)
    args = (*param_specs, q_spec, x_spec, y_spec)

    train_hlo = to_hlo_text(jax.jit(model.train_step).lower(*args))
    eval_hlo = to_hlo_text(jax.jit(model.eval_step).lower(*args))

    train_path = f"{name}_train.hlo.txt"
    eval_path = f"{name}_eval.hlo.txt"
    with open(os.path.join(out_dir, train_path), "w") as f:
        f.write(train_hlo)
    with open(os.path.join(out_dir, eval_path), "w") as f:
        f.write(eval_hlo)

    (xshape, xdt), (yshape, ydt) = model.batch_shapes()
    manifest = {
        "model": name,
        "config": cfg,
        "train_hlo": train_path,
        "eval_hlo": eval_path,
        "params": [{"name": n, "shape": list(s)} for (n, s) in model.param_specs],
        "qsites": model.qsites,
        "q_shape": [max(model.n_sites(), 1), 3],
        "batch": {"x": {"shape": list(xshape), "dtype": xdt},
                  "y": {"shape": list(yshape), "dtype": ydt}},
        "train_outputs": (["loss"] + [f"grad:{n}" for (n, _) in model.param_specs]
                          + ["qgrad", "metric"]),
        "eval_outputs": eval_output_names(cfg),
        "param_count": int(sum(int(np.prod(s)) for (_, s) in model.param_specs)),
    }
    mpath = os.path.join(out_dir, f"{name}.manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    total = manifest["param_count"]
    print(f"  {name}: {total} params, {model.n_sites()} qsites, "
          f"train={len(train_hlo)//1024}KiB eval={len(eval_hlo)//1024}KiB")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--models", default=None, help="comma-separated subset")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    names = set(args.models.split(",")) if args.models else None
    cfgs = load_configs(names)
    if not cfgs:
        print("no configs matched", file=sys.stderr)
        sys.exit(1)
    index = []
    for cfg in cfgs:
        print(f"lowering {cfg['name']} ({cfg['family']}/{cfg['task']})")
        man = lower_model(cfg, args.out_dir)
        index.append({"model": man["model"], "manifest": f"{man['model']}.manifest.json"})
    with open(os.path.join(args.out_dir, "index.json"), "w") as f:
        json.dump({"models": index}, f, indent=1)
    print(f"wrote {len(index)} models to {args.out_dir}")


if __name__ == "__main__":
    main()
