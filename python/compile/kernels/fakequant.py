"""Layer-1 Pallas kernels: parameterized fake-quantization, eqs. (1)-(6).

The quantizer is the compute hot spot of quantization-aware training — it
runs elementwise over every quantized weight and activation tensor on every
forward AND backward pass. Two kernels:

* ``fakequant_fwd`` — eq. (1) nonlinear clip-pow map + eq. (2) uniform
  round-to-step, fused in one pass.
* ``fakequant_bwd`` — the three STE partial derivatives (eqs. (4)-(6)) plus
  the clipped pass-through mask for dx, fused in one pass so the backward
  reads x once.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the tensor is flattened
and tiled with a 1-D BlockSpec so each grid step streams one VMEM-resident
block of ``BLOCK`` elements; the scalar quant parameters (d, t, q_m) ride
along as (1,1) blocks replicated to every grid step (scalar-prefetch
pattern), so a single compiled kernel serves every layer. The op is
elementwise (no MXU work): the roofline is memory-bound, and the fusion of
all four backward outputs into one kernel is what buys back bandwidth.

On this image Pallas runs ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); interpret mode lowers to plain HLO at trace time so
the AOT artifact contains ordinary fused elementwise HLO while the BlockSpec
structure is preserved for real-TPU compilation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12

# Block size: 2048 f32 = 8 KiB per operand block. VMEM per fwd grid step is
# in(8KiB) + out(8KiB) + scalars — far under the ~16 MiB VMEM budget; chosen
# small enough that tiny layers (hundreds of params) don't over-pad and big
# enough that the HBM stream is sequential. See EXPERIMENTS.md §Perf.
BLOCK = 2048


def _fwd_kernel(x_ref, d_ref, t_ref, qm_ref, o_ref):
    """Fused eq.(1)+(2): o = d * round(sgn(x)*clip_pow(|x|)/d)."""
    x = x_ref[...]
    d = d_ref[0]
    t = t_ref[0]
    qm = qm_ref[0]
    ax = jnp.abs(x)
    safe = jnp.maximum(ax, _EPS)
    c = jnp.where(ax <= qm, jnp.exp(t * jnp.log(safe)),
                  jnp.exp(t * jnp.log(jnp.maximum(qm, _EPS))))
    xt = jnp.sign(x) * c
    o_ref[...] = d * jnp.round(xt / d)


def _bwd_kernel(x_ref, d_ref, t_ref, qm_ref, gd_ref, gt_ref, gqm_ref, mask_ref):
    """Fused eqs.(4)-(6) + STE mask, one read of x."""
    x = x_ref[...]
    d = d_ref[0]
    t = t_ref[0]
    qm = qm_ref[0]
    ax = jnp.abs(x)
    inside = ax <= qm
    sgn = jnp.sign(x)
    safe_ax = jnp.maximum(ax, _EPS)
    safe_qm = jnp.maximum(qm, _EPS)
    log_ax = jnp.log(safe_ax)
    log_qm = jnp.log(safe_qm)
    # clip_pow (eq. 13) shared by eq. (4) and eq. (5)
    c = jnp.where(inside, jnp.exp(t * log_ax), jnp.exp(t * log_qm))
    cd = c / d
    # eq. (4): sgn(x) * (round(c/d) - c/d)
    gd_ref[...] = sgn * (jnp.round(cd) - cd)
    # eq. (5): sgn(x) * c * log(.), zero at exact zeros
    gt = jnp.where(inside, c * log_ax, c * log_qm)
    gt_ref[...] = sgn * jnp.where(ax <= _EPS, 0.0, gt)
    # eq. (6): zero inside, sgn(x)*t*qm^(t-1) outside
    gqm_ref[...] = jnp.where(inside, 0.0, sgn * t * jnp.exp((t - 1.0) * log_qm))
    # clipped STE pass-through mask for dx
    mask_ref[...] = jnp.where(inside, 1.0, 0.0)


def _pad_len(n):
    return (n + BLOCK - 1) // BLOCK * BLOCK


@functools.partial(jax.jit, static_argnames=())
def fakequant_fwd(x, d, t, qm):
    """Pallas forward fake-quant over a tensor of any shape.

    ``d``, ``t``, ``qm`` are scalars (one quantization site). Returns x^Q
    with the same shape/dtype as x.
    """
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    npad = _pad_len(n)
    flat = jnp.pad(flat, (0, npad - n))
    scal = lambda v: jnp.asarray(v, flat.dtype).reshape(1)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(npad // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), flat.dtype),
        interpret=True,
    )(flat, scal(d), scal(t), scal(qm))
    return out[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=())
def fakequant_bwd(x, d, t, qm):
    """Pallas backward: returns (grad_d_elem, grad_t_elem, grad_qm_elem,
    ste_mask), each with the shape of x. The caller contracts the first
    three against the upstream cotangent to get scalar (d, t, qm) grads.
    """
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    npad = _pad_len(n)
    flat = jnp.pad(flat, (0, npad - n))
    scal = lambda v: jnp.asarray(v, flat.dtype).reshape(1)
    outs = pl.pallas_call(
        _bwd_kernel,
        grid=(npad // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))] * 4,
        out_shape=[jax.ShapeDtypeStruct((npad,), flat.dtype)] * 4,
        interpret=True,
    )(flat, scal(d), scal(t), scal(qm))
    return tuple(o[:n].reshape(shape) for o in outs)
