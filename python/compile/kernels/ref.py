"""Pure-jnp oracle for the parameterized fake-quantizer.

Implements eqs. (1)-(6) and (13)-(14) of the GETA paper exactly, with no
Pallas involvement. The Pallas kernels in ``fakequant.py`` are validated
against these functions by ``python/tests/test_kernel.py``; the Rust-side
reimplementation (``rust/src/quant``) is validated against vectors exported
from here (see ``python/tests/test_vectors.py``).

All functions are elementwise over ``x`` with scalar quantization
parameters ``d`` (step size), ``t`` (exponent), ``q_m`` (clip max).
"""

import jax.numpy as jnp

# Guard for |x|**t at x == 0 (t may drift below 1 during training; the
# gradient |x|**t * log|x| is undefined at 0 — the paper's STE treats the
# 0-element contribution as 0).
_EPS = 1e-12


def clip_pow(x, t, q_m):
    """Eq. (13): clip_{q_m}^t(|x|) — the nonlinearly mapped magnitude."""
    ax = jnp.abs(x)
    return jnp.where(ax <= q_m, jnp.power(jnp.maximum(ax, _EPS), t),
                     jnp.power(jnp.maximum(q_m, _EPS), t))


def nonlinear_map(x, t, q_m):
    """Eq. (1): x-tilde = sgn(x) * clip_pow(x)."""
    return jnp.sign(x) * clip_pow(x, t, q_m)


def fake_quant(x, d, t, q_m):
    """Eqs. (1)+(2): x^Q = d * round(x-tilde / d)."""
    xt = nonlinear_map(x, t, q_m)
    return d * jnp.round(xt / d)


def residual(x, d, t, q_m):
    """Eq. (14): R(x) = round(c/d) - c/d where c = clip_pow(x)."""
    c = clip_pow(x, t, q_m)
    return jnp.round(c / d) - c / d


def bit_width(d, t, q_m):
    """Eq. (3): b = log2((q_m^t)/d + 1) + 1."""
    return jnp.log2(jnp.power(jnp.maximum(q_m, _EPS), t) / d + 1.0) + 1.0


def grad_d(x, d, t, q_m):
    """Eq. (4): dx^Q/dd = sgn(x) * (round(c/d) - c/d) = sgn(x)*R(x)."""
    return jnp.sign(x) * residual(x, d, t, q_m)


def grad_t(x, d, t, q_m):
    """Eq. (5): dx^Q/dt = sgn(x) * c * log(|x| or q_m) (STE through round)."""
    ax = jnp.abs(x)
    inside = jnp.power(jnp.maximum(ax, _EPS), t) * jnp.log(jnp.maximum(ax, _EPS))
    outside = jnp.power(jnp.maximum(q_m, _EPS), t) * jnp.log(jnp.maximum(q_m, _EPS))
    g = jnp.where(ax <= q_m, inside, outside)
    # zero contribution from exact zeros (log undefined there)
    return jnp.sign(x) * jnp.where(ax <= _EPS, 0.0, g)


def grad_qm(x, d, t, q_m):
    """Eq. (6): dx^Q/dq_m = 0 inside the clip range, sgn(x)*t*q_m^(t-1) outside."""
    ax = jnp.abs(x)
    return jnp.where(ax <= q_m, 0.0,
                     jnp.sign(x) * t * jnp.power(jnp.maximum(q_m, _EPS), t - 1.0))


def grad_x_ste(x, d, t, q_m):
    """Straight-through estimator for dx^Q/dx: pass-through inside the clip
    range, zero outside (clipped STE, standard for parameterized quantizers
    [61]; the paper does not specify dx and inherits this choice)."""
    return jnp.where(jnp.abs(x) <= q_m, 1.0, 0.0)
