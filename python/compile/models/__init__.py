"""Model registry: config dict -> ModelDef with lowerable train/eval steps.

A ModelDef packages everything aot.py needs:
  * ``param_specs``  — ordered (name, shape) list; the HLO input order.
  * ``qsites``       — ordered quantization sites; row i of the q array.
  * ``init_params``  — numpy initialization (seeded, deterministic).
  * ``train_step(*params, q, x, y)`` -> (loss, *grads, qgrad, metric)
  * ``eval_step(*params, q, x, y)``  -> task-specific outputs (see below)

Eval outputs per task:
  image_cls : (loss, correct_count)
  span_qa   : (loss, correct_count, pred_start[B], pred_end[B])
  lm        : (loss, correct_tokens, mask_count)
"""

import numpy as np
import jax
import jax.numpy as jnp

from . import common as C
from . import cnn, transformer as tfm

BATCH = {"image_cls": 32, "span_qa": 16, "lm": 16}


class ModelDef:
    def __init__(self, cfg, plan, apply_fn, loss_fn, pred_fn=None):
        self.cfg = cfg
        self.plan = plan
        self.apply_fn = apply_fn
        self.loss_fn = loss_fn
        self.pred_fn = pred_fn
        self.param_specs = [(n, s) for (n, s, _) in plan.param_specs]
        self.qsites = plan.qsites
        self.names = [n for (n, _, _) in plan.param_specs]

    # ------------------------------------------------------------ shapes
    def batch_shapes(self):
        cfg, task = self.cfg, self.cfg["task"]
        B = BATCH[task]
        if task == "image_cls":
            img = cfg["image"]
            return ((B, img["size"], img["size"], img["channels"]), "f32"), ((B,), "i32")
        if task == "span_qa":
            return ((B, cfg["seq_len"]), "i32"), ((B, 2), "i32")
        if task == "lm":
            return ((B, cfg["seq_len"]), "i32"), ((B, cfg["seq_len"]), "i32")
        raise ValueError(task)

    def n_sites(self):
        return len(self.qsites)

    # -------------------------------------------------------------- init
    def init_params(self, seed=0):
        rng = np.random.default_rng(seed)
        return {n: init(rng, shape) for (n, shape, init) in self.plan.param_specs}

    # -------------------------------------------------------------- steps
    def _pack(self, arrays):
        return dict(zip(self.names, arrays))

    def _loss(self, params, q, x, y):
        out = self.apply_fn(params, q, x)
        return self.loss_fn(out, y)

    def train_step(self, *args):
        n = len(self.names)
        params = self._pack(args[:n])
        q, x, y = args[n], args[n + 1], args[n + 2]

        def f(params, q):
            loss, metric = self._loss(params, q, x, y)
            return loss, metric

        (loss, metric), (gp, gq) = jax.value_and_grad(f, argnums=(0, 1), has_aux=True)(params, q)
        grads = [gp[name] for name in self.names]
        return (loss, *grads, gq, metric)

    def eval_step(self, *args):
        n = len(self.names)
        params = self._pack(args[:n])
        q, x, y = args[n], args[n + 1], args[n + 2]
        out = self.apply_fn(params, q, x)
        loss, metric = self.loss_fn(out, y)
        res = [loss, metric]
        if self.cfg["task"] == "span_qa":
            ps, pe = tfm.bert_preds(out)
            res += [ps, pe]
        if self.cfg["task"] == "lm":
            res += [jnp.sum((y >= 0).astype(jnp.float32))]
        return tuple(res)


def _cls_loss(logits, y):
    return C.softmax_xent(logits, y), C.correct_count(logits, y)


def build(cfg):
    fam = cfg["family"]
    if fam == "mlp":
        plan = cnn.plan_mlp(cfg)
        return ModelDef(cfg, plan, cnn.make_apply_mlp(cfg, plan), _cls_loss)
    if fam == "vgg":
        plan = cnn.plan_vgg(cfg)
        return ModelDef(cfg, plan, cnn.make_apply_vgg(cfg, plan), _cls_loss)
    if fam == "resnet":
        plan = cnn.plan_resnet(cfg)
        return ModelDef(cfg, plan, cnn.make_apply_resnet(cfg, plan), _cls_loss)
    if fam == "bert":
        plan = tfm.plan_bert(cfg)
        return ModelDef(cfg, plan, tfm.make_apply_bert(cfg, plan), tfm.bert_loss)
    if fam == "gpt":
        plan = tfm.plan_gpt(cfg)
        return ModelDef(cfg, plan, tfm.make_apply_gpt(cfg, plan), tfm.lm_loss)
    if fam == "vit":
        plan = tfm.plan_vit(cfg)
        return ModelDef(cfg, plan, tfm.make_apply_vit(cfg, plan), _cls_loss)
    if fam == "swin":
        plan = tfm.plan_swin(cfg)
        return ModelDef(cfg, plan, tfm.make_apply_swin(cfg, plan), _cls_loss)
    raise ValueError(f"unknown family {fam}")
