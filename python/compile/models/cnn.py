"""CNN families: MLP (quickstart), VGG7-mini, ResNet-mini.

Each family exposes ``plan(cfg) -> Plan`` and ``make_apply(cfg, plan) ->
apply(params, q, x)``. Shapes follow the conventions in common.py.
"""

import jax
import jax.numpy as jnp

from . import common as C


# ------------------------------------------------------------------- MLP
def plan_mlp(cfg):
    p = C.Plan(cfg)
    img = cfg["image"]
    din = img["size"] * img["size"] * img["channels"]
    dims = [din] + list(cfg["hidden"])
    for i in range(len(cfg["hidden"])):
        C.plan_linear(p, f"fc{i}", dims[i], dims[i + 1])
        C.plan_act_site(p, f"fc{i}.act")
    C.plan_linear(p, "head", dims[-1], cfg["num_classes"])
    return p


def make_apply_mlp(cfg, plan):
    idx = plan.site_index()

    def apply(params, q, x):
        env = C.QEnv(q, idx)
        B = x.shape[0]
        h = x.reshape(B, -1)
        for i in range(len(cfg["hidden"])):
            h = C.linear(env, params, f"fc{i}", h)
            h = jax.nn.relu(h)
            h = env.apply(f"fc{i}.act", h)
        return C.linear(env, params, "head", h)

    return apply


# ------------------------------------------------------------------- VGG
def plan_vgg(cfg):
    p = C.Plan(cfg)
    cin = cfg["image"]["channels"]
    for i, cout in enumerate(cfg["conv_channels"]):
        C.plan_conv(p, f"features.{i}", cin, cout)
        C.plan_norm(p, f"features.{i}.bn", cout)
        C.plan_act_site(p, f"features.{i}.act")
        cin = cout
    npool = len(cfg["conv_channels"]) // cfg["pool_every"]
    fmap = cfg["image"]["size"] >> npool
    din = cin * fmap * fmap
    dims = [din] + list(cfg["fc_dims"])
    for i in range(len(cfg["fc_dims"])):
        C.plan_linear(p, f"fc{i}", dims[i], dims[i + 1])
        C.plan_act_site(p, f"fc{i}.act")
    C.plan_linear(p, "head", dims[-1], cfg["num_classes"])
    return p


def make_apply_vgg(cfg, plan):
    idx = plan.site_index()

    def apply(params, q, x):
        env = C.QEnv(q, idx)
        h = x
        for i in range(len(cfg["conv_channels"])):
            h = C.conv2d(env, params, f"features.{i}", h)
            h = C.batchnorm(params, f"features.{i}.bn", h)
            h = jax.nn.relu(h)
            h = env.apply(f"features.{i}.act", h)
            if (i + 1) % cfg["pool_every"] == 0:
                h = C.maxpool2(h)
        B = h.shape[0]
        h = h.reshape(B, -1)
        for i in range(len(cfg["fc_dims"])):
            h = C.linear(env, params, f"fc{i}", h)
            h = jax.nn.relu(h)
            h = env.apply(f"fc{i}.act", h)
        return C.linear(env, params, "head", h)

    return apply


# ---------------------------------------------------------------- ResNet
def _stage_plan(p, sname, cin, cout, blocks, stride):
    for b in range(blocks):
        s = stride if b == 0 else 1
        proj = (s != 1) or (cin != cout)
        C.plan_conv(p, f"{sname}.{b}.conv1", cin, cout)
        C.plan_norm(p, f"{sname}.{b}.bn1", cout)
        C.plan_conv(p, f"{sname}.{b}.conv2", cout, cout)
        C.plan_norm(p, f"{sname}.{b}.bn2", cout)
        if proj:
            C.plan_conv(p, f"{sname}.{b}.proj", cin, cout, k=1)
            C.plan_norm(p, f"{sname}.{b}.bnp", cout)
        cin = cout
    return cin


def plan_resnet(cfg):
    p = C.Plan(cfg)
    C.plan_conv(p, "stem", cfg["image"]["channels"], cfg["stem_channels"])
    C.plan_norm(p, "stem.bn", cfg["stem_channels"])
    cin = cfg["stem_channels"]
    for si, cout in enumerate(cfg["stage_channels"]):
        stride = 1 if si == 0 else 2
        cin = _stage_plan(p, f"stage{si}", cin, cout, cfg["blocks_per_stage"], stride)
    C.plan_linear(p, "head", cin, cfg["num_classes"])
    return p


def make_apply_resnet(cfg, plan):
    idx = plan.site_index()

    def block(env, params, name, h, cin, cout, stride):
        proj = (stride != 1) or (cin != cout)
        y = C.conv2d(env, params, name + ".conv1", h, stride)
        y = C.batchnorm(params, name + ".bn1", y)
        y = jax.nn.relu(y)
        y = C.conv2d(env, params, name + ".conv2", y)
        y = C.batchnorm(params, name + ".bn2", y)
        if proj:
            h = C.conv2d(env, params, name + ".proj", h, stride)
            h = C.batchnorm(params, name + ".bnp", h)
        return jax.nn.relu(h + y)

    def apply(params, q, x):
        env = C.QEnv(q, idx)
        h = C.conv2d(env, params, "stem", x)
        h = C.batchnorm(params, "stem.bn", h)
        h = jax.nn.relu(h)
        cin = cfg["stem_channels"]
        for si, cout in enumerate(cfg["stage_channels"]):
            stride = 1 if si == 0 else 2
            for b in range(cfg["blocks_per_stage"]):
                s = stride if b == 0 else 1
                h = block(env, params, f"stage{si}.{b}", h, cin, cout, s)
                cin = cout
        h = jnp.mean(h, axis=(1, 2))  # global average pool
        return C.linear(env, params, "head", h)

    return apply
