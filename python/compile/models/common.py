"""Shared functional layers for the JAX model zoo (Layer 2).

Models are pure functions over a ``params`` dict (name -> array) and a
``q`` array of shape [n_sites, 3] holding one (d, t, q_m) row per
quantization site. Site order is fixed at plan time and exported in the
AOT manifest so the Rust coordinator indexes rows identically.

Weight layout conventions (mirrored by rust/src/graph/builders.rs):
  conv    : HWIO  [kh, kw, cin, cout]   (prunable dim = cout = axis 3)
  linear  : [din, dout]                 (prunable dim = dout = axis 1)
  bn/ln   : gamma/beta [c]
  embed   : [vocab, dim]
"""

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from ..quantizer import fake_quant


class Plan:
    """Collects parameter specs and quantization sites in a fixed order."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.param_specs = []   # (name, shape)
        self.qsites = []        # {name, kind, param}
        self._seen = set()

    def param(self, name, shape, init):
        assert name not in self._seen, f"duplicate param {name}"
        self._seen.add(name)
        self.param_specs.append((name, tuple(int(s) for s in shape), init))
        return name

    def qsite(self, name, kind, param=None):
        self.qsites.append({"name": name, "kind": kind, "param": param})

    def site_index(self):
        return {s["name"]: i for i, s in enumerate(self.qsites)}


class QEnv:
    """Runtime quantization context: applies fake-quant at registered sites."""

    def __init__(self, q, site_index):
        self.q = q
        self.idx = site_index

    def apply(self, site, x):
        if site not in self.idx:
            return x
        i = self.idx[site]
        return fake_quant(x, self.q[i, 0], self.q[i, 1], self.q[i, 2])


# ---------------------------------------------------------------- inits
def he_conv(rng, shape):
    kh, kw, cin, _ = shape
    std = np.sqrt(2.0 / (kh * kw * cin))
    return (rng.normal(size=shape) * std).astype(np.float32)


def glorot_linear(rng, shape):
    din, dout = shape
    std = np.sqrt(2.0 / (din + dout))
    return (rng.normal(size=shape) * std).astype(np.float32)


def zeros(rng, shape):
    return np.zeros(shape, np.float32)


def ones(rng, shape):
    return np.ones(shape, np.float32)


def embed_init(rng, shape):
    return (rng.normal(size=shape) * 0.02).astype(np.float32)


# ---------------------------------------------------------------- layers
def conv2d(env, params, name, x, stride=1):
    """3x3/1x1 conv, NHWC, SAME padding, weight-quantized at site <name>."""
    w = env.apply(name + ".weight", params[name + ".weight"])
    b = params[name + ".bias"]
    y = lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def linear(env, params, name, x):
    w = env.apply(name + ".weight", params[name + ".weight"])
    b = params[name + ".bias"]
    return x @ w + b


def batchnorm(params, name, x, eps=1e-5):
    """Batch-statistics normalization over (N, H, W); stateless (see
    DESIGN.md decision 3)."""
    axes = tuple(range(x.ndim - 1))
    mu = jnp.mean(x, axis=axes, keepdims=True)
    var = jnp.var(x, axis=axes, keepdims=True)
    xhat = (x - mu) * lax.rsqrt(var + eps)
    return xhat * params[name + ".gamma"] + params[name + ".beta"]


def layernorm(params, name, x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    xhat = (x - mu) * lax.rsqrt(var + eps)
    return xhat * params[name + ".gamma"] + params[name + ".beta"]


def maxpool2(x):
    return lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def attention(env, params, name, x, heads, causal=False):
    """Multi-head self-attention; q/k/v/o projection weights are quant sites.

    Head structure is what makes per-channel pruning insufficient (paper
    §1.1): the Rust dependency analysis groups the per-head slices of
    wq/wk/wv/wo jointly.
    """
    B, S, D = x.shape
    hd = D // heads
    q = linear(env, params, name + ".wq", x)
    k = linear(env, params, name + ".wk", x)
    v = linear(env, params, name + ".wv", x)

    def split(t):
        return t.reshape(B, S, heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split(q), split(k), split(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / np.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(B, S, D)
    return linear(env, params, name + ".wo", y)


def transformer_block(env, params, name, x, heads, mlp_ratio, causal=False):
    """Pre-LN transformer block."""
    h = layernorm(params, name + ".ln1", x)
    x = x + attention(env, params, name + ".attn", h, heads, causal)
    h = layernorm(params, name + ".ln2", x)
    h = linear(env, params, name + ".fc1", h)
    h = jax.nn.gelu(h)
    h = linear(env, params, name + ".fc2", h)
    return x + h


# ------------------------------------------------- plan-side constructors
def plan_conv(plan, name, cin, cout, k=3, quant=True):
    plan.param(name + ".weight", (k, k, cin, cout), he_conv)
    plan.param(name + ".bias", (cout,), zeros)
    if quant and plan.cfg["quant"]["weight"]:
        plan.qsite(name + ".weight", "weight", name + ".weight")


def plan_linear(plan, name, din, dout, quant=True):
    plan.param(name + ".weight", (din, dout), glorot_linear)
    plan.param(name + ".bias", (dout,), zeros)
    if quant and plan.cfg["quant"]["weight"]:
        plan.qsite(name + ".weight", "weight", name + ".weight")


def plan_norm(plan, name, c):
    plan.param(name + ".gamma", (c,), ones)
    plan.param(name + ".beta", (c,), zeros)


def plan_act_site(plan, name):
    if plan.cfg["quant"].get("act", False):
        plan.qsite(name, "act", None)


def plan_attn(plan, name, dim, quant=True):
    for p in ("wq", "wk", "wv", "wo"):
        plan_linear(plan, f"{name}.{p}", dim, dim, quant)


def plan_block(plan, name, dim, mlp_ratio, quant=True):
    plan_norm(plan, name + ".ln1", dim)
    plan_attn(plan, name + ".attn", dim, quant)
    plan_norm(plan, name + ".ln2", dim)
    plan_linear(plan, name + ".fc1", dim, dim * mlp_ratio, quant)
    plan_linear(plan, name + ".fc2", dim * mlp_ratio, dim, quant)


# ---------------------------------------------------------------- losses
def softmax_xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def correct_count(logits, labels):
    return jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
