"""Transformer families: BERT-mini (span QA), GPT-mini (causal LM),
ViT-mini / SimpleViT-mini (image classification), Swin-mini (hierarchical).

The attention-head dependency structure (per-head slices of wq/wk/wv tied
to the corresponding wo rows) is exactly what the paper's QADG handles and
per-channel schemes (DJPQ/BB) cannot — the Rust graph builders mirror these
layouts to build head-granular pruning groups.
"""

import numpy as np
import jax
import jax.numpy as jnp

from . import common as C


# ------------------------------------------------------------------ BERT
def plan_bert(cfg):
    p = C.Plan(cfg)
    p.param("embed.tok", (cfg["vocab"], cfg["dim"]), C.embed_init)
    p.param("embed.pos", (cfg["seq_len"], cfg["dim"]), C.embed_init)
    C.plan_norm(p, "embed.ln", cfg["dim"])
    for b in range(cfg["blocks"]):
        C.plan_block(p, f"block{b}", cfg["dim"], cfg["mlp_ratio"])
    C.plan_norm(p, "final.ln", cfg["dim"])
    C.plan_linear(p, "span_head", cfg["dim"], 2)
    return p


def make_apply_bert(cfg, plan):
    idx = plan.site_index()

    def apply(params, q, x):
        env = C.QEnv(q, idx)
        h = params["embed.tok"][x] + params["embed.pos"][None, :, :]
        h = C.layernorm(params, "embed.ln", h)
        for b in range(cfg["blocks"]):
            h = C.transformer_block(env, params, f"block{b}", h,
                                    cfg["heads"], cfg["mlp_ratio"])
        h = C.layernorm(params, "final.ln", h)
        logits = C.linear(env, params, "span_head", h)  # [B, S, 2]
        return logits[..., 0], logits[..., 1]           # start, end

    return apply


def bert_loss(outputs, y):
    """y: [B, 2] gold (start, end) token indices."""
    start_logits, end_logits = outputs
    loss = C.softmax_xent(start_logits, y[:, 0]) + C.softmax_xent(end_logits, y[:, 1])
    metric = (C.correct_count(start_logits, y[:, 0]) +
              C.correct_count(end_logits, y[:, 1]))
    return loss, metric


def bert_preds(outputs):
    start_logits, end_logits = outputs
    return (jnp.argmax(start_logits, axis=-1).astype(jnp.int32),
            jnp.argmax(end_logits, axis=-1).astype(jnp.int32))


# ------------------------------------------------------------------- GPT
def plan_gpt(cfg):
    p = C.Plan(cfg)
    p.param("embed.tok", (cfg["vocab"], cfg["dim"]), C.embed_init)
    p.param("embed.pos", (cfg["seq_len"], cfg["dim"]), C.embed_init)
    for b in range(cfg["blocks"]):
        C.plan_block(p, f"block{b}", cfg["dim"], cfg["mlp_ratio"])
    C.plan_norm(p, "final.ln", cfg["dim"])
    C.plan_linear(p, "lm_head", cfg["dim"], cfg["vocab"])
    return p


def make_apply_gpt(cfg, plan):
    idx = plan.site_index()

    def apply(params, q, x):
        env = C.QEnv(q, idx)
        h = params["embed.tok"][x] + params["embed.pos"][None, :, :]
        for b in range(cfg["blocks"]):
            h = C.transformer_block(env, params, f"block{b}", h,
                                    cfg["heads"], cfg["mlp_ratio"], causal=True)
        h = C.layernorm(params, "final.ln", h)
        return C.linear(env, params, "lm_head", h)  # [B, S, V]

    return apply


def lm_loss(logits, y):
    """y: [B, S] next-token targets; positions with y < 0 are masked."""
    mask = (y >= 0).astype(jnp.float32)
    labels = jnp.maximum(y, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    correct = jnp.sum((jnp.argmax(logits, -1) == labels).astype(jnp.float32) * mask)
    return loss, correct


# ------------------------------------------------------------------- ViT
def plan_vit(cfg):
    p = C.Plan(cfg)
    ps, dim = cfg["patch"], cfg["dim"]
    C.plan_conv(p, "patch_embed", cfg["image"]["channels"], dim, k=ps)
    ntok = (cfg["image"]["size"] // ps) ** 2
    if cfg["pool"] == "cls":
        p.param("cls_token", (1, 1, dim), C.zeros)
        ntok += 1
    p.param("pos_embed", (ntok, dim), C.embed_init)
    for b in range(cfg["blocks"]):
        C.plan_block(p, f"block{b}", dim, cfg["mlp_ratio"])
    C.plan_norm(p, "final.ln", dim)
    C.plan_linear(p, "head", dim, cfg["num_classes"])
    return p


def make_apply_vit(cfg, plan):
    idx = plan.site_index()
    ps = cfg["patch"]

    def apply(params, q, x):
        env = C.QEnv(q, idx)
        w = env.apply("patch_embed.weight", params["patch_embed.weight"])
        h = jax.lax.conv_general_dilated(
            x, w, window_strides=(ps, ps), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = h + params["patch_embed.bias"]
        B = h.shape[0]
        h = h.reshape(B, -1, cfg["dim"])  # [B, T, D]
        if cfg["pool"] == "cls":
            cls = jnp.broadcast_to(params["cls_token"], (B, 1, cfg["dim"]))
            h = jnp.concatenate([cls, h], axis=1)
        h = h + params["pos_embed"][None, :, :]
        for b in range(cfg["blocks"]):
            h = C.transformer_block(env, params, f"block{b}", h,
                                    cfg["heads"], cfg["mlp_ratio"])
        h = C.layernorm(params, "final.ln", h)
        h = h[:, 0] if cfg["pool"] == "cls" else jnp.mean(h, axis=1)
        return C.linear(env, params, "head", h)

    return apply


# ------------------------------------------------------------------ Swin
def plan_swin(cfg):
    """Hierarchical ViT: stages with patch merging between them. Attention
    is full within a stage (at mini scale the whole map fits one window;
    documented substitution in DESIGN.md)."""
    p = C.Plan(cfg)
    ps = cfg["patch"]
    C.plan_conv(p, "patch_embed", cfg["image"]["channels"], cfg["stage_dims"][0], k=ps)
    side = cfg["image"]["size"] // ps
    p.param("pos_embed", (side * side, cfg["stage_dims"][0]), C.embed_init)
    for si, dim in enumerate(cfg["stage_dims"]):
        for b in range(cfg["stage_blocks"][si]):
            C.plan_block(p, f"stage{si}.block{b}", dim, cfg["mlp_ratio"])
        if si + 1 < len(cfg["stage_dims"]):
            # patch merging: concat 2x2 -> linear to next dim
            C.plan_linear(p, f"merge{si}", dim * 4, cfg["stage_dims"][si + 1])
            C.plan_norm(p, f"merge{si}.ln", dim * 4)
    C.plan_norm(p, "final.ln", cfg["stage_dims"][-1])
    C.plan_linear(p, "head", cfg["stage_dims"][-1], cfg["num_classes"])
    return p


def make_apply_swin(cfg, plan):
    idx = plan.site_index()
    ps = cfg["patch"]

    def merge(env, params, name, h, side, dim):
        B = h.shape[0]
        g = h.reshape(B, side, side, dim)
        g = jnp.concatenate([g[:, 0::2, 0::2], g[:, 1::2, 0::2],
                             g[:, 0::2, 1::2], g[:, 1::2, 1::2]], axis=-1)
        g = g.reshape(B, (side // 2) * (side // 2), dim * 4)
        g = C.layernorm(params, name + ".ln", g)
        return C.linear(env, params, name, g)

    def apply(params, q, x):
        env = C.QEnv(q, idx)
        w = env.apply("patch_embed.weight", params["patch_embed.weight"])
        h = jax.lax.conv_general_dilated(
            x, w, window_strides=(ps, ps), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = h + params["patch_embed.bias"]
        B = h.shape[0]
        side = cfg["image"]["size"] // ps
        h = h.reshape(B, side * side, cfg["stage_dims"][0])
        h = h + params["pos_embed"][None, :, :]
        for si, dim in enumerate(cfg["stage_dims"]):
            for b in range(cfg["stage_blocks"][si]):
                h = C.transformer_block(env, params, f"stage{si}.block{b}", h,
                                        cfg["heads"], cfg["mlp_ratio"])
            if si + 1 < len(cfg["stage_dims"]):
                h = merge(env, params, f"merge{si}", h, side, dim)
                side //= 2
        h = C.layernorm(params, "final.ln", h)
        h = jnp.mean(h, axis=1)
        return C.linear(env, params, "head", h)

    return apply
