"""Differentiable parameterized quantizer (Layer-2 glue over the L1 kernel).

``fake_quant(x, d, t, qm)`` behaves like eqs. (1)-(2) in the forward pass
and routes the backward pass through the straight-through-estimator partial
derivatives of eqs. (4)-(6), computed by the fused Pallas backward kernel.

The custom VJP is what lets one jitted ``train_step`` produce gradients for
both the weights and the quantization parameters — which is exactly the
interface the Rust QASSO optimizer consumes.
"""

import jax
import jax.numpy as jnp

from .kernels import fakequant as fk


@jax.custom_vjp
def fake_quant(x, d, t, qm):
    """Quantize tensor ``x`` with scalar site parameters (d, t, q_m)."""
    return fk.fakequant_fwd(x, d, t, qm)


def _fq_fwd(x, d, t, qm):
    y = fk.fakequant_fwd(x, d, t, qm)
    return y, (x, d, t, qm)


def _fq_bwd(res, g):
    x, d, t, qm = res
    gd_e, gt_e, gqm_e, mask = fk.fakequant_bwd(x, d, t, qm)
    # scalar quant-param grads: contract elementwise partials with cotangent
    gd = jnp.sum(g * gd_e)
    gt = jnp.sum(g * gt_e)
    gqm = jnp.sum(g * gqm_e)
    gx = g * mask  # clipped STE
    return gx, gd, gt, gqm


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def init_qparams(w, target_bits):
    """Paper Appendix C initialization: t = 1, q_m = max|w|, d chosen so the
    initial bit width equals ``target_bits`` via inverting eq. (3):
    d = q_m^t / (2^(b-1) - 1)."""
    qm = float(jnp.max(jnp.abs(w)))
    qm = max(qm, 1e-3)
    t = 1.0
    d = (qm ** t) / (2.0 ** (target_bits - 1) - 1.0)
    return d, t, qm


def bit_width(d, t, qm):
    """Eq. (3)."""
    return jnp.log2(jnp.power(jnp.maximum(qm, 1e-12), t) / d + 1.0) + 1.0
