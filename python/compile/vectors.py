"""Export golden quantizer vectors for the Rust-side reimplementation.

rust/src/quant implements eqs. (1)-(6), (13)-(14) natively (the QASSO joint
stage needs x^Q, clip and R(x) on the Rust hot path). This script dumps the
oracle's outputs for a grid of (x, d, t, q_m) so `cargo test` can validate
the Rust math bit-for-bit against Layer 1's oracle.

Usage: python -m compile.vectors --out ../artifacts/quant_vectors.json
"""

import argparse
import json
import os

import numpy as np
import jax.numpy as jnp

from .kernels import ref


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "quant_vectors.json"))
    args = ap.parse_args()

    rng = np.random.default_rng(42)
    cases = []
    for (d, t, qm) in [(0.1, 1.0, 1.0), (0.05, 1.2, 0.8), (0.02, 0.9, 2.0),
                       (0.25, 1.0, 0.5), (0.004, 1.05, 1.5)]:
        x = np.concatenate([
            rng.normal(scale=0.7, size=24),
            np.array([0.0, qm, -qm, qm * 1.5, -qm * 2.0, d / 2, -d / 2]),
        ]).astype(np.float32)
        xj = jnp.asarray(x)
        cases.append({
            "d": d, "t": t, "qm": qm,
            "x": x.tolist(),
            "xq": np.asarray(ref.fake_quant(xj, d, t, qm)).tolist(),
            "clip": np.asarray(ref.clip_pow(xj, t, qm)).tolist(),
            "residual": np.asarray(ref.residual(xj, d, t, qm)).tolist(),
            "grad_d": np.asarray(ref.grad_d(xj, d, t, qm)).tolist(),
            "grad_t": np.asarray(ref.grad_t(xj, d, t, qm)).tolist(),
            "grad_qm": np.asarray(ref.grad_qm(xj, d, t, qm)).tolist(),
            "bit_width": float(ref.bit_width(d, t, qm)),
        })
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"cases": cases}, f)
    print(f"wrote {len(cases)} vector cases to {args.out}")


if __name__ == "__main__":
    main()
