"""AOT pipeline: manifests agree with the live models, HLO text is sane.

Requires `make artifacts` to have run (skips otherwise) — this is the
contract test between Layer 2 and the Rust runtime."""

import json
import os

import pytest

from compile import models as M
from compile.aot import load_configs, eval_output_names

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "index.json")),
    reason="artifacts not built (run `make artifacts`)")


def manifests():
    with open(os.path.join(ART, "index.json")) as f:
        idx = json.load(f)
    for entry in idx["models"]:
        with open(os.path.join(ART, entry["manifest"])) as f:
            yield json.load(f)


def test_index_lists_all_configs():
    built = {m["model"] for m in manifests()}
    want = {c["name"] for c in load_configs()}
    assert built == want


@pytest.mark.parametrize("man", list(manifests()), ids=lambda m: m["model"])
def test_manifest_matches_model(man):
    model = M.build(man["config"])
    assert [p["name"] for p in man["params"]] == model.names
    for p, (name, shape) in zip(man["params"], model.param_specs):
        assert tuple(p["shape"]) == tuple(shape), name
    assert man["qsites"] == model.qsites
    assert man["train_outputs"][0] == "loss"
    assert man["train_outputs"][-2:] == ["qgrad", "metric"]
    assert man["eval_outputs"] == eval_output_names(man["config"])
    assert man["q_shape"][0] == max(model.n_sites(), 1)


@pytest.mark.parametrize("man", list(manifests()), ids=lambda m: m["model"])
def test_hlo_text_present_and_parseable_shape(man):
    for key in ("train_hlo", "eval_hlo"):
        path = os.path.join(ART, man[key])
        assert os.path.exists(path), path
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text
        # the entry computation must take params + q + x + y inputs
        nparams = len(man["params"])
        assert text.count("parameter(") >= nparams + 3
