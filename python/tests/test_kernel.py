"""Layer-1 correctness: Pallas fake-quant kernels vs the pure-jnp oracle.

This is the core correctness signal for the kernel layer: hypothesis sweeps
shapes and parameter regimes; every output is asserted allclose against
kernels/ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fakequant as fk
from compile.kernels import ref

ATOL = 1e-5


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=shape) * scale).astype(np.float32))


@pytest.mark.parametrize("shape", [(7,), (64,), (2048,), (2049,), (33, 65), (4, 5, 6)])
def test_fwd_matches_ref_shapes(shape):
    x = _rand(shape, 0)
    d, t, qm = 0.05, 1.1, 1.2
    got = fk.fakequant_fwd(x, d, t, qm)
    want = ref.fake_quant(x, d, t, qm)
    assert got.shape == x.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL)


@pytest.mark.parametrize("shape", [(5,), (130,), (2048,), (3000,), (17, 19)])
def test_bwd_matches_ref_shapes(shape):
    x = _rand(shape, 1)
    d, t, qm = 0.03, 0.95, 0.9
    gd, gt, gqm, mask = fk.fakequant_bwd(x, d, t, qm)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(ref.grad_d(x, d, t, qm)), atol=ATOL)
    np.testing.assert_allclose(np.asarray(gt), np.asarray(ref.grad_t(x, d, t, qm)), atol=ATOL)
    np.testing.assert_allclose(np.asarray(gqm), np.asarray(ref.grad_qm(x, d, t, qm)), atol=ATOL)
    np.testing.assert_allclose(np.asarray(mask), np.asarray(ref.grad_x_ste(x, d, t, qm)), atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=512),
    d=st.floats(min_value=1e-3, max_value=0.5),
    t=st.floats(min_value=0.7, max_value=1.4),
    qm=st.floats(min_value=0.1, max_value=4.0),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_fwd_hypothesis_sweep(n, d, t, qm, seed):
    x = _rand((n,), seed, scale=qm)
    got = fk.fakequant_fwd(x, d, t, qm)
    want = ref.fake_quant(x, d, t, qm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4 * max(1.0, qm), rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=256),
    d=st.floats(min_value=1e-3, max_value=0.3),
    t=st.floats(min_value=0.8, max_value=1.3),
    qm=st.floats(min_value=0.2, max_value=2.0),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_bwd_hypothesis_sweep(n, d, t, qm, seed):
    x = _rand((n,), seed, scale=qm)
    gd, gt, gqm, mask = fk.fakequant_bwd(x, d, t, qm)
    # grad_d = sgn*(round(c/d) - c/d): the kernel computes c = exp(t*log x)
    # while the oracle uses power(x, t); a 1-ulp difference in c is
    # amplified by 1/d and can flip the round, shifting the residual by
    # exactly +-1. Compare modulo 1 with a c/d-scale-aware tolerance.
    diff = np.asarray(gd) - np.asarray(ref.grad_d(x, d, t, qm))
    tol = max(1e-4, 32 * np.finfo(np.float32).eps * (qm ** t) / d)
    assert np.max(np.abs(diff - np.round(diff))) < tol
    np.testing.assert_allclose(np.asarray(gt), np.asarray(ref.grad_t(x, d, t, qm)), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gqm), np.asarray(ref.grad_qm(x, d, t, qm)), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(mask), np.asarray(ref.grad_x_ste(x, d, t, qm)), atol=ATOL)


# ---------------------------------------------------------- oracle sanity
def test_quantized_values_are_multiples_of_d():
    x = _rand((257,), 3)
    d = 0.125
    y = np.asarray(ref.fake_quant(x, d, 1.0, 1.0))
    ratio = y / d
    np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-5)


def test_clip_saturates_beyond_qm():
    d, t, qm = 0.1, 1.0, 0.5
    big = jnp.asarray([10.0, -10.0, 0.6])
    y = np.asarray(ref.fake_quant(big, d, t, qm))
    sat = d * np.round(qm / d)
    np.testing.assert_allclose(np.abs(y), sat, atol=1e-6)


def test_bit_width_eq3_roundtrip():
    # d chosen for b bits must give back b via eq. (3)
    for b in [2, 4, 8, 16]:
        qm, t = 1.7, 1.0
        d = qm**t / (2.0 ** (b - 1) - 1)
        got = float(ref.bit_width(d, t, qm))
        assert abs(got - b) < 1e-6, (b, got)


def test_grad_qm_zero_inside_clip():
    x = jnp.asarray([0.1, -0.2, 0.3])
    g = np.asarray(ref.grad_qm(x, 0.05, 1.0, 1.0))
    np.testing.assert_allclose(g, 0.0)


def test_grad_d_bounded_by_half():
    # round(c/d) - c/d is always in [-0.5, 0.5]
    x = _rand((1000,), 7, scale=3.0)
    g = np.asarray(ref.grad_d(x, 0.07, 1.1, 1.0))
    assert np.all(np.abs(g) <= 0.5 + 1e-6)
