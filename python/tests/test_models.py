"""Layer-2 model zoo: shapes, gradient plumbing, and trainability smoke
tests for every family the AOT pipeline lowers."""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import models as M
from compile import quantizer

CONFIG_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "configs", "models")
ALL = sorted(f[:-5] for f in os.listdir(CONFIG_DIR) if f.endswith(".json"))


def load(name):
    with open(os.path.join(CONFIG_DIR, name + ".json")) as f:
        return json.load(f)


def make_batch(model, seed=0):
    rng = np.random.default_rng(seed)
    (xshape, xdt), (yshape, ydt) = model.batch_shapes()
    if xdt == "f32":
        x = jnp.asarray(rng.normal(size=xshape).astype(np.float32))
    else:
        x = jnp.asarray(rng.integers(0, model.cfg["vocab"], size=xshape).astype(np.int32))
    if model.cfg["task"] == "span_qa":
        S = model.cfg["seq_len"]
        start = rng.integers(0, S - 1, size=(yshape[0],))
        end = np.minimum(start + rng.integers(0, 4, size=(yshape[0],)), S - 1)
        y = jnp.asarray(np.stack([start, end], 1).astype(np.int32))
    elif model.cfg["task"] == "lm":
        y = jnp.asarray(rng.integers(0, model.cfg["vocab"], size=yshape).astype(np.int32))
    else:
        y = jnp.asarray(rng.integers(0, model.cfg["num_classes"], size=yshape).astype(np.int32))
    return x, y


def init_q(model, bits=8):
    params = model.init_params(0)
    rows = []
    for s in model.qsites:
        w = params[s["param"]] if s["param"] else np.ones(1, np.float32)
        rows.append(quantizer.init_qparams(jnp.asarray(w), bits))
    if not rows:
        rows = [(0.1, 1.0, 1.0)]
    return params, jnp.asarray(np.array(rows, np.float32))


@pytest.mark.parametrize("name", ALL)
def test_train_step_shapes(name):
    model = M.build(load(name))
    params, q = init_q(model)
    x, y = make_batch(model)
    args = [jnp.asarray(params[n]) for n in model.names] + [q, x, y]
    out = model.train_step(*args)
    # loss + one grad per param + qgrad + metric
    assert len(out) == 1 + len(model.names) + 2
    loss = float(out[0])
    assert np.isfinite(loss) and loss > 0
    for i, n in enumerate(model.names):
        assert out[1 + i].shape == params[n].shape, n
    assert out[-2].shape == q.shape
    # at least one quant-param gradient must be live (sites exist)
    if model.n_sites() > 0:
        assert float(jnp.max(jnp.abs(out[-2]))) > 0


@pytest.mark.parametrize("name", ALL)
def test_eval_step_outputs(name):
    cfg = load(name)
    model = M.build(cfg)
    params, q = init_q(model)
    x, y = make_batch(model)
    args = [jnp.asarray(params[n]) for n in model.names] + [q, x, y]
    out = model.eval_step(*args)
    task = cfg["task"]
    expect = {"image_cls": 2, "span_qa": 4, "lm": 3}[task]
    assert len(out) == expect
    assert np.isfinite(float(out[0]))
    B = model.batch_shapes()[0][0][0]
    if task == "image_cls":
        assert 0 <= float(out[1]) <= B
    if task == "span_qa":
        assert out[2].shape == (B,) and out[3].shape == (B,)


@pytest.mark.parametrize("name", ["mlp_tiny", "vgg7_mini", "bert_mini"])
def test_sgd_reduces_loss(name):
    """A few plain-SGD steps on a fixed batch must reduce the loss —
    proves the grads flowing through the quantizer are usable."""
    model = M.build(load(name))
    params, q = init_q(model, bits=16)
    x, y = make_batch(model)
    arrs = {n: jnp.asarray(params[n]) for n in model.names}
    lr = 0.05
    first = None
    for step in range(6):
        args = [arrs[n] for n in model.names] + [q, x, y]
        out = model.train_step(*args)
        loss = float(out[0])
        if first is None:
            first = loss
        for i, n in enumerate(model.names):
            arrs[n] = arrs[n] - lr * out[1 + i]
    assert loss < first, (first, loss)


def test_site_order_is_deterministic():
    m1 = M.build(load("vgg7_mini"))
    m2 = M.build(load("vgg7_mini"))
    assert [s["name"] for s in m1.qsites] == [s["name"] for s in m2.qsites]
    assert m1.names == m2.names


def test_act_sites_present_only_for_vgg():
    kinds = {s["kind"] for s in M.build(load("vgg7_mini")).qsites}
    assert kinds == {"weight", "act"}
    kinds = {s["kind"] for s in M.build(load("resnet_mini")).qsites}
    assert kinds == {"weight"}


def test_head_dim_divides():
    for name in ("bert_mini", "gpt_mini", "vit_mini"):
        cfg = load(name)
        assert cfg["dim"] % cfg["heads"] == 0
