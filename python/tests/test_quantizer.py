"""Layer-2 quantizer glue: custom_vjp gradients match the analytic STE
formulas (eqs. 4-6) and the init/bit-width helpers invert eq. (3)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import quantizer
from compile.kernels import ref


def _x(shape=(41,), seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=shape) * scale).astype(np.float32))


def test_vjp_scalar_grads_match_analytic():
    x = _x((23, 7), 5)
    d, t, qm = 0.04, 1.05, 1.3
    cot = _x((23, 7), 6)  # arbitrary upstream cotangent

    def f(x, d, t, qm):
        return jnp.sum(quantizer.fake_quant(x, d, t, qm) * cot)

    gx, gd, gt, gqm = jax.grad(f, argnums=(0, 1, 2, 3))(x, d, t, qm)
    np.testing.assert_allclose(float(gd), float(jnp.sum(cot * ref.grad_d(x, d, t, qm))), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(gt), float(jnp.sum(cot * ref.grad_t(x, d, t, qm))), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(gqm), float(jnp.sum(cot * ref.grad_qm(x, d, t, qm))), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(cot * ref.grad_x_ste(x, d, t, qm)), atol=1e-5)


def test_vjp_inside_jit_and_grad_of_loss():
    x = _x((64,), 9)

    @jax.jit
    def loss(x, d, t, qm):
        y = quantizer.fake_quant(x, d, t, qm)
        return jnp.mean((y - x) ** 2)

    g = jax.grad(loss, argnums=(1, 2, 3))(x, 0.05, 1.0, 1.0)
    assert all(np.isfinite(float(v)) for v in g)


@pytest.mark.parametrize("bits", [2, 4, 8, 16, 32])
def test_init_qparams_hits_target_bits(bits):
    w = _x((100,), 11, scale=0.5)
    d, t, qm = quantizer.init_qparams(w, bits)
    got = float(quantizer.bit_width(d, t, qm))
    assert abs(got - bits) < 1e-4
    assert t == 1.0
    assert abs(qm - float(jnp.max(jnp.abs(w)))) < 1e-6


def test_init_qparams_degenerate_weight():
    # all-zero weight must not produce inf/nan params
    w = jnp.zeros((10,))
    d, t, qm = quantizer.init_qparams(w, 8)
    assert np.isfinite(d) and d > 0 and qm > 0


def test_fake_quant_idempotent_on_grid():
    # quantizing an already-quantized tensor (t=1) is identity
    x = _x((200,), 13)
    d, t, qm = 0.1, 1.0, 1.0
    y1 = quantizer.fake_quant(x, d, t, qm)
    y2 = quantizer.fake_quant(y1, d, t, qm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
