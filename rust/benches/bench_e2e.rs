//! End-to-end steps/second per paper-table workload: the full
//! PJRT-step + QASSO-update loop each table's runs are made of. One bench
//! per table family (table2/3/4/5/6, fig3), reduced to a short measured
//! window.

use geta::runtime::Backend as _;
use geta::config::ExperimentConfig;
use geta::coordinator::{Compressor, GetaCompressor, Trainer};
use geta::data::BatchIter;
use geta::optim::qasso::StageMask;
use geta::util::bench::Bencher;

fn main() {
    let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut b = Bencher::new(2, 10);
    let table_models = [
        ("e2e", "mlp_tiny"),
        ("table2", "resnet_mini"),
        ("table3", "bert_mini"),
        ("table4", "vgg7_mini"),
        ("table5", "resnet_mini_l"),
        ("table6", "vit_mini"),
        ("fig3", "gpt_mini"),
    ];
    for (table, model) in table_models {
        let mut exp = ExperimentConfig::defaults_for(model);
        exp.n_train = 256;
        exp.n_eval = 64;
        let t = match Trainer::new(&art, exp) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("skipping {table}/{model}: {e}");
                continue;
            }
        };
        let mut params = t.engine.init_params(0);
        let mut q = t.engine.init_qparams(&params, t.exp.qasso.init_bits);
        let mut geta_c = GetaCompressor::new(&t.engine, &t.exp, StageMask::default()).unwrap();
        let mut iter = BatchIter::new(t.train_data.len(), t.batch_size(), 3);
        let mut step = 0usize;
        b.bench(&format!("{table}_train_step/{model}"), || {
            let idxs = iter.next_batch();
            let (x, y) = t.train_data.batch(&idxs);
            let out = t.engine.train_step(&params, &q, &x, &y).unwrap();
            geta_c.step(&mut params, &mut q, &out.grads, &out.qgrads, 0.01, step);
            step += 1;
        });
    }
    std::fs::create_dir_all("reports").ok();
    b.write_log(std::path::Path::new("reports/bench_e2e.json")).ok();
}
