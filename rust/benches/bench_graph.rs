//! Graph-analysis latency: QADNN trace build, QADG (Algorithm 1) and
//! dependency analysis per model family. These run once per training job,
//! so the target is "negligible vs one PJRT step" (see EXPERIMENTS.md §Perf).

use geta::graph::{self, builders, qadg};
use geta::util::bench::Bencher;
use geta::util::json;

fn main() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/models");
    let mut b = Bencher::new(3, 30);
    for model in [
        "mlp_tiny", "vgg7_mini", "resnet_mini", "bert_mini", "gpt_mini", "vit_mini", "swin_mini",
    ] {
        let cfg = json::parse_file(&root.join(format!("{model}.json"))).unwrap();
        b.bench(&format!("trace_build/{model}"), || {
            builders::build_trace(&cfg, true).unwrap()
        });
        let traced = builders::build_trace(&cfg, true).unwrap();
        b.bench(&format!("qadg/{model}"), || qadg::qadg_analysis(&traced));
        let reduced = qadg::qadg_analysis(&traced);
        b.bench(&format!("depgraph/{model}"), || {
            graph::analyze(&reduced).unwrap()
        });
        b.bench(&format!("full_pipeline/{model}"), || {
            graph::search_space_for(&cfg).unwrap()
        });
    }
    std::fs::create_dir_all("reports").ok();
    b.write_log(std::path::Path::new("reports/bench_graph.json")).ok();
}
