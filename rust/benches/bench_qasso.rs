//! QASSO optimizer-step latency (the Layer-3 hot path, no PJRT): joint-
//! stage steps over each model's real search space with synthetic grads.
//! Target: ≪ one PJRT train step so the coordinator is never the
//! bottleneck (EXPERIMENTS.md §Perf).

use geta::graph;
use geta::optim::qasso::{Qasso, QassoConfig, SiteSpec, StageMask};
use geta::optim::Sgd;
use geta::quant::QParams;
use geta::runtime::Manifest;
use geta::tensor::{ParamStore, Tensor};
use geta::util::bench::Bencher;
use geta::util::rng::Rng;

fn store_for(man: &Manifest, rng: &mut Rng) -> ParamStore {
    let mut s = ParamStore::new();
    for (name, shape) in &man.params {
        let mut data = vec![0.0f32; shape.iter().product()];
        rng.fill_normal(&mut data, 0.1);
        s.push(Tensor::from_vec(name, shape, data));
    }
    s
}

fn main() {
    let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut b = Bencher::new(5, 40);
    for model in ["mlp_tiny", "vgg7_mini", "resnet_mini", "bert_mini"] {
        // artifact manifest when present, natively synthesized otherwise
        let man = geta::runtime::manifest_for(&art, model).unwrap();
        let space = graph::search_space_for(&man.config).unwrap();
        let mut rng = Rng::new(1);
        let mut params = store_for(&man, &mut rng);
        let mut grads = store_for(&man, &mut rng);
        for t in grads.tensors.iter_mut() {
            for v in t.data.iter_mut() {
                *v *= 0.01;
            }
        }
        let sites: Vec<SiteSpec> = man.qsites.clone();
        let mut q: Vec<QParams> = sites.iter().map(|_| QParams::init(1.0, 16.0)).collect();
        let qg = vec![(0.001f32, 0.001f32, 0.001f32); sites.len()];
        // put the optimizer inside the joint stage (the expensive one)
        let cfg = QassoConfig {
            warmup_steps: 0,
            proj_periods: 0,
            proj_steps: 0,
            prune_periods: 1,
            prune_steps: 1_000_000,
            cooldown_steps: 0,
            target_group_sparsity: 0.4,
            ..Default::default()
        };
        let mut opt = Qasso::new(cfg, space.groups, &sites, Box::new(Sgd::plain()), &params);
        opt.mask = StageMask::default();
        b.bench(&format!("qasso_joint_step/{model}"), || {
            opt.step(&mut params, &mut q, &grads, &qg, 0.01);
        });
    }
    std::fs::create_dir_all("reports").ok();
    b.write_log(std::path::Path::new("reports/bench_qasso.json")).ok();
}
