//! Backend step latency per model (train + eval) — the runtime cost that
//! dominates wall clock. Table workloads' steps/s derive from these
//! numbers. Every zoo family runs on the native interpreter, so all rows
//! report on any machine; with artifacts + `pjrt` the same rows measure
//! the compiled-HLO engine instead.
//!
//! The trailing section benchmarks the *deployed* path: dense-f32 vs
//! compressed (`.geta`) inference throughput through `deploy::GetaEngine`
//! — the measured counterpart to the theoretical BOPs columns.

use geta::runtime::Backend as _;
use geta::config::ExperimentConfig;
use geta::coordinator::Trainer;
use geta::util::bench::Bencher;

fn main() {
    let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut b = Bencher::new(3, 15);
    for model in [
        "mlp_tiny", "vgg7_mini", "resnet_mini", "resnet_mini_l",
        "bert_mini", "gpt_mini", "vit_mini", "swin_mini",
    ] {
        let exp = ExperimentConfig::defaults_for(model);
        let t = match Trainer::new(&art, exp) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("skipping {model}: {e}");
                continue;
            }
        };
        let params = t.engine.init_params(0);
        let q = t.engine.init_qparams(&params, 8.0);
        let idxs: Vec<usize> = (0..t.batch_size()).collect();
        let (x, y) = t.train_data.batch(&idxs);
        b.bench(&format!("train_step/{model}"), || {
            t.engine.train_step(&params, &q, &x, &y).unwrap()
        });
        b.bench(&format!("eval_step/{model}"), || {
            t.engine.eval_step(&params, &q, &x, &y).unwrap()
        });
    }
    // deployed inference: dense f32 vs the exported .geta artifact
    // (brief training first so the compressed engine has real pruning)
    for model in ["mlp_tiny", "resnet_mini"] {
        match geta::report::bench_deploy(&art, model, 0.1, 0.5, b.iters.min(10), 1) {
            Ok(r) => {
                println!(
                    "{:<44} dense {:>8.2} ms/b  .geta {:>8.2} ms/b  speedup {:>5.2}x  \
                     disk {:>7.1} KiB ({:.2}x smaller)",
                    format!("deploy_infer/{model}"),
                    r.dense_ms,
                    r.compressed_ms,
                    r.dense_ms / r.compressed_ms.max(1e-9),
                    r.disk_bytes as f64 / 1024.0,
                    r.dense_bytes as f64 / r.disk_bytes.max(1) as f64,
                );
            }
            Err(e) => eprintln!("skipping deploy bench {model}: {e}"),
        }
    }
    std::fs::create_dir_all("reports").ok();
    b.write_log(std::path::Path::new("reports/bench_runtime.json")).ok();
}
