//! Backend step latency per model (train + eval) — the runtime cost that
//! dominates wall clock. Table workloads' steps/s derive from these
//! numbers. Every zoo family runs on the native interpreter, so all rows
//! report on any machine; with artifacts + `pjrt` the same rows measure
//! the compiled-HLO engine instead.

use geta::runtime::Backend as _;
use geta::config::ExperimentConfig;
use geta::coordinator::Trainer;
use geta::util::bench::Bencher;

fn main() {
    let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut b = Bencher::new(3, 15);
    for model in [
        "mlp_tiny", "vgg7_mini", "resnet_mini", "resnet_mini_l",
        "bert_mini", "gpt_mini", "vit_mini", "swin_mini",
    ] {
        let exp = ExperimentConfig::defaults_for(model);
        let t = match Trainer::new(&art, exp) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("skipping {model}: {e}");
                continue;
            }
        };
        let params = t.engine.init_params(0);
        let q = t.engine.init_qparams(&params, 8.0);
        let idxs: Vec<usize> = (0..t.batch_size()).collect();
        let (x, y) = t.train_data.batch(&idxs);
        b.bench(&format!("train_step/{model}"), || {
            t.engine.train_step(&params, &q, &x, &y).unwrap()
        });
        b.bench(&format!("eval_step/{model}"), || {
            t.engine.eval_step(&params, &q, &x, &y).unwrap()
        });
    }
    std::fs::create_dir_all("reports").ok();
    b.write_log(std::path::Path::new("reports/bench_runtime.json")).ok();
}
