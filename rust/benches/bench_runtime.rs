//! Backend step latency per model (train + eval) — the runtime cost that
//! dominates wall clock. Table workloads' steps/s derive from these
//! numbers. Every zoo family runs on the native interpreter, so all rows
//! report on any machine; with artifacts + `pjrt` the same rows measure
//! the compiled-HLO engine instead.
//!
//! The trailing sections benchmark the hot kernels and the *deployed*
//! path, and write the machine-readable perf log `BENCH_runtime.json` at
//! the repo root (also produced by `geta bench-infer --json` / `make
//! bench-json`):
//!
//! * GEMM: the forward contraction shapes resnet/vit produce at batch 32,
//!   naive reference triple loop vs the tiled multi-threaded kernels,
//!   with a bitwise thread-invariance check.
//! * Deploy: dense-f32 vs compressed (`.geta`) inference throughput
//!   through `deploy::GetaEngine` — the measured counterpart to the
//!   theoretical BOPs columns.

use geta::runtime::Backend as _;
use geta::config::ExperimentConfig;
use geta::coordinator::Trainer;
use geta::util::bench::Bencher;

fn main() {
    let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut b = Bencher::new(3, 15);
    for model in [
        "mlp_tiny", "vgg7_mini", "resnet_mini", "resnet_mini_l",
        "bert_mini", "gpt_mini", "vit_mini", "swin_mini",
    ] {
        let exp = ExperimentConfig::defaults_for(model);
        let t = match Trainer::new(&art, exp) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("skipping {model}: {e}");
                continue;
            }
        };
        let params = t.engine.init_params(0);
        let q = t.engine.init_qparams(&params, 8.0);
        let idxs: Vec<usize> = (0..t.batch_size()).collect();
        let (x, y) = t.train_data.batch(&idxs);
        b.bench(&format!("train_step/{model}"), || {
            t.engine.train_step(&params, &q, &x, &y).unwrap()
        });
        b.bench(&format!("eval_step/{model}"), || {
            t.engine.eval_step(&params, &q, &x, &y).unwrap()
        });
    }
    // hot-kernel comparison: naive reference GEMM vs the tiled threaded
    // kernels, on the exact forward shapes resnet/vit produce at batch 32
    let gemm = geta::report::standard_gemm_suite(5);
    for g in &gemm {
        println!(
            "{:<44} naive {:>8.2} ms  tiled {:>8.2} ms  speedup {:>5.2}x  \
             ({} threads, thread-invariant {})",
            format!("gemm/{}@{}", g.model, g.batch),
            g.naive_ms,
            g.tiled_ms,
            g.naive_ms / g.tiled_ms.max(1e-9),
            g.threads,
            g.thread_invariant,
        );
    }
    // deployed inference: dense f32 vs the exported .geta artifact,
    // through both compute kernels — f32-dequant and integer-domain i8
    // (brief training first so the compressed engine has real pruning)
    let threads = geta::tensor::configured_threads();
    let mut deploy = Vec::new();
    for (model, scale) in [("mlp_tiny", 0.1), ("resnet_mini", 0.1), ("vit_mini", 0.05)] {
        match geta::report::bench_deploy(&art, model, scale, 0.5, b.iters.min(10), threads) {
            Ok(rows) => {
                for r in &rows {
                    println!(
                        "{:<44} dense {:>8.2} ms/b  .geta {:>8.2} ms/b  speedup {:>5.2}x  \
                         disk {:>7.1} KiB ({:.2}x smaller, {} threads)",
                        format!("deploy_infer/{model}[{}]", r.kernel),
                        r.dense_ms,
                        r.compressed_ms,
                        r.dense_ms / r.compressed_ms.max(1e-9),
                        r.disk_bytes as f64 / 1024.0,
                        r.dense_bytes as f64 / r.disk_bytes.max(1) as f64,
                        r.threads,
                    );
                }
                deploy.extend(rows);
            }
            Err(e) => eprintln!("skipping deploy bench {model}: {e}"),
        }
    }
    // machine-readable perf trail: the full log (gitignored, uploaded by
    // CI) plus the checked-in deployment summary
    let json_path = geta::report::bench_json_path();
    match geta::report::write_bench_runtime_json(&json_path, &gemm, &deploy) {
        Ok(()) => println!("wrote {}", json_path.display()),
        Err(e) => eprintln!("failed to write BENCH_runtime.json: {e}"),
    }
    let deploy_path = geta::report::bench_deploy_json_path();
    match geta::report::write_bench_deploy_json(&deploy_path, &deploy) {
        Ok(()) => println!("wrote {}", deploy_path.display()),
        Err(e) => eprintln!("failed to write BENCH_deploy.json: {e}"),
    }
    std::fs::create_dir_all("reports").ok();
    b.write_log(std::path::Path::new("reports/bench_runtime.json")).ok();
}
