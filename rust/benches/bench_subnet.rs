//! Subnet construction latency: KeptMap build, slicing, packing, BOPs —
//! runs once at the end of a job; benched per model for the §Perf log.

use geta::graph;
use geta::metrics;
use geta::quant::QParams;
use geta::subnet;
use geta::tensor::{ParamStore, Tensor};
use geta::util::bench::Bencher;
use geta::util::rng::Rng;

fn main() {
    let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut b = Bencher::new(2, 20);
    for model in ["vgg7_mini", "resnet_mini", "bert_mini", "resnet_mini_l"] {
        // artifact manifest when present, natively synthesized otherwise
        let man = geta::runtime::manifest_for(&art, model).unwrap();
        let space = graph::search_space_for(&man.config).unwrap();
        let costs = metrics::layer_costs(&man.config).unwrap();
        let mut rng = Rng::new(2);
        let mut params = ParamStore::new();
        for (name, shape) in &man.params {
            let mut data = vec![0.0f32; shape.iter().product()];
            rng.fill_normal(&mut data, 0.1);
            params.push(Tensor::from_vec(name, shape, data));
        }
        let q: Vec<QParams> = man.qsites.iter().map(|_| QParams::init(1.0, 6.0)).collect();
        let pruned: Vec<bool> = (0..space.groups.len()).map(|i| i % 3 == 0).collect();
        b.bench(&format!("construct_subnet/{model}"), || {
            subnet::construct(&params, &space.groups, &pruned, &costs, &man.qsites, &q)
        });
    }
    std::fs::create_dir_all("reports").ok();
    b.write_log(std::path::Path::new("reports/bench_subnet.json")).ok();
}
