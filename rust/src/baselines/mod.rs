//! Baseline compressors — reimplementations of the decision rules of the
//! methods each paper table compares against, running on the identical
//! model/data/runtime substrate as GETA (see DESIGN.md §Baselines).
//!
//! * `PruneThenPtq` — the paper's sequential comparator (Table 3, Fig. 3):
//!   HESSO-style pruning-aware training (realized as QASSO with zero quant
//!   sites, which degenerates exactly to saliency + progressive forgetting
//!   of *raw* weights) followed by uniform min/max post-training
//!   quantization.
//! * `UniformQat` — fixed-bit QAT, no pruning (ablation anchor).
//! * `UnstructuredJoint` — ANNC/QST-B analog (Table 2): progressive
//!   magnitude pruning of individual weights + learned quantization with
//!   PPSG-projected step sizes.
//! * `DjpqLike` / `BbLike` — black-box regularized joint methods
//!   (Table 4): a BOPs-proxy penalty pushes step sizes up (fewer bits)
//!   and group norms down; final sparsity *emerges* from the coefficient
//!   (the paper's core usability criticism). BB adds the 0-bit gate
//!   (groups whose norm crosses the gate threshold are removed) and a
//!   second retraining phase.
//! * `ObcLike` / `ClipqLike` — post-training layerwise prune+quant and
//!   in-parallel clip+quant (Table 5).

use crate::coordinator::Compressor;
use crate::optim::qasso::{Qasso, QassoConfig, SiteSpec};
use crate::optim::{make_optimizer, Optimizer};
use crate::quant::{self, QParams};
use crate::tensor::ParamStore;

/// Min/max uniform PTQ of every weight quant site (t=1, q_m=max|w|,
/// d for the requested bits).
pub fn apply_ptq(params: &ParamStore, sites: &[SiteSpec], q: &mut [QParams], bits: f32) {
    for (i, s) in sites.iter().enumerate() {
        if let Some(p) = &s.param {
            let m = params
                .get(p)
                .map(|t| crate::tensor::max_abs(&t.data))
                .unwrap_or(1.0);
            q[i] = QParams::init(m, bits);
        } else {
            q[i] = QParams::init(4.0, bits);
        }
    }
}

// ------------------------------------------------------------- sequential
pub struct PruneThenPtq {
    /// HESSO = QASSO with no quant sites: the joint stage's x^Q term
    /// degenerates to the raw weight (pure pruning-aware training).
    pruner: Qasso,
    sites: Vec<SiteSpec>,
    ptq_bits: f32,
    label: String,
}

impl PruneThenPtq {
    pub fn new(
        mut cfg: QassoConfig,
        groups: Vec<crate::graph::PruneGroup>,
        sites: Vec<SiteSpec>,
        base: Box<dyn Optimizer>,
        params: &ParamStore,
        ptq_bits: f32,
        label: &str,
    ) -> PruneThenPtq {
        // no QAT during training: skip projection entirely
        cfg.proj_periods = 0;
        cfg.init_bits = 32.0;
        // pass NO sites to the pruner: pruning is quantization-unaware
        let pruner = Qasso::new(cfg, groups, &[], base, params);
        PruneThenPtq {
            pruner,
            sites,
            ptq_bits,
            label: label.to_string(),
        }
    }
}

impl Compressor for PruneThenPtq {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        q: &mut Vec<QParams>,
        grads: &ParamStore,
        _qgrads: &[(f32, f32, f32)],
        lr: f32,
        _step: usize,
    ) {
        // keep the fake-quantizer transparent during training: 32-bit
        for site in q.iter_mut() {
            *site = QParams::init(site.qm.max(1.0), 32.0);
        }
        self.pruner.step(params, q, grads, &[], lr);
    }

    fn total_steps(&self) -> usize {
        self.pruner.cfg.total_steps()
    }

    fn pruned_mask(&self) -> Option<&[bool]> {
        Some(self.pruner.pruned_mask())
    }

    fn finalize(&mut self, params: &mut ParamStore, q: &mut Vec<QParams>) {
        apply_ptq(params, &self.sites, q, self.ptq_bits);
    }

    fn stage_name(&self, _step: usize) -> &'static str {
        self.pruner.stage().name()
    }
}

// ------------------------------------------------------------ uniform QAT
pub struct UniformQat {
    bits: f32,
    base: Box<dyn Optimizer>,
    steps: usize,
}

impl UniformQat {
    pub fn new(bits: f32, base: Box<dyn Optimizer>, steps: usize) -> UniformQat {
        UniformQat { bits, base, steps }
    }
}

impl Compressor for UniformQat {
    fn name(&self) -> String {
        format!("UniformQAT-{}b", self.bits)
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        q: &mut Vec<QParams>,
        grads: &ParamStore,
        _qg: &[(f32, f32, f32)],
        lr: f32,
        _step: usize,
    ) {
        self.base.step(params, grads, lr);
        // re-anchor q_m to the live weight range, d to fixed bits
        for site in q.iter_mut() {
            *site = QParams::init(site.qm, self.bits);
        }
    }

    fn total_steps(&self) -> usize {
        self.steps
    }

    fn pruned_mask(&self) -> Option<&[bool]> {
        None
    }
}

// --------------------------------------------------- unstructured + quant
/// ANNC / QST-B analog: progressive magnitude pruning of individual
/// weights, jointly with learned quantization (SGD on (d,t,q_m) + PPSG).
pub struct UnstructuredJoint {
    pub target_sparsity: f64,
    b_l: f32,
    b_u: f32,
    base: Box<dyn Optimizer>,
    steps: usize,
    ramp_steps: usize,
    lr_q: f32,
    mask: Option<Vec<Vec<bool>>>,
    label: String,
}

impl UnstructuredJoint {
    pub fn new(
        target_sparsity: f64,
        b_l: f32,
        b_u: f32,
        base: Box<dyn Optimizer>,
        steps: usize,
        label: &str,
    ) -> UnstructuredJoint {
        UnstructuredJoint {
            target_sparsity,
            b_l,
            b_u,
            base,
            steps,
            ramp_steps: steps * 2 / 3,
            lr_q: 1e-4,
            mask: None,
            label: label.to_string(),
        }
    }

    fn current_target(&self, step: usize) -> f64 {
        let p = (step as f64 / self.ramp_steps.max(1) as f64).min(1.0);
        // cubic ramp (Zhu & Gupta)
        self.target_sparsity * (1.0 - (1.0 - p).powi(3))
    }
}

impl Compressor for UnstructuredJoint {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        q: &mut Vec<QParams>,
        grads: &ParamStore,
        qgrads: &[(f32, f32, f32)],
        lr: f32,
        step: usize,
    ) {
        self.base.step(params, grads, lr);
        // learned quant params with PPSG feasibility
        for (site, g) in q.iter_mut().zip(qgrads) {
            site.d = (site.d - self.lr_q * g.0).max(1e-8);
            site.t = (site.t - self.lr_q * g.1).clamp(0.5, 2.0);
            site.qm = (site.qm - self.lr_q * g.2).max(1e-3);
            quant::ppsg_project(site, self.b_l, self.b_u);
        }
        // progressive global magnitude mask
        let target = self.current_target(step);
        if self.mask.is_none() {
            self.mask = Some(params.tensors.iter().map(|t| vec![false; t.numel()]).collect());
        }
        let mask = self.mask.as_mut().unwrap();
        // threshold: per-tensor quantile approximation via sampling sort
        for (ti, t) in params.tensors.iter_mut().enumerate() {
            if t.shape.len() < 2 {
                continue; // only weight matrices/filters
            }
            let mut mags: Vec<f32> = t.data.iter().map(|v| v.abs()).collect();
            let k = ((mags.len() as f64) * target) as usize;
            if k == 0 {
                continue;
            }
            let kth = k.min(mags.len() - 1);
            let (lo, _, _) = mags.select_nth_unstable_by(kth, |a, b| a.partial_cmp(b).unwrap());
            let thr = lo.iter().cloned().fold(0.0f32, f32::max);
            for (i, v) in t.data.iter_mut().enumerate() {
                if mask[ti][i] || v.abs() <= thr {
                    mask[ti][i] = true;
                    *v = 0.0;
                }
            }
        }
    }

    fn total_steps(&self) -> usize {
        self.steps
    }

    fn pruned_mask(&self) -> Option<&[bool]> {
        None
    }

    fn unstructured_density(&self) -> f64 {
        1.0 - self.target_sparsity
    }
}

// ----------------------------------------------------- black-box joint
/// DJPQ-like: regularized joint compression. λ_bits inflates d (fewer
/// bits), λ_prune shrinks group norms; the achieved sparsity/bit width is
/// whatever the coefficients produce — black-box by construction.
pub struct RegularizedJoint {
    pub lambda_bits: f32,
    pub lambda_prune: f32,
    /// norm threshold under which a group is gated off at finalize
    pub gate: f64,
    b_l: f32,
    b_u: f32,
    base: Box<dyn Optimizer>,
    steps: usize,
    lr_q: f32,
    groups: Vec<crate::graph::PruneGroup>,
    gi: crate::optim::saliency::GroupIndex,
    pruned: Vec<bool>,
    two_stage: bool,
    label: String,
}

impl RegularizedJoint {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        lambda_bits: f32,
        lambda_prune: f32,
        gate: f64,
        b_l: f32,
        b_u: f32,
        base: Box<dyn Optimizer>,
        steps: usize,
        groups: Vec<crate::graph::PruneGroup>,
        params: &ParamStore,
        two_stage: bool,
        label: &str,
    ) -> RegularizedJoint {
        let gi = crate::optim::saliency::GroupIndex::build(&groups, params);
        let n = groups.len();
        RegularizedJoint {
            lambda_bits,
            lambda_prune,
            gate,
            b_l,
            b_u,
            base,
            steps,
            lr_q: 1e-4,
            groups,
            gi,
            pruned: vec![false; n],
            two_stage,
            label: label.to_string(),
        }
    }
}

impl Compressor for RegularizedJoint {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        q: &mut Vec<QParams>,
        grads: &ParamStore,
        qgrads: &[(f32, f32, f32)],
        lr: f32,
        step: usize,
    ) {
        self.base.step(params, grads, lr);
        // quant params: task gradient + bit penalty (∂bits/∂d < 0, so the
        // penalty *adds* to d — pushing toward fewer bits)
        for (site, g) in q.iter_mut().zip(qgrads) {
            let bit_pull = self.lambda_bits * site.d; // d log-scale pressure
            site.d = (site.d - self.lr_q * g.0 + self.lr_q * bit_pull * 1e4).max(1e-8);
            site.t = (site.t - self.lr_q * g.1).clamp(0.5, 2.0);
            site.qm = (site.qm - self.lr_q * g.2).max(1e-3);
            quant::ppsg_project(site, self.b_l, self.b_u);
        }
        // group-lasso shrinkage on every group (black-box pruning pressure)
        let search_phase = !self.two_stage || step < self.steps / 2;
        if search_phase {
            let shrink = 1.0 - lr * self.lambda_prune;
            for g in 0..self.groups.len() {
                for &(ti, ei) in &self.gi.elems[g] {
                    params.tensors[ti as usize].data[ei as usize] *= shrink;
                }
            }
        }
        // two-stage (BB): gate at the stage boundary, then retrain
        if self.two_stage && step == self.steps / 2 {
            for g in 0..self.groups.len() {
                let norm = self.gi.group_norm(g, params)
                    / (self.gi.elems[g].len().max(1) as f64).sqrt();
                if norm < self.gate {
                    self.pruned[g] = true;
                    self.gi.zero_group(g, params);
                }
            }
        }
        if self.two_stage {
            for g in 0..self.groups.len() {
                if self.pruned[g] {
                    self.gi.zero_group(g, params);
                }
            }
        }
    }

    fn total_steps(&self) -> usize {
        self.steps
    }

    fn pruned_mask(&self) -> Option<&[bool]> {
        Some(&self.pruned)
    }

    fn finalize(&mut self, params: &mut ParamStore, _q: &mut Vec<QParams>) {
        if !self.two_stage {
            // DJPQ: threshold whatever the shrinkage produced
            for g in 0..self.groups.len() {
                let norm = self.gi.group_norm(g, params)
                    / (self.gi.elems[g].len().max(1) as f64).sqrt();
                if norm < self.gate {
                    self.pruned[g] = true;
                    self.gi.zero_group(g, params);
                }
            }
        }
    }
}

// -------------------------------------------------- post-training methods
/// OBC-like: train fp32, then layerwise greedy unstructured prune + PTQ.
pub struct PostTrainPruneQuant {
    pub target_sparsity: f64,
    pub bits: f32,
    base: Box<dyn Optimizer>,
    steps: usize,
    sites: Vec<SiteSpec>,
    label: String,
}

impl PostTrainPruneQuant {
    pub fn new(
        target_sparsity: f64,
        bits: f32,
        base: Box<dyn Optimizer>,
        steps: usize,
        sites: Vec<SiteSpec>,
        label: &str,
    ) -> PostTrainPruneQuant {
        PostTrainPruneQuant {
            target_sparsity,
            bits,
            base,
            steps,
            sites,
            label: label.to_string(),
        }
    }
}

impl Compressor for PostTrainPruneQuant {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        q: &mut Vec<QParams>,
        grads: &ParamStore,
        _qg: &[(f32, f32, f32)],
        lr: f32,
        _step: usize,
    ) {
        // transparent quantizer during training
        for site in q.iter_mut() {
            *site = QParams::init(site.qm.max(1.0), 32.0);
        }
        self.base.step(params, grads, lr);
    }

    fn total_steps(&self) -> usize {
        self.steps
    }

    fn pruned_mask(&self) -> Option<&[bool]> {
        None
    }

    fn unstructured_density(&self) -> f64 {
        1.0 - self.target_sparsity
    }

    fn finalize(&mut self, params: &mut ParamStore, q: &mut Vec<QParams>) {
        // layerwise greedy: zero the smallest-|w| fraction per layer
        for t in params.tensors.iter_mut() {
            if t.shape.len() < 2 {
                continue;
            }
            let mut mags: Vec<f32> = t.data.iter().map(|v| v.abs()).collect();
            let k = ((mags.len() as f64) * self.target_sparsity) as usize;
            if k == 0 {
                continue;
            }
            let kth = k.min(mags.len() - 1);
            let (lo, _, _) = mags.select_nth_unstable_by(kth, |a, b| a.partial_cmp(b).unwrap());
            let thr = lo.iter().cloned().fold(0.0f32, f32::max);
            for v in t.data.iter_mut() {
                if v.abs() <= thr {
                    *v = 0.0;
                }
            }
        }
        apply_ptq(params, &self.sites, q, self.bits);
    }
}

/// Clip-Q-like: in-parallel clipping (magnitude mask re-derived every
/// step, never committed) + quantization during training.
pub struct ClipQLike {
    pub target_sparsity: f64,
    pub bits: f32,
    base: Box<dyn Optimizer>,
    steps: usize,
    label: String,
}

impl ClipQLike {
    pub fn new(target_sparsity: f64, bits: f32, base: Box<dyn Optimizer>, steps: usize) -> ClipQLike {
        ClipQLike {
            target_sparsity,
            bits,
            base,
            steps,
            label: "Clip-Q-like".into(),
        }
    }
}

impl Compressor for ClipQLike {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        q: &mut Vec<QParams>,
        grads: &ParamStore,
        _qg: &[(f32, f32, f32)],
        lr: f32,
        _step: usize,
    ) {
        self.base.step(params, grads, lr);
        // in-parallel: clip smallest weights this step (they may recover)
        for t in params.tensors.iter_mut() {
            if t.shape.len() < 2 {
                continue;
            }
            let mut mags: Vec<f32> = t.data.iter().map(|v| v.abs()).collect();
            let k = ((mags.len() as f64) * self.target_sparsity) as usize;
            if k == 0 {
                continue;
            }
            let kth = k.min(mags.len() - 1);
            let (lo, _, _) = mags.select_nth_unstable_by(kth, |a, b| a.partial_cmp(b).unwrap());
            let thr = lo.iter().cloned().fold(0.0f32, f32::max);
            for v in t.data.iter_mut() {
                if v.abs() <= thr {
                    *v = 0.0;
                }
            }
        }
        // fixed-bit quantizer tracking the live range
        for site in q.iter_mut() {
            *site = QParams::init(site.qm, self.bits);
        }
    }

    fn total_steps(&self) -> usize {
        self.steps
    }

    fn pruned_mask(&self) -> Option<&[bool]> {
        None
    }

    fn unstructured_density(&self) -> f64 {
        1.0 - self.target_sparsity
    }
}

// ------------------------------------------- LLM prune-then-PTQ analogs
/// Structured LLM pruning styles for the Fig. 3 comparison (each followed
/// by 8-bit PTQ via `PruneThenPtq`-style finalize).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LlmPruneStyle {
    /// SliceGPT-like: remove groups with the lowest weight-column variance
    /// (activation-variance proxy), one shot at the ramp end.
    Slice,
    /// LoraShear-like: group-lasso shrinkage then threshold.
    Shear,
    /// LLMPruner-like: gradient-magnitude saliency one-shot.
    GradMag,
}

pub struct LlmPruneThenPtq {
    style: LlmPruneStyle,
    target_sparsity: f64,
    bits: f32,
    base: Box<dyn Optimizer>,
    steps: usize,
    groups: Vec<crate::graph::PruneGroup>,
    gi: crate::optim::saliency::GroupIndex,
    pruned: Vec<bool>,
    sites: Vec<SiteSpec>,
}

impl LlmPruneThenPtq {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        style: LlmPruneStyle,
        target_sparsity: f64,
        bits: f32,
        base: Box<dyn Optimizer>,
        steps: usize,
        groups: Vec<crate::graph::PruneGroup>,
        params: &ParamStore,
        sites: Vec<SiteSpec>,
    ) -> LlmPruneThenPtq {
        let gi = crate::optim::saliency::GroupIndex::build(&groups, params);
        let n = groups.len();
        LlmPruneThenPtq {
            style,
            target_sparsity,
            bits,
            base,
            steps,
            groups,
            gi,
            pruned: vec![false; n],
            sites,
        }
    }

    fn prune_now(&mut self, params: &mut ParamStore, grads: &ParamStore) {
        let k = (self.target_sparsity * self.groups.len() as f64).round() as usize;
        let scores: Vec<f64> = match self.style {
            LlmPruneStyle::Slice => (0..self.groups.len())
                .map(|g| {
                    // column-variance proxy
                    let mut sum = 0.0;
                    let mut sq = 0.0;
                    let n = self.gi.elems[g].len().max(1) as f64;
                    for &(ti, ei) in &self.gi.elems[g] {
                        let v = params.tensors[ti as usize].data[ei as usize] as f64;
                        sum += v;
                        sq += v * v;
                    }
                    sq / n - (sum / n) * (sum / n)
                })
                .collect(),
            LlmPruneStyle::Shear => (0..self.groups.len())
                .map(|g| self.gi.group_norm(g, params))
                .collect(),
            LlmPruneStyle::GradMag => (0..self.groups.len())
                .map(|g| {
                    let mut s = 0.0;
                    for &(ti, ei) in &self.gi.elems[g] {
                        let x = params.tensors[ti as usize].data[ei as usize] as f64;
                        let gr = grads.tensors[ti as usize].data[ei as usize] as f64;
                        s += (x * gr).abs();
                    }
                    s
                })
                .collect(),
        };
        let eligible = vec![true; self.groups.len()];
        for g in crate::optim::saliency::select_redundant(&scores, &eligible, k) {
            self.pruned[g] = true;
            self.gi.zero_group(g, params);
        }
    }
}

impl Compressor for LlmPruneThenPtq {
    fn name(&self) -> String {
        match self.style {
            LlmPruneStyle::Slice => "Slice-like+PTQ".into(),
            LlmPruneStyle::Shear => "Shear-like+PTQ".into(),
            LlmPruneStyle::GradMag => "LLMPruner-like+PTQ".into(),
        }
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        q: &mut Vec<QParams>,
        grads: &ParamStore,
        _qg: &[(f32, f32, f32)],
        lr: f32,
        step: usize,
    ) {
        for site in q.iter_mut() {
            *site = QParams::init(site.qm.max(1.0), 32.0);
        }
        self.base.step(params, grads, lr);
        if self.style == LlmPruneStyle::Shear && step < self.steps / 2 {
            let shrink = 1.0 - lr * 0.05;
            for g in 0..self.groups.len() {
                for &(ti, ei) in &self.gi.elems[g] {
                    params.tensors[ti as usize].data[ei as usize] *= shrink;
                }
            }
        }
        // prune at midpoint, finetune after
        if step == self.steps / 2 {
            self.prune_now(params, grads);
        }
        for g in 0..self.groups.len() {
            if self.pruned[g] {
                self.gi.zero_group(g, params);
            }
        }
    }

    fn total_steps(&self) -> usize {
        self.steps
    }

    fn pruned_mask(&self) -> Option<&[bool]> {
        Some(&self.pruned)
    }

    fn finalize(&mut self, params: &mut ParamStore, q: &mut Vec<QParams>) {
        apply_ptq(params, &self.sites, q, self.bits);
    }
}

/// Convenience: build a fresh base optimizer matching an experiment config.
pub fn base_opt(exp: &crate::config::ExperimentConfig) -> Box<dyn Optimizer> {
    make_optimizer(&exp.optimizer, exp.weight_decay, exp.momentum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn params() -> ParamStore {
        let mut s = ParamStore::new();
        let mut rng = crate::util::rng::Rng::new(5);
        let mut w = vec![0.0f32; 64];
        rng.fill_normal(&mut w, 1.0);
        s.push(Tensor::from_vec("w.weight", &[8, 8], w));
        s
    }

    #[test]
    fn ptq_sets_uniform_bits() {
        let p = params();
        let sites = vec![SiteSpec {
            name: "w.weight".into(),
            param: Some("w.weight".into()),
        }];
        let mut q = vec![QParams::init(1.0, 32.0)];
        apply_ptq(&p, &sites, &mut q, 8.0);
        assert!((q[0].bit_width() - 8.0).abs() < 1e-3);
        assert!((q[0].qm - crate::tensor::max_abs(&p.tensors[0].data)).abs() < 1e-6);
    }

    #[test]
    fn unstructured_reaches_target() {
        let mut p = params();
        let mut q = vec![QParams::init(1.0, 16.0)];
        let mut m = UnstructuredJoint::new(
            0.5, 4.0, 16.0,
            Box::new(crate::optim::Sgd::plain()),
            30,
            "test",
        );
        let grads = p.zeros_like();
        for step in 0..30 {
            m.step(&mut p, &mut q, &grads, &[(0.0, 0.0, 0.0)], 0.0, step);
        }
        let zeros = p.tensors[0].data.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros >= 30 && zeros <= 36, "zeros={zeros}");
        assert!((m.unstructured_density() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn clipq_mask_not_committed() {
        // weights zeroed one step can regrow the next (in-parallel)
        let mut p = params();
        let mut q = vec![QParams::init(1.0, 8.0)];
        let mut m = ClipQLike::new(0.3, 8.0, Box::new(crate::optim::Sgd::plain()), 10);
        let mut grads = p.zeros_like();
        for v in grads.tensors[0].data.iter_mut() {
            *v = -1.0; // push all weights up
        }
        m.step(&mut p, &mut q, &grads, &[], 0.5, 0);
        let zeros_after_1 = p.tensors[0].data.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros_after_1 > 0);
        // several more steps: a *committed* mask would accumulate zeros
        // (old mask ∪ new clips) toward 100%; the in-parallel mask is
        // re-derived each step so zeros track the 30% target (plus ties
        // from regrown equal-magnitude weights).
        for step in 1..6 {
            m.step(&mut p, &mut q, &grads, &[], 0.5, step);
        }
        let total = p.tensors[0].data.len();
        let zeros_after = p.tensors[0].data.iter().filter(|&&v| v == 0.0).count();
        assert!(
            zeros_after <= total * 60 / 100,
            "zeros accumulated like a committed mask: {zeros_after}/{total}"
        );
    }
}
