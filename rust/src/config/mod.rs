//! Experiment configuration: defaults per model family, JSON overrides,
//! CLI overrides — the white-box control surface the paper argues for
//! (explicit sparsity + bit-range targets instead of penalty tuning).

use crate::optim::qasso::QassoConfig;
use crate::optim::Schedule;
use crate::util::cli::Args;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub model: String,
    pub seed: u64,
    pub n_train: usize,
    pub n_eval: usize,
    pub optimizer: String,
    pub momentum: f32,
    pub weight_decay: f32,
    pub lr: f32,
    pub lr_decay_every: usize,
    pub lr_decay_gamma: f32,
    pub qasso: QassoConfig,
    /// Log every k steps.
    pub log_every: usize,
}

impl ExperimentConfig {
    /// Paper Appendix C-inspired defaults, scaled to the mini models
    /// (units are steps; the paper's Table 7 uses epochs).
    pub fn defaults_for(model: &str) -> ExperimentConfig {
        let is_transformer = model.starts_with("bert")
            || model.starts_with("gpt")
            || model.contains("vit")
            || model.starts_with("swin");
        let qasso = QassoConfig {
            warmup_steps: 60,
            proj_periods: 4,
            proj_steps: 15,
            prune_periods: 5,
            prune_steps: 20,
            cooldown_steps: 180,
            bit_reduction: if is_transformer { 1.0 } else { 4.0 },
            b_l: 4.0,
            b_u: 16.0,
            init_bits: if is_transformer { 8.0 } else { 32.0 },
            target_group_sparsity: 0.35,
            ..Default::default()
        };
        ExperimentConfig {
            model: model.to_string(),
            seed: 0,
            n_train: 1024,
            n_eval: 512,
            optimizer: if is_transformer { "adamw".into() } else { "sgd".into() },
            momentum: 0.9,
            weight_decay: 1e-4,
            lr: if is_transformer { 3e-3 } else { 5e-2 },
            lr_decay_every: 150,
            lr_decay_gamma: 0.3,
            qasso,
            log_every: 25,
        }
    }

    pub fn schedule(&self) -> Schedule {
        Schedule::Step {
            lr: self.lr,
            gamma: self.lr_decay_gamma,
            every: self.lr_decay_every,
        }
    }

    pub fn total_steps(&self) -> usize {
        self.qasso.total_steps()
    }

    /// Scale all stage lengths by `f` (fast smoke runs / long full runs).
    pub fn scale_steps(&mut self, f: f64) {
        let s = |v: usize| ((v as f64 * f).round() as usize).max(1);
        self.qasso.warmup_steps = s(self.qasso.warmup_steps);
        self.qasso.proj_steps = s(self.qasso.proj_steps);
        self.qasso.prune_steps = s(self.qasso.prune_steps);
        self.qasso.cooldown_steps = s(self.qasso.cooldown_steps);
        self.lr_decay_every = s(self.lr_decay_every);
    }

    /// Apply `--key value` CLI overrides.
    pub fn apply_args(&mut self, a: &Args) {
        self.seed = a.usize_or("seed", self.seed as usize) as u64;
        self.n_train = a.usize_or("n-train", self.n_train);
        self.n_eval = a.usize_or("n-eval", self.n_eval);
        self.lr = a.f64_or("lr", self.lr as f64) as f32;
        self.qasso.target_group_sparsity =
            a.f64_or("sparsity", self.qasso.target_group_sparsity);
        self.qasso.b_l = a.f64_or("b-l", self.qasso.b_l as f64) as f32;
        self.qasso.b_u = a.f64_or("b-u", self.qasso.b_u as f64) as f32;
        self.qasso.init_bits = a.f64_or("init-bits", self.qasso.init_bits as f64) as f32;
        if let Some(v) = a.opt("steps-scale") {
            if let Ok(f) = v.parse::<f64>() {
                self.scale_steps(f);
            }
        }
        if let Some(o) = a.opt("optimizer") {
            self.optimizer = o.to_string();
        }
    }

    /// Apply overrides from a JSON object (experiment files).
    pub fn apply_json(&mut self, j: &Json) {
        self.seed = j.usize_or("seed", self.seed as usize) as u64;
        self.n_train = j.usize_or("n_train", self.n_train);
        self.n_eval = j.usize_or("n_eval", self.n_eval);
        self.lr = j.f64_or("lr", self.lr as f64) as f32;
        let q = &mut self.qasso;
        q.target_group_sparsity = j.f64_or("sparsity", q.target_group_sparsity);
        q.b_l = j.f64_or("b_l", q.b_l as f64) as f32;
        q.b_u = j.f64_or("b_u", q.b_u as f64) as f32;
        q.init_bits = j.f64_or("init_bits", q.init_bits as f64) as f32;
        q.warmup_steps = j.usize_or("warmup_steps", q.warmup_steps);
        q.proj_periods = j.usize_or("proj_periods", q.proj_periods);
        q.proj_steps = j.usize_or("proj_steps", q.proj_steps);
        q.prune_periods = j.usize_or("prune_periods", q.prune_periods);
        q.prune_steps = j.usize_or("prune_steps", q.prune_steps);
        q.cooldown_steps = j.usize_or("cooldown_steps", q.cooldown_steps);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_differ_by_family() {
        let cnn = ExperimentConfig::defaults_for("resnet_mini");
        let tfm = ExperimentConfig::defaults_for("bert_mini");
        assert_eq!(cnn.optimizer, "sgd");
        assert_eq!(tfm.optimizer, "adamw");
        assert_eq!(cnn.qasso.init_bits, 32.0);
        assert_eq!(tfm.qasso.init_bits, 8.0);
    }

    #[test]
    fn cli_overrides() {
        let mut c = ExperimentConfig::defaults_for("resnet_mini");
        let a = Args::parse(&[
            "train".into(),
            "--sparsity".into(),
            "0.6".into(),
            "--b-l".into(),
            "2".into(),
        ]);
        c.apply_args(&a);
        assert_eq!(c.qasso.target_group_sparsity, 0.6);
        assert_eq!(c.qasso.b_l, 2.0);
    }

    #[test]
    fn scale_steps_shrinks() {
        let mut c = ExperimentConfig::defaults_for("resnet_mini");
        let before = c.total_steps();
        c.scale_steps(0.25);
        assert!(c.total_steps() < before / 2);
        assert!(c.qasso.warmup_steps >= 1);
    }

    #[test]
    fn json_overrides() {
        let mut c = ExperimentConfig::defaults_for("resnet_mini");
        let j = crate::util::json::parse(r#"{"sparsity": 0.7, "prune_periods": 9}"#).unwrap();
        c.apply_json(&j);
        assert_eq!(c.qasso.target_group_sparsity, 0.7);
        assert_eq!(c.qasso.prune_periods, 9);
    }
}
