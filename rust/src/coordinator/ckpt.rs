//! `.getackpt` — resumable training checkpoints, in the `.geta` container
//! style (versioned, little-endian, strict reader).
//!
//! A checkpoint captures *everything* a `geta train --resume` needs to
//! continue bit-identically: the (possibly shrink-sliced) parameters, the
//! base optimizer's momentum/moment stores and scalar step count, the
//! learned quantizer rows, QASSO's forgetting-schedule position, the batch
//! iterator's shuffle + RNG state, the full per-step loss history (so a
//! resumed run can emit a complete loss file), the cumulative kept-channel
//! slice map and the re-plan step log.
//!
//! Layout (all integers little-endian; `[str]` = u32 length + UTF-8;
//! `[store]` = u32 count, then per tensor `[str]` name, u8 ndim,
//! ndim × u32 dims, numel × f32 data):
//!
//! | field | type | meaning |
//! |---|---|---|
//! | magic | 4 bytes | `"GCKP"` |
//! | version | u16 | format version (currently 1) |
//! | flags | u16 | reserved, must be 0 |
//! | model | [str] | model name (must match the resuming config) |
//! | step / total / seed | 3 × u64 | completed steps, schedule length, seed |
//! | params | [store] | current (possibly sliced) parameters |
//! | optimizer | [str] + u64 + u8 + stores | name, scalar state, per-param stores |
//! | qparams | u32 + n × 3 f32 | (d, t, q_m) per quant site |
//! | qasso | u64, f32, u32, … | step count, b_u, group state (see below) |
//! | batch iter | u32 + order, u64 pos, u32 bs, u64 rng, u8+u64 spare | shuffle state |
//! | trace | u32 + rows (u64, f32, u8) | logged (step, loss, stage) rows |
//! | losses | u32 + n × f32 | per-step loss history, steps 0..step |
//! | kept map | u32 + entries | cumulative removed indices per tensor/axis |
//! | replans | u32 + n × u64 | steps after which the plan was rebuilt |
//!
//! The reader is strict: bad magic, unknown version, nonzero flags,
//! truncation, trailing bytes, and any cross-reference violation
//! (optimizer stores not mirroring the parameter store, slice-map names
//! not resolving, out-of-range stage codes or shuffle indices) are hard
//! errors, never best-effort reads.

use anyhow::{Context, Result};

use crate::data::BatchIterState;
use crate::metrics::TrainTrace;
use crate::optim::qasso::QassoState;
use crate::quant::QParams;
use crate::subnet::KeptMap;
use crate::tensor::{ParamStore, Tensor};

pub const MAGIC: [u8; 4] = *b"GCKP";
pub const VERSION: u16 = 1;

/// Allocation caps guarding the strict reader against corrupt lengths.
const MAX_NUMEL: u64 = 1 << 28;
const MAX_DIMS: usize = 8;
const MAX_COUNT: usize = 1 << 24;

/// Stage-name table shared by writer and reader; `TrainTrace` stores
/// `&'static str` stage labels, so codes map back into this table.
const STAGES: [&str; 6] = ["warmup", "projection", "joint", "cooldown", "done", "train"];

fn stage_code(name: &str) -> u8 {
    STAGES.iter().position(|&s| s == name).unwrap_or(5) as u8
}

/// Everything a resumable training run checkpoints.
#[derive(Debug, Clone)]
pub struct TrainCkpt {
    pub model: String,
    /// Completed steps; the resumed run continues at this step index.
    pub step: u64,
    pub total_steps: u64,
    pub seed: u64,
    /// Current parameters, in their live (possibly shrink-sliced) shapes.
    pub params: ParamStore,
    pub opt_name: String,
    pub opt_scalar: u64,
    /// Base-optimizer per-param stores (momentum / Adam moments), in
    /// `Optimizer::state_stores` order; empty when not yet allocated.
    pub opt_stores: Vec<ParamStore>,
    pub q: Vec<QParams>,
    pub qasso: QassoState,
    pub batch: BatchIterState,
    pub trace: TrainTrace,
    /// Per-step losses for steps `0..step` (resumed runs append to this,
    /// so a finished run always has the complete curve).
    pub losses: Vec<f32>,
    /// Cumulative slice map in ORIGINAL dense coordinates.
    pub kept: KeptMap,
    /// Step counts after which the executor plan was rebuilt.
    pub replans: Vec<u64>,
}

impl TrainCkpt {
    // ------------------------------------------------------------ writing
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::default();
        w.bytes(&MAGIC);
        w.u16(VERSION);
        w.u16(0); // flags
        w.str(&self.model);
        w.u64(self.step);
        w.u64(self.total_steps);
        w.u64(self.seed);
        write_store(&mut w, &self.params);
        w.str(&self.opt_name);
        w.u64(self.opt_scalar);
        w.u8(self.opt_stores.len() as u8);
        for s in &self.opt_stores {
            write_store(&mut w, s);
        }
        w.u32(self.q.len() as u32);
        for qp in &self.q {
            w.f32(qp.d);
            w.f32(qp.t);
            w.f32(qp.qm);
        }
        // qasso scheduling state
        w.u64(self.qasso.step_count as u64);
        w.f32(self.qasso.bu_cur);
        w.u32(self.qasso.pruned.len() as u32);
        for &p in &self.qasso.pruned {
            w.u8(p as u8);
        }
        w.u32(self.qasso.redundant.len() as u32);
        for &g in &self.qasso.redundant {
            w.u32(g as u32);
        }
        for &g in &self.qasso.gamma {
            w.f32(g); // length == pruned.len()
        }
        w.u32(self.qasso.gamma_scale.len() as u32);
        for &s in &self.qasso.gamma_scale {
            w.f32(s);
        }
        // batch iterator
        w.u32(self.batch.order.len() as u32);
        for &i in &self.batch.order {
            w.u32(i as u32);
        }
        w.u64(self.batch.pos as u64);
        w.u32(self.batch.bs as u32);
        w.u64(self.batch.rng_state);
        match self.batch.rng_spare {
            Some(sp) => {
                w.u8(1);
                w.u64(sp.to_bits());
            }
            None => {
                w.u8(0);
                w.u64(0);
            }
        }
        // trace rows
        w.u32(self.trace.steps.len() as u32);
        for i in 0..self.trace.steps.len() {
            w.u64(self.trace.steps[i] as u64);
            w.f32(self.trace.losses[i]);
            w.u8(stage_code(self.trace.stages[i]));
        }
        // full per-step loss history
        w.u32(self.losses.len() as u32);
        for &l in &self.losses {
            w.f32(l);
        }
        // cumulative kept map
        w.u32(self.kept.removed.len() as u32);
        for (name, axes) in &self.kept.removed {
            w.str(name);
            w.u32(axes.len() as u32);
            for (&axis, idxs) in axes {
                w.u32(axis as u32);
                w.u32(idxs.len() as u32);
                for &i in idxs {
                    w.u32(i as u32);
                }
            }
        }
        w.u32(self.replans.len() as u32);
        for &r in &self.replans {
            w.u64(r);
        }
        w.0
    }

    /// Crash-safe: goes through [`crate::util::atomic_write`], so a kill
    /// mid-checkpoint leaves the previous `.getackpt` intact — `--resume`
    /// never sees a torn file.
    pub fn write(&self, path: &std::path::Path) -> Result<()> {
        crate::util::atomic_write(path, &self.to_bytes())
            .with_context(|| format!("write {}", path.display()))
    }

    // ------------------------------------------------------------ reading
    pub fn from_bytes(b: &[u8]) -> Result<TrainCkpt> {
        let mut r = Reader { b, pos: 0 };
        let magic = r.take(4)?;
        anyhow::ensure!(magic == MAGIC, "bad magic {magic:02x?} (not a .getackpt file)");
        let version = r.u16()?;
        anyhow::ensure!(
            version == VERSION,
            "unsupported .getackpt version {version} (this build reads {VERSION})"
        );
        let flags = r.u16()?;
        anyhow::ensure!(flags == 0, "unknown .getackpt flags {flags:#06x}");
        let model = r.str()?;
        let step = r.u64()?;
        let total_steps = r.u64()?;
        anyhow::ensure!(
            step <= total_steps,
            "checkpoint step {step} beyond schedule of {total_steps} steps"
        );
        let seed = r.u64()?;
        let params = read_store(&mut r, "params")?;
        let opt_name = r.str()?;
        let opt_scalar = r.u64()?;
        let n_stores = r.u8()? as usize;
        anyhow::ensure!(n_stores <= 4, "implausible optimizer store count {n_stores}");
        let mut opt_stores = Vec::with_capacity(n_stores);
        for si in 0..n_stores {
            let s = read_store(&mut r, "optimizer state")?;
            // cross-ref: every state store mirrors the parameter store
            anyhow::ensure!(
                s.len() == params.len(),
                "optimizer store {si}: {} tensors vs {} params",
                s.len(),
                params.len()
            );
            for (st, pt) in s.tensors.iter().zip(&params.tensors) {
                anyhow::ensure!(
                    st.name == pt.name && st.shape == pt.shape,
                    "optimizer store {si}: `{}` {:?} does not mirror param `{}` {:?}",
                    st.name,
                    st.shape,
                    pt.name,
                    pt.shape
                );
            }
            opt_stores.push(s);
        }
        let n_q = r.count("qparams")?;
        let mut q = Vec::with_capacity(n_q);
        for i in 0..n_q {
            let qp = QParams {
                d: r.f32()?,
                t: r.f32()?,
                qm: r.f32()?,
            };
            anyhow::ensure!(
                qp.d.is_finite() && qp.d > 0.0 && qp.t.is_finite() && qp.qm.is_finite(),
                "qparam {i}: degenerate values {qp:?}"
            );
            q.push(qp);
        }
        let q_step_count = r.u64()? as usize;
        let bu_cur = r.f32()?;
        let n_groups = r.count("groups")?;
        let mut pruned = Vec::with_capacity(n_groups);
        for _ in 0..n_groups {
            pruned.push(r.u8()? != 0);
        }
        let n_red = r.count("redundant groups")?;
        let mut redundant = Vec::with_capacity(n_red);
        for i in 0..n_red {
            let g = r.u32()? as usize;
            anyhow::ensure!(
                g < n_groups,
                "redundant[{i}] = {g} out of range for {n_groups} groups"
            );
            redundant.push(g);
        }
        let mut gamma = Vec::with_capacity(n_groups);
        for _ in 0..n_groups {
            gamma.push(r.f32()?);
        }
        let n_scale = r.count("gamma scales")?;
        let mut gamma_scale = Vec::with_capacity(n_scale);
        for _ in 0..n_scale {
            gamma_scale.push(r.f32()?);
        }
        let n_order = r.count("shuffle order")?;
        let mut order = Vec::with_capacity(n_order);
        for i in 0..n_order {
            let v = r.u32()? as usize;
            anyhow::ensure!(
                v < n_order,
                "shuffle order[{i}] = {v} out of range for {n_order} samples"
            );
            order.push(v);
        }
        let pos = r.u64()? as usize;
        anyhow::ensure!(
            pos <= n_order,
            "shuffle position {pos} beyond order of {n_order}"
        );
        let bs = r.u32()? as usize;
        let rng_state = r.u64()?;
        let has_spare = r.u8()?;
        anyhow::ensure!(has_spare <= 1, "bad rng spare flag {has_spare}");
        let spare_bits = r.u64()?;
        let rng_spare = (has_spare == 1).then(|| f64::from_bits(spare_bits));
        let n_rows = r.count("trace rows")?;
        let mut trace = TrainTrace::default();
        for i in 0..n_rows {
            let s = r.u64()? as usize;
            let l = r.f32()?;
            let code = r.u8()? as usize;
            let stage = *STAGES
                .get(code)
                .with_context(|| format!("trace row {i}: unknown stage code {code}"))?;
            trace.push(s, l, stage);
        }
        let n_losses = r.count("losses")?;
        anyhow::ensure!(
            n_losses as u64 == step,
            "loss history has {n_losses} entries for {step} completed steps"
        );
        let mut losses = Vec::with_capacity(n_losses);
        for _ in 0..n_losses {
            losses.push(r.f32()?);
        }
        let n_kept = r.count("kept-map tensors")?;
        let mut kept = KeptMap::default();
        for _ in 0..n_kept {
            let name = r.str()?;
            anyhow::ensure!(
                params.get(&name).is_some(),
                "slice map names unknown tensor `{name}`"
            );
            let n_axes = r.count("kept-map axes")?;
            let axes = kept.removed.entry(name.clone()).or_default();
            for _ in 0..n_axes {
                let axis = r.u32()? as usize;
                anyhow::ensure!(axis < MAX_DIMS, "`{name}`: slice axis {axis}");
                let n_idx = r.count("removed indices")?;
                let mut idxs = Vec::with_capacity(n_idx);
                let mut prev: Option<usize> = None;
                for _ in 0..n_idx {
                    let i = r.u32()? as usize;
                    anyhow::ensure!(
                        prev.map(|p| p < i).unwrap_or(true),
                        "`{name}` axis {axis}: removed indices not strictly ascending"
                    );
                    prev = Some(i);
                    idxs.push(i);
                }
                axes.insert(axis, idxs);
            }
        }
        let n_replans = r.count("replans")?;
        let mut replans = Vec::with_capacity(n_replans);
        for _ in 0..n_replans {
            replans.push(r.u64()?);
        }
        anyhow::ensure!(
            r.pos == r.b.len(),
            "trailing bytes: {} past the end of the checkpoint",
            r.b.len() - r.pos
        );
        Ok(TrainCkpt {
            model,
            step,
            total_steps,
            seed,
            params,
            opt_name,
            opt_scalar,
            opt_stores,
            q,
            qasso: QassoState {
                step_count: q_step_count,
                bu_cur,
                pruned,
                redundant,
                gamma,
                gamma_scale,
            },
            batch: BatchIterState {
                order,
                pos,
                bs,
                rng_state,
                rng_spare,
            },
            trace,
            losses,
            kept,
            replans,
        })
    }

    pub fn load(path: &std::path::Path) -> Result<TrainCkpt> {
        let b = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
        TrainCkpt::from_bytes(&b)
    }
}

fn write_store(w: &mut Writer, s: &ParamStore) {
    w.u32(s.len() as u32);
    for t in &s.tensors {
        w.str(&t.name);
        w.u8(t.shape.len() as u8);
        for &d in &t.shape {
            w.u32(d as u32);
        }
        for &x in &t.data {
            w.f32(x);
        }
    }
}

fn read_store(r: &mut Reader, what: &str) -> Result<ParamStore> {
    let n = r.count(what)?;
    let mut s = ParamStore::new();
    for _ in 0..n {
        let name = r.str()?;
        anyhow::ensure!(
            s.get(&name).is_none(),
            "{what}: duplicate tensor `{name}`"
        );
        let ndim = r.u8()? as usize;
        anyhow::ensure!(ndim <= MAX_DIMS, "{what}: tensor `{name}` has {ndim} dims");
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()? as usize);
        }
        // checked: corrupt dims can otherwise overflow the product
        let numel = shape
            .iter()
            .try_fold(1u64, |acc, &d| acc.checked_mul(d as u64))
            .filter(|&n| n <= MAX_NUMEL)
            .ok_or_else(|| anyhow::anyhow!("{what}: tensor `{name}` numel of {shape:?} too large"))?;
        let raw = r.take(numel as usize * 4)?;
        let mut data = Vec::with_capacity(numel as usize);
        for c in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        s.push(Tensor::from_vec(&name, &shape, data));
    }
    Ok(s)
}

#[derive(Default)]
struct Writer(Vec<u8>);

impl Writer {
    fn bytes(&mut self, b: &[u8]) {
        self.0.extend_from_slice(b);
    }
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.bytes(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.bytes(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.bytes(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.bytes(s.as_bytes());
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.b.len(),
            "truncated .getackpt file: need {n} bytes at offset {}, have {}",
            self.pos,
            self.b.len() - self.pos
        );
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        anyhow::ensure!(n <= MAX_COUNT, "implausible string length {n}");
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|e| anyhow::anyhow!("bad UTF-8 string: {e}"))
    }
    /// A u32 list-length field with a sanity bound.
    fn count(&mut self, what: &str) -> Result<usize> {
        let n = self.u32()? as usize;
        anyhow::ensure!(n <= MAX_COUNT, "implausible {what} count {n}");
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TrainCkpt {
        let mut params = ParamStore::new();
        params.push(Tensor::from_vec("w", &[2, 3], vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6]));
        params.push(Tensor::from_vec("b", &[3], vec![-1.0, 0.0, 1.0]));
        let mut vel = ParamStore::new();
        vel.push(Tensor::from_vec("w", &[2, 3], vec![0.0; 6]));
        vel.push(Tensor::from_vec("b", &[3], vec![0.5, 0.5, 0.5]));
        let mut trace = TrainTrace::default();
        trace.push(0, 2.5, "warmup");
        trace.push(5, 1.5, "joint");
        let mut kept = KeptMap::default();
        kept.removed
            .entry("w".to_string())
            .or_default()
            .insert(1, vec![0, 2]);
        TrainCkpt {
            model: "mlp_tiny".into(),
            step: 6,
            total_steps: 40,
            seed: 17,
            params,
            opt_name: "sgd".into(),
            opt_scalar: 0,
            opt_stores: vec![vel],
            q: vec![QParams::init(1.0, 8.0), QParams::init(0.5, 6.0)],
            qasso: QassoState {
                step_count: 6,
                bu_cur: 9.5,
                pruned: vec![true, false, true],
                redundant: vec![1],
                gamma: vec![0.0, 0.25, 0.0],
                gamma_scale: vec![1.0, 0.5],
            },
            batch: BatchIterState {
                order: vec![2, 0, 1, 3],
                pos: 2,
                bs: 2,
                rng_state: 0xDEADBEEF,
                rng_spare: Some(-0.37),
            },
            trace,
            losses: vec![2.5, 2.2, 2.0, 1.8, 1.6, 1.5],
            kept,
            replans: vec![4],
        }
    }

    #[test]
    fn round_trip_is_lossless() {
        let a = sample();
        let b = TrainCkpt::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(b.model, a.model);
        assert_eq!((b.step, b.total_steps, b.seed), (a.step, a.total_steps, a.seed));
        for (x, y) in b.params.tensors.iter().zip(&a.params.tensors) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.shape, y.shape);
            let xb: Vec<u32> = x.data.iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u32> = y.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(xb, yb);
        }
        assert_eq!(b.opt_stores.len(), 1);
        assert_eq!(b.opt_stores[0].tensors[1].data, a.opt_stores[0].tensors[1].data);
        assert_eq!(b.q.len(), 2);
        assert_eq!(b.q[1].d.to_bits(), a.q[1].d.to_bits());
        assert_eq!(b.qasso.pruned, a.qasso.pruned);
        assert_eq!(b.qasso.redundant, a.qasso.redundant);
        assert_eq!(b.qasso.bu_cur.to_bits(), a.qasso.bu_cur.to_bits());
        assert_eq!(b.batch.order, a.batch.order);
        assert_eq!(b.batch.rng_state, a.batch.rng_state);
        assert_eq!(
            b.batch.rng_spare.unwrap().to_bits(),
            a.batch.rng_spare.unwrap().to_bits()
        );
        assert_eq!(b.trace.steps, a.trace.steps);
        assert_eq!(b.trace.stages, a.trace.stages);
        assert_eq!(b.losses, a.losses);
        assert_eq!(b.kept.removed, a.kept.removed);
        assert_eq!(b.replans, a.replans);
    }

    #[test]
    fn reader_rejects_bad_magic() {
        let mut b = sample().to_bytes();
        b[0] = b'X';
        let err = TrainCkpt::from_bytes(&b).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn reader_rejects_unknown_version() {
        let mut b = sample().to_bytes();
        b[4] = 99;
        let err = TrainCkpt::from_bytes(&b).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn reader_rejects_truncation_at_every_length() {
        let full = sample().to_bytes();
        // every strict prefix must fail, never panic or best-effort parse
        for cut in [6, 20, full.len() / 3, full.len() / 2, full.len() - 1] {
            let err = TrainCkpt::from_bytes(&full[..cut]).unwrap_err().to_string();
            assert!(
                err.contains("truncated") || err.contains("need"),
                "cut {cut}: {err}"
            );
        }
    }

    #[test]
    fn reader_rejects_trailing_bytes() {
        let mut b = sample().to_bytes();
        b.push(0);
        let err = TrainCkpt::from_bytes(&b).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn reader_rejects_optimizer_store_mismatch() {
        let mut c = sample();
        c.opt_stores[0].tensors[0].shape = vec![3, 2];
        let err = TrainCkpt::from_bytes(&c.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("mirror"), "{err}");
    }

    #[test]
    fn reader_rejects_unknown_slice_map_tensor() {
        let mut c = sample();
        let idxs = c.kept.removed.remove("w").unwrap();
        c.kept.removed.insert("nope".into(), idxs);
        let err = TrainCkpt::from_bytes(&c.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("unknown tensor"), "{err}");
    }

    #[test]
    fn reader_rejects_loss_history_mismatch() {
        let mut c = sample();
        c.losses.pop();
        let err = TrainCkpt::from_bytes(&c.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("loss history"), "{err}");
    }
}
