//! Training coordinator: the Layer-3 event loop.
//!
//! A `Trainer` owns an execution backend (PJRT or native — see
//! `runtime::Backend`; the native interpreter serves every zoo family, so
//! CNN and transformer runs are hermetic), the synthetic dataset and the
//! QASSO optimizer state and drives the full GETA pipeline:
//!
//!   batch -> backend train_step (loss+grads) -> QASSO update ->
//!   stage transitions -> eval sweeps -> subnet construction -> report.
//!
//! Layer costs for BOPs accounting are derived from the lowered program's
//! real op shapes (`metrics::layer_costs` -> `runtime::lowering`), so the
//! reported compression always describes the graph the backend executed.
//!
//! Every native step runs through the planned executor (`runtime::exec`):
//! shapes resolved once per model, buffers recycled across steps, and the
//! tiled contraction kernels honoring the process-wide `GETA_THREADS` /
//! `--threads` worker budget — with results bitwise identical at any
//! thread count, so a trained run is reproducible regardless of how many
//! cores it was given.
//!
//! Baselines (rust/src/baselines/) reuse the same loop through the
//! `Compressor` trait, so every method in every paper table runs on an
//! identical substrate.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::data::{BatchIter, SynthData};
use crate::graph;
use crate::metrics::{self, bops::LayerCost, EvalAccum, TrainTrace};
use crate::optim::qasso::{Qasso, StageMask};
use crate::optim::make_optimizer;
use crate::quant::QParams;
use crate::runtime::Backend;
use crate::subnet;
use crate::tensor::ParamStore;

/// Pluggable compression method (GETA or a baseline).
pub trait Compressor {
    fn name(&self) -> String;

    /// One optimizer update given the AOT step's gradients.
    fn step(
        &mut self,
        params: &mut ParamStore,
        q: &mut Vec<QParams>,
        grads: &ParamStore,
        qgrads: &[(f32, f32, f32)],
        lr: f32,
        step: usize,
    );

    /// Total steps this method wants.
    fn total_steps(&self) -> usize;

    /// Group-level pruned mask (structured methods).
    fn pruned_mask(&self) -> Option<&[bool]>;

    /// Extra MAC density factor for unstructured methods (1.0 otherwise).
    fn unstructured_density(&self) -> f64 {
        1.0
    }

    /// Post-training hook (e.g. PTQ) before the final eval.
    fn finalize(&mut self, _params: &mut ParamStore, _q: &mut Vec<QParams>) {}

    fn stage_name(&self, _step: usize) -> &'static str {
        "train"
    }
}

/// GETA = QASSO driven by the QADG search space.
pub struct GetaCompressor {
    pub qasso: Qasso,
}

impl GetaCompressor {
    pub fn new(
        engine: &dyn Backend,
        exp: &ExperimentConfig,
        mask: StageMask,
    ) -> Result<GetaCompressor> {
        let space = graph::search_space_for(&engine.manifest().config)?;
        let params = engine.init_params(exp.seed);
        let base = make_optimizer(&exp.optimizer, exp.weight_decay, exp.momentum);
        let mut qasso = Qasso::new(
            exp.qasso.clone(),
            space.groups,
            &engine.site_specs(),
            base,
            &params,
        );
        qasso.mask = mask;
        Ok(GetaCompressor { qasso })
    }
}

impl Compressor for GetaCompressor {
    fn name(&self) -> String {
        "GETA".into()
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        q: &mut Vec<QParams>,
        grads: &ParamStore,
        qgrads: &[(f32, f32, f32)],
        lr: f32,
        _step: usize,
    ) {
        self.qasso.step(params, q, grads, qgrads, lr);
    }

    fn total_steps(&self) -> usize {
        self.qasso.cfg.total_steps()
    }

    fn pruned_mask(&self) -> Option<&[bool]> {
        Some(self.qasso.pruned_mask())
    }

    fn stage_name(&self, _step: usize) -> &'static str {
        self.qasso.stage().name()
    }
}

/// Result of one full run — the row every paper table is built from.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub method: String,
    pub model: String,
    /// Primary metric: accuracy % (cls/lm) or EM % (span).
    pub accuracy: f64,
    pub em: Option<f64>,
    pub f1: Option<f64>,
    /// Per-family accuracies (lm task, Fig. 3).
    pub per_family: Vec<f64>,
    pub rel_bops: f64,
    pub avg_bits: f64,
    pub group_sparsity: f64,
    pub param_sparsity: f64,
    pub trace: TrainTrace,
    pub final_loss: f64,
}

/// A finished run plus the trained state the deployment path consumes.
#[derive(Debug)]
pub struct Trained {
    pub result: RunResult,
    pub params: ParamStore,
    pub q: Vec<QParams>,
}

pub struct Trainer {
    pub engine: Box<dyn Backend>,
    pub exp: ExperimentConfig,
    pub train_data: SynthData,
    pub eval_data: SynthData,
    pub costs: Vec<LayerCost>,
    pub verbose: bool,
}

impl Trainer {
    pub fn new(art_dir: &std::path::Path, exp: ExperimentConfig) -> Result<Trainer> {
        let engine = crate::runtime::load_backend(art_dir, &exp.model)?;
        let (train_data, eval_data) =
            SynthData::for_model(&engine.manifest().config, exp.n_train, exp.n_eval, exp.seed + 1);
        let costs = metrics::layer_costs(&engine.manifest().config)?;
        Ok(Trainer {
            engine,
            exp,
            train_data,
            eval_data,
            costs,
            verbose: false,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.engine.manifest().batch.batch_size()
    }

    /// Run a compression method end to end and report.
    pub fn run(&self, method: &mut dyn Compressor) -> Result<RunResult> {
        Ok(self.run_trained(method)?.result)
    }

    /// Like [`run`](Self::run), but also hands back the trained parameters
    /// and quantizer rows — the inputs the deployment path (`geta export`,
    /// `deploy::export_to_file`) needs to build a `.geta` artifact.
    pub fn run_trained(&self, method: &mut dyn Compressor) -> Result<Trained> {
        let mut params = self.engine.init_params(self.exp.seed);
        let mut q = self
            .engine
            .init_qparams(&params, self.exp.qasso.init_bits);
        let sched = self.exp.schedule();
        let mut iter = BatchIter::new(self.train_data.len(), self.batch_size(), self.exp.seed + 7);
        let mut trace = TrainTrace::default();
        let total = method.total_steps();
        for step in 0..total {
            let idxs = iter.next_batch();
            let (x, y) = self.train_data.batch(&idxs);
            let out = {
                let _g = crate::obs::span("train", "train_step");
                self.engine.train_step(&params, &q, &x, &y)?
            };
            {
                let _g = crate::obs::span("train", "optim_step");
                method.step(&mut params, &mut q, &out.grads, &out.qgrads, sched.lr(step), step);
            }
            if step % self.exp.log_every == 0 || step + 1 == total {
                trace.push(step, out.loss, method.stage_name(step));
                if self.verbose {
                    println!(
                        "  [{:>5}/{total}] {:<10} loss {:.4} bits {:.1}",
                        step,
                        method.stage_name(step),
                        out.loss,
                        Qasso::avg_bits(&q)
                    );
                }
            }
        }
        method.finalize(&mut params, &mut q);
        let result = self.report(method, &params, &q, trace)?;
        Ok(Trained { result, params, q })
    }

    fn report(
        &self,
        method: &dyn Compressor,
        params: &ParamStore,
        q: &[QParams],
        trace: TrainTrace,
    ) -> Result<RunResult> {
        let eval = self.evaluate(params, q)?;
        // compression accounting
        let space = graph::search_space_for(&self.engine.manifest().config)?;
        let ngroups = space.groups.len();
        let default_mask = vec![false; ngroups];
        let pruned = method.pruned_mask().unwrap_or(&default_mask);
        let cm = subnet::construct(
            params,
            &space.groups,
            pruned,
            &self.costs,
            &self.engine.site_specs(),
            q,
        );
        let mut rel = cm.bops.rel_percent();
        // unstructured methods carry their density in MACs, not slicing
        rel *= method.unstructured_density();
        let group_sparsity =
            pruned.iter().filter(|&&p| p).count() as f64 / ngroups.max(1) as f64;
        Ok(RunResult {
            method: method.name(),
            model: self.exp.model.clone(),
            accuracy: eval.0,
            em: eval.1,
            f1: eval.2,
            per_family: eval.3,
            rel_bops: rel,
            avg_bits: cm.avg_bits as f64,
            group_sparsity,
            param_sparsity: cm.param_sparsity(),
            final_loss: trace.tail_mean(3),
            trace,
        })
    }

    /// Full eval sweep. Returns (primary metric %, EM, F1, per-family accs).
    #[allow(clippy::type_complexity)]
    pub fn evaluate(
        &self,
        params: &ParamStore,
        q: &[QParams],
    ) -> Result<(f64, Option<f64>, Option<f64>, Vec<f64>)> {
        let bs = self.batch_size();
        let batches = BatchIter::eval_batches(self.eval_data.len(), bs);
        let mut acc = EvalAccum::default();
        let mut preds: Vec<(i32, i32)> = Vec::new();
        let mut gold: Vec<(i32, i32)> = Vec::new();
        // per-family accumulation for LM
        let mut fam_correct: Vec<f64> = Vec::new();
        let mut fam_total: Vec<f64> = Vec::new();
        for idxs in &batches {
            let (x, y) = self.eval_data.batch(idxs);
            let out = self.engine.eval_step(params, q, &x, &y)?;
            acc.add(out.loss, out.metric, self.eval_data.metric_denom(idxs));
            if let SynthData::Spans(d) = &self.eval_data {
                let ps = &out.extra[0];
                let pe = &out.extra[1];
                for (k, &i) in idxs.iter().enumerate() {
                    preds.push((ps[k] as i32, pe[k] as i32));
                    gold.push(d.spans[i]);
                }
            }
            if let SynthData::Lm(d) = &self.eval_data {
                // attribute whole-batch correctness to families by running
                // per-family batches below instead; cheap approximation:
                // accumulate per dominant family of the batch
                let _ = d;
            }
        }
        // LM per-family sweep (Fig. 3): group eval indices by family
        if let SynthData::Lm(d) = &self.eval_data {
            let fams = d.families;
            fam_correct = vec![0.0; fams];
            fam_total = vec![0.0; fams];
            for fam in 0..fams {
                let idxs: Vec<usize> = (0..d.n).filter(|&i| d.family_of[i] == fam).collect();
                for chunk in idxs.chunks(bs) {
                    if chunk.len() < bs {
                        break;
                    }
                    let (x, y) = self.eval_data.batch(chunk);
                    let out = self.engine.eval_step(params, q, &x, &y)?;
                    fam_correct[fam] += out.metric as f64;
                    fam_total[fam] += self.eval_data.metric_denom(chunk);
                }
            }
        }
        match &self.eval_data {
            SynthData::Images(_) => Ok((acc.accuracy(), None, None, vec![])),
            SynthData::Spans(_) => {
                let (em, f1) = metrics::span_em_f1(&preds, &gold);
                Ok((em, Some(em), Some(f1), vec![]))
            }
            SynthData::Lm(_) => {
                let per_family: Vec<f64> = fam_correct
                    .iter()
                    .zip(&fam_total)
                    .map(|(c, t)| 100.0 * c / t.max(1.0))
                    .collect();
                Ok((acc.accuracy(), None, None, per_family))
            }
        }
    }
}
