//! Training coordinator: the Layer-3 event loop.
//!
//! A `Trainer` owns an execution backend (PJRT or native — see
//! `runtime::Backend`; the native interpreter serves every zoo family, so
//! CNN and transformer runs are hermetic), the synthetic dataset and the
//! QASSO optimizer state and drives the full GETA pipeline:
//!
//!   batch -> backend train_step (loss+grads) -> QASSO update ->
//!   stage transitions -> eval sweeps -> subnet construction -> report.
//!
//! Layer costs for BOPs accounting are derived from the lowered program's
//! real op shapes (`metrics::layer_costs` -> `runtime::lowering`), so the
//! reported compression always describes the graph the backend executed.
//!
//! Every native step runs through the planned executor (`runtime::exec`):
//! shapes resolved once per model, buffers recycled across steps, and the
//! tiled contraction kernels honoring the process-wide `GETA_THREADS` /
//! `--threads` worker budget — with results bitwise identical at any
//! thread count, so a trained run is reproducible regardless of how many
//! cores it was given.
//!
//! Baselines (rust/src/baselines/) reuse the same loop through the
//! `Compressor` trait, so every method in every paper table runs on an
//! identical substrate.

pub mod ckpt;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::data::{BatchIter, SynthData};
use crate::graph;
use crate::metrics::{self, bops::LayerCost, EvalAccum, TrainTrace};
use crate::optim::qasso::{Qasso, StageMask};
use crate::optim::make_optimizer;
use crate::quant::QParams;
use crate::runtime::lowering::{OpKind, Program};
use crate::runtime::{Backend, NativeEngine};
use crate::subnet::{self, KeptMap};
use crate::tensor::ParamStore;

/// Pluggable compression method (GETA or a baseline).
pub trait Compressor {
    fn name(&self) -> String;

    /// One optimizer update given the AOT step's gradients.
    fn step(
        &mut self,
        params: &mut ParamStore,
        q: &mut Vec<QParams>,
        grads: &ParamStore,
        qgrads: &[(f32, f32, f32)],
        lr: f32,
        step: usize,
    );

    /// Total steps this method wants.
    fn total_steps(&self) -> usize;

    /// Group-level pruned mask (structured methods).
    fn pruned_mask(&self) -> Option<&[bool]>;

    /// Extra MAC density factor for unstructured methods (1.0 otherwise).
    fn unstructured_density(&self) -> f64 {
        1.0
    }

    /// Post-training hook (e.g. PTQ) before the final eval.
    fn finalize(&mut self, _params: &mut ParamStore, _q: &mut Vec<QParams>) {}

    fn stage_name(&self, _step: usize) -> &'static str {
        "train"
    }

    /// The QASSO state, when this method is GETA — the shrink-as-you-train
    /// re-planner and the checkpoint path need its forgetting schedule,
    /// prune groups and base-optimizer state. Baselines keep `None` and
    /// train dense without checkpoint support.
    fn qasso_mut(&mut self) -> Option<&mut Qasso> {
        None
    }
}

/// GETA = QASSO driven by the QADG search space.
pub struct GetaCompressor {
    pub qasso: Qasso,
}

impl GetaCompressor {
    pub fn new(
        engine: &dyn Backend,
        exp: &ExperimentConfig,
        mask: StageMask,
    ) -> Result<GetaCompressor> {
        let space = graph::search_space_for(&engine.manifest().config)?;
        let params = engine.init_params(exp.seed);
        let base = make_optimizer(&exp.optimizer, exp.weight_decay, exp.momentum);
        let mut qasso = Qasso::new(
            exp.qasso.clone(),
            space.groups,
            &engine.site_specs(),
            base,
            &params,
        );
        qasso.mask = mask;
        Ok(GetaCompressor { qasso })
    }
}

impl Compressor for GetaCompressor {
    fn name(&self) -> String {
        "GETA".into()
    }

    fn step(
        &mut self,
        params: &mut ParamStore,
        q: &mut Vec<QParams>,
        grads: &ParamStore,
        qgrads: &[(f32, f32, f32)],
        lr: f32,
        _step: usize,
    ) {
        self.qasso.step(params, q, grads, qgrads, lr);
    }

    fn total_steps(&self) -> usize {
        self.qasso.cfg.total_steps()
    }

    fn pruned_mask(&self) -> Option<&[bool]> {
        Some(self.qasso.pruned_mask())
    }

    fn stage_name(&self, _step: usize) -> &'static str {
        self.qasso.stage().name()
    }

    fn qasso_mut(&mut self) -> Option<&mut Qasso> {
        Some(&mut self.qasso)
    }
}

/// Knobs for [`Trainer::run_trained_opts`] — the shrink-as-you-train
/// re-planner and the `.getackpt` checkpoint cadence. `Default` reproduces
/// the plain dense-masked [`Trainer::run_trained`] loop exactly.
#[derive(Debug, Clone, Default)]
pub struct TrainOpts {
    /// Rebuild the executor Plan on the sliced subnet after every prune
    /// commit (bitwise identical to dense-masked training; see module docs).
    pub replan: bool,
    /// Write `.getackpt` checkpoints to this path.
    pub ckpt: Option<std::path::PathBuf>,
    /// Checkpoint every N completed steps (0 = only at halt/finish).
    pub ckpt_every: usize,
    /// Resume from a `.getackpt` written by a previous run.
    pub resume: Option<std::path::PathBuf>,
    /// Stop after this many completed steps (writes a final checkpoint
    /// when `ckpt` is set); the run reports `halted` instead of evaluating.
    pub halt_at: Option<usize>,
}

/// Result of one full run — the row every paper table is built from.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub method: String,
    pub model: String,
    /// Primary metric: accuracy % (cls/lm) or EM % (span).
    pub accuracy: f64,
    pub em: Option<f64>,
    pub f1: Option<f64>,
    /// Per-family accuracies (lm task, Fig. 3).
    pub per_family: Vec<f64>,
    pub rel_bops: f64,
    pub avg_bits: f64,
    pub group_sparsity: f64,
    pub param_sparsity: f64,
    pub trace: TrainTrace,
    pub final_loss: f64,
}

/// A finished run plus the trained state the deployment path consumes.
/// `params` is always in DENSE coordinates (shrink-sliced tensors are
/// zero-expanded back), so report/export/deploy run unchanged.
#[derive(Debug)]
pub struct Trained {
    pub result: RunResult,
    pub params: ParamStore,
    pub q: Vec<QParams>,
    /// Per-step training losses for every step of the run (resumed runs
    /// include the pre-resume history, so the curve is always complete).
    pub losses: Vec<f32>,
    /// Step counts after which the executor plan was rebuilt on the
    /// shrunken subnet (empty for dense-masked runs).
    pub replans: Vec<usize>,
    /// True when the run stopped at `TrainOpts::halt_at` before the
    /// schedule finished — `result` then carries only the trace.
    pub halted: bool,
}

pub struct Trainer {
    pub engine: Box<dyn Backend>,
    pub exp: ExperimentConfig,
    pub train_data: SynthData,
    pub eval_data: SynthData,
    pub costs: Vec<LayerCost>,
    pub verbose: bool,
}

impl Trainer {
    pub fn new(art_dir: &std::path::Path, exp: ExperimentConfig) -> Result<Trainer> {
        let engine = crate::runtime::load_backend(art_dir, &exp.model)?;
        let (train_data, eval_data) =
            SynthData::for_model(&engine.manifest().config, exp.n_train, exp.n_eval, exp.seed + 1);
        let costs = metrics::layer_costs(&engine.manifest().config)?;
        Ok(Trainer {
            engine,
            exp,
            train_data,
            eval_data,
            costs,
            verbose: false,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.engine.manifest().batch.batch_size()
    }

    /// Run a compression method end to end and report.
    pub fn run(&self, method: &mut dyn Compressor) -> Result<RunResult> {
        Ok(self.run_trained(method)?.result)
    }

    /// Like [`run`](Self::run), but also hands back the trained parameters
    /// and quantizer rows — the inputs the deployment path (`geta export`,
    /// `deploy::export_to_file`) needs to build a `.geta` artifact.
    pub fn run_trained(&self, method: &mut dyn Compressor) -> Result<Trained> {
        self.run_trained_opts(method, &TrainOpts::default())
    }

    /// The full training loop with shrink-as-you-train re-planning and
    /// `.getackpt` checkpointing (see [`TrainOpts`]).
    ///
    /// With `replan` set, every QASSO prune commit triggers a re-plan:
    /// the cumulative kept map is rebuilt from the ORIGINAL groups, the
    /// live parameters and base-optimizer stores are sliced to kept
    /// channels, QASSO's group index is rebound, and a fresh executor
    /// Plan is built on the shrunken program. The switch is bit-exact —
    /// pruned groups' output-side members are exact zeros, every GEMM
    /// accumulates in a strict k-ascending f64 fold, and elementwise
    /// optimizer updates have no cross terms — so losses, eval logits and
    /// all surviving parameter/optimizer values stay bitwise identical to
    /// the dense-masked run (CI diffs both at 1 and 4 threads).
    pub fn run_trained_opts(
        &self,
        method: &mut dyn Compressor,
        opts: &TrainOpts,
    ) -> Result<Trained> {
        let total = method.total_steps();
        let sched = self.exp.schedule();
        let needs_qasso = opts.ckpt.is_some() || opts.resume.is_some();
        anyhow::ensure!(
            !needs_qasso || method.qasso_mut().is_some(),
            "--ckpt/--resume support the GETA compressor only (method `{}` has no \
             checkpointable state)",
            method.name()
        );
        // shrink support is gated on (a) a native backend exposing its
        // lowered program and (b) an op set whose kernels are proven
        // slice-invariant (LayerNorm divides by channel count, so
        // transformers train dense-masked).
        let orig_program = self.engine.as_native().map(|e| e.program().clone());
        let can_shrink = orig_program.as_ref().map(|p| replan_supported(p)).unwrap_or(false);
        if opts.replan && !can_shrink && self.verbose {
            println!("  --replan: program not slice-invariant here; training dense-masked");
        }

        // ---------------- state: fresh, or restored from a checkpoint
        let mut params;
        let mut q;
        let mut iter;
        let mut trace;
        let mut losses: Vec<f32>;
        let mut replans: Vec<usize>;
        let mut kept = KeptMap::default();
        let mut shrunk: Option<NativeEngine> = None;
        let mut start = 0usize;
        if let Some(path) = &opts.resume {
            let ck = ckpt::TrainCkpt::load(path)?;
            self.validate_ckpt(&ck, method, total)?;
            start = ck.step as usize;
            params = ck.params;
            q = ck.q;
            iter = BatchIter::from_state(ck.batch);
            trace = ck.trace;
            losses = ck.losses;
            replans = ck.replans.iter().map(|&r| r as usize).collect();
            kept = ck.kept;
            let qasso = method.qasso_mut().expect("validated above");
            qasso.restore_ckpt_state(ck.qasso);
            qasso.base_optimizer_mut().set_scalar_state(ck.opt_scalar);
            qasso.base_optimizer_mut().set_state_stores(ck.opt_stores);
            if !kept.removed.is_empty() {
                qasso.rebind(&kept, &params);
                let prog = orig_program.as_ref().ok_or_else(|| {
                    anyhow::anyhow!(
                        "checkpoint holds a sliced subnet but backend `{}` cannot re-plan",
                        self.engine.platform()
                    )
                })?;
                let sliced_prog = subnet::propagate_slices(prog, &params)?;
                shrunk = Some(NativeEngine::with_program(
                    self.engine.manifest().clone(),
                    sliced_prog,
                ));
            }
            if self.verbose {
                println!(
                    "  resumed {} at step {start}/{total} ({} re-plans so far)",
                    path.display(),
                    replans.len()
                );
            }
        } else {
            params = self.engine.init_params(self.exp.seed);
            q = self.engine.init_qparams(&params, self.exp.qasso.init_bits);
            iter = BatchIter::new(self.train_data.len(), self.batch_size(), self.exp.seed + 7);
            trace = TrainTrace::default();
            losses = Vec::with_capacity(total);
            replans = Vec::new();
        }
        let mut pruned_seen = method
            .qasso_mut()
            .map(|qa| qa.pruned_count())
            .unwrap_or(0);

        // ---------------- the step loop
        for step in start..total {
            let idxs = iter.next_batch();
            let (x, y) = self.train_data.batch(&idxs);
            let live: &dyn Backend = match &shrunk {
                Some(e) => e,
                None => self.engine.as_ref(),
            };
            let out = {
                let _g = crate::obs::span("train", "train_step");
                live.train_step(&params, &q, &x, &y)?
            };
            {
                let _g = crate::obs::span("train", "optim_step");
                method.step(&mut params, &mut q, &out.grads, &out.qgrads, sched.lr(step), step);
            }
            losses.push(out.loss);
            if step % self.exp.log_every == 0 || step + 1 == total {
                trace.push(step, out.loss, method.stage_name(step));
                if self.verbose {
                    println!(
                        "  [{:>5}/{total}] {:<10} loss {:.4} bits {:.1}",
                        step,
                        method.stage_name(step),
                        out.loss,
                        Qasso::avg_bits(&q)
                    );
                }
            }
            // re-plan after a prune commit: the NEXT step runs shrunken
            if let Some(qasso) = method.qasso_mut() {
                let live_groups = qasso.n_groups() - qasso.pruned_count();
                crate::obs::metrics::global()
                    .gauge("geta_train_live_groups")
                    .set(live_groups as i64);
                if opts.replan && can_shrink && qasso.pruned_count() > pruned_seen {
                    pruned_seen = qasso.pruned_count();
                    match replan(
                        orig_program.as_ref().expect("can_shrink implies native"),
                        self.engine.manifest(),
                        qasso,
                        &mut params,
                        &kept,
                    ) {
                        Ok((new_kept, engine)) => {
                            kept = new_kept;
                            shrunk = Some(engine);
                            replans.push(step + 1);
                            if self.verbose {
                                println!(
                                    "  [{:>5}/{total}] re-plan: {} live groups, {} params",
                                    step + 1,
                                    live_groups,
                                    params.total_params()
                                );
                            }
                        }
                        Err(e) => {
                            // safe fallback: keep training dense-masked
                            eprintln!("re-plan at step {} failed ({e:#}); staying dense", step + 1);
                        }
                    }
                }
            }
            // checkpoint cadence + halt
            let done = step + 1;
            if let Some(path) = &opts.ckpt {
                let due = (opts.ckpt_every > 0 && done % opts.ckpt_every == 0)
                    || opts.halt_at == Some(done)
                    || done == total;
                if due {
                    self.save_ckpt(path, method, done, total, &params, &q, &iter, &trace, &losses, &kept, &replans)?;
                }
            }
            if opts.halt_at == Some(done) && done < total {
                let result = RunResult {
                    method: method.name(),
                    model: self.exp.model.clone(),
                    accuracy: 0.0,
                    em: None,
                    f1: None,
                    per_family: vec![],
                    rel_bops: 0.0,
                    avg_bits: Qasso::avg_bits(&q) as f64,
                    group_sparsity: 0.0,
                    param_sparsity: 0.0,
                    final_loss: trace.tail_mean(3),
                    trace,
                };
                let params = expand_store(&kept, &params);
                return Ok(Trained {
                    result,
                    params,
                    q,
                    losses,
                    replans,
                    halted: true,
                });
            }
        }
        // hand dense-shaped params to finalize/report/export
        let mut params = expand_store(&kept, &params);
        method.finalize(&mut params, &mut q);
        let result = self.report(method, &params, &q, trace)?;
        Ok(Trained {
            result,
            params,
            q,
            losses,
            replans,
            halted: false,
        })
    }

    /// Cross-check a loaded checkpoint against this trainer + method
    /// before restoring any state.
    fn validate_ckpt(
        &self,
        ck: &ckpt::TrainCkpt,
        method: &mut dyn Compressor,
        total: usize,
    ) -> Result<()> {
        anyhow::ensure!(
            ck.model == self.exp.model,
            "checkpoint is for model `{}`, not `{}`",
            ck.model,
            self.exp.model
        );
        anyhow::ensure!(
            ck.total_steps as usize == total,
            "checkpoint schedule has {} steps, this config has {total}",
            ck.total_steps
        );
        anyhow::ensure!(
            ck.seed == self.exp.seed,
            "checkpoint seed {} vs config seed {}",
            ck.seed,
            self.exp.seed
        );
        let qsites = self.engine.manifest().qsites.len();
        anyhow::ensure!(
            ck.q.len() == qsites,
            "checkpoint has {} quant sites, model has {qsites}",
            ck.q.len()
        );
        let names: Vec<&str> = self
            .engine
            .manifest()
            .params
            .iter()
            .map(|(n, _)| n.as_str())
            .collect();
        anyhow::ensure!(
            ck.params.len() == names.len()
                && ck.params.tensors.iter().zip(&names).all(|(t, n)| t.name == *n),
            "checkpoint parameter names do not match model `{}`",
            self.exp.model
        );
        let qasso = method.qasso_mut().expect("checked by caller");
        anyhow::ensure!(
            ck.qasso.pruned.len() == qasso.n_groups(),
            "checkpoint has {} prune groups, model has {}",
            ck.qasso.pruned.len(),
            qasso.n_groups()
        );
        anyhow::ensure!(
            ck.qasso.gamma_scale.len() == qsites,
            "checkpoint has {} gamma scales, model has {qsites} sites",
            ck.qasso.gamma_scale.len()
        );
        anyhow::ensure!(
            ck.opt_name == qasso.base_optimizer().name(),
            "checkpoint optimizer `{}` vs configured `{}`",
            ck.opt_name,
            qasso.base_optimizer().name()
        );
        anyhow::ensure!(
            ck.batch.order.len() == self.train_data.len() && ck.batch.bs == self.batch_size(),
            "checkpoint batch state ({} samples, bs {}) does not match data ({} samples, bs {})",
            ck.batch.order.len(),
            ck.batch.bs,
            self.train_data.len(),
            self.batch_size()
        );
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn save_ckpt(
        &self,
        path: &std::path::Path,
        method: &mut dyn Compressor,
        done: usize,
        total: usize,
        params: &ParamStore,
        q: &[QParams],
        iter: &BatchIter,
        trace: &TrainTrace,
        losses: &[f32],
        kept: &KeptMap,
        replans: &[usize],
    ) -> Result<()> {
        let _g = crate::obs::span("train", "checkpoint");
        let qasso = method.qasso_mut().expect("checked at loop entry");
        let ck = ckpt::TrainCkpt {
            model: self.exp.model.clone(),
            step: done as u64,
            total_steps: total as u64,
            seed: self.exp.seed,
            params: params.clone(),
            opt_name: qasso.base_optimizer().name().to_string(),
            opt_scalar: qasso.base_optimizer().scalar_state(),
            opt_stores: qasso
                .base_optimizer()
                .state_stores()
                .into_iter()
                .cloned()
                .collect(),
            q: q.to_vec(),
            qasso: qasso.ckpt_state(),
            batch: iter.state(),
            trace: trace.clone(),
            losses: losses.to_vec(),
            kept: kept.clone(),
            replans: replans.iter().map(|&r| r as u64).collect(),
        };
        ck.write(path)
    }

    fn report(
        &self,
        method: &dyn Compressor,
        params: &ParamStore,
        q: &[QParams],
        trace: TrainTrace,
    ) -> Result<RunResult> {
        let eval = self.evaluate(params, q)?;
        // compression accounting
        let space = graph::search_space_for(&self.engine.manifest().config)?;
        let ngroups = space.groups.len();
        let default_mask = vec![false; ngroups];
        let pruned = method.pruned_mask().unwrap_or(&default_mask);
        let cm = subnet::construct(
            params,
            &space.groups,
            pruned,
            &self.costs,
            &self.engine.site_specs(),
            q,
        );
        let mut rel = cm.bops.rel_percent();
        // unstructured methods carry their density in MACs, not slicing
        rel *= method.unstructured_density();
        let group_sparsity =
            pruned.iter().filter(|&&p| p).count() as f64 / ngroups.max(1) as f64;
        Ok(RunResult {
            method: method.name(),
            model: self.exp.model.clone(),
            accuracy: eval.0,
            em: eval.1,
            f1: eval.2,
            per_family: eval.3,
            rel_bops: rel,
            avg_bits: cm.avg_bits as f64,
            group_sparsity,
            param_sparsity: cm.param_sparsity(),
            final_loss: trace.tail_mean(3),
            trace,
        })
    }

    /// Full eval sweep. Returns (primary metric %, EM, F1, per-family accs).
    #[allow(clippy::type_complexity)]
    pub fn evaluate(
        &self,
        params: &ParamStore,
        q: &[QParams],
    ) -> Result<(f64, Option<f64>, Option<f64>, Vec<f64>)> {
        let bs = self.batch_size();
        let batches = BatchIter::eval_batches(self.eval_data.len(), bs);
        let mut acc = EvalAccum::default();
        let mut preds: Vec<(i32, i32)> = Vec::new();
        let mut gold: Vec<(i32, i32)> = Vec::new();
        // per-family accumulation for LM
        let mut fam_correct: Vec<f64> = Vec::new();
        let mut fam_total: Vec<f64> = Vec::new();
        for idxs in &batches {
            let (x, y) = self.eval_data.batch(idxs);
            let out = self.engine.eval_step(params, q, &x, &y)?;
            acc.add(out.loss, out.metric, self.eval_data.metric_denom(idxs));
            if let SynthData::Spans(d) = &self.eval_data {
                let ps = &out.extra[0];
                let pe = &out.extra[1];
                for (k, &i) in idxs.iter().enumerate() {
                    preds.push((ps[k] as i32, pe[k] as i32));
                    gold.push(d.spans[i]);
                }
            }
            if let SynthData::Lm(d) = &self.eval_data {
                // attribute whole-batch correctness to families by running
                // per-family batches below instead; cheap approximation:
                // accumulate per dominant family of the batch
                let _ = d;
            }
        }
        // LM per-family sweep (Fig. 3): group eval indices by family
        if let SynthData::Lm(d) = &self.eval_data {
            let fams = d.families;
            fam_correct = vec![0.0; fams];
            fam_total = vec![0.0; fams];
            for fam in 0..fams {
                let idxs: Vec<usize> = (0..d.n).filter(|&i| d.family_of[i] == fam).collect();
                for chunk in idxs.chunks(bs) {
                    if chunk.len() < bs {
                        break;
                    }
                    let (x, y) = self.eval_data.batch(chunk);
                    let out = self.engine.eval_step(params, q, &x, &y)?;
                    fam_correct[fam] += out.metric as f64;
                    fam_total[fam] += self.eval_data.metric_denom(chunk);
                }
            }
        }
        match &self.eval_data {
            SynthData::Images(_) => Ok((acc.accuracy(), None, None, vec![])),
            SynthData::Spans(_) => {
                let (em, f1) = metrics::span_em_f1(&preds, &gold);
                Ok((em, Some(em), Some(f1), vec![]))
            }
            SynthData::Lm(_) => {
                let per_family: Vec<f64> = fam_correct
                    .iter()
                    .zip(&fam_total)
                    .map(|(c, t)| 100.0 * c / t.max(1.0))
                    .collect();
                Ok((acc.accuracy(), None, None, per_family))
            }
        }
    }
}

/// True when every op in the program has a slice-invariant kernel: dropping
/// exact-zero channels cannot change a bit of any output. LayerNorm (and
/// anything else normalizing by channel COUNT) is excluded — transformer
/// families keep training dense-masked.
pub fn replan_supported(prog: &Program) -> bool {
    prog.nodes.iter().all(|n| {
        matches!(
            n.op,
            OpKind::Input
                | OpKind::Linear { .. }
                | OpKind::Conv2d { .. }
                | OpKind::BatchNorm { .. }
                | OpKind::Relu
                | OpKind::ActQuant { .. }
                | OpKind::Add
                | OpKind::MaxPool2
                | OpKind::GlobalAvgPool
                | OpKind::Reshape
        )
    })
}

/// Zero-expand every tensor of a (possibly sliced) store back to dense
/// coordinates. A no-op clone when the kept map is empty.
fn expand_store(kept: &KeptMap, params: &ParamStore) -> ParamStore {
    let mut s = ParamStore::new();
    for t in &params.tensors {
        s.push(kept.expand(t));
    }
    s
}

/// One shrink re-plan. Builds the new cumulative kept map from the
/// ORIGINAL groups (monotone: old removed ⊆ new removed, so
/// `slice(expand(x))` is an exact incremental slice), slices params into a
/// fresh store, validates coherence via `propagate_slices`, and only then
/// commits: params and base-optimizer stores swap to the sliced shapes,
/// QASSO rebinds its group index, and a fresh Plan-bearing engine is
/// returned. On any error nothing has been mutated — the caller stays on
/// the dense plan.
fn replan(
    prog: &Program,
    manifest: &crate::runtime::Manifest,
    qasso: &mut Qasso,
    params: &mut ParamStore,
    kept_old: &KeptMap,
) -> Result<(KeptMap, NativeEngine)> {
    let fin = crate::obs::span("replan", "finalize");
    let new_kept = KeptMap::from_groups(qasso.orig_groups(), qasso.pruned_mask());
    drop(fin);
    let sl = crate::obs::span("replan", "slice");
    let mut sliced = ParamStore::new();
    for t in &params.tensors {
        sliced.push(new_kept.slice(&kept_old.expand(t)));
    }
    drop(sl);
    let rb = crate::obs::span("replan", "rebuild");
    let new_prog = subnet::propagate_slices(prog, &sliced)?;
    let engine = NativeEngine::with_program(manifest.clone(), new_prog);
    // ---- fallible work done; commit
    *params = sliced;
    for store in qasso.base_optimizer_mut().state_stores_mut() {
        let mut ns = ParamStore::new();
        for t in &store.tensors {
            ns.push(new_kept.slice(&kept_old.expand(t)));
        }
        *store = ns;
    }
    qasso.rebind(&new_kept, params);
    drop(rb);
    Ok((new_kept, engine))
}
