//! Deterministic synthetic datasets (offline substitutes for CIFAR10 /
//! ImageNet / SQuAD / common-sense suites — see DESIGN.md substitutions).
//!
//! Every generator is seeded and class-separable-but-noisy so accuracy
//! degrades smoothly as capacity is removed — the property the paper's
//! relative accuracy/BOPs comparisons need.

use crate::runtime::{BatchSpec, HostArray};
use crate::util::rng::Rng;

/// Synthetic image classification: each class is a mixture of a spatial
/// frequency pattern and a color bias, plus Gaussian noise.
pub struct SynthImages {
    pub size: usize,
    pub channels: usize,
    pub classes: usize,
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
}

impl SynthImages {
    /// `class_seed` fixes the class signatures (shared between the train
    /// and eval splits); `sample_seed` varies the draws.
    pub fn generate(n: usize, size: usize, channels: usize, classes: usize, noise: f32, class_seed: u64, sample_seed: u64) -> SynthImages {
        let mut sig_rng = Rng::new(class_seed);
        // per-class signature: frequency pair, phase, color vector
        let sigs: Vec<(f64, f64, f64, Vec<f32>)> = (0..classes)
            .map(|_| {
                let fx = 1.0 + sig_rng.uniform() * 3.0;
                let fy = 1.0 + sig_rng.uniform() * 3.0;
                let ph = sig_rng.uniform() * std::f64::consts::TAU;
                let color: Vec<f32> = (0..channels).map(|_| sig_rng.normal_f32(0.5)).collect();
                (fx, fy, ph, color)
            })
            .collect();
        let mut rng = Rng::new(sample_seed);
        let mut images = Vec::with_capacity(n * size * size * channels);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let cls = rng.below(classes);
            let (fx, fy, ph, color) = &sigs[cls];
            labels.push(cls as i32);
            for h in 0..size {
                for w in 0..size {
                    let arg = std::f64::consts::TAU
                        * (fx * h as f64 / size as f64 + fy * w as f64 / size as f64)
                        + ph;
                    let pat = arg.sin() as f32;
                    for c in 0..channels {
                        images.push(pat * 0.8 + color[c] + rng.normal_f32(noise));
                    }
                }
            }
        }
        SynthImages {
            size,
            channels,
            classes,
            images,
            labels,
            n,
        }
    }

    fn sample_numel(&self) -> usize {
        self.size * self.size * self.channels
    }

    pub fn batch(&self, idxs: &[usize]) -> (HostArray, HostArray) {
        let k = self.sample_numel();
        let mut x = Vec::with_capacity(idxs.len() * k);
        let mut y = Vec::with_capacity(idxs.len());
        for &i in idxs {
            x.extend_from_slice(&self.images[i * k..(i + 1) * k]);
            y.push(self.labels[i]);
        }
        (HostArray::F32(x), HostArray::I32(y))
    }
}

/// Synthetic span extraction ("SQuAD-mini"): sequences of random tokens;
/// a trigger token opens the answer span, a close token ends it; the gold
/// label is (start, end) of the span between them. The model must learn to
/// point at the delimiters — positional + lexical reasoning.
pub struct SynthSpans {
    pub vocab: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
    pub spans: Vec<(i32, i32)>,
    pub n: usize,
}

impl SynthSpans {
    pub const TRIGGER: i32 = 1;
    pub const CLOSE: i32 = 2;

    pub fn generate(n: usize, vocab: usize, seq_len: usize, seed: u64) -> SynthSpans {
        let mut rng = Rng::new(seed);
        let mut tokens = Vec::with_capacity(n * seq_len);
        let mut spans = Vec::with_capacity(n);
        for _ in 0..n {
            // real span: gold = (first TRIGGER, first CLOSE after it).
            // Decoy CLOSE tokens *before* the trigger and decoy TRIGGER
            // tokens *after* the close force order-sensitive reasoning —
            // a bag-of-tokens shortcut cannot solve the task.
            let start = 3 + rng.below(seq_len - 8);
            let len = 1 + rng.below((seq_len - start - 4).min(5));
            let end = start + len;
            let mut row = vec![0i32; seq_len];
            for (pos, slot) in row.iter_mut().enumerate() {
                *slot = if pos == start {
                    Self::TRIGGER
                } else if pos == end {
                    Self::CLOSE
                } else {
                    // body tokens from 3..vocab (0 is pad, 1/2 reserved)
                    3 + rng.below(vocab - 3) as i32
                };
            }
            // decoy CLOSE strictly before the trigger
            if start >= 2 {
                row[rng.below(start - 1) + 1] = Self::CLOSE;
            }
            // decoy TRIGGER strictly after the close
            if end + 2 < seq_len {
                row[end + 1 + rng.below(seq_len - end - 2) + 1 - 1] = Self::TRIGGER;
            }
            tokens.extend_from_slice(&row);
            spans.push((start as i32, end as i32));
        }
        SynthSpans {
            vocab,
            seq_len,
            tokens,
            spans,
            n,
        }
    }

    pub fn batch(&self, idxs: &[usize]) -> (HostArray, HostArray) {
        let s = self.seq_len;
        let mut x = Vec::with_capacity(idxs.len() * s);
        let mut y = Vec::with_capacity(idxs.len() * 2);
        for &i in idxs {
            x.extend_from_slice(&self.tokens[i * s..(i + 1) * s]);
            y.push(self.spans[i].0);
            y.push(self.spans[i].1);
        }
        (HostArray::I32(x), HostArray::I32(y))
    }

    pub fn gold(&self, idxs: &[usize]) -> Vec<(i32, i32)> {
        idxs.iter().map(|&i| self.spans[i]).collect()
    }
}

/// Synthetic language modelling with `families` distinct affine rules
/// (next = (a*prev + b) mod (vocab-8) + 8, with noise). Each family is a
/// "task" for the Fig. 3 common-sense-suite analog: per-family next-token
/// accuracy plays the role of per-benchmark scores.
pub struct SynthLm {
    pub vocab: usize,
    pub seq_len: usize,
    pub families: usize,
    pub tokens: Vec<i32>,
    pub family_of: Vec<usize>,
    pub n: usize,
}

impl SynthLm {
    pub fn generate(n: usize, vocab: usize, seq_len: usize, families: usize, noise: f64, rule_seed: u64, sample_seed: u64) -> SynthLm {
        let mut rule_rng = Rng::new(rule_seed);
        let body = vocab - 8;
        let rules: Vec<(usize, usize)> = (0..families)
            .map(|_| (1 + 2 * rule_rng.below(body / 2 - 1), rule_rng.below(body)))
            .collect();
        let mut rng = Rng::new(sample_seed);
        let mut tokens = Vec::with_capacity(n * seq_len);
        let mut family_of = Vec::with_capacity(n);
        for _ in 0..n {
            let fam = rng.below(families);
            family_of.push(fam);
            let (a, b) = rules[fam];
            // first token encodes the family (like a task prompt)
            let mut prev = rng.below(body);
            tokens.push((fam % 8) as i32);
            for _ in 1..seq_len {
                prev = if rng.uniform() < noise {
                    rng.below(body)
                } else {
                    (a * prev + b) % body
                };
                tokens.push((prev + 8) as i32);
            }
        }
        SynthLm {
            vocab,
            seq_len,
            families,
            tokens,
            family_of,
            n,
        }
    }

    /// x = tokens, y = next-token targets (shift left; last position masked).
    pub fn batch(&self, idxs: &[usize]) -> (HostArray, HostArray) {
        let s = self.seq_len;
        let mut x = Vec::with_capacity(idxs.len() * s);
        let mut y = Vec::with_capacity(idxs.len() * s);
        for &i in idxs {
            let row = &self.tokens[i * s..(i + 1) * s];
            x.extend_from_slice(row);
            y.extend_from_slice(&row[1..]);
            y.push(-1); // mask final position
        }
        (HostArray::I32(x), HostArray::I32(y))
    }
}

/// Task-agnostic dataset wrapper the coordinator consumes.
pub enum SynthData {
    Images(SynthImages),
    Spans(SynthSpans),
    Lm(SynthLm),
}

impl SynthData {
    /// Build train+eval splits for a model config (see configs/models/).
    pub fn for_model(cfg: &crate::util::json::Json, n_train: usize, n_eval: usize, seed: u64) -> (SynthData, SynthData) {
        let task = cfg.str_or("task", "image_cls");
        match task.as_str() {
            "image_cls" => {
                let img = cfg.get("image").cloned().unwrap_or(crate::util::json::Json::Null);
                let size = img.usize_or("size", 16);
                let ch = img.usize_or("channels", 3);
                let classes = cfg.usize_or("num_classes", 10);
                (
                    SynthData::Images(SynthImages::generate(n_train, size, ch, classes, 1.0, seed, seed ^ 1)),
                    SynthData::Images(SynthImages::generate(n_eval, size, ch, classes, 1.0, seed, seed ^ 0xEEE)),
                )
            }
            "span_qa" => {
                let v = cfg.usize_or("vocab", 128);
                let s = cfg.usize_or("seq_len", 32);
                (
                    SynthData::Spans(SynthSpans::generate(n_train, v, s, seed)),
                    SynthData::Spans(SynthSpans::generate(n_eval, v, s, seed ^ 0xEEE)),
                )
            }
            "lm" => {
                let v = cfg.usize_or("vocab", 128);
                let s = cfg.usize_or("seq_len", 32);
                (
                    SynthData::Lm(SynthLm::generate(n_train, v, s, 7, 0.15, seed, seed ^ 1)),
                    SynthData::Lm(SynthLm::generate(n_eval, v, s, 7, 0.15, seed, seed ^ 0xEEE)),
                )
            }
            other => panic!("unknown task {other}"),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            SynthData::Images(d) => d.n,
            SynthData::Spans(d) => d.n,
            SynthData::Lm(d) => d.n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn batch(&self, idxs: &[usize]) -> (HostArray, HostArray) {
        match self {
            SynthData::Images(d) => d.batch(idxs),
            SynthData::Spans(d) => d.batch(idxs),
            SynthData::Lm(d) => d.batch(idxs),
        }
    }

    /// Per-example metric denominator of one batch (for metric averaging):
    /// images: 1 per example; spans: 2 (start+end); lm: unmasked tokens.
    pub fn metric_denom(&self, idxs: &[usize]) -> f64 {
        match self {
            SynthData::Images(_) => idxs.len() as f64,
            SynthData::Spans(_) => 2.0 * idxs.len() as f64,
            SynthData::Lm(d) => (idxs.len() * (d.seq_len - 1)) as f64,
        }
    }
}

/// Epoch-shuffled batch index iterator.
pub struct BatchIter {
    order: Vec<usize>,
    pos: usize,
    bs: usize,
    rng: Rng,
}

impl BatchIter {
    pub fn new(n: usize, bs: usize, seed: u64) -> BatchIter {
        let mut rng = Rng::new(seed);
        let order = rng.permutation(n);
        BatchIter {
            order,
            pos: 0,
            bs,
            rng,
        }
    }

    /// Next batch of indices (reshuffles at epoch boundaries; always full).
    pub fn next_batch(&mut self) -> Vec<usize> {
        if self.pos + self.bs > self.order.len() {
            let n = self.order.len();
            self.order = self.rng.permutation(n);
            self.pos = 0;
        }
        let out = self.order[self.pos..self.pos + self.bs].to_vec();
        self.pos += self.bs;
        out
    }

    /// Sequential non-shuffled coverage (for eval): full batches only.
    pub fn eval_batches(n: usize, bs: usize) -> Vec<Vec<usize>> {
        (0..n / bs).map(|b| (b * bs..(b + 1) * bs).collect()).collect()
    }

    /// Full iterator state for checkpointing: the in-flight epoch order,
    /// the cursor, and the shuffle RNG. [`BatchIter::from_state`] rebuilds
    /// an iterator that emits exactly the batches this one would have.
    pub fn state(&self) -> BatchIterState {
        let (rng_state, rng_spare) = self.rng.state();
        BatchIterState {
            order: self.order.clone(),
            pos: self.pos,
            bs: self.bs,
            rng_state,
            rng_spare,
        }
    }

    /// Rebuild an iterator from [`BatchIter::state`] output.
    pub fn from_state(s: BatchIterState) -> BatchIter {
        BatchIter {
            order: s.order,
            pos: s.pos,
            bs: s.bs,
            rng: Rng::from_state(s.rng_state, s.rng_spare),
        }
    }
}

/// Serializable snapshot of a [`BatchIter`] (the `.getackpt` RNG-state
/// section).
#[derive(Debug, Clone)]
pub struct BatchIterState {
    pub order: Vec<usize>,
    pub pos: usize,
    pub bs: usize,
    pub rng_state: u64,
    pub rng_spare: Option<f64>,
}

/// Sanity helper: does a batch match the manifest's spec?
pub fn check_batch(spec: &BatchSpec, x: &HostArray, y: &HostArray) -> bool {
    let xn: usize = spec.x_shape.iter().product();
    let yn: usize = spec.y_shape.iter().product();
    x.len() == xn && y.len() == yn
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_shapes_and_determinism() {
        let d1 = SynthImages::generate(40, 8, 3, 10, 0.3, 7, 9);
        let d2 = SynthImages::generate(40, 8, 3, 10, 0.3, 7, 9);
        assert_eq!(d1.images, d2.images);
        assert_eq!(d1.images.len(), 40 * 8 * 8 * 3);
        assert!(d1.labels.iter().all(|&l| (0..10).contains(&l)));
        let (x, y) = d1.batch(&[0, 5, 39]);
        assert_eq!(x.len(), 3 * 8 * 8 * 3);
        assert_eq!(y.len(), 3);
    }

    #[test]
    fn images_classes_are_separable() {
        // nearest-centroid accuracy must beat chance by a wide margin
        let d = SynthImages::generate(400, 8, 3, 4, 0.3, 11, 12);
        let k = 8 * 8 * 3;
        let mut centroids = vec![vec![0.0f64; k]; 4];
        let mut counts = [0usize; 4];
        for i in 0..200 {
            let c = d.labels[i] as usize;
            counts[c] += 1;
            for j in 0..k {
                centroids[c][j] += d.images[i * k + j] as f64;
            }
        }
        for c in 0..4 {
            for v in centroids[c].iter_mut() {
                *v /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 200..400 {
            let mut best = (f64::MAX, 0);
            for c in 0..4 {
                let mut dist = 0.0;
                for j in 0..k {
                    let dd = d.images[i * k + j] as f64 - centroids[c][j];
                    dist += dd * dd;
                }
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == d.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / 200.0;
        assert!(acc > 0.6, "nearest-centroid acc {acc}");
    }

    #[test]
    fn spans_are_recoverable_from_delimiters() {
        let d = SynthSpans::generate(50, 64, 32, 3);
        for i in 0..50 {
            let (s, e) = d.spans[i];
            assert_eq!(d.tokens[i * 32 + s as usize], SynthSpans::TRIGGER);
            assert_eq!(d.tokens[i * 32 + e as usize], SynthSpans::CLOSE);
            assert!(s < e && (e as usize) < 32);
        }
        let (x, y) = d.batch(&[1, 2]);
        assert_eq!(x.len(), 64);
        assert_eq!(y.len(), 4);
    }

    #[test]
    fn lm_rules_are_predictive() {
        let d = SynthLm::generate(20, 128, 32, 4, 0.0, 5, 6);
        // with zero noise the sequence is deterministic given the rule
        let (x, y) = d.batch(&[0]);
        let (HostArray::I32(xs), HostArray::I32(ys)) = (&x, &y) else {
            panic!()
        };
        for p in 0..31 {
            assert_eq!(ys[p], xs[p + 1]);
        }
        assert_eq!(ys[31], -1);
    }

    #[test]
    fn batch_iter_epochs() {
        let mut it = BatchIter::new(10, 4, 1);
        let mut seen = Vec::new();
        for _ in 0..5 {
            let b = it.next_batch();
            assert_eq!(b.len(), 4);
            seen.extend(b);
        }
        assert!(seen.iter().all(|&i| i < 10));
        let ev = BatchIter::eval_batches(10, 4);
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[1], vec![4, 5, 6, 7]);
    }
}
