//! Packed-integer inference engine: executes an exported `.geta` model
//! over the **shrunk** (kept-channel-sliced) shapes.
//!
//! Load path: parse the container, dequantize every packed weight once
//! (`level * d` — bit-identical to the fake-quantized weights the training
//! interpreter multiplies), re-lower the embedded config through
//! `runtime::lowering`, then shrink the program's shapes to the sliced
//! parameter store via `subnet::propagate_slices`. The forward pass is
//! inference-only: no backward state, no per-step weight fake-quant — the
//! only quantization left at runtime is the activation sites, applied with
//! their learned (d, t, q_m).
//!
//! Batching: [`GetaEngine::infer`] splits the input into micro-batches
//! (default: the family's training batch size) and shards those
//! micro-batches across `std::thread` workers. Batch-statistics
//! normalization is computed **per micro-batch**, matching the training
//! interpreter's stateless-batchnorm semantics — which is exactly what
//! makes the parity obligation testable, and makes results independent of
//! the thread count (sharding only ever happens at micro-batch
//! boundaries).

use anyhow::{Context, Result};

use super::format::{GetaContainer, Payload, SiteKind};
use crate::graph::builders;
use crate::quant::{self, QParams};
use crate::runtime::lowering::{self, OpKind, Program};
use crate::runtime::HostArray;
use crate::subnet;
use crate::tensor::{
    self, batchnorm_rows, gelu, im2col, layernorm_rows, matmul, matmul_nt, softmax_rows,
    ParamStore, Tensor,
};
use crate::util::json::Json;

const NORM_EPS: f32 = 1e-5;

/// Input dtype the loaded model expects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputKind {
    F32,
    I32,
}

/// Borrowed view of one micro-batch of inputs.
enum In<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

pub struct GetaEngine {
    pub model: String,
    pub task: String,
    config: Json,
    /// Slice-propagated program, lowered with batch dim 1; the executor
    /// substitutes the runtime micro-batch size.
    program: Program,
    weights: ParamStore,
    /// Learned activation-quant parameters by q-row (None = weight site or
    /// quantization disabled, as in the dense-f32 baseline engine).
    act_q: Vec<Option<QParams>>,
    /// Apply activation quantization (false for the dense baseline).
    apply_act_quant: bool,
    /// Micro-batch size: normalization statistics and thread sharding both
    /// operate at this granularity.
    pub micro_batch: usize,
    /// Worker threads for [`infer`](Self::infer) (1 = sequential).
    pub threads: usize,
}

impl GetaEngine {
    pub fn load(path: &std::path::Path) -> Result<GetaEngine> {
        Self::from_container(&GetaContainer::read(path)?)
    }

    /// Build the engine from a parsed container: dequantize, re-lower,
    /// shrink. Site metadata is cross-checked against the config's own
    /// plan-order sites so a tampered container cannot mis-map q rows.
    pub fn from_container(c: &GetaContainer) -> Result<GetaEngine> {
        let config = c.config()?;
        let sites = builders::quant_site_specs(&config)?;
        anyhow::ensure!(
            sites.len() == c.sites.len(),
            "container has {} sites, config plans {}",
            c.sites.len(),
            sites.len()
        );
        for (i, (rec, spec)) in c.sites.iter().zip(&sites).enumerate() {
            anyhow::ensure!(
                rec.name == spec.name,
                "site {i}: container `{}` vs config plan `{}`",
                rec.name,
                spec.name
            );
            let want = if spec.param.is_some() {
                SiteKind::Weight
            } else {
                SiteKind::Act
            };
            anyhow::ensure!(rec.kind == want, "site {i} (`{}`): kind mismatch", rec.name);
        }
        let mut weights = ParamStore::new();
        for t in &c.tensors {
            let data = match &t.payload {
                Payload::F32(v) => v.clone(),
                Payload::Packed {
                    site,
                    min_level,
                    pack_bits,
                    bytes,
                    numel,
                } => {
                    // the site must be the one whose param names this tensor,
                    // or a swapped site index would dequantize with the wrong
                    // step d and produce silently wrong weights
                    anyhow::ensure!(
                        sites[*site as usize].param.as_deref() == Some(t.name.as_str()),
                        "tensor `{}`: packed payload references site {} (`{}`), not its own \
                         weight site",
                        t.name,
                        site,
                        c.sites[*site as usize].name
                    );
                    let d = c.sites[*site as usize].q.d;
                    let levels =
                        super::format::unpack_levels(bytes, *numel, *min_level, *pack_bits)?;
                    levels.iter().map(|&l| l as f32 * d).collect()
                }
            };
            anyhow::ensure!(
                data.len() == t.numel(),
                "tensor `{}`: {} values for shape {:?}",
                t.name,
                data.len(),
                t.shape
            );
            weights.push(Tensor::from_vec(&t.name, &t.shape, data));
        }
        let base = lowering::lower(&config, &sites, 1)?;
        let program = subnet::propagate_slices(&base, &weights)
            .context("sliced shapes do not propagate coherently")?;
        let mut act_q = vec![None; sites.len()];
        for (i, rec) in c.sites.iter().enumerate() {
            if rec.kind == SiteKind::Act {
                act_q[i] = Some(rec.q);
            }
        }
        Ok(GetaEngine {
            model: c.model.clone(),
            task: c.task.clone(),
            config,
            program,
            weights,
            act_q,
            apply_act_quant: true,
            micro_batch: crate::runtime::native::batch_size_for(&c.task),
            threads: default_threads(),
        })
    }

    /// Dense-f32 baseline over the same executor: the unpruned program with
    /// raw f32 parameters and no quantization anywhere. This is the model
    /// the `.geta` artifact is benchmarked against.
    pub fn dense(config: &Json, params: ParamStore) -> Result<GetaEngine> {
        let sites = builders::quant_site_specs(config)?;
        let task = config.str_or("task", "image_cls");
        let program = lowering::lower(config, &sites, 1)?;
        Ok(GetaEngine {
            model: config.str_or("name", "<dense>"),
            task: task.clone(),
            config: config.clone(),
            program,
            weights: params,
            act_q: vec![None; sites.len()],
            apply_act_quant: false,
            micro_batch: crate::runtime::native::batch_size_for(&task),
            threads: default_threads(),
        })
    }

    pub fn program(&self) -> &Program {
        &self.program
    }

    pub fn config(&self) -> &Json {
        &self.config
    }

    pub fn input_kind(&self) -> InputKind {
        match self.program.nodes.first().map(|n| &n.op) {
            Some(OpKind::Embed { .. }) => InputKind::I32,
            _ => InputKind::F32,
        }
    }

    /// Flat input values per sample (pixels, or tokens for embed models —
    /// the Embed node's *output* is [1, seq, dim] but its input is the
    /// [seq] token ids).
    pub fn input_per_sample(&self) -> usize {
        let n0 = &self.program.nodes[0];
        match &n0.op {
            OpKind::Embed { .. } => n0.shape[1],
            _ => n0.shape[1..].iter().product(),
        }
    }

    /// Flat logits per sample.
    pub fn output_per_sample(&self) -> usize {
        let out = &self.program.nodes[self.program.output()];
        out.shape[1..].iter().product()
    }

    /// Run a batch of `n` samples through the model and return the logits
    /// `[n, ...]` flattened. Inputs beyond one micro-batch are chunked and
    /// the chunks sharded across threads; outputs are stitched back in
    /// input order, so results are identical for any thread count.
    pub fn infer(&self, x: &HostArray) -> Result<Vec<f32>> {
        let per = self.input_per_sample();
        anyhow::ensure!(per > 0, "degenerate model input");
        let n = x.len() / per;
        anyhow::ensure!(n * per == x.len(), "input length {} not a multiple of {per}", x.len());
        match (self.input_kind(), x) {
            (InputKind::F32, HostArray::F32(_)) | (InputKind::I32, HostArray::I32(_)) => {}
            (k, _) => anyhow::bail!("model expects {k:?} inputs"),
        }
        let mb = self.micro_batch.max(1);
        let chunks: Vec<(usize, usize)> = (0..n)
            .step_by(mb)
            .map(|s| (s, mb.min(n - s)))
            .collect();
        let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); chunks.len()];
        let nthreads = self.threads.max(1).min(chunks.len().max(1));
        if nthreads <= 1 {
            for (slot, &(start, len)) in outputs.iter_mut().zip(&chunks) {
                let xin = match x {
                    HostArray::F32(v) => In::F32(&v[start * per..(start + len) * per]),
                    HostArray::I32(v) => In::I32(&v[start * per..(start + len) * per]),
                };
                *slot = self.forward_chunk(&xin, len)?;
            }
        } else {
            // static round-robin partition: each worker owns disjoint slots
            let mut per_thread: Vec<Vec<(usize, &mut Vec<f32>)>> =
                (0..nthreads).map(|_| Vec::new()).collect();
            for (i, slot) in outputs.iter_mut().enumerate() {
                per_thread[i % nthreads].push((i, slot));
            }
            let chunks = &chunks;
            std::thread::scope(|sc| -> Result<()> {
                let mut handles = Vec::new();
                for list in per_thread {
                    handles.push(sc.spawn(move || -> Result<()> {
                        for (ci, slot) in list {
                            let (start, len) = chunks[ci];
                            let xin = match x {
                                HostArray::F32(v) => In::F32(&v[start * per..(start + len) * per]),
                                HostArray::I32(v) => In::I32(&v[start * per..(start + len) * per]),
                            };
                            *slot = self.forward_chunk(&xin, len)?;
                        }
                        Ok(())
                    }));
                }
                for h in handles {
                    h.join().expect("inference worker panicked")?;
                }
                Ok(())
            })?;
        }
        let out_per = self.output_per_sample();
        let mut out = Vec::with_capacity(n * out_per);
        for o in outputs {
            out.extend_from_slice(&o);
        }
        debug_assert_eq!(out.len(), n * out_per);
        Ok(out)
    }

    fn weight<'a>(&'a self, name: &str) -> Result<&'a [f32]> {
        self.weights
            .get(name)
            .map(|t| t.data.as_slice())
            .with_context(|| format!("engine missing tensor `{name}`"))
    }

    /// One micro-batch forward over the sliced program. `bsz` replaces the
    /// program's batch-1 leading dim in every shape computation.
    ///
    /// NOTE: each op here deliberately mirrors the forward pass of
    /// `runtime/interp.rs` (minus aux saving and per-step weight
    /// fake-quant). Any change to an interpreter forward kernel must be
    /// mirrored below — the per-family roundtrip parity tests
    /// (`rust/tests/test_deploy.rs`) are what enforce the two staying in
    /// lockstep.
    fn forward_chunk(&self, x: &In<'_>, bsz: usize) -> Result<Vec<f32>> {
        let nodes = &self.program.nodes;
        let per = |id: usize| -> usize { nodes[id].shape[1..].iter().product() };
        let mut vals: Vec<Vec<f32>> = Vec::with_capacity(nodes.len());
        for (id, node) in nodes.iter().enumerate() {
            let numel = bsz * per(id);
            let dims = &node.shape; // [1, ...per-sample dims]
            let input = |k: usize| -> &Vec<f32> { &vals[node.inputs[k]] };
            let in_dims = |k: usize| -> &Vec<usize> { &nodes[node.inputs[k]].shape };
            let out: Vec<f32> = match &node.op {
                OpKind::Input => {
                    let In::F32(xv) = x else {
                        anyhow::bail!("image model expects f32 inputs")
                    };
                    anyhow::ensure!(xv.len() == numel, "input batch mismatch");
                    xv.to_vec()
                }
                OpKind::Embed { tok, pos } => {
                    let In::I32(toks) = x else {
                        anyhow::bail!("token model expects i32 inputs")
                    };
                    let (seq, dim) = (dims[1], dims[2]);
                    anyhow::ensure!(toks.len() == bsz * seq, "token batch mismatch");
                    let tokw = self.weight(tok)?;
                    let posw = self.weight(pos)?;
                    let vocab = tokw.len() / dim;
                    let mut out = vec![0.0f32; numel];
                    for (r, &id) in toks.iter().enumerate() {
                        anyhow::ensure!(
                            (0..vocab as i32).contains(&id),
                            "token id {id} outside vocab {vocab}"
                        );
                        let dst = &mut out[r * dim..(r + 1) * dim];
                        dst.copy_from_slice(&tokw[id as usize * dim..(id as usize + 1) * dim]);
                        tensor::axpy(1.0, &posw[(r % seq) * dim..(r % seq + 1) * dim], dst);
                    }
                    out
                }
                OpKind::Linear { w, .. } => {
                    let wq = self.weight(&format!("{w}.weight"))?;
                    let bias = self.weight(&format!("{w}.bias"))?;
                    let din = *in_dims(0).last().unwrap();
                    let dout = *dims.last().unwrap();
                    let rows = numel / dout;
                    let mut out = matmul(input(0), wq, rows, din, dout);
                    for r in 0..rows {
                        tensor::axpy(1.0, bias, &mut out[r * dout..(r + 1) * dout]);
                    }
                    out
                }
                OpKind::Conv2d { w, k, stride, pad, .. } => {
                    let wq = self.weight(&format!("{w}.weight"))?;
                    let bias = self.weight(&format!("{w}.bias"))?;
                    let is = in_dims(0);
                    let (h, wd, cin) = (is[1], is[2], is[3]);
                    let (ho, wo, cout) = (dims[1], dims[2], dims[3]);
                    let cols = im2col(input(0), bsz, h, wd, cin, *k, *stride, *pad, ho, wo);
                    let rows = bsz * ho * wo;
                    let mut out = matmul(&cols, wq, rows, k * k * cin, cout);
                    for r in 0..rows {
                        tensor::axpy(1.0, bias, &mut out[r * cout..(r + 1) * cout]);
                    }
                    out
                }
                OpKind::BatchNorm { p } | OpKind::LayerNorm { p } => {
                    let gamma = self.weight(&format!("{p}.gamma"))?;
                    let beta = self.weight(&format!("{p}.beta"))?;
                    let c = *dims.last().unwrap();
                    let rows = numel / c;
                    let (out, _aux) = if matches!(node.op, OpKind::BatchNorm { .. }) {
                        batchnorm_rows(input(0), gamma, beta, rows, c, NORM_EPS)
                    } else {
                        layernorm_rows(input(0), gamma, beta, rows, c, NORM_EPS)
                    };
                    out
                }
                OpKind::Relu => input(0).iter().map(|&v| v.max(0.0)).collect(),
                OpKind::Gelu => input(0).iter().map(|&v| gelu(v)).collect(),
                OpKind::ActQuant { site } => {
                    if !self.apply_act_quant {
                        input(0).clone()
                    } else {
                        let qp = self.act_q[*site].with_context(|| {
                            format!("{}: activation site {site} missing from container", node.name)
                        })?;
                        input(0).iter().map(|&v| quant::fake_quant(v, &qp)).collect()
                    }
                }
                OpKind::Add => {
                    let mut out = input(0).clone();
                    tensor::axpy(1.0, input(1), &mut out);
                    out
                }
                OpKind::MaxPool2 => {
                    let is = in_dims(0);
                    let (h, wd, c) = (is[1], is[2], is[3]);
                    let (ho, wo) = (dims[1], dims[2]);
                    let xin = input(0);
                    let mut out = vec![0.0f32; numel];
                    for b in 0..bsz {
                        for oh in 0..ho {
                            for ow in 0..wo {
                                for ch in 0..c {
                                    let mut best = f32::NEG_INFINITY;
                                    for dh in 0..2 {
                                        for dw in 0..2 {
                                            let idx = ((b * h + oh * 2 + dh) * wd + ow * 2 + dw)
                                                * c
                                                + ch;
                                            best = best.max(xin[idx]);
                                        }
                                    }
                                    out[((b * ho + oh) * wo + ow) * c + ch] = best;
                                }
                            }
                        }
                    }
                    out
                }
                OpKind::GlobalAvgPool => {
                    let is = in_dims(0);
                    let (h, wd, c) = (is[1], is[2], is[3]);
                    let xin = input(0);
                    let mut out = vec![0.0f32; bsz * c];
                    for b in 0..bsz {
                        for pix in 0..h * wd {
                            tensor::axpy(
                                1.0,
                                &xin[(b * h * wd + pix) * c..(b * h * wd + pix + 1) * c],
                                &mut out[b * c..(b + 1) * c],
                            );
                        }
                    }
                    let scale = 1.0 / (h * wd) as f32;
                    for v in out.iter_mut() {
                        *v *= scale;
                    }
                    out
                }
                OpKind::Reshape => input(0).clone(),
                OpKind::ConcatCls { cls } => {
                    let clsw = self.weight(cls)?;
                    let (t1, dim) = (dims[1], dims[2]);
                    let xin = input(0);
                    let mut out = vec![0.0f32; numel];
                    for b in 0..bsz {
                        out[b * t1 * dim..b * t1 * dim + dim].copy_from_slice(clsw);
                        out[b * t1 * dim + dim..(b + 1) * t1 * dim]
                            .copy_from_slice(&xin[b * (t1 - 1) * dim..(b + 1) * (t1 - 1) * dim]);
                    }
                    out
                }
                OpKind::AddPos { pos } => {
                    let posw = self.weight(pos)?;
                    let rest = per(id);
                    anyhow::ensure!(posw.len() == rest, "pos table size mismatch");
                    let mut out = input(0).clone();
                    for b in 0..bsz {
                        tensor::axpy(1.0, posw, &mut out[b * rest..(b + 1) * rest]);
                    }
                    out
                }
                OpKind::Attention { heads, causal } => {
                    let (s, d) = (dims[1], dims[2]);
                    let hd = d / heads;
                    let scale = 1.0 / (hd as f32).sqrt();
                    let (qv, kv, vv) = (input(0), input(1), input(2));
                    let mut out = vec![0.0f32; numel];
                    let mut qh = vec![0.0f32; s * hd];
                    let mut kh = vec![0.0f32; s * hd];
                    let mut vh = vec![0.0f32; s * hd];
                    for b in 0..bsz {
                        for head in 0..*heads {
                            let off = head * hd;
                            for t in 0..s {
                                let src = (b * s + t) * d + off;
                                qh[t * hd..(t + 1) * hd].copy_from_slice(&qv[src..src + hd]);
                                kh[t * hd..(t + 1) * hd].copy_from_slice(&kv[src..src + hd]);
                                vh[t * hd..(t + 1) * hd].copy_from_slice(&vv[src..src + hd]);
                            }
                            let mut att = matmul_nt(&qh, &kh, s, hd, s);
                            for v in att.iter_mut() {
                                *v *= scale;
                            }
                            if *causal {
                                for i in 0..s {
                                    for j in i + 1..s {
                                        att[i * s + j] = -1e9;
                                    }
                                }
                            }
                            softmax_rows(&mut att, s, s);
                            let yh = matmul(&att, &vh, s, s, hd);
                            for t in 0..s {
                                let dst = (b * s + t) * d + off;
                                out[dst..dst + hd].copy_from_slice(&yh[t * hd..(t + 1) * hd]);
                            }
                        }
                    }
                    out
                }
                OpKind::PatchMerge { side } => {
                    let dim4 = dims[2];
                    let dim = dim4 / 4;
                    let half = side / 2;
                    let xin = input(0);
                    let mut out = vec![0.0f32; numel];
                    for b in 0..bsz {
                        for i in 0..half {
                            for j in 0..half {
                                let o = (b * half * half + i * half + j) * dim4;
                                for (slot, (di, dj)) in
                                    [(0, 0), (1, 0), (0, 1), (1, 1)].iter().enumerate()
                                {
                                    let src = (b * side * side
                                        + (2 * i + di) * side
                                        + (2 * j + dj))
                                        * dim;
                                    out[o + slot * dim..o + (slot + 1) * dim]
                                        .copy_from_slice(&xin[src..src + dim]);
                                }
                            }
                        }
                    }
                    out
                }
                OpKind::TokenPoolCls => {
                    let is = in_dims(0);
                    let (t, dim) = (is[1], is[2]);
                    let xin = input(0);
                    let mut out = vec![0.0f32; bsz * dim];
                    for b in 0..bsz {
                        out[b * dim..(b + 1) * dim]
                            .copy_from_slice(&xin[b * t * dim..b * t * dim + dim]);
                    }
                    out
                }
                OpKind::TokenPoolMean => {
                    let is = in_dims(0);
                    let (t, dim) = (is[1], is[2]);
                    let xin = input(0);
                    let mut out = vec![0.0f32; bsz * dim];
                    for b in 0..bsz {
                        for tok in 0..t {
                            tensor::axpy(
                                1.0,
                                &xin[(b * t + tok) * dim..(b * t + tok + 1) * dim],
                                &mut out[b * dim..(b + 1) * dim],
                            );
                        }
                    }
                    let scale = 1.0 / t as f32;
                    for v in out.iter_mut() {
                        *v *= scale;
                    }
                    out
                }
            };
            debug_assert_eq!(out.len(), numel, "{}: shape/val mismatch", node.name);
            vals.push(out);
        }
        Ok(vals.pop().expect("program has at least one node"))
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
