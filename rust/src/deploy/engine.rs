//! Packed-integer inference engine: executes an exported `.geta` model
//! over the **shrunk** (kept-channel-sliced) shapes.
//!
//! Load path: parse the container, unpack every packed weight's levels
//! once, re-lower the embedded config through `runtime::lowering`, shrink
//! the program's shapes to the sliced parameter store via
//! `subnet::propagate_slices`, then build a shape-resolved `exec::Plan`
//! for the inference micro-batch size. What the unpacked levels become
//! depends on the engine's [`KernelKind`]:
//!
//! * [`KernelKind::F32`] — dequantize to f32 at load (`level * d`,
//!   bit-identical to the fake-quantized weights the training interpreter
//!   multiplies) and run the f32 kernels. The historical deploy path and
//!   the baseline the integer path is benchmarked against.
//! * [`KernelKind::Int8`] — weights whose levels fit i8 are **never
//!   dequantized**: they load straight into resident i8 level tensors
//!   (`tensor::IntWeight`; the parameter store keeps a shape-only
//!   placeholder for slice propagation, which reads weight shapes only)
//!   and multiply through the integer kernels in `tensor/iops.rs`: i8×i8
//!   with exact i32
//!   accumulation where the input carries activation-quant levels, mixed
//!   f32×i8 elsewhere, the dequantization scales folded into the
//!   epilogue. Sites whose levels exceed i8 fall back to the f32 path
//!   per tensor.
//! * [`KernelKind::Int4`] — weights whose levels fit a signed nibble
//!   (|level| ≤ 7, i.e. sites trained to ≤ 4 bits) load into
//!   **nibble-packed** panels (`tensor::U4Weight`, two levels per byte —
//!   half the resident bytes of i8) and multiply through the u4 GEMMs in
//!   `tensor/u4.rs`, unpacking nibbles in-register. Sites that fit i8 but
//!   not a nibble fall back to i8 residency per tensor, and anything
//!   beyond i8 to f32 — so `--int4` is always at least as packed as
//!   `--int8`.
//!
//! The forward pass is `runtime::exec::forward` with a
//! [`exec::DeployParams`] (f32) or [`exec::QuantizedParams`] (int8/int4)
//! source — **the same op kernels the training interpreter runs** plus
//! the integer GEMMs, so the execution paths cannot drift apart. There is
//! no per-op math in this file. Inference-only differences live entirely
//! in the parameter source: no per-step weight fake-quant and activation
//! sites applied with their learned (d, t, q_m) container rows.
//!
//! Batching: [`GetaEngine::infer`] splits the input into micro-batches
//! (default: the family's training batch size) and shards those
//! micro-batches across `std::thread` workers. Batch-statistics
//! normalization is computed **per micro-batch**, matching the training
//! interpreter's stateless-batchnorm semantics — which is exactly what
//! makes the parity obligation testable, and makes results independent of
//! the thread count (sharding only ever happens at micro-batch
//! boundaries, and the underlying kernels are themselves bitwise
//! thread-count-invariant). Each worker pins the shared tiled kernels to
//! one thread (`tensor::serial_scope`) so micro-batch sharding and kernel
//! threading never oversubscribe the machine; a single large batch that
//! collapses to one chunk instead lets the kernels use the full
//! `GETA_THREADS` budget.
//!
//! Concurrency: the engine is **safe to share across threads and
//! lock-free on the hot path**. Scratch buffers come from an
//! [`exec::ArenaPool`] whose lock is held only to pop/push an arena —
//! never across a forward pass — so concurrent `infer` callers (a serving
//! worker pool holding one `Arc<GetaEngine>`) do not serialize on each
//! other, and repeated calls keep reusing warmed buffers on *both* the
//! sequential and the thread-sharded path. One-off plans for non-default
//! chunk sizes (tail chunks, single-sample serving requests) are memoized
//! in a per-size plan cache, so a stream of same-shaped requests resolves
//! shapes exactly once.
//!
//! [`GetaEngine::infer_many`] is the request-coalescing entry point the
//! `serve` subsystem batches through: each request keeps **its own**
//! micro-batch chunk boundaries (exactly the chunks a solo `infer` call
//! would produce — so batch-statistics normalization, and therefore every
//! logit, is bitwise identical to per-request inference), but the merged
//! chunk list is executed in one pass: one arena draw, one worker scope,
//! one plan-cache hit per distinct chunk size.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::format::{GetaContainer, Payload, SiteKind};
use crate::graph::builders;
use crate::quant::QParams;
use crate::runtime::exec::{
    self, Arena, ArenaPool, DeployParams, Input, ParamSource, Plan, QuantizedParams,
};
use crate::runtime::lowering::{self, OpKind, Program};
use crate::runtime::HostArray;
use crate::tensor::{self, IntWeight, ParamStore, Tensor, U4Weight};
use crate::util::json::Json;

/// Input dtype the loaded model expects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputKind {
    F32,
    I32,
}

/// Which compute path the engine runs (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// Dequantize packed weights to f32 at load; f32 GEMMs.
    F32,
    /// Keep eligible weights resident as i8 levels; integer GEMMs.
    Int8,
    /// Keep ≤4-bit weights resident as nibble-packed panels (two levels
    /// per byte); other eligible sites fall back to i8, then f32.
    Int4,
}

impl KernelKind {
    /// Stable machine-readable label (`BENCH_runtime.json` `kernel` field).
    pub fn label(self) -> &'static str {
        match self {
            KernelKind::F32 => "f32",
            KernelKind::Int8 => "int8",
            KernelKind::Int4 => "int4",
        }
    }
}

pub struct GetaEngine {
    pub model: String,
    pub task: String,
    config: Json,
    /// Slice-propagated program, lowered with batch dim 1; `plan`
    /// substitutes the runtime micro-batch size.
    program: Program,
    /// Shape-resolved plan for `micro_batch`, built once at load.
    plan: std::sync::Arc<Plan>,
    /// Memoized plans for non-default chunk sizes (tail chunks, serving
    /// requests smaller than a micro-batch). Keyed by batch size; bounded
    /// because chunk sizes never exceed `micro_batch`. The lock is held
    /// only to look up or insert an `Arc` — never across a forward pass.
    plans: std::sync::Mutex<BTreeMap<usize, std::sync::Arc<Plan>>>,
    weights: ParamStore,
    /// i8-resident weight tensors (Int8/Int4 kernels; empty otherwise).
    /// Tensors present here keep only their shape in `weights`.
    iweights: BTreeMap<String, IntWeight>,
    /// Nibble-packed u4-resident weight tensors (Int4 kernel only;
    /// disjoint from `iweights` — each site packs in exactly one form).
    /// Tensors present here keep only their shape in `weights`.
    uweights: BTreeMap<String, U4Weight>,
    /// Quant site the container recorded per packed tensor — the executor
    /// validates its requests against this map.
    weight_sites: BTreeMap<String, usize>,
    /// Which compute path `forward_chunk` selects.
    pub kernel: KernelKind,
    /// Learned activation-quant parameters by q-row (None = weight site or
    /// quantization disabled, as in the dense-f32 baseline engine).
    act_q: Vec<Option<QParams>>,
    /// Apply activation quantization (false for the dense baseline).
    apply_act_quant: bool,
    /// Micro-batch size: normalization statistics and thread sharding both
    /// operate at this granularity.
    pub micro_batch: usize,
    /// Worker threads for [`infer`](Self::infer) (1 = sequential).
    pub threads: usize,
    /// Buffer pool shared by every `infer` path: sequential calls and
    /// sharding workers alike pop a warmed arena, run lock-free, and push
    /// it back — so concurrent callers never serialize on scratch space
    /// and repeated calls reuse buffers on both paths.
    arenas: ArenaPool,
}

impl GetaEngine {
    pub fn load(path: &std::path::Path) -> Result<GetaEngine> {
        Self::load_kernel(path, KernelKind::F32)
    }

    /// [`load`](Self::load) with an explicit compute path (`geta infer
    /// --int8`).
    pub fn load_kernel(path: &std::path::Path, kernel: KernelKind) -> Result<GetaEngine> {
        let c = {
            let _g = crate::obs::span("deploy", "load/read");
            GetaContainer::read(path)?
        };
        Self::from_container_kernel(&c, kernel)
    }

    /// Build the f32-dequant engine from a parsed container (the
    /// historical default path).
    pub fn from_container(c: &GetaContainer) -> Result<GetaEngine> {
        Self::from_container_kernel(c, KernelKind::F32)
    }

    /// Build the engine from a parsed container: unpack, re-lower,
    /// shrink. Site metadata is cross-checked against the config's own
    /// plan-order sites so a tampered container cannot mis-map q rows.
    pub fn from_container_kernel(c: &GetaContainer, kernel: KernelKind) -> Result<GetaEngine> {
        let config = c.config()?;
        let sites = builders::quant_site_specs(&config)?;
        anyhow::ensure!(
            sites.len() == c.sites.len(),
            "container has {} sites, config plans {}",
            c.sites.len(),
            sites.len()
        );
        for (i, (rec, spec)) in c.sites.iter().zip(&sites).enumerate() {
            anyhow::ensure!(
                rec.name == spec.name,
                "site {i}: container `{}` vs config plan `{}`",
                rec.name,
                spec.name
            );
            let want = if spec.param.is_some() {
                SiteKind::Weight
            } else {
                SiteKind::Act
            };
            anyhow::ensure!(rec.kind == want, "site {i} (`{}`): kind mismatch", rec.name);
        }
        let mut weights = ParamStore::new();
        let mut weight_sites = BTreeMap::new();
        let mut iweights = BTreeMap::new();
        let mut uweights = BTreeMap::new();
        let unpack_span = crate::obs::span("deploy", "load/unpack");
        for t in &c.tensors {
            match &t.payload {
                Payload::F32(v) => {
                    anyhow::ensure!(
                        v.len() == t.numel(),
                        "tensor `{}`: {} values for shape {:?}",
                        t.name,
                        v.len(),
                        t.shape
                    );
                    weights.push(Tensor::from_vec(&t.name, &t.shape, v.clone()));
                }
                Payload::Packed { site, .. } => {
                    // the site must be the one whose param names this tensor,
                    // or a swapped site index would dequantize with the wrong
                    // step d and produce silently wrong weights
                    anyhow::ensure!(
                        sites[*site as usize].param.as_deref() == Some(t.name.as_str()),
                        "tensor `{}`: packed payload references site {} (`{}`), not its own \
                         weight site",
                        t.name,
                        site,
                        c.sites[*site as usize].name
                    );
                    let d = c.sites[*site as usize].q.d;
                    let levels = t.payload.levels()?.expect("packed payload has levels");
                    anyhow::ensure!(
                        levels.len() == t.numel(),
                        "tensor `{}`: {} levels for shape {:?}",
                        t.name,
                        levels.len(),
                        t.shape
                    );
                    weight_sites.insert(t.name.clone(), *site as usize);
                    let n = t.shape.last().copied().unwrap_or(0);
                    // residency ladder: Int4 tries the nibble-packed form
                    // first and degrades per tensor (u4 → i8 → f32); Int8
                    // tries only i8; F32 dequantizes everything.
                    let uw = if kernel == KernelKind::Int4 {
                        U4Weight::from_levels(&levels, n, d)
                    } else {
                        None
                    };
                    let iw = if uw.is_none()
                        && matches!(kernel, KernelKind::Int8 | KernelKind::Int4)
                    {
                        IntWeight::from_levels(&levels, n, d)
                    } else {
                        None
                    };
                    if let Some(uw) = uw {
                        // integer-resident: never dequantized. The store
                        // keeps a shape-only placeholder — slice propagation
                        // below reads weight *shapes* only, and the executor
                        // reaches this tensor exclusively through
                        // `weight_u4` / the uweights fallback.
                        uweights.insert(t.name.clone(), uw);
                        weights.push(Tensor::shape_only(&t.name, &t.shape));
                    } else if let Some(iw) = iw {
                        // same placeholder discipline, served via `weight_i8`
                        iweights.insert(t.name.clone(), iw);
                        weights.push(Tensor::shape_only(&t.name, &t.shape));
                    } else {
                        // f32 kernel, or levels beyond i8: dequantize once
                        weights.push(Tensor::from_vec(
                            &t.name,
                            &t.shape,
                            levels.iter().map(|&l| l as f32 * d).collect(),
                        ));
                    }
                }
            }
        }
        drop(unpack_span);
        let lower_span = crate::obs::span("deploy", "load/lower");
        let base = lowering::lower(&config, &sites, 1)?;
        drop(lower_span);
        let slice_span = crate::obs::span("deploy", "load/slice");
        let program = crate::subnet::propagate_slices(&base, &weights)
            .context("sliced shapes do not propagate coherently")?;
        drop(slice_span);
        let mut act_q = vec![None; sites.len()];
        for (i, rec) in c.sites.iter().enumerate() {
            if rec.kind == SiteKind::Act {
                act_q[i] = Some(rec.q);
            }
        }
        let micro_batch = crate::runtime::native::batch_size_for(&c.task);
        let plan_span = crate::obs::span("deploy", "load/plan");
        let plan = std::sync::Arc::new(Plan::new(&program, micro_batch));
        drop(plan_span);
        Ok(GetaEngine {
            model: c.model.clone(),
            task: c.task.clone(),
            config,
            program,
            plan,
            plans: std::sync::Mutex::new(BTreeMap::new()),
            weights,
            iweights,
            uweights,
            weight_sites,
            kernel,
            act_q,
            apply_act_quant: true,
            micro_batch,
            threads: tensor::configured_threads(),
            arenas: ArenaPool::new(),
        })
    }

    /// Dense-f32 baseline over the same executor: the unpruned program with
    /// raw f32 parameters and no quantization anywhere. This is the model
    /// the `.geta` artifact is benchmarked against.
    pub fn dense(config: &Json, params: ParamStore) -> Result<GetaEngine> {
        let sites = builders::quant_site_specs(config)?;
        let task = config.str_or("task", "image_cls");
        let program = lowering::lower(config, &sites, 1)?;
        let micro_batch = crate::runtime::native::batch_size_for(&task);
        let plan = std::sync::Arc::new(Plan::new(&program, micro_batch));
        Ok(GetaEngine {
            model: config.str_or("name", "<dense>"),
            task: task.clone(),
            config: config.clone(),
            program,
            plan,
            plans: std::sync::Mutex::new(BTreeMap::new()),
            weights: params,
            iweights: BTreeMap::new(),
            uweights: BTreeMap::new(),
            weight_sites: BTreeMap::new(),
            kernel: KernelKind::F32,
            act_q: vec![None; sites.len()],
            apply_act_quant: false,
            micro_batch,
            threads: tensor::configured_threads(),
            arenas: ArenaPool::new(),
        })
    }

    /// How many weight tensors are resident as i8 levels (0 for the f32
    /// kernel, or when every site trained past 8 bits).
    pub fn int_sites(&self) -> usize {
        self.iweights.len()
    }

    /// How many weight tensors are resident as nibble-packed u4 panels
    /// (0 for every kernel but Int4, or when every site trained past 4
    /// bits).
    pub fn u4_sites(&self) -> usize {
        self.uweights.len()
    }

    pub fn program(&self) -> &Program {
        &self.program
    }

    pub fn config(&self) -> &Json {
        &self.config
    }

    pub fn input_kind(&self) -> InputKind {
        match self.program.nodes.first().map(|n| &n.op) {
            Some(OpKind::Embed { .. }) => InputKind::I32,
            _ => InputKind::F32,
        }
    }

    /// Flat input values per sample (pixels, or tokens for embed models —
    /// the Embed node's *output* is [1, seq, dim] but its input is the
    /// [seq] token ids).
    pub fn input_per_sample(&self) -> usize {
        let n0 = &self.program.nodes[0];
        match &n0.op {
            OpKind::Embed { .. } => n0.shape[1],
            _ => n0.shape[1..].iter().product(),
        }
    }

    /// Flat logits per sample.
    pub fn output_per_sample(&self) -> usize {
        let out = &self.program.nodes[self.program.output()];
        out.shape[1..].iter().product()
    }

    /// Run a batch of `n` samples through the model and return the logits
    /// `[n, ...]` flattened. Inputs beyond one micro-batch are chunked and
    /// the chunks sharded across threads; outputs are stitched back in
    /// input order, so results are identical for any thread count.
    pub fn infer(&self, x: &HostArray) -> Result<Vec<f32>> {
        let mut out = self.infer_many(&[x])?;
        Ok(out.pop().expect("one request in, one logits vector out"))
    }

    /// Run several independent requests in one pass and return one logits
    /// vector per request, in request order. Each request is chunked into
    /// micro-batches **on its own** — the chunk boundaries are exactly the
    /// ones a solo [`infer`](Self::infer) call would produce, so
    /// batch-statistics normalization (and therefore every logit) is
    /// bitwise identical to per-request inference. The merged chunk list
    /// is what gets sharded across threads, so a coalesced batch pays for
    /// one arena draw and one worker scope instead of one per request.
    pub fn infer_many(&self, xs: &[&HostArray]) -> Result<Vec<Vec<f32>>> {
        let per = self.input_per_sample();
        anyhow::ensure!(per > 0, "degenerate model input");
        let kind = self.input_kind();
        let mut counts = Vec::with_capacity(xs.len());
        // chunk list across all requests: (request, start sample, samples)
        let mut chunks: Vec<(usize, usize, usize)> = Vec::new();
        let mb = self.micro_batch.max(1);
        for (r, x) in xs.iter().enumerate() {
            let n = x.len() / per;
            anyhow::ensure!(
                n * per == x.len(),
                "request {r}: input length {} not a multiple of {per}",
                x.len()
            );
            match (kind, x) {
                (InputKind::F32, HostArray::F32(_)) | (InputKind::I32, HostArray::I32(_)) => {}
                (k, _) => anyhow::bail!("request {r}: model expects {k:?} inputs"),
            }
            counts.push(n);
            chunks.extend((0..n).step_by(mb).map(|s| (r, s, mb.min(n - s))));
        }
        let slice_input = |&(r, start, len): &(usize, usize, usize)| match xs[r] {
            HostArray::F32(v) => Input::F32(&v[start * per..(start + len) * per]),
            HostArray::I32(v) => Input::I32(&v[start * per..(start + len) * per]),
        };
        let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); chunks.len()];
        let nthreads = self.threads.max(1).min(chunks.len().max(1));
        if nthreads <= 1 {
            // sequential chunks: one pooled arena carries buffers across
            // the whole call (and, via the pool, across calls), and the
            // shared kernels keep their full thread budget
            let mut arena = self.arenas.take();
            let run = || -> Result<()> {
                for (slot, c) in outputs.iter_mut().zip(&chunks) {
                    *slot = self.forward_chunk(&slice_input(c), c.2, &mut arena)?;
                }
                Ok(())
            };
            let res = run();
            self.arenas.give(arena);
            res?;
        } else {
            // static round-robin partition: each worker owns disjoint slots
            let mut per_thread: Vec<Vec<(usize, &mut Vec<f32>)>> =
                (0..nthreads).map(|_| Vec::new()).collect();
            for (i, slot) in outputs.iter_mut().enumerate() {
                per_thread[i % nthreads].push((i, slot));
            }
            let chunks = &chunks;
            let slice_input = &slice_input;
            std::thread::scope(|sc| -> Result<()> {
                let mut handles = Vec::new();
                for list in per_thread {
                    handles.push(sc.spawn(move || -> Result<()> {
                        let mut arena = self.arenas.take();
                        let res = tensor::serial_scope(|| -> Result<()> {
                            for (ci, slot) in list {
                                let c = &chunks[ci];
                                *slot = self.forward_chunk(&slice_input(c), c.2, &mut arena)?;
                            }
                            Ok(())
                        });
                        self.arenas.give(arena);
                        res
                    }));
                }
                for h in handles {
                    h.join().expect("inference worker panicked")?;
                }
                Ok(())
            })?;
        }
        let out_per = self.output_per_sample();
        let mut results: Vec<Vec<f32>> =
            counts.iter().map(|&n| Vec::with_capacity(n * out_per)).collect();
        for (o, &(r, ..)) in outputs.iter().zip(&chunks) {
            results[r].extend_from_slice(o);
        }
        for (r, (res, &n)) in results.iter().zip(&counts).enumerate() {
            debug_assert_eq!(res.len(), n * out_per, "request {r}: stitched output length");
        }
        Ok(results)
    }

    /// Shape-resolved plan for a chunk of `bsz` samples: the prebuilt plan
    /// for full micro-batches, a memoized one for any other size.
    fn plan_for(&self, bsz: usize) -> std::sync::Arc<Plan> {
        if bsz == self.plan.bsz {
            return self.plan.clone();
        }
        let mut plans = self.plans.lock().unwrap_or_else(|e| e.into_inner());
        plans
            .entry(bsz)
            .or_insert_with(|| std::sync::Arc::new(Plan::new(&self.program, bsz)))
            .clone()
    }

    /// One micro-batch forward over the sliced program via the shared
    /// planned executor. The engine's prebuilt plan serves full
    /// micro-batches; other chunk sizes hit the memoized plan cache.
    fn forward_chunk(&self, x: &Input<'_>, bsz: usize, arena: &mut Arena) -> Result<Vec<f32>> {
        let f32_src;
        let int_src;
        let src: &dyn ParamSource = match self.kernel {
            KernelKind::F32 => {
                f32_src = DeployParams {
                    weights: &self.weights,
                    act_q: &self.act_q,
                    apply_act_quant: self.apply_act_quant,
                    weight_sites: &self.weight_sites,
                };
                &f32_src
            }
            KernelKind::Int8 | KernelKind::Int4 => {
                int_src = QuantizedParams {
                    weights: &self.weights,
                    iweights: &self.iweights,
                    uweights: &self.uweights,
                    weight_sites: &self.weight_sites,
                    act_q: &self.act_q,
                };
                &int_src
            }
        };
        let plan = self.plan_for(bsz);
        let (mut vals, _aux) = exec::forward(&self.program, &plan, src, x, false, arena)?;
        let out = std::mem::take(vals.last_mut().expect("program has at least one node"));
        arena.reclaim_all(vals);
        Ok(out)
    }
}
