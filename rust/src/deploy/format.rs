//! The `.geta` container — a versioned little-endian binary format for
//! deployed compressed models.
//!
//! Layout (all integers little-endian; `[str]` = u32 length + UTF-8):
//!
//! | field | type | meaning |
//! |---|---|---|
//! | magic | 4 bytes | `"GETA"` |
//! | version | u16 | format version (currently 1) |
//! | flags | u16 | reserved, must be 0 |
//! | model / family / task | 3 × [str] | identity of the exported model |
//! | config | [str] | the model config JSON (re-lowered at load time) |
//! | n_sites | u32 | quant-site records, plan order (`quant_site_specs`) |
//! | site · name | [str] | site name |
//! | site · kind | u8 | 0 = weight, 1 = activation |
//! | site · d, t, q_m | 3 × f32 | learned quantizer parameters |
//! | site · bits | u8 | rounded eq. (3) bit width (reporting/size) |
//! | n_tensors | u32 | tensor records, parameter-store order |
//! | tensor · name | [str] | tensor name |
//! | tensor · ndim, dims | u8, ndim × u32 | **kept-channel-sliced** shape |
//! | tensor · enc | u8 | 0 = raw f32, 1 = bit-packed integer levels |
//! | enc 0 | u32 numel + numel × f32 | biases, norms, embeddings |
//! | enc 1 | u32 site, u32 numel, i32 min_level, u8 pack_bits, u32 nbytes, bytes | quantized weight |
//!
//! Packed payloads store the signed quantization levels
//! `round(sgn(w)·clip(w)/d)` offset by `min_level` and bit-packed LSB-first
//! at `pack_bits` per value — `pack_bits` is the smallest width that holds
//! the tensor's actual level range, which equals the learned bit width
//! except when training left a site mid-projection. Dequantization is
//! `(min_level + u) as f32 * d`, bit-identical to the fake-quantized
//! weights the training interpreter multiplies, which is what makes the
//! deployed engine's parity obligation (≤ 1e-4 vs masked eval) hold.
//!
//! The reader is strict: bad magic, unknown version, nonzero flags,
//! truncation, trailing bytes, out-of-range site references and
//! shape/payload mismatches are all hard errors, never best-effort reads.

use anyhow::{Context, Result};

use crate::quant::QParams;

pub const MAGIC: [u8; 4] = *b"GETA";
pub const VERSION: u16 = 1;

/// Allocation cap for a single tensor (guards the strict reader against
/// corrupt length fields; far above any zoo model).
const MAX_NUMEL: u64 = 1 << 28;
const MAX_DIMS: usize = 8;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SiteKind {
    Weight,
    Act,
}

/// One quant site: learned (d, t, q_m) plus the rounded bit width.
#[derive(Debug, Clone)]
pub struct SiteRecord {
    pub name: String,
    pub kind: SiteKind,
    pub q: QParams,
    pub bits: u8,
}

#[derive(Debug, Clone)]
pub enum Payload {
    /// Raw f32 values (biases, norm scales, embeddings, unquantized weights).
    F32(Vec<f32>),
    /// Bit-packed integer levels of a quantized weight site.
    Packed {
        /// Index into the container's site table (must be a weight site).
        site: u32,
        min_level: i32,
        pack_bits: u8,
        bytes: Vec<u8>,
        numel: usize,
    },
}

impl Payload {
    /// Unpacked integer levels of a packed payload (`None` for raw f32).
    /// One shared decode for every consumer of the read path — the f32
    /// dequantizer and the int8 engine's resident level tensors both go
    /// through this, so they cannot disagree about the bit layout.
    pub fn levels(&self) -> Result<Option<Vec<i32>>> {
        match self {
            Payload::F32(_) => Ok(None),
            Payload::Packed {
                min_level,
                pack_bits,
                bytes,
                numel,
                ..
            } => Ok(Some(unpack_levels(bytes, *numel, *min_level, *pack_bits)?)),
        }
    }

    /// Cheap read-path hint for int4 residency, decided from the stored
    /// `min_level`/`pack_bits` header alone — no unpacking. The packed
    /// encoding can only represent levels in `min_level ..= min_level +
    /// (2^pack_bits - 1)`; when that whole span sits inside the signed
    /// nibble range `-7..=7`, **every** decodable level fits the int4
    /// engine's bound, guaranteed. `false` means "might not fit" (the
    /// minimal-width span can overshoot the tensor's actual maximum by up
    /// to a factor of two), so `U4Weight::from_levels` — which sees the
    /// unpacked levels — remains the sole residency authority; this
    /// accessor only lets size estimators and tooling classify payloads
    /// without paying for a decode.
    pub fn fits_nibble(&self) -> bool {
        match self {
            Payload::F32(_) => false,
            Payload::Packed { min_level, pack_bits, .. } => {
                let lo = *min_level as i64;
                let hi = lo + ((1i64 << (*pack_bits).min(32) as i64) - 1);
                lo >= -7 && hi <= 7
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorRecord {
    pub name: String,
    /// Kept-channel-sliced shape (post structured pruning).
    pub shape: Vec<usize>,
    pub payload: Payload,
}

impl TensorRecord {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A parsed (or to-be-written) `.geta` file.
#[derive(Debug, Clone)]
pub struct GetaContainer {
    pub model: String,
    pub family: String,
    pub task: String,
    /// The model config JSON text the engine re-lowers at load time.
    pub config_text: String,
    pub sites: Vec<SiteRecord>,
    pub tensors: Vec<TensorRecord>,
}

impl GetaContainer {
    pub fn config(&self) -> Result<crate::util::json::Json> {
        crate::util::json::parse(&self.config_text)
            .map_err(|e| anyhow::anyhow!("container config json: {e}"))
    }

    // ------------------------------------------------------------- writing
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::default();
        w.bytes(&MAGIC);
        w.u16(VERSION);
        w.u16(0); // flags
        w.str(&self.model);
        w.str(&self.family);
        w.str(&self.task);
        w.str(&self.config_text);
        w.u32(self.sites.len() as u32);
        for s in &self.sites {
            w.str(&s.name);
            w.u8(match s.kind {
                SiteKind::Weight => 0,
                SiteKind::Act => 1,
            });
            w.f32(s.q.d);
            w.f32(s.q.t);
            w.f32(s.q.qm);
            w.u8(s.bits);
        }
        w.u32(self.tensors.len() as u32);
        for t in &self.tensors {
            w.str(&t.name);
            w.u8(t.shape.len() as u8);
            for &d in &t.shape {
                w.u32(d as u32);
            }
            match &t.payload {
                Payload::F32(v) => {
                    w.u8(0);
                    w.u32(v.len() as u32);
                    for &x in v {
                        w.f32(x);
                    }
                }
                Payload::Packed {
                    site,
                    min_level,
                    pack_bits,
                    bytes,
                    numel,
                } => {
                    w.u8(1);
                    w.u32(*site);
                    w.u32(*numel as u32);
                    w.i32(*min_level);
                    w.u8(*pack_bits);
                    w.u32(bytes.len() as u32);
                    w.bytes(bytes);
                }
            }
        }
        w.0
    }

    /// Crash-safe: goes through [`crate::util::atomic_write`], so a kill
    /// mid-export leaves any previous `.geta` at `path` intact — a serving
    /// process hot-reloading the artifact can never read a torn file.
    pub fn write(&self, path: &std::path::Path) -> Result<()> {
        crate::util::atomic_write(path, &self.to_bytes())
            .with_context(|| format!("write {}", path.display()))
    }

    // ------------------------------------------------------------- reading
    pub fn from_bytes(b: &[u8]) -> Result<GetaContainer> {
        let mut r = Reader { b, pos: 0 };
        let magic = r.take(4)?;
        anyhow::ensure!(magic == MAGIC, "bad magic {magic:02x?} (not a .geta file)");
        let version = r.u16()?;
        anyhow::ensure!(
            version == VERSION,
            "unsupported .geta version {version} (this build reads {VERSION})"
        );
        let flags = r.u16()?;
        anyhow::ensure!(flags == 0, "unknown .geta flags {flags:#06x}");
        let model = r.str()?;
        let family = r.str()?;
        let task = r.str()?;
        let config_text = r.str()?;
        let n_sites = r.u32()? as usize;
        let mut sites = Vec::with_capacity(n_sites.min(4096));
        for i in 0..n_sites {
            let name = r.str()?;
            let kind = match r.u8()? {
                0 => SiteKind::Weight,
                1 => SiteKind::Act,
                k => anyhow::bail!("site {i} (`{name}`): unknown kind {k}"),
            };
            let q = QParams {
                d: r.f32()?,
                t: r.f32()?,
                qm: r.f32()?,
            };
            anyhow::ensure!(
                q.d.is_finite() && q.d > 0.0 && q.t.is_finite() && q.qm.is_finite(),
                "site {i} (`{name}`): degenerate qparams {q:?}"
            );
            let bits = r.u8()?;
            anyhow::ensure!((2..=32).contains(&bits), "site {i} (`{name}`): bits {bits}");
            sites.push(SiteRecord { name, kind, q, bits });
        }
        let n_tensors = r.u32()? as usize;
        let mut tensors: Vec<TensorRecord> = Vec::with_capacity(n_tensors.min(4096));
        for _ in 0..n_tensors {
            let name = r.str()?;
            anyhow::ensure!(
                tensors.iter().all(|t| t.name != name),
                "duplicate tensor `{name}`"
            );
            let ndim = r.u8()? as usize;
            anyhow::ensure!(ndim <= MAX_DIMS, "tensor `{name}`: {ndim} dims");
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u32()? as usize);
            }
            // checked: corrupt dims can otherwise overflow the product
            let numel = shape
                .iter()
                .try_fold(1u64, |acc, &d| acc.checked_mul(d as u64))
                .filter(|&n| n <= MAX_NUMEL)
                .ok_or_else(|| anyhow::anyhow!("tensor `{name}`: numel of {shape:?} too large"))?;
            let numel = numel as usize;
            let payload = match r.u8()? {
                0 => {
                    let n = r.u32()? as usize;
                    anyhow::ensure!(n == numel, "tensor `{name}`: f32 numel {n} != shape {numel}");
                    let raw = r.take(n * 4)?;
                    let mut v = Vec::with_capacity(n);
                    for c in raw.chunks_exact(4) {
                        v.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                    }
                    Payload::F32(v)
                }
                1 => {
                    let site = r.u32()?;
                    anyhow::ensure!(
                        (site as usize) < sites.len(),
                        "tensor `{name}`: site {site} out of range ({} sites)",
                        sites.len()
                    );
                    anyhow::ensure!(
                        sites[site as usize].kind == SiteKind::Weight,
                        "tensor `{name}`: packed payload references activation site {site}"
                    );
                    let n = r.u32()? as usize;
                    anyhow::ensure!(n == numel, "tensor `{name}`: packed numel {n} != shape {numel}");
                    let min_level = r.i32()?;
                    let pack_bits = r.u8()?;
                    anyhow::ensure!(
                        (1..=32).contains(&pack_bits),
                        "tensor `{name}`: pack_bits {pack_bits}"
                    );
                    let nbytes = r.u32()? as usize;
                    let want = (numel * pack_bits as usize).div_ceil(8);
                    anyhow::ensure!(
                        nbytes == want,
                        "tensor `{name}`: payload {nbytes} bytes, expected {want}"
                    );
                    let bytes = r.take(nbytes)?.to_vec();
                    Payload::Packed {
                        site,
                        min_level,
                        pack_bits,
                        bytes,
                        numel,
                    }
                }
                e => anyhow::bail!("tensor `{name}`: unknown encoding {e}"),
            };
            tensors.push(TensorRecord { name, shape, payload });
        }
        anyhow::ensure!(
            r.pos == b.len(),
            "{} trailing bytes after the last tensor record",
            b.len() - r.pos
        );
        Ok(GetaContainer {
            model,
            family,
            task,
            config_text,
            sites,
            tensors,
        })
    }

    pub fn read(path: &std::path::Path) -> Result<GetaContainer> {
        let b = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
        Self::from_bytes(&b).with_context(|| format!("parse {}", path.display()))
    }
}

// ------------------------------------------------------------- bit packing

/// Smallest bit width that represents every value in `0..=range`.
pub fn bits_for_range(range: u64) -> u8 {
    ((64 - range.leading_zeros()) as u8).max(1)
}

/// Pack `levels` as unsigned `(level - min)` values, `bits` per value,
/// LSB-first. The caller guarantees `level - min < 2^bits` for all levels
/// (use [`bits_for_range`] on the actual range).
pub fn pack_levels(levels: &[i32], min: i32, bits: u8) -> Vec<u8> {
    assert!((1..=32).contains(&bits));
    let mut out = vec![0u8; (levels.len() * bits as usize).div_ceil(8)];
    let mut bitpos = 0usize;
    for &l in levels {
        let mut u = (l as i64 - min as i64) as u64;
        // a real assert: packing runs once at export, and a masked-off high
        // bit would write a silently corrupt payload the reader accepts
        assert!(u < (1u64 << bits), "level {l} out of {bits}-bit range (min {min})");
        let mut remaining = bits as usize;
        while remaining > 0 {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (8 - off).min(remaining);
            out[byte] |= ((u & ((1u64 << take) - 1)) as u8) << off;
            u >>= take;
            bitpos += take;
            remaining -= take;
        }
    }
    out
}

/// Inverse of [`pack_levels`].
pub fn unpack_levels(bytes: &[u8], numel: usize, min: i32, bits: u8) -> Result<Vec<i32>> {
    anyhow::ensure!((1..=32).contains(&bits), "pack bits {bits}");
    anyhow::ensure!(
        bytes.len() == (numel * bits as usize).div_ceil(8),
        "packed payload is {} bytes, expected {}",
        bytes.len(),
        (numel * bits as usize).div_ceil(8)
    );
    let mut out = Vec::with_capacity(numel);
    let mut bitpos = 0usize;
    for _ in 0..numel {
        let mut u: u64 = 0;
        let mut got = 0usize;
        while got < bits as usize {
            let byte = bitpos / 8;
            let off = bitpos % 8;
            let take = (8 - off).min(bits as usize - got);
            let chunk = ((bytes[byte] >> off) as u64) & ((1u64 << take) - 1);
            u |= chunk << got;
            got += take;
            bitpos += take;
        }
        out.push((min as i64 + u as i64) as i32);
    }
    Ok(out)
}

// ------------------------------------------------------------ byte helpers

#[derive(Default)]
struct Writer(Vec<u8>);

impl Writer {
    fn bytes(&mut self, b: &[u8]) {
        self.0.extend_from_slice(b);
    }
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.bytes(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.bytes(&v.to_le_bytes());
    }
    fn i32(&mut self, v: i32) {
        self.bytes(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.bytes(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.bytes(s.as_bytes());
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.b.len(),
            "truncated .geta file: need {n} bytes at offset {}, have {}",
            self.pos,
            self.b.len() - self.pos
        );
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn i32(&mut self) -> Result<i32> {
        let b = self.take(4)?;
        Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        anyhow::ensure!(n <= self.b.len(), "string length {n} exceeds file size");
        let raw = self.take(n)?;
        Ok(std::str::from_utf8(raw)
            .map_err(|_| anyhow::anyhow!("invalid utf-8 string at offset {}", self.pos - n))?
            .to_string())
    }
}

// ----------------------------------------------------------------- tests
#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_container() -> GetaContainer {
        let levels = vec![-3i32, -1, 0, 2, 3, 1];
        let min = -3;
        let pack_bits = bits_for_range(6);
        GetaContainer {
            model: "toy".into(),
            family: "mlp".into(),
            task: "image_cls".into(),
            config_text: r#"{"name":"toy","family":"mlp"}"#.into(),
            sites: vec![
                SiteRecord {
                    name: "fc0.weight".into(),
                    kind: SiteKind::Weight,
                    q: QParams { d: 0.25, t: 1.0, qm: 1.0 },
                    bits: 3,
                },
                SiteRecord {
                    name: "fc0.act".into(),
                    kind: SiteKind::Act,
                    q: QParams { d: 0.1, t: 1.0, qm: 4.0 },
                    bits: 6,
                },
            ],
            tensors: vec![
                TensorRecord {
                    name: "fc0.weight".into(),
                    shape: vec![2, 3],
                    payload: Payload::Packed {
                        site: 0,
                        min_level: min,
                        pack_bits,
                        bytes: pack_levels(&levels, min, pack_bits),
                        numel: 6,
                    },
                },
                TensorRecord {
                    name: "fc0.bias".into(),
                    shape: vec![3],
                    payload: Payload::F32(vec![0.5, -0.25, 0.0]),
                },
            ],
        }
    }

    #[test]
    fn container_roundtrips() {
        let c = tiny_container();
        let bytes = c.to_bytes();
        let back = GetaContainer::from_bytes(&bytes).unwrap();
        assert_eq!(back.model, "toy");
        assert_eq!(back.sites.len(), 2);
        assert_eq!(back.sites[1].kind, SiteKind::Act);
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors[0].shape, vec![2, 3]);
        let Payload::Packed { bytes: pb, min_level, pack_bits, numel, .. } =
            &back.tensors[0].payload
        else {
            panic!("expected packed payload")
        };
        let levels = unpack_levels(pb, *numel, *min_level, *pack_bits).unwrap();
        assert_eq!(levels, vec![-3, -1, 0, 2, 3, 1]);
        let Payload::F32(v) = &back.tensors[1].payload else {
            panic!("expected f32 payload")
        };
        assert_eq!(v, &vec![0.5, -0.25, 0.0]);
        assert!(back.config().unwrap().str_or("family", "") == "mlp");
    }

    #[test]
    fn reader_rejects_bad_magic_version_and_truncation() {
        let c = tiny_container();
        let bytes = c.to_bytes();
        // magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        let err = GetaContainer::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        // version
        let mut bad = bytes.clone();
        bad[4] = 99;
        let err = GetaContainer::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        // truncation at every prefix length must error, never panic
        for cut in [5, 12, bytes.len() / 2, bytes.len() - 1] {
            assert!(GetaContainer::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
        // trailing garbage
        let mut bad = bytes.clone();
        bad.push(0);
        let err = GetaContainer::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn reader_rejects_cross_references() {
        // packed tensor referencing an activation site
        let mut c = tiny_container();
        if let Payload::Packed { site, .. } = &mut c.tensors[0].payload {
            *site = 1;
        }
        let err = GetaContainer::from_bytes(&c.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("activation site"), "{err}");
        // out-of-range site index
        let mut c = tiny_container();
        if let Payload::Packed { site, .. } = &mut c.tensors[0].payload {
            *site = 7;
        }
        let err = GetaContainer::from_bytes(&c.to_bytes()).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn pack_unpack_roundtrip_every_bitwidth_2_to_8() {
        // deterministic boundary sweep: for each learned bit width the full
        // signed level range [-cap, cap] must survive pack -> unpack
        for bits in 2u8..=8 {
            let cap = 1i32 << (bits - 1);
            let mut levels: Vec<i32> = (-cap..=cap).collect();
            levels.extend([0, cap, -cap, 1 - cap, cap - 1]);
            let min = *levels.iter().min().unwrap();
            let range = (*levels.iter().max().unwrap() - min) as u64;
            let pb = bits_for_range(range);
            let bytes = pack_levels(&levels, min, pb);
            let back = unpack_levels(&bytes, levels.len(), min, pb).unwrap();
            assert_eq!(back, levels, "bits {bits}");
            // the payload really is sub-byte-packed, not i32-sized
            assert!(bytes.len() < levels.len() * 4, "bits {bits}");
        }
    }

    #[test]
    fn prop_pack_unpack_roundtrip_is_lossless() {
        crate::util::prop::check(
            120,
            |g| {
                let bits = 2 + g.rng.below(7) as u8; // 2..=8
                let cap = 1i32 << (bits - 1);
                let n = g.size(48);
                let levels: Vec<i32> = (0..n)
                    .map(|_| (g.f32_in(-(cap as f32), cap as f32)).round() as i32)
                    .collect();
                (bits, levels)
            },
            |(bits, levels)| {
                let min = *levels.iter().min().unwrap();
                let range = (*levels.iter().max().unwrap() - min) as u64;
                let pb = bits_for_range(range).max(*bits);
                let bytes = pack_levels(levels, min, pb);
                let back = unpack_levels(&bytes, levels.len(), min, pb)
                    .map_err(|e| e.to_string())?;
                if &back == levels {
                    Ok(())
                } else {
                    Err(format!("lossy roundtrip at {pb} bits: {levels:?} -> {back:?}"))
                }
            },
        );
    }

    #[test]
    fn fits_nibble_is_a_sound_hint() {
        let packed = |min_level: i32, pack_bits: u8| Payload::Packed {
            site: 0,
            min_level,
            pack_bits,
            bytes: Vec::new(),
            numel: 0,
        };
        // full signed-nibble span: -7 + (2^4 - 1) = 8 > 7 — not guaranteed
        assert!(!packed(-7, 4).fits_nibble());
        // spans that provably sit inside -7..=7
        assert!(packed(-7, 3).fits_nibble()); // -7..=0
        assert!(packed(0, 3).fits_nibble()); // 0..=7
        assert!(packed(-4, 3).fits_nibble()); // -4..=3
        // clearly out of range
        assert!(!packed(-128, 8).fits_nibble());
        assert!(!packed(8, 1).fits_nibble());
        assert!(!Payload::F32(vec![1.0]).fits_nibble());
    }

    #[test]
    fn bits_for_range_is_minimal() {
        assert_eq!(bits_for_range(0), 1);
        assert_eq!(bits_for_range(1), 1);
        assert_eq!(bits_for_range(2), 2);
        assert_eq!(bits_for_range(3), 2);
        assert_eq!(bits_for_range(255), 8);
        assert_eq!(bits_for_range(256), 9);
    }
}
