//! Deployment subsystem: turn a trained, group-zeroed, quantized model
//! into a `.geta` artifact and run it with a packed-integer inference
//! engine over the shrunk shapes.
//!
//! The training pipeline only ever *simulates* compression (fake-quant
//! forward, zeroed groups); this module makes it physical:
//!
//! * [`format`] — the versioned little-endian `.geta` container:
//!   kept-channel-sliced shapes, bit-packed integer weights at each site's
//!   learned bit width, per-site (d, t, q_m), strict reader.
//! * [`engine`] — [`GetaEngine`]: the **shared planned executor**
//!   (`runtime::exec` — the same tiled, multi-threaded op kernels the
//!   training interpreter runs) over the slice-propagated program
//!   (`subnet::propagate_slices`), batched `infer` with `std::thread`
//!   micro-batch sharding, plus a dense-f32 baseline over the same
//!   executor for honest speedup numbers. Two compute paths
//!   ([`KernelKind`]): dequantize-on-load f32, or the integer path that
//!   keeps ≤8-bit weight sites resident as i8 levels and multiplies them
//!   through the `tensor/iops.rs` integer GEMMs (i8×i8 with exact i32
//!   accumulation at activation-quant-fed nodes, mixed f32×i8 elsewhere).
//! * [`export_compressed`] / [`export_to_file`] — the bridge from
//!   `subnet::construct`'s `CompressedModel` to the container.
//!
//! Parity obligation: for every exportable family, the compressed engine's
//! logits must match the native interpreter's masked-model eval within
//! 1e-4 (`rust/tests/test_deploy.rs`). This holds because (1) packed
//! levels dequantize to exactly the fake-quantized weights the
//! interpreter multiplies, (2) structured slicing removes only channels
//! whose masked contribution is exactly zero, and (3) both sides run the
//! **same executor core** (`runtime::exec::forward`, f64-accumulated
//! kernels) with per-micro-batch normalization statistics.

pub mod engine;
pub mod format;

pub use engine::{GetaEngine, KernelKind};
pub use format::{GetaContainer, Payload, SiteKind, SiteRecord, TensorRecord};

use anyhow::Result;

use crate::graph::PruneGroup;
use crate::metrics::bops::LayerCost;
use crate::optim::qasso::SiteSpec;
use crate::quant::QParams;
use crate::subnet::{self, CompressedModel};
use crate::tensor::ParamStore;
use crate::util::json::Json;

/// Build a `.geta` container from a constructed [`CompressedModel`].
/// `sites`/`q` are the plan-order site list and learned quantizer rows
/// (`graph::builders::quant_site_specs` order — the same rows the training
/// interpreter indexed).
pub fn export_compressed(
    config: &Json,
    sites: &[SiteSpec],
    q: &[QParams],
    cm: &CompressedModel,
) -> Result<GetaContainer> {
    anyhow::ensure!(
        sites.len() == q.len(),
        "site/qparam count mismatch: {} vs {}",
        sites.len(),
        q.len()
    );
    let site_records: Vec<SiteRecord> = sites
        .iter()
        .zip(q)
        .map(|(s, qp)| SiteRecord {
            name: s.name.clone(),
            kind: if s.param.is_some() {
                SiteKind::Weight
            } else {
                SiteKind::Act
            },
            q: *qp,
            bits: (qp.bit_width().round() as i64).clamp(2, 32) as u8,
        })
        .collect();
    let mut tensors = Vec::with_capacity(cm.sliced.tensors.len());
    for t in &cm.sliced.tensors {
        let packed = cm.packed.iter().find(|p| p.name == t.name);
        let payload = match packed {
            Some(p) => {
                let site = sites
                    .iter()
                    .position(|s| s.param.as_deref() == Some(t.name.as_str()))
                    .ok_or_else(|| {
                        anyhow::anyhow!("packed tensor `{}` has no weight site", t.name)
                    })?;
                anyhow::ensure!(
                    p.levels.len() == t.numel(),
                    "packed tensor `{}`: {} levels for {} elements",
                    t.name,
                    p.levels.len(),
                    t.numel()
                );
                let min = p.levels.iter().copied().min().unwrap_or(0);
                let max = p.levels.iter().copied().max().unwrap_or(0);
                let pack_bits = format::bits_for_range((max as i64 - min as i64) as u64).min(32);
                Payload::Packed {
                    site: site as u32,
                    min_level: min,
                    pack_bits,
                    bytes: format::pack_levels(&p.levels, min, pack_bits),
                    numel: p.levels.len(),
                }
            }
            None => Payload::F32(t.data.clone()),
        };
        tensors.push(TensorRecord {
            name: t.name.clone(),
            shape: t.shape.clone(),
            payload,
        });
    }
    Ok(GetaContainer {
        model: config.str_or("name", "<unnamed>"),
        family: config.str_or("family", ""),
        task: config.str_or("task", "image_cls"),
        config_text: config.to_string(),
        sites: site_records,
        tensors,
    })
}

/// Full in-memory export path: re-zero pruned groups (masked-eval parity
/// must never depend on optimizer drift), construct the compressed
/// deliverable, and build the container. Every consumer of the artifact —
/// the `geta export` CLI, `bench-infer`, and the round-trip tests — goes
/// through this one function, so the benchmarked path and the shipped path
/// can never drift apart.
#[allow(clippy::too_many_arguments)]
pub fn export_model(
    config: &Json,
    sites: &[SiteSpec],
    groups: &[PruneGroup],
    pruned: &[bool],
    costs: &[LayerCost],
    params: &mut ParamStore,
    q: &[QParams],
) -> Result<(GetaContainer, CompressedModel)> {
    subnet::zero_pruned(params, groups, pruned);
    let cm = subnet::construct(params, groups, pruned, costs, sites, q);
    let container = export_compressed(config, sites, q, &cm)?;
    Ok((container, cm))
}

/// [`export_model`] plus the file write.
#[allow(clippy::too_many_arguments)]
pub fn export_to_file(
    config: &Json,
    sites: &[SiteSpec],
    groups: &[PruneGroup],
    pruned: &[bool],
    costs: &[LayerCost],
    params: &mut ParamStore,
    q: &[QParams],
    path: &std::path::Path,
) -> Result<(GetaContainer, CompressedModel)> {
    let (container, cm) = export_model(config, sites, groups, pruned, costs, params, q)?;
    container.write(path)?;
    Ok((container, cm))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph;
    use crate::runtime::{native, Backend, HostArray};
    use crate::util::json;

    /// End-to-end export -> load -> infer on an untrained tiny mlp: parity
    /// with the masked interpreter eval, without any training in the loop.
    /// (The trained per-family roundtrips live in tests/test_deploy.rs.)
    #[test]
    fn export_load_infer_parity_on_tiny_mlp() {
        let cfg = json::parse(
            r#"{"name": "t_mlp", "family": "mlp", "task": "image_cls",
                "image": {"size": 4, "channels": 2}, "hidden": [8, 6],
                "num_classes": 3, "quant": {"weight": true, "act": true}}"#,
        )
        .unwrap();
        let e = native::NativeEngine::from_config(&cfg).unwrap();
        let mut params = e.init_params(7);
        let q = e.init_qparams(&params, 6.0);
        let space = graph::search_space_for(&cfg).unwrap();
        // prune every third group
        let pruned: Vec<bool> = (0..space.groups.len()).map(|g| g % 3 == 0).collect();
        let costs = crate::metrics::layer_costs(&cfg).unwrap();
        let sites = e.site_specs();
        let path = std::env::temp_dir().join("geta_unit_tiny_mlp.geta");
        let (container, cm) =
            export_to_file(&cfg, &sites, &space.groups, &pruned, &costs, &mut params, &q, &path)
                .unwrap();
        assert!(cm.params_after < cm.params_before);
        let disk = std::fs::metadata(&path).unwrap().len() as usize;
        assert!(disk < cm.size_fp32_before, "{disk} vs dense {}", cm.size_fp32_before);
        assert_eq!(disk, container.to_bytes().len());

        let engine = GetaEngine::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let bsz = e.manifest().batch.batch_size();
        let (train, _) = crate::data::SynthData::for_model(&cfg, bsz.max(8), 8, 3);
        let idxs: Vec<usize> = (0..bsz).collect();
        let (x, y) = train.batch(&idxs);
        let masked = e.eval_logits(&params, &q, &x, &y).unwrap();
        let got = engine.infer(&x).unwrap();
        assert_eq!(got.len(), masked.len());
        for i in 0..got.len() {
            assert!(
                (got[i] - masked[i]).abs() <= 1e-4 * (1.0 + masked[i].abs()),
                "logit[{i}]: {} vs masked {}",
                got[i],
                masked[i]
            );
        }

        // the integer compute path must hold the same parity bar: weights
        // stay resident as i8 levels (6-bit init — every site eligible)
        // and the GEMMs run in the integer domain
        let int_engine = GetaEngine::from_container_kernel(&container, KernelKind::Int8).unwrap();
        assert_eq!(int_engine.kernel, KernelKind::Int8);
        assert!(
            int_engine.int_sites() > 0,
            "no weight became i8-resident at 6-bit init"
        );
        let got_int = int_engine.infer(&x).unwrap();
        assert_eq!(got_int.len(), masked.len());
        for i in 0..got_int.len() {
            assert!(
                (got_int[i] - masked[i]).abs() <= 1e-4 * (1.0 + masked[i].abs()),
                "int8 logit[{i}]: {} vs masked {}",
                got_int[i],
                masked[i]
            );
        }
        // thread count must not change results (micro-batch sharding only)
        let mut many = GetaEngine::from_container(&container).unwrap();
        many.threads = 4;
        many.micro_batch = bsz; // same stats granularity
        let HostArray::F32(xv) = &x else { panic!() };
        let mut x2 = xv.clone();
        x2.extend_from_slice(xv);
        let big = HostArray::F32(x2);
        let a = {
            let mut one = GetaEngine::from_container(&container).unwrap();
            one.threads = 1;
            one.micro_batch = bsz;
            one.infer(&big).unwrap()
        };
        let b = many.infer(&big).unwrap();
        assert_eq!(a, b, "thread sharding changed results");
        // integer path: bitwise identical across worker counts too (i32
        // accumulation is associative; the epilogue is per-element)
        let ia = {
            let mut one = GetaEngine::from_container_kernel(&container, KernelKind::Int8).unwrap();
            one.threads = 1;
            one.micro_batch = bsz;
            one.infer(&big).unwrap()
        };
        let ib = {
            let mut four = GetaEngine::from_container_kernel(&container, KernelKind::Int8).unwrap();
            four.threads = 4;
            four.micro_batch = bsz;
            four.infer(&big).unwrap()
        };
        assert_eq!(ia, ib, "int8 thread sharding changed results");

        // int4 kernel at a 6-bit init: nothing fits a nibble, so the
        // residency ladder must degrade every packed site to i8 — never
        // to a silent f32 dequant — and hold the same parity bar
        let i4 = GetaEngine::from_container_kernel(&container, KernelKind::Int4).unwrap();
        assert_eq!(i4.kernel, KernelKind::Int4);
        assert_eq!(i4.u4_sites(), 0, "6-bit levels cannot be u4-resident");
        assert_eq!(
            i4.int_sites(),
            int_engine.int_sites(),
            "int4 ladder must fall back to i8 residency site-for-site"
        );
        let got_i4 = i4.infer(&x).unwrap();
        assert_eq!(got_i4, got_int, "int4 fallback must run the same i8 kernels");

        // re-export with a 4-bit init: every site's levels fit a signed
        // nibble, so the int4 engine keeps them packed two-per-byte and
        // the u4 GEMMs must hold the masked-eval parity bar themselves
        let q4 = e.init_qparams(&params, 4.0);
        let mut params4 = params.clone();
        let (container4, _) = export_model(
            &cfg,
            &sites,
            &space.groups,
            &pruned,
            &costs,
            &mut params4,
            &q4,
        )
        .unwrap();
        let u4 = GetaEngine::from_container_kernel(&container4, KernelKind::Int4).unwrap();
        assert!(u4.u4_sites() > 0, "no weight became u4-resident at 4-bit init");
        assert_eq!(u4.int_sites(), 0, "4-bit levels should all pack as u4");
        let masked4 = e.eval_logits(&params4, &q4, &x, &y).unwrap();
        let got_u4 = u4.infer(&x).unwrap();
        assert_eq!(got_u4.len(), masked4.len());
        for i in 0..got_u4.len() {
            assert!(
                (got_u4[i] - masked4[i]).abs() <= 1e-4 * (1.0 + masked4[i].abs()),
                "int4 logit[{i}]: {} vs masked {}",
                got_u4[i],
                masked4[i]
            );
        }
        // and stays bitwise invariant across worker counts
        let ua = {
            let mut one = GetaEngine::from_container_kernel(&container4, KernelKind::Int4).unwrap();
            one.threads = 1;
            one.micro_batch = bsz;
            one.infer(&big).unwrap()
        };
        let ub = {
            let mut four =
                GetaEngine::from_container_kernel(&container4, KernelKind::Int4).unwrap();
            four.threads = 4;
            four.micro_batch = bsz;
            four.infer(&big).unwrap()
        };
        assert_eq!(ua, ub, "int4 thread sharding changed results");

        // tampering: swapping two packed tensors' site indices must be
        // rejected at load (each would dequantize with the other's step d)
        let mut tampered = container.clone();
        let packed_idx: Vec<usize> = tampered
            .tensors
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t.payload, Payload::Packed { .. }))
            .map(|(i, _)| i)
            .collect();
        assert!(packed_idx.len() >= 2);
        let (i0, i1) = (packed_idx[0], packed_idx[1]);
        let s0 = match &tampered.tensors[i0].payload {
            Payload::Packed { site, .. } => *site,
            _ => unreachable!(),
        };
        let s1 = match &tampered.tensors[i1].payload {
            Payload::Packed { site, .. } => *site,
            _ => unreachable!(),
        };
        if let Payload::Packed { site, .. } = &mut tampered.tensors[i0].payload {
            *site = s1;
        }
        if let Payload::Packed { site, .. } = &mut tampered.tensors[i1].payload {
            *site = s0;
        }
        let err = GetaEngine::from_container(&tampered).unwrap_err().to_string();
        assert!(err.contains("not its own weight site"), "{err}");
    }
}
