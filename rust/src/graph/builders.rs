//! Model-family trace-graph builders.
//!
//! Each builder mirrors the corresponding JAX plan function in
//! `python/compile/models/` node-for-node and name-for-name — the config
//! JSON under `configs/models/` is the single source of truth for both
//! sides, and `rust/tests/test_manifest_graph.rs` cross-checks the AOT
//! manifest against these graphs.
//!
//! With `with_quant = true` the builder emits the *quantization-aware*
//! trace graph: every quantized weight grows an attached branch
//! (QParam -> QPow -> QClip -> QRound -> QScale -> consumer) and every
//! activation-quant site threads an inserted branch between the activation
//! and its consumers — the structures Algorithm 1 must merge away.

use anyhow::Result;

use super::ir::{NodeId, Op, TraceGraph};
use crate::optim::qasso::SiteSpec;
use crate::util::json::Json;

/// Build the trace graph for a model config.
pub fn build_trace(cfg: &Json, with_quant: bool) -> Result<TraceGraph> {
    let family = cfg.req("family")?.as_str().unwrap_or_default().to_string();
    let mut b = Builder {
        g: TraceGraph::new(),
        cfg: cfg.clone(),
        with_quant,
        quant_weight: cfg
            .get("quant")
            .map(|q| q.bool_or("weight", false))
            .unwrap_or(false),
        quant_act: cfg
            .get("quant")
            .map(|q| q.bool_or("act", false))
            .unwrap_or(false),
        qsites: Vec::new(),
    };
    match family.as_str() {
        "mlp" => b.mlp()?,
        "vgg" => b.vgg()?,
        "resnet" => b.resnet()?,
        "bert" => b.bert()?,
        "gpt" => b.gpt()?,
        "vit" => b.vit()?,
        "swin" => b.swin()?,
        other => anyhow::bail!("unknown family {other}"),
    }
    Ok(b.g)
}

/// Ordered quant sites of a config (must match the python plan order).
pub fn quant_sites(cfg: &Json) -> Result<Vec<(String, String)>> {
    let mut b = Builder {
        g: TraceGraph::new(),
        cfg: cfg.clone(),
        with_quant: true,
        quant_weight: cfg
            .get("quant")
            .map(|q| q.bool_or("weight", false))
            .unwrap_or(false),
        quant_act: cfg
            .get("quant")
            .map(|q| q.bool_or("act", false))
            .unwrap_or(false),
        qsites: Vec::new(),
    };
    match cfg.req("family")?.as_str().unwrap_or_default() {
        "mlp" => b.mlp()?,
        "vgg" => b.vgg()?,
        "resnet" => b.resnet()?,
        "bert" => b.bert()?,
        "gpt" => b.gpt()?,
        "vit" => b.vit()?,
        "swin" => b.swin()?,
        other => anyhow::bail!("unknown family {other}"),
    }
    Ok(b.qsites)
}

/// [`quant_sites`] as optimizer `SiteSpec`s — the plan-order site metadata
/// shared by manifest synthesis (runtime/native.rs), the op lowering
/// (runtime/lowering.rs) and BOPs accounting (metrics/bops.rs), so all
/// three index q rows identically.
pub fn quant_site_specs(cfg: &Json) -> Result<Vec<SiteSpec>> {
    Ok(quant_sites(cfg)?
        .into_iter()
        .map(|(name, kind)| SiteSpec {
            param: (kind == "weight").then(|| name.clone()),
            name,
        })
        .collect())
}

struct Builder {
    g: TraceGraph,
    cfg: Json,
    with_quant: bool,
    quant_weight: bool,
    quant_act: bool,
    /// (site name, kind) in plan order.
    qsites: Vec<(String, String)>,
}

impl Builder {
    // ------------------------------------------------------- quant plumbing
    /// Attach a weight-quant branch to layer node `layer` for site `name`.
    fn attach_weight_quant(&mut self, layer: NodeId, site: &str) {
        if self.quant_weight {
            self.qsites.push((site.to_string(), "weight".into()));
        }
        if !(self.with_quant && self.quant_weight) {
            return;
        }
        let p = self.g.add(&format!("{site}.qparam"), Op::QParam { site: site.into() });
        let pow = self.g.chain(p, &format!("{site}.qpow"), Op::QPow);
        let clip = self.g.chain(pow, &format!("{site}.qclip"), Op::QClip);
        let rnd = self.g.chain(clip, &format!("{site}.qround"), Op::QRound);
        let sc = self.g.chain(rnd, &format!("{site}.qscale"), Op::QScale);
        self.g.edge(sc, layer);
    }

    /// Insert an activation-quant branch after node `act` and return the
    /// node consumers should connect from.
    fn insert_act_quant(&mut self, act: NodeId, site: &str) -> NodeId {
        if self.quant_act {
            self.qsites.push((site.to_string(), "act".into()));
        }
        if !(self.with_quant && self.quant_act) {
            return act;
        }
        let m = self
            .g
            .chain(act, &format!("{site}.qmark"), Op::QActMark { site: site.into() });
        let pow = self.g.chain(m, &format!("{site}.qpow"), Op::QPow);
        let clip = self.g.chain(pow, &format!("{site}.qclip"), Op::QClip);
        let rnd = self.g.chain(clip, &format!("{site}.qround"), Op::QRound);
        self.g.chain(rnd, &format!("{site}.qscale"), Op::QScale)
    }

    fn conv(&mut self, prev: NodeId, name: &str, cin: usize, cout: usize, k: usize, stride: usize) -> NodeId {
        let id = self.g.chain(
            prev,
            name,
            Op::Conv {
                cin,
                cout,
                k,
                stride,
                param: format!("{name}.weight"),
            },
        );
        self.attach_weight_quant(id, &format!("{name}.weight"));
        id
    }

    fn linear(&mut self, prev: NodeId, name: &str, din: usize, dout: usize) -> NodeId {
        let id = self.g.chain(
            prev,
            name,
            Op::Linear {
                din,
                dout,
                param: format!("{name}.weight"),
            },
        );
        self.attach_weight_quant(id, &format!("{name}.weight"));
        id
    }

    fn bn(&mut self, prev: NodeId, name: &str, c: usize) -> NodeId {
        self.g.chain(prev, name, Op::BatchNorm { c, param: name.into() })
    }

    fn ln(&mut self, prev: NodeId, name: &str, c: usize) -> NodeId {
        self.g.chain(prev, name, Op::LayerNorm { c, param: name.into() })
    }

    // ------------------------------------------------------------ families
    fn mlp(&mut self) -> Result<()> {
        let img = self.cfg.req("image")?.clone();
        let din0 = img.usize_or("size", 8).pow(2) * img.usize_or("channels", 3);
        let hidden = self.cfg.usize_arr("hidden");
        let ncls = self.cfg.usize_or("num_classes", 10);
        let inp = self.g.add("input", Op::Input);
        let mut prev = self
            .g
            .chain(inp, "flatten", Op::Flatten { spatial: 1 });
        let mut din = din0;
        for (i, &dout) in hidden.iter().enumerate() {
            prev = self.linear(prev, &format!("fc{i}"), din, dout);
            prev = self.g.chain(prev, &format!("fc{i}.relu"), Op::Relu);
            prev = self.insert_act_quant(prev, &format!("fc{i}.act"));
            din = dout;
        }
        let head = self.linear(prev, "head", din, ncls);
        self.g.chain(head, "output", Op::Output);
        Ok(())
    }

    fn vgg(&mut self) -> Result<()> {
        let img = self.cfg.req("image")?.clone();
        let mut cin = img.usize_or("channels", 3);
        let mut size = img.usize_or("size", 16);
        let channels = self.cfg.usize_arr("conv_channels");
        let pool_every = self.cfg.usize_or("pool_every", 2);
        let fc_dims = self.cfg.usize_arr("fc_dims");
        let ncls = self.cfg.usize_or("num_classes", 10);
        let inp = self.g.add("input", Op::Input);
        let mut prev = inp;
        for (i, &cout) in channels.iter().enumerate() {
            prev = self.conv(prev, &format!("features.{i}"), cin, cout, 3, 1);
            prev = self.bn(prev, &format!("features.{i}.bn"), cout);
            prev = self.g.chain(prev, &format!("features.{i}.relu"), Op::Relu);
            prev = self.insert_act_quant(prev, &format!("features.{i}.act"));
            if (i + 1) % pool_every == 0 {
                prev = self.g.chain(prev, &format!("pool{i}"), Op::MaxPool);
                size /= 2;
            }
            cin = cout;
        }
        prev = self.g.chain(
            prev,
            "flatten",
            Op::Flatten { spatial: size * size },
        );
        let mut din = cin * size * size;
        for (i, &dout) in fc_dims.iter().enumerate() {
            prev = self.linear(prev, &format!("fc{i}"), din, dout);
            prev = self.g.chain(prev, &format!("fc{i}.relu"), Op::Relu);
            prev = self.insert_act_quant(prev, &format!("fc{i}.act"));
            din = dout;
        }
        let head = self.linear(prev, "head", din, ncls);
        self.g.chain(head, "output", Op::Output);
        Ok(())
    }

    fn resnet(&mut self) -> Result<()> {
        let img = self.cfg.req("image")?.clone();
        let stem_c = self.cfg.usize_or("stem_channels", 8);
        let stages = self.cfg.usize_arr("stage_channels");
        let blocks = self.cfg.usize_or("blocks_per_stage", 2);
        let ncls = self.cfg.usize_or("num_classes", 10);
        let inp = self.g.add("input", Op::Input);
        let mut prev = self.conv(inp, "stem", img.usize_or("channels", 3), stem_c, 3, 1);
        prev = self.bn(prev, "stem.bn", stem_c);
        prev = self.g.chain(prev, "stem.relu", Op::Relu);
        let mut cin = stem_c;
        for (si, &cout) in stages.iter().enumerate() {
            let stage_stride = if si == 0 { 1 } else { 2 };
            for b in 0..blocks {
                let s = if b == 0 { stage_stride } else { 1 };
                let name = format!("stage{si}.{b}");
                let proj_needed = s != 1 || cin != cout;
                let y1 = self.conv(prev, &format!("{name}.conv1"), cin, cout, 3, s);
                let y1 = self.bn(y1, &format!("{name}.bn1"), cout);
                let y1 = self.g.chain(y1, &format!("{name}.relu1"), Op::Relu);
                let y2 = self.conv(y1, &format!("{name}.conv2"), cout, cout, 3, 1);
                let y2 = self.bn(y2, &format!("{name}.bn2"), cout);
                let skip = if proj_needed {
                    let p = self.conv(prev, &format!("{name}.proj"), cin, cout, 1, s);
                    self.bn(p, &format!("{name}.bnp"), cout)
                } else {
                    prev
                };
                let add = self.g.add(&format!("{name}.add"), Op::Add);
                self.g.edge(y2, add);
                self.g.edge(skip, add);
                prev = self.g.chain(add, &format!("{name}.relu2"), Op::Relu);
                cin = cout;
            }
        }
        prev = self.g.chain(prev, "gap", Op::GlobalAvgPool);
        let head = self.linear(prev, "head", cin, ncls);
        self.g.chain(head, "output", Op::Output);
        Ok(())
    }

    /// Shared pre-LN transformer block; returns the new residual node.
    fn transformer_block(&mut self, x: NodeId, name: &str, dim: usize, heads: usize, mlp_ratio: usize) -> NodeId {
        let ln1 = self.ln(x, &format!("{name}.ln1"), dim);
        let wq = self.linear(ln1, &format!("{name}.attn.wq"), dim, dim);
        let wk = self.linear(ln1, &format!("{name}.attn.wk"), dim, dim);
        let wv = self.linear(ln1, &format!("{name}.attn.wv"), dim, dim);
        let join = self.g.add(
            &format!("{name}.attn.join"),
            Op::AttentionJoin {
                heads,
                head_dim: dim / heads,
            },
        );
        self.g.edge(wq, join);
        self.g.edge(wk, join);
        self.g.edge(wv, join);
        let wo = self.linear(join, &format!("{name}.attn.wo"), dim, dim);
        let add1 = self.g.add(&format!("{name}.add1"), Op::Add);
        self.g.edge(x, add1);
        self.g.edge(wo, add1);
        let ln2 = self.ln(add1, &format!("{name}.ln2"), dim);
        let fc1 = self.linear(ln2, &format!("{name}.fc1"), dim, dim * mlp_ratio);
        let gelu = self.g.chain(fc1, &format!("{name}.gelu"), Op::Gelu);
        let fc2 = self.linear(gelu, &format!("{name}.fc2"), dim * mlp_ratio, dim);
        let add2 = self.g.add(&format!("{name}.add2"), Op::Add);
        self.g.edge(add1, add2);
        self.g.edge(fc2, add2);
        add2
    }

    fn bert(&mut self) -> Result<()> {
        let dim = self.cfg.usize_or("dim", 64);
        let heads = self.cfg.usize_or("heads", 4);
        let blocks = self.cfg.usize_or("blocks", 2);
        let ratio = self.cfg.usize_or("mlp_ratio", 4);
        let inp = self.g.add("input", Op::Input);
        let mut prev = self.g.chain(
            inp,
            "embed",
            Op::Embedding {
                dim,
                param: "embed.tok".into(),
            },
        );
        prev = self.ln(prev, "embed.ln", dim);
        for b in 0..blocks {
            prev = self.transformer_block(prev, &format!("block{b}"), dim, heads, ratio);
        }
        prev = self.ln(prev, "final.ln", dim);
        let head = self.linear(prev, "span_head", dim, 2);
        self.g.chain(head, "output", Op::Output);
        Ok(())
    }

    fn gpt(&mut self) -> Result<()> {
        let dim = self.cfg.usize_or("dim", 64);
        let heads = self.cfg.usize_or("heads", 4);
        let blocks = self.cfg.usize_or("blocks", 2);
        let ratio = self.cfg.usize_or("mlp_ratio", 4);
        let vocab = self.cfg.usize_or("vocab", 128);
        let inp = self.g.add("input", Op::Input);
        let mut prev = self.g.chain(
            inp,
            "embed",
            Op::Embedding {
                dim,
                param: "embed.tok".into(),
            },
        );
        for b in 0..blocks {
            prev = self.transformer_block(prev, &format!("block{b}"), dim, heads, ratio);
        }
        prev = self.ln(prev, "final.ln", dim);
        let head = self.linear(prev, "lm_head", dim, vocab);
        self.g.chain(head, "output", Op::Output);
        Ok(())
    }

    fn vit(&mut self) -> Result<()> {
        let img = self.cfg.req("image")?.clone();
        let dim = self.cfg.usize_or("dim", 48);
        let heads = self.cfg.usize_or("heads", 4);
        let blocks = self.cfg.usize_or("blocks", 2);
        let ratio = self.cfg.usize_or("mlp_ratio", 4);
        let patch = self.cfg.usize_or("patch", 4);
        let ncls = self.cfg.usize_or("num_classes", 10);
        let inp = self.g.add("input", Op::Input);
        // Patch embedding = conv(k=patch, stride=patch); its output space
        // joins the residual stream (frozen by the pos-embed addition).
        let mut prev = self.conv(inp, "patch_embed", img.usize_or("channels", 3), dim, patch, patch);
        // pos-embed add couples the stream with a parameter table => the
        // depgraph treats Embedding spaces as frozen.
        let pos = self.g.add(
            "pos_embed",
            Op::Embedding {
                dim,
                param: "pos_embed".into(),
            },
        );
        let add = self.g.add("embed.add", Op::Add);
        self.g.edge(prev, add);
        self.g.edge(pos, add);
        prev = add;
        for b in 0..blocks {
            prev = self.transformer_block(prev, &format!("block{b}"), dim, heads, ratio);
        }
        prev = self.ln(prev, "final.ln", dim);
        prev = self.g.chain(prev, "pool", Op::TokenPool);
        let head = self.linear(prev, "head", dim, ncls);
        self.g.chain(head, "output", Op::Output);
        Ok(())
    }

    fn swin(&mut self) -> Result<()> {
        let img = self.cfg.req("image")?.clone();
        let dims = self.cfg.usize_arr("stage_dims");
        let stage_blocks = self.cfg.usize_arr("stage_blocks");
        let heads = self.cfg.usize_or("heads", 4);
        let ratio = self.cfg.usize_or("mlp_ratio", 2);
        let patch = self.cfg.usize_or("patch", 2);
        let ncls = self.cfg.usize_or("num_classes", 10);
        let inp = self.g.add("input", Op::Input);
        let mut prev = self.conv(inp, "patch_embed", img.usize_or("channels", 3), dims[0], patch, patch);
        let pos = self.g.add(
            "pos_embed",
            Op::Embedding {
                dim: dims[0],
                param: "pos_embed".into(),
            },
        );
        let add = self.g.add("embed.add", Op::Add);
        self.g.edge(prev, add);
        self.g.edge(pos, add);
        prev = add;
        for (si, &dim) in dims.iter().enumerate() {
            for b in 0..stage_blocks[si] {
                prev = self.transformer_block(prev, &format!("stage{si}.block{b}"), dim, heads, ratio);
            }
            if si + 1 < dims.len() {
                // patch merging: 2x2 channel concat then linear projection
                let cat = self
                    .g
                    .chain(prev, &format!("merge{si}.cat"), Op::ConcatReplicate { k: 4 });
                let mln = self.ln(cat, &format!("merge{si}.ln"), dim * 4);
                prev = self.linear(mln, &format!("merge{si}"), dim * 4, dims[si + 1]);
            }
        }
        prev = self.ln(prev, "final.ln", *dims.last().unwrap());
        prev = self.g.chain(prev, "pool", Op::TokenPool);
        let head = self.linear(prev, "head", *dims.last().unwrap(), ncls);
        self.g.chain(head, "output", Op::Output);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn cfg(name: &str) -> Json {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/models")
            .join(format!("{name}.json"));
        json::parse_file(&path).unwrap()
    }

    #[test]
    fn all_families_build_both_modes() {
        for name in [
            "mlp_tiny", "vgg7_mini", "resnet_mini", "resnet_mini_l",
            "bert_mini", "gpt_mini", "vit_mini", "simplevit_mini", "swin_mini",
        ] {
            let c = cfg(name);
            let plain = build_trace(&c, false).unwrap();
            let quant = build_trace(&c, true).unwrap();
            assert!(plain.topo_order().is_ok(), "{name}");
            assert!(quant.topo_order().is_ok(), "{name}");
            assert_eq!(plain.count_quant_vertices(), 0, "{name}");
            assert!(quant.count_quant_vertices() > 0, "{name}");
            assert!(quant.len() > plain.len(), "{name}");
        }
    }

    #[test]
    fn vgg_has_act_and_weight_branches() {
        let q = build_trace(&cfg("vgg7_mini"), true).unwrap();
        let marks = q
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::QActMark { .. }))
            .count();
        let wparams = q
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::QParam { .. }))
            .count();
        assert_eq!(marks, 6); // one per conv relu
        assert_eq!(wparams, 7); // 6 convs + head
    }

    #[test]
    fn site_order_matches_python_convention() {
        // python plan order for vgg7: conv weights and act sites interleaved
        let sites = quant_sites(&cfg("vgg7_mini")).unwrap();
        assert_eq!(sites[0].0, "features.0.weight");
        assert_eq!(sites[1].0, "features.0.act");
        assert_eq!(sites.last().unwrap().0, "head.weight");
        assert_eq!(sites.len(), 13);
    }

    #[test]
    fn resnet_residual_adds_present() {
        let g = build_trace(&cfg("resnet_mini"), false).unwrap();
        let adds = g.nodes.iter().filter(|n| matches!(n.op, Op::Add)).count();
        assert_eq!(adds, 6); // 3 stages x 2 blocks
    }

    #[test]
    fn bert_attention_joins() {
        let g = build_trace(&cfg("bert_mini"), false).unwrap();
        let joins = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::AttentionJoin { .. }))
            .count();
        assert_eq!(joins, 2);
    }
}
