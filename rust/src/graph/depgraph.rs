//! Dependency-graph analysis: derive the pruning search space (minimally
//! removable structures) from a QADG-reduced trace graph.
//!
//! The analysis propagates **channel spaces** through the graph in
//! topological order (the OTOv2-style analysis the paper's Line 15 defers
//! to), with the extensions the GETA model zoo needs:
//!
//! * residual `Add` joins union the participating spaces (ResNet stages
//!   prune jointly, including projection convs);
//! * `AttentionJoin` unions the q/k/v projection spaces and raises the
//!   space granularity to `head_dim`, producing per-head groups — the
//!   structure per-channel schemes (DJPQ, BB) cannot express;
//! * `Flatten`/`ConcatReplicate` record a copy-major replication so
//!   consumers' input rows map back to producer channels (conv→fc flatten,
//!   Swin patch merging);
//! * `Embedding` spaces and the logits space are frozen (not prunable),
//!   freezing anything they union with (the transformer residual stream).

use std::collections::BTreeMap;

use anyhow::Result;

use super::ir::{Op, TraceGraph};

/// Which side of a layer a member touches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    /// Output structure: zeroed during training, removed at slicing.
    Out,
    /// Input structure: untouched during training (upstream zeros make it
    /// dead), removed at slicing.
    In,
}

/// One tensor slice belonging to a prune group: the elements of `tensor`
/// whose coordinate along `axis` is in `indices`.
#[derive(Debug, Clone)]
pub struct Member {
    pub tensor: String,
    pub axis: usize,
    pub indices: Vec<usize>,
    pub side: Side,
}

/// A minimally removable structure.
#[derive(Debug, Clone)]
pub struct PruneGroup {
    pub id: usize,
    pub label: String,
    pub members: Vec<Member>,
}

impl PruneGroup {
    pub fn out_members(&self) -> impl Iterator<Item = &Member> {
        self.members.iter().filter(|m| m.side == Side::Out)
    }
}

#[derive(Debug)]
pub struct SearchSpace {
    pub groups: Vec<PruneGroup>,
    /// Channel spaces that exist but are frozen (diagnostics).
    pub frozen_spaces: usize,
}

// ---------------------------------------------------------------- internals

#[derive(Debug, Clone)]
struct View {
    space: usize,
    /// copy-major replication: physical channel index = m*C + j.
    copies: usize,
}

struct Uf {
    parent: Vec<usize>,
    granularity: Vec<usize>,
    frozen: Vec<bool>,
    size: Vec<usize>,
    label: Vec<String>,
}

impl Uf {
    fn new() -> Uf {
        Uf {
            parent: Vec::new(),
            granularity: Vec::new(),
            frozen: Vec::new(),
            size: Vec::new(),
            label: Vec::new(),
        }
    }

    fn fresh(&mut self, channels: usize, frozen: bool, label: &str) -> usize {
        let id = self.parent.len();
        self.parent.push(id);
        self.granularity.push(1);
        self.frozen.push(frozen);
        self.size.push(channels);
        self.label.push(label.to_string());
        id
    }

    fn find(&mut self, x: usize) -> usize {
        if self.parent[x] != x {
            let r = self.find(self.parent[x]);
            self.parent[x] = r;
        }
        self.parent[x]
    }

    fn union(&mut self, a: usize, b: usize) -> Result<usize> {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra == rb {
            return Ok(ra);
        }
        if self.size[ra] != self.size[rb] {
            anyhow::bail!(
                "space size mismatch in union: {} ({}) vs {} ({})",
                self.label[ra], self.size[ra], self.label[rb], self.size[rb]
            );
        }
        self.parent[rb] = ra;
        self.granularity[ra] = self.granularity[ra].max(self.granularity[rb]);
        self.frozen[ra] = self.frozen[ra] || self.frozen[rb];
        Ok(ra)
    }

    fn freeze(&mut self, x: usize) {
        let r = self.find(x);
        self.frozen[r] = true;
    }
}

#[derive(Debug, Clone)]
enum Attach {
    /// Conv weight HWIO: out axis 3; in axis 2.
    ConvOut { tensor: String },
    ConvIn { tensor: String },
    /// Linear weight [din, dout]: out axis 1; in axis 0 with replication.
    LinearOut { tensor: String },
    LinearIn { tensor: String, copies: usize },
    /// 1-D channel tensors (bias, gamma, beta): axis 0.
    Channel { tensor: String },
}

/// Run the dependency analysis on a (QADG-reduced) trace graph.
pub fn analyze(g: &TraceGraph) -> Result<SearchSpace> {
    let order = g.topo_order()?;
    let mut uf = Uf::new();
    let mut views: BTreeMap<usize, View> = BTreeMap::new(); // node -> output view
    let mut attachments: Vec<(usize, Attach)> = Vec::new(); // (space, attach)

    let input_space = usize::MAX; // sentinel: image/token inputs have no space

    for &id in &order {
        let node = g.node(id);
        let pred_view = |views: &BTreeMap<usize, View>| -> Option<View> {
            g.preds[id].first().and_then(|p| views.get(p).cloned())
        };
        match &node.op {
            Op::Input => {
                views.insert(id, View { space: input_space, copies: 1 });
            }
            Op::Output => {
                if let Some(v) = pred_view(&views) {
                    if v.space != input_space {
                        uf.freeze(v.space);
                    }
                }
            }
            Op::Conv { cout, param, .. } => {
                let sp = uf.fresh(*cout, false, &node.name);
                attachments.push((sp, Attach::ConvOut { tensor: param.clone() }));
                let bias = param.replace(".weight", ".bias");
                attachments.push((sp, Attach::Channel { tensor: bias }));
                if let Some(v) = pred_view(&views) {
                    if v.space != input_space {
                        attachments.push((
                            v.space,
                            Attach::ConvIn { tensor: param.clone() },
                        ));
                    }
                }
                views.insert(id, View { space: sp, copies: 1 });
            }
            Op::Linear { dout, param, .. } => {
                let sp = uf.fresh(*dout, false, &node.name);
                attachments.push((sp, Attach::LinearOut { tensor: param.clone() }));
                let bias = param.replace(".weight", ".bias");
                attachments.push((sp, Attach::Channel { tensor: bias }));
                if let Some(v) = pred_view(&views) {
                    if v.space != input_space {
                        attachments.push((
                            v.space,
                            Attach::LinearIn { tensor: param.clone(), copies: v.copies },
                        ));
                    }
                }
                views.insert(id, View { space: sp, copies: 1 });
            }
            Op::Embedding { dim, param } => {
                // Embedding tables define the residual stream; frozen.
                let sp = uf.fresh(*dim, true, &node.name);
                attachments.push((sp, Attach::LinearOut { tensor: param.clone() }));
                views.insert(id, View { space: sp, copies: 1 });
            }
            Op::BatchNorm { param, .. } | Op::LayerNorm { param, .. } => {
                let v = pred_view(&views)
                    .ok_or_else(|| anyhow::anyhow!("{}: norm without input", node.name))?;
                if v.space != input_space {
                    // gamma/beta have one entry per *physical* channel; with
                    // replication the same space channel owns `copies`
                    // entries — recorded per group at emission time.
                    attachments.push((v.space, Attach::Channel { tensor: format!("{param}.gamma") }));
                    attachments.push((v.space, Attach::Channel { tensor: format!("{param}.beta") }));
                }
                views.insert(id, v);
            }
            Op::Relu | Op::Gelu | Op::Softmax | Op::MaxPool | Op::GlobalAvgPool | Op::TokenPool => {
                let v = pred_view(&views)
                    .ok_or_else(|| anyhow::anyhow!("{}: passthrough without input", node.name))?;
                views.insert(id, v);
            }
            Op::Flatten { spatial } => {
                let v = pred_view(&views)
                    .ok_or_else(|| anyhow::anyhow!("{}: flatten without input", node.name))?;
                let copies = if v.space == input_space { 1 } else { v.copies * spatial };
                views.insert(id, View { space: v.space, copies });
            }
            Op::ConcatReplicate { k } => {
                let v = pred_view(&views)
                    .ok_or_else(|| anyhow::anyhow!("{}: concat without input", node.name))?;
                views.insert(id, View { space: v.space, copies: v.copies * k });
            }
            Op::Add => {
                let mut it = g.preds[id].iter().filter_map(|p| views.get(p).cloned());
                let first = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("{}: add without inputs", node.name))?;
                let mut root = first.space;
                for v in it {
                    if v.space == input_space || root == input_space {
                        anyhow::bail!("{}: add over raw input", node.name);
                    }
                    if v.copies != first.copies {
                        anyhow::bail!("{}: add with mismatched replication", node.name);
                    }
                    root = uf.union(root, v.space)?;
                }
                views.insert(id, View { space: root, copies: first.copies });
            }
            Op::AttentionJoin { head_dim, .. } => {
                // union q/k/v spaces; per-head granularity
                let spaces: Vec<usize> = g.preds[id]
                    .iter()
                    .filter_map(|p| views.get(p).map(|v| v.space))
                    .collect();
                anyhow::ensure!(spaces.len() == 3, "{}: attention needs q,k,v", node.name);
                let mut root = spaces[0];
                for s in &spaces[1..] {
                    root = uf.union(root, *s)?;
                }
                let r = uf.find(root);
                uf.granularity[r] = uf.granularity[r].max(*head_dim);
                views.insert(id, View { space: root, copies: 1 });
            }
            Op::QParam { .. } | Op::QPow | Op::QClip | Op::QRound | Op::QScale | Op::QActMark { .. } => {
                anyhow::bail!(
                    "{}: quant vertex reached dependency analysis — run qadg_analysis first",
                    node.name
                );
            }
            Op::Merged { .. } => {
                let v = pred_view(&views)
                    .ok_or_else(|| anyhow::anyhow!("{}: merged without input", node.name))?;
                views.insert(id, v);
            }
        }
    }

    // ------------------------------------------------ emit prune groups
    // group attachments by space root
    let mut by_space: BTreeMap<usize, Vec<Attach>> = BTreeMap::new();
    let nspaces = uf.parent.len();
    for (sp, at) in attachments {
        let r = uf.find(sp);
        by_space.entry(r).or_default().push(at);
    }
    // replication per (space, consumer) is already encoded in LinearIn.

    let mut groups = Vec::new();
    let mut frozen_spaces = 0;
    for root in 0..nspaces {
        if uf.find(root) != root {
            continue;
        }
        if uf.frozen[root] {
            frozen_spaces += 1;
            continue;
        }
        let channels = uf.size[root];
        let gran = uf.granularity[root];
        if channels % gran != 0 {
            anyhow::bail!(
                "space {}: channels {} not divisible by granularity {}",
                uf.label[root], channels, gran
            );
        }
        let attaches = match by_space.get(&root) {
            Some(a) => a,
            None => continue,
        };
        // canonical label: lexicographically-first creator tensor — stable
        // under traversal-order differences between plain and QADG-reduced
        // graphs (union roots depend on pred visit order, names don't).
        let label_base = attaches
            .iter()
            .filter_map(|a| match a {
                Attach::ConvOut { tensor } | Attach::LinearOut { tensor } => {
                    Some(tensor.trim_end_matches(".weight").to_string())
                }
                _ => None,
            })
            .min()
            .unwrap_or_else(|| uf.label[root].clone());
        for gi in 0..(channels / gran) {
            let chans: Vec<usize> = (gi * gran..(gi + 1) * gran).collect();
            let mut members = Vec::new();
            for at in attaches {
                match at {
                    Attach::ConvOut { tensor } => members.push(Member {
                        tensor: tensor.clone(),
                        axis: 3,
                        indices: chans.clone(),
                        side: Side::Out,
                    }),
                    Attach::LinearOut { tensor } => members.push(Member {
                        tensor: tensor.clone(),
                        axis: 1,
                        indices: chans.clone(),
                        side: Side::Out,
                    }),
                    Attach::Channel { tensor } => members.push(Member {
                        tensor: tensor.clone(),
                        axis: 0,
                        indices: chans.clone(),
                        side: Side::Out,
                    }),
                    Attach::ConvIn { tensor } => members.push(Member {
                        tensor: tensor.clone(),
                        axis: 2,
                        indices: chans.clone(),
                        side: Side::In,
                    }),
                    Attach::LinearIn { tensor, copies } => {
                        let mut idx = Vec::with_capacity(chans.len() * copies);
                        for m in 0..*copies {
                            for &j in &chans {
                                idx.push(m * channels + j);
                            }
                        }
                        members.push(Member {
                            tensor: tensor.clone(),
                            axis: 0,
                            indices: idx,
                            side: Side::In,
                        });
                    }
                }
            }
            let label = if gran > 1 {
                format!("{label_base}:head{gi}")
            } else {
                format!("{label_base}:ch{gi}")
            };
            groups.push(PruneGroup {
                id: groups.len(),
                label,
                members,
            });
        }
    }
    Ok(SearchSpace {
        groups,
        frozen_spaces,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builders::build_trace;
    use crate::graph::qadg::qadg_analysis;
    use crate::util::json::{self, Json};

    fn cfg(name: &str) -> Json {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/models")
            .join(format!("{name}.json"));
        json::parse_file(&path).unwrap()
    }

    fn space(name: &str) -> SearchSpace {
        let t = build_trace(&cfg(name), true).unwrap();
        analyze(&qadg_analysis(&t)).unwrap()
    }

    #[test]
    fn mlp_groups_are_hidden_neurons() {
        let s = space("mlp_tiny");
        assert_eq!(s.groups.len(), 64 + 32);
        // head output space must be frozen
        assert!(s.groups.iter().all(|g| !g.label.starts_with("head")));
    }

    #[test]
    fn vgg_groups_per_conv_channel() {
        let s = space("vgg7_mini");
        // 16+16+32+32+64+64 conv channels; head frozen
        assert_eq!(s.groups.len(), 224);
        // last conv's groups must carry flatten-expanded head input rows
        let g = s
            .groups
            .iter()
            .find(|g| g.label.starts_with("features.5"))
            .unwrap();
        let head_in = g
            .members
            .iter()
            .find(|m| m.tensor == "head.weight" && m.side == Side::In)
            .expect("flatten-coupled head input member");
        // spatial 2x2 = 4 copies of channel index
        assert_eq!(head_in.indices.len(), 4);
    }

    #[test]
    fn resnet_residual_joint_groups() {
        let s = space("resnet_mini");
        // joint stage spaces: stem+stage0 (8), stage1 (16), stage2 (32);
        // inner conv1 spaces: 8,8,16,16,32,32
        let joint0 = s.groups.iter().filter(|g| g.label.contains("stem")
            || g.label.contains("stage0.0.add") || g.label.contains("stage0")).count();
        assert!(joint0 > 0);
        let total: usize = s.groups.len();
        assert_eq!(total, 8 + 16 + 32 + (8 + 8 + 16 + 16 + 32 + 32));
        // a joint group must contain members from multiple convs + bns
        let g = s.groups.iter().find(|g| {
            g.members.iter().any(|m| m.tensor == "stem.weight")
        }).unwrap();
        assert!(g.members.iter().any(|m| m.tensor == "stage0.0.conv2.weight"));
        assert!(g.members.iter().any(|m| m.tensor == "stem.bn.gamma"));
    }

    #[test]
    fn bert_head_and_neuron_groups() {
        let s = space("bert_mini");
        let heads: Vec<_> = s.groups.iter().filter(|g| g.label.contains("head")).collect();
        assert_eq!(heads.len(), 2 * 4); // 2 blocks x 4 heads
        // each head group ties wq/wk/wv outs and wo ins
        let h = &heads[0];
        for t in ["wq", "wk", "wv"] {
            assert!(
                h.members.iter().any(|m| m.tensor.contains(t) && m.side == Side::Out),
                "missing {t}"
            );
        }
        assert!(h.members.iter().any(|m| m.tensor.contains("wo") && m.side == Side::In));
        // fc1 neuron groups
        let neurons = s.groups.iter().filter(|g| g.label.contains("fc1")).count();
        assert_eq!(neurons, 2 * 256);
        assert_eq!(s.groups.len(), 8 + 512);
    }

    #[test]
    fn swin_merge_replication() {
        let s = space("swin_mini");
        // stage0 attention space groups exist and merge0 input rows are
        // 4-way replicated
        let g = s
            .groups
            .iter()
            .find(|g| g.members.iter().any(|m| m.tensor == "merge0.weight" && m.side == Side::In));
        // stage0 residual stream is frozen (pos embed), so merge0 input
        // comes from the frozen space — no group should reference it.
        assert!(g.is_none());
        // but stage attention + fc1 groups exist
        assert!(s.groups.iter().any(|g| g.label.contains("attn")));
        assert!(s.groups.iter().any(|g| g.label.contains("fc1")));
    }

    #[test]
    fn quant_graph_same_groups_as_plain() {
        for name in ["vgg7_mini", "resnet_mini", "bert_mini", "vit_mini"] {
            let mut plain = analyze(&build_trace(&cfg(name), false).unwrap()).unwrap();
            let mut quant =
                analyze(&qadg_analysis(&build_trace(&cfg(name), true).unwrap())).unwrap();
            assert_eq!(plain.groups.len(), quant.groups.len(), "{name}");
            // group emission order follows space-creation (topo) order,
            // which legitimately differs when QParam roots exist; the
            // *set* of structures must be identical.
            plain.groups.sort_by(|a, b| a.label.cmp(&b.label));
            quant.groups.sort_by(|a, b| a.label.cmp(&b.label));
            for (a, b) in plain.groups.iter().zip(quant.groups.iter()) {
                assert_eq!(a.label, b.label, "{name}");
                assert_eq!(a.members.len(), b.members.len(), "{name}: {}", a.label);
            }
        }
    }

    #[test]
    fn depgraph_rejects_unreduced_quant_graph() {
        let t = build_trace(&cfg("vgg7_mini"), true).unwrap();
        assert!(analyze(&t).is_err());
    }
}
