//! Trace-graph intermediate representation of a quantization-aware DNN.
//!
//! Nodes mirror the operators the JAX model zoo emits, *including* the
//! quantizer sub-graphs: weight quantization hangs an **attached branch**
//! (QParam -> QPow -> QClip -> QRound -> QScale) off its consumer layer,
//! and activation quantization threads an **inserted branch** between an
//! activation and its consumer (paper Fig. 2). These branches contain
//! weight-sharing and shape-ambiguous vertices that break plain dependency
//! analysis — exactly the problem QADG (Algorithm 1) solves.

pub type NodeId = usize;

#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Graph input (image or token batch).
    Input,
    /// Graph output (logits).
    Output,
    /// Convolution, weight layout HWIO. `param` is the weight tensor name
    /// (bias is `<param minus .weight>.bias`).
    Conv {
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        param: String,
    },
    /// Dense layer, weight [din, dout].
    Linear {
        din: usize,
        dout: usize,
        param: String,
    },
    /// Batch normalization (gamma/beta under `param` prefix).
    BatchNorm { c: usize, param: String },
    LayerNorm { c: usize, param: String },
    Relu,
    Gelu,
    Softmax,
    /// Elementwise sum (residual join).
    Add,
    /// Channel-replicating concat: output = k copies of the input space
    /// stacked channelwise (Swin patch merging).
    ConcatReplicate { k: usize },
    MaxPool,
    GlobalAvgPool,
    /// Flatten NHWC -> N,(H*W*C); `spatial` = H*W expansion factor.
    Flatten { spatial: usize },
    /// Token / position embedding lookup-add; creates the residual stream.
    Embedding { dim: usize, param: String },
    /// Multi-head attention joint: unions the q/k/v spaces with per-head
    /// granularity; its output is read by the `wo` projection.
    AttentionJoin { heads: usize, head_dim: usize },
    /// Mean over tokens / cls-token select (passthrough for channels).
    TokenPool,

    // ----- parameterized-quantizer vertices (the QADNN additions) -----
    /// Raw weight tensor vertex — root of an attached branch. Weight
    /// sharing: the same `site` may feed several QPow chains.
    QParam { site: String },
    /// Nonlinear power map |x|^t (shape-ambiguous: scalar exponent
    /// broadcast).
    QPow,
    /// Clip at q_m.
    QClip,
    /// Round-to-step (not differentiable; STE).
    QRound,
    /// Rescale by d.
    QScale,
    /// Activation-quant entry marker carrying the site name.
    QActMark { site: String },
    /// Result of QADG merging — behaves like the op it wraps.
    Merged { label: String, inner: Box<Op> },
}

impl Op {
    /// Does this op create a fresh channel space (vs pass one through)?
    pub fn creates_space(&self) -> bool {
        matches!(
            self,
            Op::Conv { .. } | Op::Linear { .. } | Op::Embedding { .. }
        )
    }

    pub fn param_name(&self) -> Option<&str> {
        match self {
            Op::Conv { param, .. }
            | Op::Linear { param, .. }
            | Op::BatchNorm { param, .. }
            | Op::LayerNorm { param, .. }
            | Op::Embedding { param, .. } => Some(param),
            Op::Merged { inner, .. } => inner.param_name(),
            _ => None,
        }
    }

    pub fn is_quant_vertex(&self) -> bool {
        matches!(
            self,
            Op::QParam { .. }
                | Op::QPow
                | Op::QClip
                | Op::QRound
                | Op::QScale
                | Op::QActMark { .. }
        )
    }
}

#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: Op,
}

/// Directed multigraph with adjacency kept in both directions.
#[derive(Debug, Clone, Default)]
pub struct TraceGraph {
    pub nodes: Vec<Node>,
    pub succs: Vec<Vec<NodeId>>,
    pub preds: Vec<Vec<NodeId>>,
}

impl TraceGraph {
    pub fn new() -> TraceGraph {
        Default::default()
    }

    pub fn add(&mut self, name: &str, op: Op) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            op,
        });
        self.succs.push(Vec::new());
        self.preds.push(Vec::new());
        id
    }

    pub fn edge(&mut self, from: NodeId, to: NodeId) {
        self.succs[from].push(to);
        self.preds[to].push(from);
    }

    /// Convenience: add node with a single predecessor, return its id.
    pub fn chain(&mut self, prev: NodeId, name: &str, op: Op) -> NodeId {
        let id = self.add(name, op);
        self.edge(prev, id);
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn find_by_name(&self, name: &str) -> Option<NodeId> {
        self.nodes.iter().find(|n| n.name == name).map(|n| n.id)
    }

    /// Depth-first traversal order from all roots (nodes with no preds).
    pub fn dfs_order(&self) -> Vec<NodeId> {
        let mut order = Vec::with_capacity(self.len());
        let mut seen = vec![false; self.len()];
        let mut stack: Vec<NodeId> = (0..self.len())
            .filter(|&i| self.preds[i].is_empty())
            .rev()
            .collect();
        while let Some(id) = stack.pop() {
            if seen[id] {
                continue;
            }
            seen[id] = true;
            order.push(id);
            for &s in self.succs[id].iter().rev() {
                if !seen[s] {
                    stack.push(s);
                }
            }
        }
        order
    }

    /// Topological order (Kahn). Errors on cycles — trace graphs are DAGs
    /// by construction, so a cycle means a builder bug.
    pub fn topo_order(&self) -> anyhow::Result<Vec<NodeId>> {
        let mut indeg: Vec<usize> = self.preds.iter().map(|p| p.len()).collect();
        let mut q: Vec<NodeId> = (0..self.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(id) = q.pop() {
            order.push(id);
            for &s in &self.succs[id] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    q.push(s);
                }
            }
        }
        if order.len() != self.len() {
            anyhow::bail!("trace graph has a cycle");
        }
        Ok(order)
    }

    pub fn count_quant_vertices(&self) -> usize {
        self.nodes.iter().filter(|n| n.op.is_quant_vertex()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> TraceGraph {
        let mut g = TraceGraph::new();
        let i = g.add("in", Op::Input);
        let c = g.chain(
            i,
            "conv",
            Op::Conv {
                cin: 3,
                cout: 8,
                k: 3,
                stride: 1,
                param: "conv.weight".into(),
            },
        );
        let r = g.chain(c, "relu", Op::Relu);
        g.chain(r, "out", Op::Output);
        g
    }

    #[test]
    fn builds_and_orders() {
        let g = tiny();
        assert_eq!(g.len(), 4);
        let topo = g.topo_order().unwrap();
        assert_eq!(topo.len(), 4);
        let pos = |n: &str| topo.iter().position(|&i| g.node(i).name == n).unwrap();
        assert!(pos("in") < pos("conv"));
        assert!(pos("conv") < pos("relu"));
    }

    #[test]
    fn dfs_visits_all() {
        let g = tiny();
        assert_eq!(g.dfs_order().len(), 4);
    }

    #[test]
    fn cycle_detected() {
        let mut g = tiny();
        g.edge(3, 0);
        assert!(g.topo_order().is_err());
    }

    #[test]
    fn quant_vertex_class() {
        assert!(Op::QPow.is_quant_vertex());
        assert!(Op::QParam { site: "s".into() }.is_quant_vertex());
        assert!(!Op::Relu.is_quant_vertex());
    }
}
