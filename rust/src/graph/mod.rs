//! Trace-graph IR, QADG analysis (paper Algorithm 1) and dependency-group
//! analysis (the pruning search space).
//!
//! Pipeline: `builders` constructs the quantization-aware trace graph of a
//! model (mirroring the JAX model zoo layer-for-layer, including the
//! attached/inserted quantizer branches that parameterized quantization
//! introduces); `qadg` merges those branches per Algorithm 1; `depgraph`
//! then derives the minimally-removable structures (PruneGroups) that the
//! QASSO optimizer partitions into important/redundant sets.

pub mod ir;
pub mod builders;
pub mod qadg;
pub mod depgraph;

pub use depgraph::{analyze, Member, PruneGroup, SearchSpace, Side};
pub use ir::{Node, NodeId, Op, TraceGraph};
pub use qadg::qadg_analysis;

use crate::util::json::Json;

/// Full pipeline: config -> traced QADNN -> QADG -> pruning search space.
pub fn search_space_for(cfg: &Json) -> anyhow::Result<SearchSpace> {
    let traced = builders::build_trace(cfg, true)?;
    let reduced = qadg_analysis(&traced);
    analyze(&reduced)
}
