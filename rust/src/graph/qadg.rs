//! Quantization-Aware Dependency Graph analysis — paper Algorithm 1.
//!
//! Parameterized quantization rewrites the trace graph in two ways that
//! break classic dependency analysis:
//!
//! * **attached branches** (weight quantization, Fig. 2a): the raw weight
//!   becomes its own vertex feeding a QPow→QClip→QRound→QScale chain into
//!   the consumer layer. The chain contains weight-sharing (QParam) and
//!   shape-ambiguous (scalar-broadcast QPow/QScale) vertices.
//! * **inserted branches** (activation quantization, Fig. 2b): a
//!   QActMark→…→QScale chain is threaded between an activation and its
//!   consumer, splitting what used to be a direct pruning dependency.
//!
//! Algorithm 1 merges each branch into a single vertex and reconnects the
//! graph, after which the standard dependency analysis ([12], implemented
//! in `depgraph.rs`) applies. We realize "merge + replace" by absorbing
//! each branch into its root (weight case) or end (activation case) vertex
//! and recording the absorption in a merge log.

use std::collections::BTreeMap;

use super::ir::{Op, TraceGraph};

#[derive(Debug, Clone, PartialEq)]
pub enum MergeKind {
    /// Attached (weight-quant) branch absorbed into its consumer layer.
    Attached,
    /// Inserted (act-quant) branch absorbed into its end vertex, with the
    /// root (activation) reconnected to it.
    Inserted,
}

#[derive(Debug, Clone)]
pub struct MergeRecord {
    pub site: String,
    pub kind: MergeKind,
    /// Name of the vertex that absorbed the branch.
    pub into: String,
    /// Number of vertices merged away.
    pub merged_vertices: usize,
}

#[derive(Debug)]
pub struct QadgResult {
    pub graph: TraceGraph,
    pub log: Vec<MergeRecord>,
}

/// Run Algorithm 1 and return the reduced graph.
pub fn qadg_analysis(g: &TraceGraph) -> TraceGraph {
    qadg_analysis_logged(g).graph
}

pub fn qadg_analysis_logged(g: &TraceGraph) -> QadgResult {
    let n = g.len();
    let mut delete = vec![false; n];
    let mut log = Vec::new();

    // ---- Lines 3-8: weight-quant attached branches.
    // Roots of attached branches are QParam vertices (V_root^weight); the
    // branch is the maximal quant-vertex chain they feed. Each branch's
    // final QScale feeds the consumer layer, which absorbs the merge.
    for id in 0..n {
        if let Op::QParam { site } = &g.node(id).op {
            let mut branch = vec![id];
            let mut cur = id;
            // follow the single-successor quant chain
            loop {
                let next: Vec<_> = g.succs[cur]
                    .iter()
                    .copied()
                    .filter(|&s| g.node(s).op.is_quant_vertex())
                    .collect();
                if next.len() != 1 {
                    break;
                }
                cur = next[0];
                branch.push(cur);
            }
            // consumer(s) = non-quant successors of the chain tail
            let consumers: Vec<_> = g.succs[cur]
                .iter()
                .copied()
                .filter(|&s| !g.node(s).op.is_quant_vertex())
                .collect();
            for b in &branch {
                delete[*b] = true;
            }
            log.push(MergeRecord {
                site: site.clone(),
                kind: MergeKind::Attached,
                into: consumers
                    .first()
                    .map(|&c| g.node(c).name.clone())
                    .unwrap_or_default(),
                merged_vertices: branch.len(),
            });
        }
    }

    // ---- Lines 9-14: activation-quant inserted branches.
    // Root vertices (V_root^act) are the predecessors of QActMark; end
    // vertices (V_end^act) are the non-quant consumers of the chain tail.
    // The chain is merged into the end vertex and the root reconnected —
    // realized below by transitive edge resolution through deleted nodes.
    for id in 0..n {
        if let Op::QActMark { site } = &g.node(id).op {
            let mut branch = vec![id];
            let mut cur = id;
            loop {
                let next: Vec<_> = g.succs[cur]
                    .iter()
                    .copied()
                    .filter(|&s| g.node(s).op.is_quant_vertex())
                    .collect();
                if next.len() != 1 {
                    break;
                }
                cur = next[0];
                branch.push(cur);
            }
            let ends: Vec<_> = g.succs[cur]
                .iter()
                .copied()
                .filter(|&s| !g.node(s).op.is_quant_vertex())
                .collect();
            for b in &branch {
                delete[*b] = true;
            }
            log.push(MergeRecord {
                site: site.clone(),
                kind: MergeKind::Inserted,
                into: ends
                    .first()
                    .map(|&c| g.node(c).name.clone())
                    .unwrap_or_default(),
                merged_vertices: branch.len(),
            });
        }
    }

    // ---- Rebuild: keep non-deleted vertices; resolve edges transitively
    // through deleted ones (this is the "replace + reconnect" of lines
    // 7 and 12-13 in one pass).
    let mut remap: BTreeMap<usize, usize> = BTreeMap::new();
    let mut out = TraceGraph::new();
    for id in 0..n {
        if !delete[id] {
            let node = g.node(id);
            let nid = out.add(&node.name, node.op.clone());
            remap.insert(id, nid);
        }
    }
    // kept ancestors of a node, walking back through deleted vertices
    fn kept_sources(g: &TraceGraph, delete: &[bool], id: usize, acc: &mut Vec<usize>) {
        for &p in &g.preds[id] {
            if delete[p] {
                kept_sources(g, delete, p, acc);
            } else {
                acc.push(p);
            }
        }
    }
    for id in 0..n {
        if delete[id] {
            continue;
        }
        let mut srcs = Vec::new();
        kept_sources(g, &delete, id, &mut srcs);
        srcs.sort_unstable();
        srcs.dedup();
        for s in srcs {
            out.edge(remap[&s], remap[&id]);
        }
    }
    QadgResult { graph: out, log }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builders::build_trace;
    use crate::util::json;

    fn cfg(name: &str) -> json::Json {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/models")
            .join(format!("{name}.json"));
        json::parse_file(&path).unwrap()
    }

    #[test]
    fn removes_all_quant_vertices_every_model() {
        for name in [
            "mlp_tiny", "vgg7_mini", "resnet_mini", "bert_mini",
            "gpt_mini", "vit_mini", "swin_mini",
        ] {
            let q = build_trace(&cfg(name), true).unwrap();
            let reduced = qadg_analysis(&q);
            assert_eq!(reduced.count_quant_vertices(), 0, "{name}");
            assert!(reduced.topo_order().is_ok(), "{name}");
        }
    }

    /// The central QADG invariant: after Algorithm 1, the reduced graph is
    /// isomorphic (names, ops, edges) to the trace of the *plain* model —
    /// i.e. quantization no longer perturbs the pruning search space.
    #[test]
    fn reduced_graph_matches_plain_trace() {
        for name in ["vgg7_mini", "resnet_mini", "bert_mini", "swin_mini"] {
            let c = cfg(name);
            let plain = build_trace(&c, false).unwrap();
            let reduced = qadg_analysis(&build_trace(&c, true).unwrap());
            assert_eq!(plain.len(), reduced.len(), "{name}: vertex count");
            for (a, b) in plain.nodes.iter().zip(reduced.nodes.iter()) {
                assert_eq!(a.name, b.name, "{name}");
                assert_eq!(a.op, b.op, "{name}: {}", a.name);
            }
            // edge sets must match as (name, name) pairs
            let edges = |g: &TraceGraph| {
                let mut e: Vec<(String, String)> = (0..g.len())
                    .flat_map(|i| {
                        g.succs[i]
                            .iter()
                            .map(move |&s| (i, s))
                            .collect::<Vec<_>>()
                    })
                    .map(|(i, s)| (g.node(i).name.clone(), g.node(s).name.clone()))
                    .collect();
                e.sort();
                e.dedup();
                e
            };
            assert_eq!(edges(&plain), edges(&reduced), "{name}: edges");
        }
    }

    #[test]
    fn merge_log_accounts_for_every_site() {
        let q = build_trace(&cfg("vgg7_mini"), true).unwrap();
        let res = qadg_analysis_logged(&q);
        let attached = res.log.iter().filter(|r| r.kind == MergeKind::Attached).count();
        let inserted = res.log.iter().filter(|r| r.kind == MergeKind::Inserted).count();
        assert_eq!(attached, 7); // 6 conv + head weights
        assert_eq!(inserted, 6); // 6 act sites
        // attached branches merge into their consumer layers
        let conv0 = res.log.iter().find(|r| r.site == "features.0.weight").unwrap();
        assert_eq!(conv0.into, "features.0");
        assert_eq!(conv0.merged_vertices, 5); // QParam,QPow,QClip,QRound,QScale
    }

    #[test]
    fn noop_on_plain_graph() {
        let plain = build_trace(&cfg("resnet_mini"), false).unwrap();
        let res = qadg_analysis_logged(&plain);
        assert!(res.log.is_empty());
        assert_eq!(res.graph.len(), plain.len());
    }
}
