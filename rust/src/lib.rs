//! GETA — General and Efficient Training framework that Automates joint
//! structured pruning and quantization-aware training.
//!
//! Reproduction of "Automatic Joint Structured Pruning and Quantization for
//! Efficient Neural Network Training and Compression" (Qu et al., 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's algorithmic contribution:
//!   quantization-aware dependency graph (QADG) construction, the QASSO
//!   four-stage optimizer (warm-up / projection / joint / cool-down), the
//!   PPSG bit-width projection, saliency-driven group partitioning, subnet
//!   construction, BOPs accounting, baselines, and the training coordinator.
//! * **Layer 2 (python/compile/model.py + models/)** — JAX forward/backward
//!   of each model family with parameterized fake-quantization, lowered once
//!   to HLO text by `python/compile/aot.py`.
//! * **Layer 1 (python/compile/kernels/)** — the fake-quant hot spot as a
//!   Pallas kernel (interpret=True on CPU), checked against a pure-jnp
//!   oracle.
//!
//! Python never runs on the training path: the Rust binary owns every
//! update rule and drives one of two execution backends behind the
//! `runtime::Backend` trait:
//!
//! * **NativeEngine** (always available) — a pure-Rust manifest-driven op
//!   interpreter covering every zoo family: each config lowers to a typed
//!   op IR (runtime/lowering.rs — linear, conv-as-im2col, batch/layer
//!   norm, residual add, multi-head attention, gelu/relu, patch
//!   embed/merge, pooling) executed by the **planned executor**
//!   (runtime/exec.rs: a shape-resolved Plan built once per model, a
//!   buffer arena reused across steps, and a ParamSource seam shared with
//!   deployment), with loss heads + backward with per-site
//!   fake-quantization and STE quant-parameter gradients
//!   (runtime/interp.rs), plus natively synthesized manifests for every
//!   model config. The contraction kernels (tensor/ops.rs) are
//!   cache-tiled and `std::thread`-parallel with f64 per-tile
//!   accumulation, bitwise identical at every `GETA_THREADS` value. This
//!   is what makes `cargo build --release && cargo test -q` hermetic —
//!   CNN and transformer e2e runs included: no Python, JAX or XLA
//!   anywhere.
//! * **PJRT engine** (`--features pjrt`) — loads the AOT artifacts
//!   produced by `make artifacts` and executes the compiled HLO of all
//!   nine zoo models. The `xla` dependency defaults to a vendored stub;
//!   point it at real bindings to run artifacts (see README.md).
//!
//! The **deploy** subsystem closes the loop from simulated to physical
//! compression: `deploy::format` is the versioned `.geta` binary container
//! (kept-channel-sliced shapes + bit-packed integer weights at the learned
//! bit widths), and `deploy::GetaEngine` is a packed-integer inference
//! engine that re-lowers the embedded config, shrinks it with
//! `subnet::propagate_slices`, and serves batched `infer` with
//! `std::thread` micro-batch sharding — running the **same**
//! `runtime::exec` forward core as training, with a parity obligation
//! against the masked interpreter eval (`geta export` / `geta infer` /
//! `geta bench-infer`). Its **integer compute path** (`geta infer
//! --int8`) keeps ≤8-bit weight sites resident as i8 level tensors and
//! multiplies them with the integer kernels in `tensor/iops.rs` — i8×i8
//! with exact i32 accumulation where the input carries activation-quant
//! levels, mixed f32×i8 elsewhere, the dequantization scales folded into
//! a per-output-channel epilogue — so the learned bit widths buy measured
//! wall-clock, not just a BOPs column.
//!
//! The **serve** subsystem puts the compressed artifact behind a request
//! path: `serve::ModelCache` loads each `.geta` model once into an
//! `Arc<GetaEngine>` shared read-only by every worker (failed loads are
//! never cached; `evict` drops replaced artifacts), `serve::Server`
//! fronts it with a bounded 3-lane priority queue (typed load-shedding
//! at saturation, never an unbounded block; per-request deadlines
//! expired in-queue as typed `DeadlineExceeded`), a request coalescer
//! that merges queued requests into one `infer_many` call under a
//! configurable latency budget — bitwise identical to per-request
//! inference, because each request keeps its own micro-batch chunk
//! boundaries — a **supervised** worker pool (the model call runs under
//! `catch_unwind`: a panic fails only its own request as typed
//! `WorkerPanic`, batchmates are re-served solo, and the tainted thread
//! is retired and respawned), and per-request p50/p95/p99 latency
//! histograms; `serve::faults` is a seeded, schedule-driven fault
//! injector (worker panics / latency spikes / poisoned inputs /
//! transient model errors as a pure function of `(seed, arrival index)`)
//! behind the `geta bench-serve --faults` chaos soak, zero-cost and
//! bit-invisible when disarmed; `serve::loadgen` is the open-loop
//! synthetic load generator behind `geta serve` and `geta bench-serve`
//! (RPS × batch-window × workers sweeps into `BENCH_serve.json`), whose
//! pressure mode retries shed submissions under bounded exponential
//! backoff with deterministic jitter. Artifact writes (`.geta`,
//! `.getackpt`) go through `util::atomic_write` (temp file + fsync +
//! rename), so a crash mid-export can never tear the file a server or
//! `--resume` reads next.
//!
//! The **obs** subsystem is the cross-cutting telemetry layer: a span
//! tracer (per-thread buffers → Chrome trace-event JSON) instrumented at
//! per-node forward/backward, QASSO step phases, `.geta` load, and the
//! serve request lifecycle; a process-wide metrics registry (counters /
//! gauges / latency histograms with Prometheus-style exposition and JSON
//! snapshots); and the shared `obs::Stopwatch`. Off by default — enabled
//! via `--trace` / `GETA_TRACE` — with spans kept outside the numeric
//! kernels so traced and untraced logits are bitwise identical
//! (`geta profile`, `geta serve --metrics-every`).

// Clippy policy (CI runs `cargo clippy --workspace -- -D warnings`):
// correctness/suspicious/perf lints stay live; the style lints below are
// allowed deliberately. The numeric kernels and (de)serializers index with
// explicit `for i in 0..n` loops and byte-at-a-time copies on purpose —
// accumulation order is part of the bitwise-determinism contract, so
// iterator/memcpy rewrites are not behavior-preserving here. Builders like
// `Arena::new` are internal and not `Default`-shaped APIs; the bench entry
// points take their full sweep grids as explicit arguments.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::new_without_default,
    clippy::type_complexity,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::len_without_is_empty,
    clippy::excessive_precision
)]

pub mod util;
pub mod obs;
pub mod tensor;
pub mod graph;
pub mod quant;
pub mod optim;
pub mod runtime;
pub mod data;
pub mod metrics;
pub mod subnet;
pub mod deploy;
pub mod serve;
pub mod baselines;
pub mod coordinator;
pub mod config;
pub mod report;
