//! `geta` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   geta graph  --model <name>                 inspect QADG + search space
//!   geta train  --model <name> [--sparsity ..] run GETA on one model
//!   geta repro  <table2|table3|table4|table5|table6|fig3|fig4a|fig4b|table1|all>
//!   geta bench  [--iters N]                    runtime micro-benchmarks
//!   geta models                                list AOT artifacts

use anyhow::Result;

use geta::runtime::Backend as _;
use geta::config::ExperimentConfig;
use geta::coordinator::{GetaCompressor, Trainer};
use geta::optim::qasso::StageMask;
use geta::report::ReportCtx;
use geta::util::cli::Args;

fn art_dir(a: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(a.opt_or("artifacts", "artifacts"))
}

fn main() -> Result<()> {
    let a = Args::from_env();
    match a.subcommand.as_deref() {
        Some("models") => cmd_models(&a),
        Some("graph") => cmd_graph(&a),
        Some("train") => cmd_train(&a),
        Some("repro") => cmd_repro(&a),
        Some("bench") => cmd_bench(&a),
        // `geta --model <name> [...]` without a subcommand means train: the
        // common quick-run spelling (`cargo run -- --model resnet_mini`)
        None if a.opt("model").is_some() => cmd_train(&a),
        _ => {
            println!(
                "geta — joint structured pruning + quantization-aware training\n\n\
                 usage: geta <models|graph|train|repro|bench> [options]\n\
                   geta graph --model vgg7_mini\n\
                   geta train --model resnet_mini --sparsity 0.35 --verbose\n\
                   geta repro all [--steps-scale 0.2]\n\
                   geta bench --iters 20"
            );
            Ok(())
        }
    }
}

fn cmd_models(a: &Args) -> Result<()> {
    let dir = art_dir(a);
    for m in geta::runtime::available_models(&dir) {
        let man = geta::runtime::manifest_for(&dir, &m)?;
        let aot = geta::runtime::uses_artifact(&dir, &m);
        println!(
            "{:<16} task={:<10} params={:<8} qsites={:<4} ({})",
            man.model,
            man.task,
            man.param_count,
            man.qsites.len(),
            if aot { "aot" } else { "native manifest" },
        );
    }
    Ok(())
}

fn cmd_graph(a: &Args) -> Result<()> {
    let model = a.opt_or("model", "vgg7_mini");
    let dir = art_dir(a);
    let man = geta::runtime::manifest_for(&dir, &model)?;
    let traced = geta::graph::builders::build_trace(&man.config, true)?;
    let res = geta::graph::qadg::qadg_analysis_logged(&traced);
    let space = geta::graph::analyze(&res.graph)?;
    println!("model {model}");
    println!(
        "  QADNN trace: {} vertices ({} quantizer vertices)",
        traced.len(),
        traced.count_quant_vertices()
    );
    println!(
        "  QADG: merged {} branches -> {} vertices",
        res.log.len(),
        res.graph.len()
    );
    println!(
        "  search space: {} prunable groups, {} frozen spaces",
        space.groups.len(),
        space.frozen_spaces
    );
    if a.flag("verbose") {
        for g in space.groups.iter().take(12) {
            println!("    {:<28} {} members", g.label, g.members.len());
        }
        if space.groups.len() > 12 {
            println!("    ... {} more", space.groups.len() - 12);
        }
    }
    Ok(())
}

fn cmd_train(a: &Args) -> Result<()> {
    let model = a.opt_or("model", "mlp_tiny");
    let mut exp = ExperimentConfig::defaults_for(&model);
    exp.apply_args(a);
    let mut t = Trainer::new(&art_dir(a), exp)?;
    t.verbose = a.flag("verbose");
    println!(
        "training {model} on {} samples (platform {}), {} steps",
        t.train_data.len(),
        t.engine.platform(),
        t.exp.total_steps()
    );
    let mut geta_c = GetaCompressor::new(&t.engine, &t.exp, StageMask::default())?;
    let r = t.run(&mut geta_c)?;
    println!(
        "\nresult: acc {:.2}%  rel BOPs {:.2}%  avg bits {:.1}  group sparsity {:.2}  param sparsity {:.2}",
        r.accuracy, r.rel_bops, r.avg_bits, r.group_sparsity, r.param_sparsity
    );
    Ok(())
}

fn cmd_repro(a: &Args) -> Result<()> {
    let which = a
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let scale = a.f64_or("steps-scale", 1.0);
    let mut ctx = ReportCtx::new(&art_dir(a), scale, a.flag("verbose"));
    let all = which == "all";
    if all || which == "table1" {
        ctx.table1();
    }
    if all || which == "table2" {
        ctx.table2()?;
    }
    if all || which == "table3" {
        ctx.table3()?;
    }
    if all || which == "table4" {
        ctx.table4()?;
    }
    if all || which == "table5" {
        ctx.table5()?;
    }
    if all || which == "table6" {
        ctx.table6()?;
    }
    if all || which == "fig3" {
        ctx.fig3()?;
    }
    if all || which == "fig4a" {
        ctx.fig4a()?;
    }
    if all || which == "fig4b" {
        ctx.fig4b()?;
    }
    ctx.write_markdown(std::path::Path::new("reports"))?;
    println!("\nmarkdown written to reports/");
    Ok(())
}

fn cmd_bench(a: &Args) -> Result<()> {
    let iters = a.usize_or("iters", 15);
    let dir = art_dir(a);
    let mut b = geta::util::bench::Bencher::new(3, iters);
    // graph analysis latency per model
    for model in ["vgg7_mini", "resnet_mini", "bert_mini"] {
        let man = geta::runtime::manifest_for(&dir, model)?;
        b.bench(&format!("qadg+depgraph/{model}"), || {
            geta::graph::search_space_for(&man.config).unwrap()
        });
    }
    // backend step latency (models without a usable backend are skipped)
    for model in ["mlp_tiny", "resnet_mini", "bert_mini"] {
        let exp = ExperimentConfig::defaults_for(model);
        let t = match Trainer::new(&dir, exp) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("skipping {model}: {e}");
                continue;
            }
        };
        let platform = t.engine.platform();
        let params = t.engine.init_params(0);
        let q = t.engine.init_qparams(&params, 16.0);
        let idxs: Vec<usize> = (0..t.batch_size()).collect();
        let (x, y) = t.train_data.batch(&idxs);
        b.bench(&format!("{platform}_train_step/{model}"), || {
            t.engine.train_step(&params, &q, &x, &y).unwrap()
        });
        b.bench(&format!("{platform}_eval_step/{model}"), || {
            t.engine.eval_step(&params, &q, &x, &y).unwrap()
        });
    }
    std::fs::create_dir_all("reports").ok();
    b.write_log(std::path::Path::new("reports/bench_cli.json")).ok();
    Ok(())
}
