//! `geta` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   geta graph  --model <name>                 inspect QADG + search space
//!   geta train  --model <name> [--sparsity ..] run GETA on one model
//!                                              (--replan: shrink-as-you-train —
//!                                              rebuild the executor plan on the
//!                                              sliced subnet at every prune
//!                                              commit, bitwise identical to the
//!                                              masked-dense loop; --ckpt/
//!                                              --ckpt-every/--halt-at/--resume:
//!                                              .getackpt checkpointing;
//!                                              --losses/--logits: Debug-format
//!                                              determinism probes)
//!   geta export --model <name> [--out f.geta]  train + write a .geta artifact
//!   geta infer  --file f.geta [--int8|--int4]  run the packed inference engine
//!                                              (--int8: integer-domain GEMMs on
//!                                              resident i8 levels; --int4:
//!                                              nibble-packed u4 panels, falling
//!                                              back to i8 then f32 per tensor)
//!   geta bench-infer --model <name> [--json]   dense-f32 vs compressed (f32-dequant,
//!                                              int8 and int4 kernels) wall-clock
//!                                              (--json: BENCH_runtime.json +
//!                                              BENCH_deploy.json at repo root)
//!   geta serve  --model <name> | --file f.geta batched, back-pressured inference
//!                                              service driven by an open-loop
//!                                              load generator (--rps/--requests/
//!                                              --workers/--batch-window-us;
//!                                              --deadline-ms N: expire requests
//!                                              still queued after N ms with a
//!                                              typed DeadlineExceeded; --faults
//!                                              <spec> --seed N: arm the
//!                                              deterministic fault injector)
//!   geta bench-serve --model <name> [--json]   serving latency/throughput sweep
//!                                              over RPS x batch-window x workers
//!                                              (--json: BENCH_serve.json at repo
//!                                              root). With --faults <spec>
//!                                              (e.g. panic:0.05,slow:0.05) runs
//!                                              the chaos soak instead: injected
//!                                              worker panics / latency spikes /
//!                                              poisoned inputs / transient model
//!                                              errors (--seed N, --out f.json;
//!                                              same seed => byte-identical
//!                                              summary), asserting liveness,
//!                                              typed per-request failure, zero
//!                                              ticket leaks and bitwise survivor
//!                                              logits
//!   geta bench-train --model <name> [--json]   training throughput, masked-dense
//!                                              vs shrink-as-you-train, over
//!                                              --threads-sweep (--json:
//!                                              BENCH_train.json at repo root)
//!   geta profile --model <m> [--int8|--int4]   per-op self-time table (op x
//!                                              kernel) from a traced inference
//!                                              pass, plus a Chrome trace-event
//!                                              trace.json; also takes --file
//!   geta repro  <table2|..|fig4b|deploy|all>
//!   geta bench  [--iters N]                    runtime micro-benchmarks
//!   geta models                                list AOT artifacts
//!   geta --list-models                         list valid --model names
//!
//! `--threads N` on any subcommand (and the GETA_THREADS env var) sets the
//! one process-wide worker budget the tiled kernels honor — training and
//! inference alike.
//!
//! `--trace <path>` on any subcommand turns on the span tracer (`geta::obs`)
//! and writes everything recorded over the run to `<path>` as Chrome
//! trace-event JSON (loadable in chrome://tracing or Perfetto). The
//! GETA_TRACE env var does the same (set it to a `.json` path to also name
//! the output file). Tracing is off by default and the instrumentation
//! points cost one relaxed atomic load when off; timing wraps the numeric
//! kernels from the outside, so logits are bitwise identical traced vs
//! untraced. `geta serve --metrics-every <secs>` additionally dumps the
//! process metrics registry (Prometheus text exposition) to stderr on a
//! timer while the load runs.

// Same clippy policy as lib.rs (the bin is its own crate root): style
// lints on explicit index loops / wide bench signatures are deliberate.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::new_without_default,
    clippy::type_complexity,
    clippy::collapsible_if,
    clippy::collapsible_else_if
)]

use anyhow::Result;

use geta::runtime::Backend as _;
use geta::config::ExperimentConfig;
use geta::coordinator::{GetaCompressor, Trainer};
use geta::optim::qasso::StageMask;
use geta::report::ReportCtx;
use geta::util::cli::Args;

fn art_dir(a: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(a.opt_or("artifacts", "artifacts"))
}

/// Resolve `--model`, failing with the full list of valid model names
/// instead of a bare config-load error deep in the stack.
fn resolve_model(a: &Args, default: &str) -> Result<String> {
    let model = a.opt_or("model", default);
    let known = geta::runtime::available_models(&art_dir(a));
    if !known.contains(&model) {
        anyhow::bail!(
            "unknown model `{model}`; valid models are: {}\n(see `geta --list-models`)",
            known.join(", ")
        );
    }
    Ok(model)
}

fn main() -> Result<()> {
    let a = Args::from_env();
    // one shared worker budget: training, inference and the benches all
    // run the tiled kernels in tensor/ops.rs, which honor this (CLI
    // `--threads` > GETA_THREADS env > available parallelism)
    if let Some(t) = a.opt("threads") {
        let n: usize = t
            .parse()
            .map_err(|_| anyhow::anyhow!("--threads `{t}` is not a number"))?;
        geta::tensor::set_threads(n);
    }
    // `--trace <path>` (or the GETA_TRACE env var, folded in by
    // obs::enabled) turns the span tracer on for the whole run; the drain
    // + write happens after the subcommand returns
    let trace_arg = a.opt("trace").map(|s| s.to_string());
    if trace_arg.is_some() {
        geta::obs::set_enabled(true);
    }
    // one stopwatch for the uniform elapsed report every subcommand gets
    // (stderr, so stdout stays byte-stable for the determinism diffs)
    let sw = geta::obs::Stopwatch::start();
    let res = match a.subcommand.as_deref() {
        Some("models") => cmd_models(&a),
        Some("graph") => cmd_graph(&a),
        Some("train") => cmd_train(&a),
        Some("export") => cmd_export(&a),
        Some("infer") => cmd_infer(&a),
        Some("bench-infer") => cmd_bench_infer(&a),
        Some("serve") => cmd_serve(&a),
        Some("bench-serve") => cmd_bench_serve(&a),
        Some("bench-train") => cmd_bench_train(&a),
        Some("profile") => cmd_profile(&a),
        Some("repro") => cmd_repro(&a),
        Some("bench") => cmd_bench(&a),
        None if a.flag("list-models") => {
            for m in geta::runtime::available_models(&art_dir(&a)) {
                println!("{m}");
            }
            Ok(())
        }
        // `geta --model <name> [...]` without a subcommand means train: the
        // common quick-run spelling (`cargo run -- --model resnet_mini`)
        None if a.opt("model").is_some() => cmd_train(&a),
        _ => {
            println!(
                "geta — joint structured pruning + quantization-aware training\n\n\
                 usage: geta <models|graph|train|export|infer|bench-infer|serve|bench-serve|bench-train|profile|repro|bench> [options]\n\
                   geta graph --model vgg7_mini\n\
                   geta train --model resnet_mini --sparsity 0.35 --verbose\n\
                   geta train --model mlp_tiny --sparsity 0.85 --replan --losses losses.txt\n\
                   geta train --model mlp_tiny --ckpt run.getackpt --halt-at 120\n\
                   geta train --model mlp_tiny --resume run.getackpt --replan\n\
                   geta bench-train --model mlp_tiny --sparsity 0.85 --threads-sweep 1,4 --json\n\
                   geta export --model resnet_mini --sparsity 0.5 --out resnet.geta\n\
                   geta infer --file resnet.geta --n 256 --threads 4 [--int8|--int4]\n\
                   geta bench-infer --model resnet_mini --iters 10 --json\n\
                   geta serve --model mlp_tiny --rps 500 --workers 2 --batch-window-us 500\n\
                   geta serve --file resnet.geta --requests 512 --rps 0\n\
                   geta bench-serve --model mlp_tiny --workers 1,2 --windows-us 0,500 --json\n\
                   geta bench-serve --model mlp_tiny --faults panic:0.05,slow:0.05 --seed 7\n\
                   geta profile --model mlp_tiny --int8 [--trace trace.json --metrics-out metrics.txt]\n\
                   geta repro all [--steps-scale 0.2]\n\
                   geta bench --iters 20\n\
                   geta --list-models\n\
                 \n\
                 any subcommand also takes --threads N and --trace <path> (span\n\
                 tracer -> Chrome trace-event JSON; GETA_TRACE=1 works too)"
            );
            Ok(())
        }
    };
    // `profile` writes its own trace file and drains the buffer; for every
    // other subcommand, flush whatever the run recorded
    if geta::obs::enabled() {
        let events = geta::obs::trace::drain();
        if !events.is_empty() {
            let path = trace_arg
                .or_else(geta::obs::env_trace_path)
                .unwrap_or_else(|| "trace.json".to_string());
            geta::obs::trace::write_chrome_trace(std::path::Path::new(&path), &events)?;
            let dropped = geta::obs::trace::dropped();
            eprintln!(
                "[geta] wrote {} trace events to {path}{}",
                events.len(),
                if dropped > 0 { format!(" ({dropped} dropped at buffer cap)") } else { String::new() },
            );
        }
    }
    eprintln!(
        "[geta] {} finished in {:.2}s",
        a.subcommand.as_deref().unwrap_or("(no subcommand)"),
        sw.elapsed_s()
    );
    res
}

fn cmd_models(a: &Args) -> Result<()> {
    let dir = art_dir(a);
    for m in geta::runtime::available_models(&dir) {
        let man = geta::runtime::manifest_for(&dir, &m)?;
        let aot = geta::runtime::uses_artifact(&dir, &m);
        println!(
            "{:<16} task={:<10} params={:<8} qsites={:<4} ({})",
            man.model,
            man.task,
            man.param_count,
            man.qsites.len(),
            if aot { "aot" } else { "native manifest" },
        );
    }
    Ok(())
}

fn cmd_graph(a: &Args) -> Result<()> {
    let model = resolve_model(a, "vgg7_mini")?;
    let dir = art_dir(a);
    let man = geta::runtime::manifest_for(&dir, &model)?;
    let traced = geta::graph::builders::build_trace(&man.config, true)?;
    let res = geta::graph::qadg::qadg_analysis_logged(&traced);
    let space = geta::graph::analyze(&res.graph)?;
    println!("model {model}");
    println!(
        "  QADNN trace: {} vertices ({} quantizer vertices)",
        traced.len(),
        traced.count_quant_vertices()
    );
    println!(
        "  QADG: merged {} branches -> {} vertices",
        res.log.len(),
        res.graph.len()
    );
    println!(
        "  search space: {} prunable groups, {} frozen spaces",
        space.groups.len(),
        space.frozen_spaces
    );
    if a.flag("verbose") {
        for g in space.groups.iter().take(12) {
            println!("    {:<28} {} members", g.label, g.members.len());
        }
        if space.groups.len() > 12 {
            println!("    ... {} more", space.groups.len() - 12);
        }
    }
    Ok(())
}

fn cmd_train(a: &Args) -> Result<()> {
    let model = resolve_model(a, "mlp_tiny")?;
    let mut exp = ExperimentConfig::defaults_for(&model);
    exp.apply_args(a);
    let mut t = Trainer::new(&art_dir(a), exp)?;
    t.verbose = a.flag("verbose");
    let opts = geta::coordinator::TrainOpts {
        replan: a.flag("replan"),
        ckpt: a.opt("ckpt").map(std::path::PathBuf::from),
        ckpt_every: a.usize_or("ckpt-every", 0),
        resume: a.opt("resume").map(std::path::PathBuf::from),
        halt_at: a
            .opt("halt-at")
            .map(|s| {
                s.parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("--halt-at `{s}` is not a number"))
            })
            .transpose()?,
    };
    println!(
        "training {model} on {} samples (platform {}), {} steps{}{}",
        t.train_data.len(),
        t.engine.platform(),
        t.exp.total_steps(),
        if opts.replan { " [shrink-as-you-train]" } else { "" },
        match &opts.resume {
            Some(p) => format!(" [resuming from {}]", p.display()),
            None => String::new(),
        },
    );
    let mut geta_c = GetaCompressor::new(&t.engine, &t.exp, StageMask::default())?;
    let trained = t.run_trained_opts(&mut geta_c, &opts)?;
    // --losses <path>: the full per-step loss curve, one Debug-formatted
    // f32 per line (shortest round-trip representation) — two files diff
    // equal iff the curves are bitwise equal. This is the CI probe for
    // shrink-vs-dense and resume-vs-uninterrupted determinism.
    if let Some(lp) = a.opt("losses") {
        let mut out = String::with_capacity(trained.losses.len() * 12);
        for v in &trained.losses {
            out.push_str(&format!("{v:?}\n"));
        }
        std::fs::write(lp, out)?;
    }
    // --logits <path>: eval logits of the trained model on the first eval
    // batch, through the DENSE engine on the zero-expanded parameters —
    // the coordinate system both modes share (same format as
    // `geta infer --logits`).
    if let Some(lp) = a.opt("logits") {
        let idxs: Vec<usize> = (0..t.batch_size().min(t.eval_data.len())).collect();
        let (x, y) = t.eval_data.batch(&idxs);
        let logits = t.engine.eval_logits(&trained.params, &trained.q, &x, &y)?;
        let mut out = String::with_capacity(logits.len() * 12);
        for v in &logits {
            out.push_str(&format!("{v:?}\n"));
        }
        std::fs::write(lp, out)?;
    }
    if !trained.replans.is_empty() {
        println!(
            "re-planned {}x (after steps {:?}); final plan runs kept-channel shapes",
            trained.replans.len(),
            trained.replans,
        );
    }
    if trained.halted {
        println!(
            "\nhalted at step {} of {}{}",
            trained.losses.len(),
            t.exp.total_steps(),
            match &opts.ckpt {
                Some(p) => format!(" (checkpoint {})", p.display()),
                None => String::new(),
            },
        );
        return Ok(());
    }
    let r = &trained.result;
    println!(
        "\nresult: acc {:.2}%  rel BOPs {:.2}%  avg bits {:.1}  group sparsity {:.2}  param sparsity {:.2}",
        r.accuracy, r.rel_bops, r.avg_bits, r.group_sparsity, r.param_sparsity
    );
    Ok(())
}

fn cmd_export(a: &Args) -> Result<()> {
    use geta::coordinator::Compressor as _;
    let model = resolve_model(a, "mlp_tiny")?;
    let mut exp = ExperimentConfig::defaults_for(&model);
    exp.apply_args(a);
    let mut t = Trainer::new(&art_dir(a), exp)?;
    t.verbose = a.flag("verbose");
    println!(
        "training {model} for export ({} steps, platform {})",
        t.exp.total_steps(),
        t.engine.platform()
    );
    let mut geta_c = GetaCompressor::new(&t.engine, &t.exp, StageMask::default())?;
    let mut trained = t.run_trained(&mut geta_c)?;
    let cfg = t.engine.manifest().config.clone();
    let space = geta::graph::search_space_for(&cfg)?;
    let pruned: Vec<bool> = geta_c
        .pruned_mask()
        .map(|m| m.to_vec())
        .unwrap_or_else(|| vec![false; space.groups.len()]);
    let out = a.opt_or("out", &format!("{model}.geta"));
    let path = std::path::PathBuf::from(&out);
    let (_, cm) = geta::deploy::export_to_file(
        &cfg,
        &t.engine.site_specs(),
        &space.groups,
        &pruned,
        &t.costs,
        &mut trained.params,
        &trained.q,
        &path,
    )?;
    let disk = std::fs::metadata(&path)?.len() as usize;
    println!(
        "\nwrote {out}: {:.1} KiB on disk vs {:.1} KiB dense f32 ({:.2}x smaller)",
        disk as f64 / 1024.0,
        cm.size_fp32_before as f64 / 1024.0,
        cm.size_fp32_before as f64 / disk.max(1) as f64,
    );
    println!(
        "  rel BOPs {:.2}%  avg bits {:.1}  params {} -> {}  acc {:.2}%",
        trained.result.rel_bops,
        trained.result.avg_bits,
        cm.params_before,
        cm.params_after,
        trained.result.accuracy,
    );
    Ok(())
}

fn cmd_infer(a: &Args) -> Result<()> {
    let file = a
        .opt("file")
        .ok_or_else(|| anyhow::anyhow!("`geta infer` needs --file <model.geta>"))?;
    // --threads was already folded into the process-wide budget in main();
    // the engine picks it up via tensor::configured_threads()
    let kernel = if a.flag("int4") {
        geta::deploy::KernelKind::Int4
    } else if a.flag("int8") {
        geta::deploy::KernelKind::Int8
    } else {
        geta::deploy::KernelKind::F32
    };
    let engine = geta::deploy::GetaEngine::load_kernel(std::path::Path::new(file), kernel)?;
    let n = a.usize_or("n", 256);
    // only the eval split is used: keep the discarded train split minimal
    let (_, eval) = geta::data::SynthData::for_model(engine.config(), 1, n.max(1), 1);
    let idxs: Vec<usize> = (0..eval.len()).collect();
    let (x, y) = eval.batch(&idxs);
    let sw = geta::obs::Stopwatch::start();
    let logits = engine.infer(&x)?;
    let ms = sw.elapsed_ms();
    let samples = eval.len();
    if let Some(lp) = a.opt("logits") {
        // one logit per line, Debug-formatted: f32's shortest round-trip
        // representation, so two files diff equal iff the logits are
        // bitwise equal (the CI traced-vs-untraced identity check)
        let mut out = String::with_capacity(logits.len() * 12);
        for v in &logits {
            out.push_str(&format!("{v:?}\n"));
        }
        std::fs::write(lp, out)?;
    }
    println!(
        "{} ({}): {samples} samples in {ms:.2} ms ({:.0} samples/s, {} threads, {} kernel{})",
        engine.model,
        engine.task,
        samples as f64 / (ms / 1e3).max(1e-9),
        engine.threads,
        kernel.label(),
        match kernel {
            geta::deploy::KernelKind::Int8 =>
                format!(", {} i8-resident weights", engine.int_sites()),
            geta::deploy::KernelKind::Int4 => format!(
                ", {} u4-resident + {} i8-resident weights",
                engine.u4_sites(),
                engine.int_sites()
            ),
            geta::deploy::KernelKind::F32 => String::new(),
        },
    );
    if engine.task == "image_cls" {
        let ncls = engine.output_per_sample();
        let geta::runtime::HostArray::I32(labels) = &y else {
            anyhow::bail!("image task expects i32 labels")
        };
        let mut correct = 0usize;
        for (i, &lab) in labels.iter().enumerate() {
            let row = &logits[i * ncls..(i + 1) * ncls];
            let mut best = 0;
            for j in 1..ncls {
                if row[j] > row[best] {
                    best = j;
                }
            }
            if best == lab as usize {
                correct += 1;
            }
        }
        println!("  accuracy {:.2}% on synthetic eval data", 100.0 * correct as f64 / samples as f64);
    }
    Ok(())
}

fn cmd_bench_infer(a: &Args) -> Result<()> {
    let model = resolve_model(a, "mlp_tiny")?;
    let iters = a.usize_or("iters", 10);
    let scale = a.f64_or("steps-scale", 0.12);
    let sparsity = a.f64_or("sparsity", 0.5);
    // default to the process-wide budget so --threads / GETA_THREADS mean
    // the same thing here as in `make bench-json` and the JSON rows agree
    let threads = a.usize_or("threads", geta::tensor::configured_threads());
    let rows = geta::report::bench_deploy(&art_dir(a), &model, scale, sparsity, iters, threads)?;
    let r0 = &rows[0];
    println!(
        "\nbench-infer {model} (batch {}, {iters} iters, best-of):\n\
         \x20 dense f32   {:>8.2} ms/batch   {:>8.1} KiB params",
        r0.batch,
        r0.dense_ms,
        r0.dense_bytes as f64 / 1024.0,
    );
    for r in &rows {
        println!(
            "\x20 .geta {:<5} {:>8.2} ms/batch   {:>8.1} KiB on disk   {:.2}x vs dense{}",
            r.kernel,
            r.compressed_ms,
            r.disk_bytes as f64 / 1024.0,
            r.dense_ms / r.compressed_ms.max(1e-9),
            match r.kernel.as_str() {
                "int8" => format!(
                    "   {:.2}x vs f32-dequant   {} i8-resident weights",
                    r0.compressed_ms / r.compressed_ms.max(1e-9),
                    r.int_sites,
                ),
                "int4" => format!(
                    "   {:.2}x vs f32-dequant   {} u4-resident + {} i8-resident weights",
                    r0.compressed_ms / r.compressed_ms.max(1e-9),
                    r.u4_sites,
                    r.int_sites,
                ),
                _ => String::new(),
            },
        );
    }
    println!(
        "\x20 size {:.2}x smaller   rel BOPs {:.2}%   sparsity {:.2}   avg bits {:.1}",
        r0.dense_bytes as f64 / r0.disk_bytes.max(1) as f64,
        r0.rel_bops,
        r0.group_sparsity,
        r0.avg_bits,
    );
    if a.flag("json") {
        // machine-readable perf log: this model's deploy rows plus the
        // standard resnet/vit batch-32 kernel comparison, so every --json
        // run re-demonstrates the tiled-vs-naive speedup; the deploy rows
        // also land in the checked-in BENCH_deploy.json summary
        let gemm = geta::report::standard_gemm_suite(iters.min(5));
        let path = geta::report::bench_json_path();
        geta::report::write_bench_runtime_json(&path, &gemm, &rows)?;
        let dpath = geta::report::bench_deploy_json_path();
        geta::report::write_bench_deploy_json(&dpath, &rows)?;
        for g in &gemm {
            println!(
                "  gemm {}@{}: naive {:.2} ms -> tiled {:.2} ms ({:.2}x, {} threads, invariant {})",
                g.model,
                g.batch,
                g.naive_ms,
                g.tiled_ms,
                g.naive_ms / g.tiled_ms.max(1e-9),
                g.threads,
                g.thread_invariant,
            );
        }
        println!("  wrote {}", path.display());
        println!("  wrote {}", dpath.display());
    }
    Ok(())
}

/// Comma-separated numeric list option (`--workers 1,2,4`), with a
/// default when the flag is absent.
fn list_opt<T: std::str::FromStr>(a: &Args, key: &str, default: &[T]) -> Result<Vec<T>>
where
    T: Copy,
{
    let Some(raw) = a.opt(key) else {
        return Ok(default.to_vec());
    };
    let mut out = Vec::new();
    for part in raw.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(
            part.parse::<T>()
                .map_err(|_| anyhow::anyhow!("--{key}: `{part}` is not a number"))?,
        );
    }
    anyhow::ensure!(!out.is_empty(), "--{key}: empty list");
    Ok(out)
}

/// Serving kernel: int8 by default (the deployment path serving exists
/// for), `--f32` to force the dequantized baseline.
fn serve_kernel(a: &Args) -> geta::deploy::KernelKind {
    if a.flag("f32") {
        geta::deploy::KernelKind::F32
    } else {
        geta::deploy::KernelKind::Int8
    }
}

fn cmd_serve(a: &Args) -> Result<()> {
    use geta::serve::{loadgen, ModelCache, ServeConfig, Server};
    let kernel = serve_kernel(a);
    let cache = ModelCache::new(kernel);
    // engine + request source: a `.geta` artifact, or an in-process
    // train + export when only --model is given
    let (engine, inputs, key) = if let Some(file) = a.opt("file") {
        let engine = cache.get_or_load(std::path::Path::new(file))?;
        let n = a.usize_or("distinct-inputs", 64);
        let (_, eval) = geta::data::SynthData::for_model(engine.config(), 1, n.max(1), 1);
        (engine, loadgen::single_sample_inputs(&eval, n), file.to_string())
    } else {
        let model = resolve_model(a, "mlp_tiny")?;
        let scale = a.f64_or("steps-scale", 0.12);
        let sparsity = a.f64_or("sparsity", 0.5);
        println!("no --file: training {model} in-process (steps-scale {scale})");
        let art = geta::report::train_export(&art_dir(a), &model, scale, sparsity, 8.0)?;
        let mut engine = geta::deploy::GetaEngine::from_container_kernel(&art.container, kernel)?;
        engine.threads = 1;
        let engine = std::sync::Arc::new(engine);
        cache.put(&model, engine.clone());
        let inputs = loadgen::single_sample_inputs(&art.trainer.eval_data, 64);
        (engine, inputs, model)
    };
    let cfg = ServeConfig {
        workers: a.usize_or("workers", 2),
        queue_depth: a.usize_or("queue-depth", 64),
        batch_window: std::time::Duration::from_micros(a.usize_or("batch-window-us", 500) as u64),
        max_batch: a.usize_or("max-batch", 8),
    };
    // --deadline-ms N (0 = none): requests still queued after N ms are
    // expired with a typed DeadlineExceeded instead of occupying a slot
    let deadline_ms = a.usize_or("deadline-ms", 0);
    let spec = loadgen::LoadSpec {
        rps: a.f64_or("rps", 500.0),
        requests: a.usize_or("requests", 512),
        clients: a.usize_or("clients", if a.f64_or("rps", 500.0) > 0.0 { 1 } else { 4 }),
        deadline: (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms as u64)),
        ..Default::default()
    };
    // --faults <spec> --seed N arms the deterministic injector (see
    // serve::faults); unset = the production path, bit-for-bit
    let plan = match a.opt("faults") {
        Some(s) => Some(std::sync::Arc::new(geta::serve::FaultPlan::parse(
            s,
            a.usize_or("seed", 7) as u64,
        )?)),
        None => None,
    };
    println!(
        "serving {key} ({} kernel): {} workers, queue {}, window {}us, max batch {}",
        kernel.label(),
        cfg.workers,
        cfg.queue_depth,
        cfg.batch_window.as_micros(),
        cfg.max_batch,
    );
    println!(
        "load: {} requests at {} ({} client{})",
        spec.requests,
        if spec.rps > 0.0 {
            format!("{:.0} rps open-loop", spec.rps)
        } else {
            "saturation (pressure mode)".to_string()
        },
        spec.clients,
        if spec.clients == 1 { "" } else { "s" },
    );
    let server = Server::start_faulted(engine, cfg, plan.clone());
    // --metrics-every <secs>: dump the process metrics registry (Prometheus
    // text exposition — geta_serve_* counters, queue-depth gauge, latency
    // summary) to stderr on a timer while the load runs
    let metrics_every = a.usize_or("metrics-every", 0);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let dumper = (metrics_every > 0).then(|| {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let period = std::time::Duration::from_secs(metrics_every as u64);
            let mut last = std::time::Instant::now();
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                // sleep in short slices so shutdown isn't held up by the period
                std::thread::sleep(std::time::Duration::from_millis(50));
                if last.elapsed() >= period {
                    last = std::time::Instant::now();
                    eprintln!("--- metrics ---\n{}", geta::obs::metrics::global().exposition());
                }
            }
        })
    });
    let load = loadgen::run(&server, &inputs, &spec);
    let report = server.shutdown();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    if let Some(d) = dumper {
        let _ = d.join();
        // one final snapshot so short runs still see the counters land
        eprintln!("--- metrics (final) ---\n{}", geta::obs::metrics::global().exposition());
    }
    println!(
        "\naccepted {}  shed {}  completed {}  failed {}  expired {}  batches {} (avg batch {:.2})",
        report.stats.accepted,
        report.stats.shed,
        load.completed,
        load.failed,
        report.stats.expired,
        report.stats.batches,
        load.completed as f64 / report.stats.batches.max(1) as f64,
    );
    if load.failed > 0 || report.stats.expired > 0 {
        println!(
            "failure classes: deadline {}  worker_panic {}  model {}  other {}",
            load.failed_deadline, load.failed_panic, load.failed_model, load.failed_other,
        );
    }
    if let Some(plan) = &plan {
        let [p, s, po, t] = plan.injected();
        println!(
            "faults injected: panic {p}  slow {s}  poison {po}  transient {t}  \
             (worker panics {}  restarts {}  dead workers {})",
            report.stats.worker_panics, report.stats.worker_restarts, report.dead_workers,
        );
    }
    println!(
        "throughput {:.0} req/s over {:.2}s",
        load.achieved_rps,
        load.wall.as_secs_f64()
    );
    println!("latency: {}", report.histogram.summary());
    Ok(())
}

/// `geta bench-serve --faults <spec>`: the chaos soak. Drives a
/// fault-armed server and **asserts** (exit code, not just a report) the
/// robustness contract — liveness, typed per-request failure, zero
/// ticket leaks, bitwise survivor logits. The JSON summary it writes is
/// deterministic per (model, seed, spec, requests); CI runs it twice and
/// byte-diffs the two files.
fn cmd_chaos(a: &Args, spec_str: &str) -> Result<()> {
    use geta::serve::{faults, loadgen, FaultPlan, ServeConfig};
    let model = resolve_model(a, "mlp_tiny")?;
    let kernel = serve_kernel(a);
    let scale = a.f64_or("steps-scale", 0.08);
    let sparsity = a.f64_or("sparsity", 0.5);
    let seed = a.usize_or("seed", 7) as u64;
    let requests = a.usize_or("requests", 200);
    let clients = a.usize_or("clients", 4);
    let plan = std::sync::Arc::new(FaultPlan::parse(spec_str, seed)?);
    let art = geta::report::train_export(&art_dir(a), &model, scale, sparsity, 8.0)?;
    let mut engine = geta::deploy::GetaEngine::from_container_kernel(&art.container, kernel)?;
    engine.threads = 1;
    let engine = std::sync::Arc::new(engine);
    let inputs = loadgen::single_sample_inputs(&art.trainer.eval_data, 16);
    // fault-free reference logits, one per distinct input — survivor
    // replies must match these bitwise
    let expected: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| engine.infer(x))
        .collect::<Result<_>>()?;
    let cfg = ServeConfig {
        workers: a.usize_or("workers", 2),
        queue_depth: a.usize_or("queue-depth", 32),
        batch_window: std::time::Duration::from_micros(a.usize_or("batch-window-us", 200) as u64),
        max_batch: a.usize_or("max-batch", 4),
    };
    println!(
        "chaos soak: {model} ({} kernel), {requests} requests x {clients} clients, \
         faults `{spec_str}` seed {seed}",
        kernel.label(),
    );
    // injected panics are expected traffic here — keep their default
    // backtrace spew out of the logs for the duration of the soak
    std::panic::set_hook(Box::new(|_| {}));
    let mut chaos = faults::chaos_soak(engine, &inputs, &expected, cfg, plan, requests, clients);
    let _ = std::panic::take_hook();
    chaos.model = model.clone();
    println!(
        "  completed {}  failed: worker_panic {}  model {}  deadline {}  other {}",
        chaos.completed,
        chaos.failed_worker_panic,
        chaos.failed_model,
        chaos.failed_deadline,
        chaos.failed_other,
    );
    println!(
        "  injected: panic {}  slow {}  poison {}  transient {}",
        chaos.injected_panic, chaos.injected_slow, chaos.injected_poison, chaos.injected_transient,
    );
    println!(
        "  mismatched logits {}  unresolved tickets {}  restarts>0 {}  live after {}",
        chaos.mismatched_logits,
        chaos.unresolved,
        chaos.worker_restarts_positive,
        chaos.server_live_after,
    );
    let out = std::path::PathBuf::from(a.opt_or("out", "chaos_serve.json"));
    geta::report::write_chaos_json(&out, &chaos)?;
    println!("  wrote {}", out.display());
    anyhow::ensure!(chaos.unresolved == 0, "chaos soak leaked {} tickets", chaos.unresolved);
    anyhow::ensure!(
        chaos.mismatched_logits == 0,
        "{} surviving requests returned logits differing from the fault-free run",
        chaos.mismatched_logits
    );
    anyhow::ensure!(chaos.failed_other == 0, "untyped failures: {}", chaos.failed_other);
    anyhow::ensure!(chaos.server_live_after, "server stopped answering after the fault storm");
    anyhow::ensure!(
        chaos.completed + chaos.failed_worker_panic + chaos.failed_model + chaos.failed_deadline
            == chaos.requests,
        "request accounting does not close"
    );
    if chaos.injected_panic > 0 {
        anyhow::ensure!(
            chaos.worker_restarts_positive,
            "panics were injected but no worker was ever respawned"
        );
    }
    println!("chaos soak passed");
    Ok(())
}

fn cmd_bench_serve(a: &Args) -> Result<()> {
    if let Some(spec) = a.opt("faults") {
        let spec = spec.to_string();
        return cmd_chaos(a, &spec);
    }
    let model = resolve_model(a, "mlp_tiny")?;
    let kernel = serve_kernel(a);
    let scale = a.f64_or("steps-scale", 0.08);
    let sparsity = a.f64_or("sparsity", 0.5);
    let workers = list_opt(a, "workers", &[1usize, 2])?;
    let windows = list_opt(a, "windows-us", &[0u64, 500])?;
    let rps = list_opt(a, "rps", &[0.0f64, 500.0])?;
    let requests = a.usize_or("requests", 400);
    let queue_depth = a.usize_or("queue-depth", 128);
    let max_batch = a.usize_or("max-batch", 8);
    println!(
        "bench-serve {model} ({} kernel): workers {workers:?} x windows(us) {windows:?} x rps \
         {rps:?} (0 = saturation), {requests} requests per point",
        kernel.label(),
    );
    let rows = geta::report::bench_serve(
        &art_dir(a),
        &model,
        scale,
        sparsity,
        kernel,
        &workers,
        &windows,
        &rps,
        requests,
        queue_depth,
        max_batch,
    )?;
    println!(
        "\n{:>7} {:>10} {:>8} {:>9} {:>9} {:>9} {:>9} {:>6} {:>9}",
        "workers", "window_us", "rps", "ach_rps", "p50_us", "p95_us", "p99_us", "shed", "avg_batch"
    );
    for r in &rows {
        println!(
            "{:>7} {:>10} {:>8} {:>9.0} {:>9.0} {:>9.0} {:>9.0} {:>6} {:>9.2}",
            r.workers,
            r.batch_window_us,
            if r.rps_target > 0.0 {
                format!("{:.0}", r.rps_target)
            } else {
                "sat".to_string()
            },
            r.achieved_rps,
            r.p50_us,
            r.p95_us,
            r.p99_us,
            r.shed,
            r.avg_batch,
        );
    }
    if a.flag("json") {
        let path = geta::report::bench_serve_json_path();
        geta::report::write_bench_serve_json(&path, &rows)?;
        println!("\nwrote {}", path.display());
    }
    Ok(())
}

fn cmd_bench_train(a: &Args) -> Result<()> {
    let model = resolve_model(a, "mlp_tiny")?;
    let scale = a.f64_or("steps-scale", 0.25);
    // high sparsity by default: the shrink win scales with how much of the
    // net the schedule removes, and the acceptance bar is stated at >= 0.8
    let sparsity = a.f64_or("sparsity", 0.85);
    // `--threads` is the single process-wide budget; the sweep flag is
    // separate so `bench-train` can compare thread counts in one run
    let threads = if a.opt("threads").is_some() && a.opt("threads-sweep").is_none() {
        vec![geta::tensor::configured_threads()]
    } else {
        list_opt(a, "threads-sweep", &[1usize, 4])?
    };
    println!(
        "bench-train {model}: dense-masked vs shrink-as-you-train, sparsity {sparsity}, \
         threads {threads:?} (both modes train bitwise identically; this measures wall-clock)",
    );
    let rows = geta::report::bench_train(&art_dir(a), &model, scale, sparsity, &threads)?;
    println!(
        "\n{:>7} {:>7} {:>6} {:>8} {:>10} {:>13} {:>9} {:>9} {:>10}",
        "threads", "mode", "steps", "replans", "steps/s", "tail_steps/s", "fwbw_ms", "optim_ms", "replan_ms"
    );
    for r in &rows {
        println!(
            "{:>7} {:>7} {:>6} {:>8} {:>10.1} {:>13.1} {:>9.2} {:>9.2} {:>10.2}",
            r.threads,
            r.mode,
            r.steps,
            r.replans,
            r.steps_per_s,
            r.tail_steps_per_s,
            r.train_step_ms,
            r.optim_step_ms,
            r.replan_ms,
        );
    }
    for t in &threads {
        let find = |mode: &str| rows.iter().find(|r| r.threads == *t && r.mode == mode);
        if let (Some(d), Some(s)) = (find("dense"), find("shrink")) {
            println!(
                "  threads {}: post-shrink tail {:.2}x dense (from step {} of {})",
                t,
                s.tail_steps_per_s / d.tail_steps_per_s.max(1e-9),
                s.tail_from_step,
                s.steps,
            );
        }
    }
    if a.flag("json") {
        let path = geta::report::bench_train_json_path();
        geta::report::write_bench_train_json(&path, &rows)?;
        println!("\nwrote {}", path.display());
    }
    Ok(())
}

/// `geta profile`: run a traced inference pass and print a per-op
/// self-time table (op kind x kernel kind, the span names the executor
/// records), then write the raw spans as Chrome trace-event JSON. The
/// engine comes from `--file <m.geta>` or an in-process train + export of
/// `--model`; tracing is switched on only after training finishes, so the
/// trace holds the `.geta` load phases plus the per-node exec spans — not
/// the training loop (pass --trace to a `geta train` run for that).
///
/// `--replan` (with `--model`) instead traces the in-process training run
/// itself with shrink-as-you-train on, and prints a second table of the
/// training-loop phases: `train` (train_step/optim_step/checkpoint) and
/// `replan` (finalize/slice/rebuild) span aggregates.
fn cmd_profile(a: &Args) -> Result<()> {
    let kernel = if a.flag("int4") {
        geta::deploy::KernelKind::Int4
    } else if a.flag("int8") {
        geta::deploy::KernelKind::Int8
    } else {
        geta::deploy::KernelKind::F32
    };
    let engine = if let Some(file) = a.opt("file") {
        geta::obs::set_enabled(true);
        geta::deploy::GetaEngine::load_kernel(std::path::Path::new(file), kernel)?
    } else {
        let model = resolve_model(a, "mlp_tiny")?;
        let scale = a.f64_or("steps-scale", 0.12);
        let replan = a.flag("replan");
        // profiling the re-planner needs a schedule that actually prunes:
        // default high sparsity when --replan is on
        let sparsity = a.f64_or("sparsity", if replan { 0.85 } else { 0.5 });
        println!(
            "no --file: training {model} in-process (steps-scale {scale}{})",
            if replan { ", shrink-as-you-train, traced" } else { "" },
        );
        if replan {
            // tracing goes on BEFORE training so the train/replan spans
            // land in the drain below (and in the Chrome trace)
            geta::obs::set_enabled(true);
        }
        let art = geta::report::train_export_opts(&art_dir(a), &model, scale, sparsity, 8.0, replan)?;
        geta::obs::set_enabled(true);
        geta::deploy::GetaEngine::from_container_kernel(&art.container, kernel)?
    };
    let n = a.usize_or("n", 256);
    let iters = a.usize_or("iters", 3).max(1);
    let (_, eval) = geta::data::SynthData::for_model(engine.config(), 1, n.max(1), 1);
    let idxs: Vec<usize> = (0..eval.len()).collect();
    let (x, _y) = eval.batch(&idxs);
    // whole-batch latency lands in the registry so --metrics-out has a
    // populated summary to expose alongside the span-level table
    let reg = geta::obs::metrics::global();
    let hist = reg.histogram("geta_profile_infer_us");
    let passes = reg.counter("geta_profile_passes_total");
    for _ in 0..iters {
        let sw = geta::obs::Stopwatch::start();
        let _ = engine.infer(&x)?;
        hist.record(sw.elapsed());
        passes.inc();
    }
    let events = geta::obs::trace::drain();
    let agg = geta::obs::trace::aggregate(&events, Some("exec"));
    let total: f64 = agg.iter().map(|r| r.total_us).sum();
    println!(
        "\nprofile {} ({} kernel): {} samples x {} pass{}",
        engine.model,
        kernel.label(),
        eval.len(),
        iters,
        if iters == 1 { "" } else { "es" },
    );
    println!(
        "{:<28} {:>7} {:>11} {:>7} {:>11}",
        "op/kernel", "calls", "total_ms", "%", "mean_us"
    );
    for r in &agg {
        println!(
            "{:<28} {:>7} {:>11.3} {:>6.1}% {:>11.1}",
            r.name,
            r.calls,
            r.total_us / 1e3,
            100.0 * r.total_us / total.max(1e-12),
            r.mean_us(),
        );
    }
    // with --replan the drained buffer also holds the traced training
    // loop: surface the train/replan phase aggregates as their own table
    // (replan rows are the finalize/slice/rebuild cost of each Plan
    // rebuild — the price paid once per prune commit for the shrunken
    // GEMMs every step after)
    let mut phase_rows: Vec<(&'static str, geta::obs::trace::OpAgg)> = Vec::new();
    for cat in ["train", "replan"] {
        for r in geta::obs::trace::aggregate(&events, Some(cat)) {
            phase_rows.push((cat, r));
        }
    }
    if !phase_rows.is_empty() {
        println!(
            "\n{:<28} {:>7} {:>11} {:>11}",
            "training phase", "calls", "total_ms", "mean_us"
        );
        for (cat, r) in &phase_rows {
            println!(
                "{:<28} {:>7} {:>11.3} {:>11.1}",
                format!("{cat}/{}", r.name),
                r.calls,
                r.total_us / 1e3,
                r.mean_us(),
            );
        }
    }
    let trace_path = a
        .opt("trace")
        .map(|s| s.to_string())
        .or_else(geta::obs::env_trace_path)
        .unwrap_or_else(|| "trace.json".to_string());
    geta::obs::trace::write_chrome_trace(std::path::Path::new(&trace_path), &events)?;
    println!(
        "\nwrote {} spans to {trace_path} (load in chrome://tracing or ui.perfetto.dev)",
        events.len()
    );
    if let Some(mp) = a.opt("metrics-out") {
        std::fs::write(mp, reg.exposition())?;
        println!("wrote metrics exposition to {mp}");
    }
    Ok(())
}

fn cmd_repro(a: &Args) -> Result<()> {
    let which = a
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let scale = a.f64_or("steps-scale", 1.0);
    let mut ctx = ReportCtx::new(&art_dir(a), scale, a.flag("verbose"));
    let all = which == "all";
    if all || which == "table1" {
        ctx.table1();
    }
    if all || which == "table2" {
        ctx.table2()?;
    }
    if all || which == "table3" {
        ctx.table3()?;
    }
    if all || which == "table4" {
        ctx.table4()?;
    }
    if all || which == "table5" {
        ctx.table5()?;
    }
    if all || which == "table6" {
        ctx.table6()?;
    }
    if all || which == "fig3" {
        ctx.fig3()?;
    }
    if all || which == "fig4a" {
        ctx.fig4a()?;
    }
    if all || which == "fig4b" {
        ctx.fig4b()?;
    }
    if all || which == "deploy" {
        ctx.deploy()?;
    }
    ctx.write_markdown(std::path::Path::new("reports"))?;
    println!("\nmarkdown written to reports/");
    Ok(())
}

fn cmd_bench(a: &Args) -> Result<()> {
    let iters = a.usize_or("iters", 15);
    let dir = art_dir(a);
    let mut b = geta::util::bench::Bencher::new(3, iters);
    // graph analysis latency per model
    for model in ["vgg7_mini", "resnet_mini", "bert_mini"] {
        let man = geta::runtime::manifest_for(&dir, model)?;
        b.bench(&format!("qadg+depgraph/{model}"), || {
            geta::graph::search_space_for(&man.config).unwrap()
        });
    }
    // backend step latency (models without a usable backend are skipped)
    for model in ["mlp_tiny", "resnet_mini", "bert_mini"] {
        let exp = ExperimentConfig::defaults_for(model);
        let t = match Trainer::new(&dir, exp) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("skipping {model}: {e}");
                continue;
            }
        };
        let platform = t.engine.platform();
        let params = t.engine.init_params(0);
        let q = t.engine.init_qparams(&params, 16.0);
        let idxs: Vec<usize> = (0..t.batch_size()).collect();
        let (x, y) = t.train_data.batch(&idxs);
        b.bench(&format!("{platform}_train_step/{model}"), || {
            t.engine.train_step(&params, &q, &x, &y).unwrap()
        });
        b.bench(&format!("{platform}_eval_step/{model}"), || {
            t.engine.eval_step(&params, &q, &x, &y).unwrap()
        });
    }
    std::fs::create_dir_all("reports").ok();
    b.write_log(std::path::Path::new("reports/bench_cli.json")).ok();
    Ok(())
}
