//! BOPs (bit-operations) accounting — the paper's efficiency metric.
//!
//! BOPs(layer) = MACs · b_w · b_a (DJPQ's definition), summed over the
//! parameterized layers. The relative BOPs of a compressed model divides
//! by the full-precision (32×32) baseline of the same architecture.
//! Structured pruning scales a layer's MACs by the retained input and
//! output fractions; learned bit widths set b_w (weight site) and b_a
//! (the quant site of the layer's *input* activation, 32 when absent).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct LayerCost {
    /// Weight tensor name ("<layer>.weight").
    pub param: String,
    /// Multiply-accumulates per sample at full width.
    pub macs: f64,
    pub cin: usize,
    pub cout: usize,
    /// Activation-quant site feeding this layer (None = fp32 input).
    pub act_in_site: Option<String>,
}

/// Derive per-layer MAC counts from a model config (mirrors the builders'
/// spatial bookkeeping; embedding lookups are excluded — they are not
/// multiply ops).
pub fn layer_costs(cfg: &Json) -> Result<Vec<LayerCost>> {
    let fam = cfg.req("family")?.as_str().unwrap_or_default();
    let mut out = Vec::new();
    let img_size = cfg
        .get("image")
        .map(|i| i.usize_or("size", 16))
        .unwrap_or(16);
    let img_ch = cfg
        .get("image")
        .map(|i| i.usize_or("channels", 3))
        .unwrap_or(3);
    let ncls = cfg.usize_or("num_classes", 10);
    let mut push = |name: &str, macs: f64, cin: usize, cout: usize, act: Option<String>| {
        out.push(LayerCost {
            param: format!("{name}.weight"),
            macs,
            cin,
            cout,
            act_in_site: act,
        });
    };
    match fam {
        "mlp" => {
            let mut din = img_size * img_size * img_ch;
            let hidden = cfg.usize_arr("hidden");
            let mut act: Option<String> = None;
            for (i, &dout) in hidden.iter().enumerate() {
                push(&format!("fc{i}"), (din * dout) as f64, din, dout, act.clone());
                act = Some(format!("fc{i}.act"));
                din = dout;
            }
            push("head", (din * ncls) as f64, din, ncls, act);
        }
        "vgg" => {
            let channels = cfg.usize_arr("conv_channels");
            let pool_every = cfg.usize_or("pool_every", 2);
            let mut size = img_size;
            let mut cin = img_ch;
            let mut act: Option<String> = None;
            for (i, &cout) in channels.iter().enumerate() {
                let macs = (size * size * 9 * cin * cout) as f64;
                push(&format!("features.{i}"), macs, cin, cout, act.clone());
                act = Some(format!("features.{i}.act"));
                if (i + 1) % pool_every == 0 {
                    size /= 2;
                }
                cin = cout;
            }
            let mut din = cin * size * size;
            for (i, &dout) in cfg.usize_arr("fc_dims").iter().enumerate() {
                push(&format!("fc{i}"), (din * dout) as f64, din, dout, act.clone());
                act = Some(format!("fc{i}.act"));
                din = dout;
            }
            push("head", (din * ncls) as f64, din, ncls, act);
        }
        "resnet" => {
            let stem_c = cfg.usize_or("stem_channels", 8);
            let stages = cfg.usize_arr("stage_channels");
            let blocks = cfg.usize_or("blocks_per_stage", 2);
            let mut size = img_size;
            push("stem", (size * size * 9 * img_ch * stem_c) as f64, img_ch, stem_c, None);
            let mut cin = stem_c;
            for (si, &cout) in stages.iter().enumerate() {
                if si > 0 {
                    size /= 2; // stage-entry stride
                }
                for b in 0..blocks {
                    let n = format!("stage{si}.{b}");
                    push(&format!("{n}.conv1"), (size * size * 9 * cin * cout) as f64, cin, cout, None);
                    push(&format!("{n}.conv2"), (size * size * 9 * cout * cout) as f64, cout, cout, None);
                    if b == 0 && (si > 0 || cin != cout) {
                        push(&format!("{n}.proj"), (size * size * cin * cout) as f64, cin, cout, None);
                    }
                    cin = cout;
                }
            }
            push("head", (cin * ncls) as f64, cin, ncls, None);
        }
        "bert" | "gpt" => {
            let dim = cfg.usize_or("dim", 64);
            let s = cfg.usize_or("seq_len", 32);
            let blocks = cfg.usize_or("blocks", 2);
            let ratio = cfg.usize_or("mlp_ratio", 4);
            for b in 0..blocks {
                for p in ["wq", "wk", "wv", "wo"] {
                    push(&format!("block{b}.attn.{p}"), (s * dim * dim) as f64, dim, dim, None);
                }
                push(&format!("block{b}.fc1"), (s * dim * dim * ratio) as f64, dim, dim * ratio, None);
                push(&format!("block{b}.fc2"), (s * dim * ratio * dim) as f64, dim * ratio, dim, None);
            }
            if fam == "bert" {
                push("span_head", (s * dim * 2) as f64, dim, 2, None);
            } else {
                let vocab = cfg.usize_or("vocab", 128);
                push("lm_head", (s * dim * vocab) as f64, dim, vocab, None);
            }
        }
        "vit" => {
            let dim = cfg.usize_or("dim", 48);
            let patch = cfg.usize_or("patch", 4);
            let blocks = cfg.usize_or("blocks", 2);
            let ratio = cfg.usize_or("mlp_ratio", 4);
            let grid = img_size / patch;
            let mut t = grid * grid;
            push("patch_embed", (t * patch * patch * img_ch * dim) as f64, img_ch, dim, None);
            if cfg.str_or("pool", "cls") == "cls" {
                t += 1;
            }
            for b in 0..blocks {
                for p in ["wq", "wk", "wv", "wo"] {
                    push(&format!("block{b}.attn.{p}"), (t * dim * dim) as f64, dim, dim, None);
                }
                push(&format!("block{b}.fc1"), (t * dim * dim * ratio) as f64, dim, dim * ratio, None);
                push(&format!("block{b}.fc2"), (t * dim * ratio * dim) as f64, dim * ratio, dim, None);
            }
            push("head", (dim * ncls) as f64, dim, ncls, None);
        }
        "swin" => {
            let dims = cfg.usize_arr("stage_dims");
            let stage_blocks = cfg.usize_arr("stage_blocks");
            let patch = cfg.usize_or("patch", 2);
            let ratio = cfg.usize_or("mlp_ratio", 2);
            let mut side = img_size / patch;
            push("patch_embed", (side * side * patch * patch * img_ch * dims[0]) as f64, img_ch, dims[0], None);
            for (si, &dim) in dims.iter().enumerate() {
                let t = side * side;
                for b in 0..stage_blocks[si] {
                    let n = format!("stage{si}.block{b}");
                    for p in ["wq", "wk", "wv", "wo"] {
                        push(&format!("{n}.attn.{p}"), (t * dim * dim) as f64, dim, dim, None);
                    }
                    push(&format!("{n}.fc1"), (t * dim * dim * ratio) as f64, dim, dim * ratio, None);
                    push(&format!("{n}.fc2"), (t * dim * ratio * dim) as f64, dim * ratio, dim, None);
                }
                if si + 1 < dims.len() {
                    side /= 2;
                    push(&format!("merge{si}"), (side * side * dim * 4 * dims[si + 1]) as f64, dim * 4, dims[si + 1], None);
                }
            }
            push("head", (dims[dims.len() - 1] * ncls) as f64, dims[dims.len() - 1], ncls, None);
        }
        other => anyhow::bail!("unknown family {other}"),
    }
    Ok(out)
}

#[derive(Debug, Clone)]
pub struct BopsReport {
    pub full: f64,
    pub compressed: f64,
}

impl BopsReport {
    /// Relative BOPs in percent (the paper's "Rel. BOPs (%)" column).
    pub fn rel_percent(&self) -> f64 {
        100.0 * self.compressed / self.full.max(1.0)
    }
}

/// Compute full vs compressed BOPs.
///
/// * `kept`: per weight tensor, (input fraction, output fraction) retained
///   after structured pruning (1.0, 1.0 when absent).
/// * `wbits`: learned weight bit width per site (tensor name); 32 default.
/// * `abits`: learned activation bit width per act site; 32 default.
/// * `unstructured_density`: extra multiplicative MAC density for
///   unstructured baselines (1.0 for structured methods — their savings
///   are in `kept`).
pub fn bops(
    costs: &[LayerCost],
    kept: &BTreeMap<String, (f64, f64)>,
    wbits: &BTreeMap<String, f32>,
    abits: &BTreeMap<String, f32>,
    unstructured_density: f64,
) -> BopsReport {
    let mut full = 0.0;
    let mut comp = 0.0;
    for c in costs {
        full += c.macs * 32.0 * 32.0;
        let (fin, fout) = kept.get(&c.param).copied().unwrap_or((1.0, 1.0));
        let bw = wbits.get(&c.param).copied().unwrap_or(32.0) as f64;
        let ba = c
            .act_in_site
            .as_ref()
            .and_then(|s| abits.get(s))
            .copied()
            .unwrap_or(32.0) as f64;
        comp += c.macs * fin * fout * unstructured_density * bw * ba;
    }
    BopsReport {
        full,
        compressed: comp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn cfg(name: &str) -> Json {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/models")
            .join(format!("{name}.json"));
        json::parse_file(&p).unwrap()
    }

    #[test]
    fn costs_cover_all_weight_sites() {
        for name in [
            "mlp_tiny", "vgg7_mini", "resnet_mini", "bert_mini",
            "gpt_mini", "vit_mini", "swin_mini",
        ] {
            let c = cfg(name);
            let costs = layer_costs(&c).unwrap();
            let sites = crate::graph::builders::quant_sites(&c).unwrap();
            let weight_sites: Vec<_> = sites
                .iter()
                .filter(|(_, k)| k == "weight")
                .map(|(n, _)| n.clone())
                .collect();
            let cost_params: Vec<_> = costs.iter().map(|l| l.param.clone()).collect();
            for w in &weight_sites {
                assert!(cost_params.contains(w), "{name}: missing cost for {w}");
            }
            assert!(costs.iter().all(|l| l.macs > 0.0), "{name}");
        }
    }

    #[test]
    fn full_precision_baseline_is_100_percent() {
        let costs = layer_costs(&cfg("vgg7_mini")).unwrap();
        let r = bops(&costs, &BTreeMap::new(), &BTreeMap::new(), &BTreeMap::new(), 1.0);
        assert!((r.rel_percent() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn bits_and_pruning_compose() {
        let costs = layer_costs(&cfg("mlp_tiny")).unwrap();
        let mut kept = BTreeMap::new();
        let mut wbits = BTreeMap::new();
        for c in &costs {
            kept.insert(c.param.clone(), (1.0, 0.5));
            wbits.insert(c.param.clone(), 8.0);
        }
        let r = bops(&costs, &kept, &wbits, &BTreeMap::new(), 1.0);
        // 0.5 output fraction * 8/32 weight bits = 12.5% — input fractions
        // of downstream layers stay 1.0 here so this is exact
        assert!((r.rel_percent() - 12.5).abs() < 1e-6, "{}", r.rel_percent());
    }

    #[test]
    fn act_bits_apply_to_consumer_layer() {
        let costs = layer_costs(&cfg("vgg7_mini")).unwrap();
        // features.1 consumes features.0.act
        let l = costs.iter().find(|c| c.param == "features.1.weight").unwrap();
        assert_eq!(l.act_in_site.as_deref(), Some("features.0.act"));
        let mut abits = BTreeMap::new();
        abits.insert("features.0.act".to_string(), 4.0f32);
        let r = bops(&costs, &BTreeMap::new(), &BTreeMap::new(), &abits, 1.0);
        assert!(r.rel_percent() < 100.0);
    }

    #[test]
    fn vgg_macs_match_hand_count() {
        let costs = layer_costs(&cfg("vgg7_mini")).unwrap();
        let c0 = &costs[0]; // 16x16 * 9 * 3 * 16
        assert_eq!(c0.macs, (16 * 16 * 9 * 3 * 16) as f64);
    }
}
