//! BOPs (bit-operations) accounting — the paper's efficiency metric.
//!
//! BOPs(layer) = MACs · b_w · b_a (DJPQ's definition), summed over the
//! parameterized layers. The relative BOPs of a compressed model divides
//! by the full-precision (32×32) baseline of the same architecture.
//! Structured pruning scales a layer's MACs by the retained input and
//! output fractions; learned bit widths set b_w (weight site) and b_a
//! (the quant site of the layer's *input* activation, 32 when absent).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct LayerCost {
    /// Weight tensor name ("<layer>.weight").
    pub param: String,
    /// Multiply-accumulates per sample at full width.
    pub macs: f64,
    pub cin: usize,
    pub cout: usize,
    /// Activation-quant site feeding this layer (None = fp32 input).
    pub act_in_site: Option<String>,
}

/// Derive per-layer MAC counts from a model config. Costs come from the
/// native lowering's real op shapes (`runtime::lowering::layer_costs`):
/// conv MACs use the interpreter's spatial output dims and linear MACs the
/// true token counts, so BOPs accounting can never drift from what the
/// engine actually executes. Embedding lookups are excluded — they are
/// not multiply ops.
pub fn layer_costs(cfg: &Json) -> Result<Vec<LayerCost>> {
    crate::runtime::lowering::layer_costs(cfg)
}

#[derive(Debug, Clone)]
pub struct BopsReport {
    pub full: f64,
    pub compressed: f64,
}

impl BopsReport {
    /// Relative BOPs in percent (the paper's "Rel. BOPs (%)" column).
    pub fn rel_percent(&self) -> f64 {
        100.0 * self.compressed / self.full.max(1.0)
    }
}

/// Compute full vs compressed BOPs.
///
/// * `kept`: per weight tensor, (input fraction, output fraction) retained
///   after structured pruning (1.0, 1.0 when absent).
/// * `wbits`: learned weight bit width per site (tensor name); 32 default.
/// * `abits`: learned activation bit width per act site; 32 default.
/// * `unstructured_density`: extra multiplicative MAC density for
///   unstructured baselines (1.0 for structured methods — their savings
///   are in `kept`).
pub fn bops(
    costs: &[LayerCost],
    kept: &BTreeMap<String, (f64, f64)>,
    wbits: &BTreeMap<String, f32>,
    abits: &BTreeMap<String, f32>,
    unstructured_density: f64,
) -> BopsReport {
    let mut full = 0.0;
    let mut comp = 0.0;
    for c in costs {
        full += c.macs * 32.0 * 32.0;
        let (fin, fout) = kept.get(&c.param).copied().unwrap_or((1.0, 1.0));
        let bw = wbits.get(&c.param).copied().unwrap_or(32.0) as f64;
        let ba = c
            .act_in_site
            .as_ref()
            .and_then(|s| abits.get(s))
            .copied()
            .unwrap_or(32.0) as f64;
        comp += c.macs * fin * fout * unstructured_density * bw * ba;
    }
    BopsReport {
        full,
        compressed: comp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn cfg(name: &str) -> Json {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/models")
            .join(format!("{name}.json"));
        json::parse_file(&p).unwrap()
    }

    #[test]
    fn costs_cover_all_weight_sites() {
        for name in [
            "mlp_tiny", "vgg7_mini", "resnet_mini", "bert_mini",
            "gpt_mini", "vit_mini", "swin_mini",
        ] {
            let c = cfg(name);
            let costs = layer_costs(&c).unwrap();
            let sites = crate::graph::builders::quant_sites(&c).unwrap();
            let weight_sites: Vec<_> = sites
                .iter()
                .filter(|(_, k)| k == "weight")
                .map(|(n, _)| n.clone())
                .collect();
            let cost_params: Vec<_> = costs.iter().map(|l| l.param.clone()).collect();
            for w in &weight_sites {
                assert!(cost_params.contains(w), "{name}: missing cost for {w}");
            }
            assert!(costs.iter().all(|l| l.macs > 0.0), "{name}");
        }
    }

    #[test]
    fn full_precision_baseline_is_100_percent() {
        let costs = layer_costs(&cfg("vgg7_mini")).unwrap();
        let r = bops(&costs, &BTreeMap::new(), &BTreeMap::new(), &BTreeMap::new(), 1.0);
        assert!((r.rel_percent() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn bits_and_pruning_compose() {
        let costs = layer_costs(&cfg("mlp_tiny")).unwrap();
        let mut kept = BTreeMap::new();
        let mut wbits = BTreeMap::new();
        for c in &costs {
            kept.insert(c.param.clone(), (1.0, 0.5));
            wbits.insert(c.param.clone(), 8.0);
        }
        let r = bops(&costs, &kept, &wbits, &BTreeMap::new(), 1.0);
        // 0.5 output fraction * 8/32 weight bits = 12.5% — input fractions
        // of downstream layers stay 1.0 here so this is exact
        assert!((r.rel_percent() - 12.5).abs() < 1e-6, "{}", r.rel_percent());
    }

    #[test]
    fn act_bits_apply_to_consumer_layer() {
        let costs = layer_costs(&cfg("vgg7_mini")).unwrap();
        // features.1 consumes features.0.act
        let l = costs.iter().find(|c| c.param == "features.1.weight").unwrap();
        assert_eq!(l.act_in_site.as_deref(), Some("features.0.act"));
        let mut abits = BTreeMap::new();
        abits.insert("features.0.act".to_string(), 4.0f32);
        let r = bops(&costs, &BTreeMap::new(), &BTreeMap::new(), &abits, 1.0);
        assert!(r.rel_percent() < 100.0);
    }

    #[test]
    fn vgg_macs_match_hand_count() {
        let costs = layer_costs(&cfg("vgg7_mini")).unwrap();
        let c0 = &costs[0]; // 16x16 * 9 * 3 * 16
        assert_eq!(c0.macs, (16 * 16 * 9 * 3 * 16) as f64);
    }

    #[test]
    fn resnet_and_vit_totals_pinned() {
        // Regression pins for the interpreter-shape-derived MAC totals.
        // resnet_mini, by hand from the lowered shapes: stem 16x16x9x3x8;
        // stage0 4 convs at 16x16 (8->8); stage1/2 strided entry blocks
        // with 1x1 projections at 8x8 / 4x4; head 32x10.
        let costs = layer_costs(&cfg("resnet_mini")).unwrap();
        let total: f64 = costs.iter().map(|c| c.macs).sum();
        assert_eq!(total, 1_694_016.0);
        // conv1 of the strided stage-1 entry block runs at 8x8 output
        let c = costs.iter().find(|c| c.param == "stage1.0.conv1.weight").unwrap();
        assert_eq!(c.macs, (8 * 8 * 9 * 8 * 16) as f64);
        // its 1x1 projection too
        let p = costs.iter().find(|c| c.param == "stage1.0.proj.weight").unwrap();
        assert_eq!(p.macs, (8 * 8 * 8 * 16) as f64);

        // vit_mini: patch embed over the 4x4 grid (16 tokens), blocks over
        // 17 tokens (grid + cls), head after pooling (1 token).
        let costs = layer_costs(&cfg("vit_mini")).unwrap();
        let total: f64 = costs.iter().map(|c| c.macs).sum();
        assert_eq!(total, 977_376.0);
        let pe = costs.iter().find(|c| c.param == "patch_embed.weight").unwrap();
        assert_eq!(pe.macs, (16 * 4 * 4 * 3 * 48) as f64);
        let wq = costs.iter().find(|c| c.param == "block0.attn.wq.weight").unwrap();
        assert_eq!(wq.macs, (17 * 48 * 48) as f64);
        let head = costs.iter().find(|c| c.param == "head.weight").unwrap();
        assert_eq!(head.macs, (48 * 10) as f64);
    }
}
