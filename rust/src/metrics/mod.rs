//! Evaluation metrics: BOPs accounting, accuracy, EM/F1, loss curves.

pub mod bops;

pub use bops::{layer_costs, BopsReport, LayerCost};

/// Span-extraction exact match + token-overlap F1 (the SQuAD metrics).
pub fn span_em_f1(pred: &[(i32, i32)], gold: &[(i32, i32)]) -> (f64, f64) {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return (0.0, 0.0);
    }
    let mut em = 0.0;
    let mut f1 = 0.0;
    for (&(ps, pe), &(gs, ge)) in pred.iter().zip(gold) {
        if ps == gs && pe == ge {
            em += 1.0;
        }
        // token overlap of closed intervals [s, e]
        let (ps, pe) = (ps.min(pe), ps.max(pe));
        let inter = ((pe.min(ge) - ps.max(gs)) + 1).max(0) as f64;
        let p_len = (pe - ps + 1).max(1) as f64;
        let g_len = (ge - gs + 1).max(1) as f64;
        if inter > 0.0 {
            let prec = inter / p_len;
            let rec = inter / g_len;
            f1 += 2.0 * prec * rec / (prec + rec);
        }
    }
    let n = pred.len() as f64;
    (100.0 * em / n, 100.0 * f1 / n)
}

/// Streaming mean-loss / accuracy accumulator for an eval sweep.
#[derive(Debug, Default, Clone)]
pub struct EvalAccum {
    pub loss_sum: f64,
    pub metric_sum: f64,
    pub denom: f64,
    pub batches: usize,
}

impl EvalAccum {
    pub fn add(&mut self, loss: f32, metric: f32, denom: f64) {
        self.loss_sum += loss as f64;
        self.metric_sum += metric as f64;
        self.denom += denom;
        self.batches += 1;
    }

    pub fn loss(&self) -> f64 {
        self.loss_sum / self.batches.max(1) as f64
    }

    /// Accuracy in percent.
    pub fn accuracy(&self) -> f64 {
        100.0 * self.metric_sum / self.denom.max(1.0)
    }
}

/// Loss/metric trace of one training run (dumped for EXPERIMENTS.md).
#[derive(Debug, Default, Clone)]
pub struct TrainTrace {
    pub steps: Vec<usize>,
    pub losses: Vec<f32>,
    pub stages: Vec<&'static str>,
}

impl TrainTrace {
    pub fn push(&mut self, step: usize, loss: f32, stage: &'static str) {
        self.steps.push(step);
        self.losses.push(loss);
        self.stages.push(stage);
    }

    /// Mean loss over the last `k` recorded points.
    pub fn tail_mean(&self, k: usize) -> f64 {
        let n = self.losses.len();
        if n == 0 {
            return f64::NAN;
        }
        let k = k.min(n);
        self.losses[n - k..].iter().map(|&l| l as f64).sum::<f64>() / k as f64
    }

    pub fn csv(&self) -> String {
        let mut s = String::from("step,loss,stage\n");
        for i in 0..self.steps.len() {
            s.push_str(&format!("{},{},{}\n", self.steps[i], self.losses[i], self.stages[i]));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn em_f1_exact_and_partial() {
        let (em, f1) = span_em_f1(&[(3, 5)], &[(3, 5)]);
        assert_eq!((em, f1), (100.0, 100.0));
        // pred [2,4] vs gold [3,5]: overlap {3,4}=2, p_len 3, g_len 3
        let (em, f1) = span_em_f1(&[(2, 4)], &[(3, 5)]);
        assert_eq!(em, 0.0);
        assert!((f1 - 100.0 * (2.0 / 3.0)).abs() < 1e-9);
        // disjoint
        let (em, f1) = span_em_f1(&[(0, 1)], &[(5, 6)]);
        assert_eq!((em, f1), (0.0, 0.0));
    }

    #[test]
    fn accum_averages() {
        let mut a = EvalAccum::default();
        a.add(1.0, 10.0, 16.0);
        a.add(3.0, 6.0, 16.0);
        assert!((a.loss() - 2.0).abs() < 1e-9);
        assert!((a.accuracy() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn trace_tail() {
        let mut t = TrainTrace::default();
        for i in 0..10 {
            t.push(i, i as f32, "warmup");
        }
        assert!((t.tail_mean(2) - 8.5).abs() < 1e-9);
        assert!(t.csv().lines().count() == 11);
    }
}
