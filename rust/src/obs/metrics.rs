//! Process-wide metrics registry: counters, gauges, and histograms with
//! Prometheus-style text exposition and a JSON snapshot writer.
//!
//! Counters and gauges are relaxed atomics behind `Arc` handles — a
//! holder increments without touching the registry map or any lock.
//! Histograms reuse the log-bucketed [`LatencyHistogram`] from `serve`
//! behind a mutex (recorded per batch, not per op, so the lock is cold).
//! The [`global`] registry is what the CLI exposes via
//! `geta serve --metrics-every` and `geta profile --metrics-out`;
//! independent [`Registry`] instances exist for tests.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::serve::LatencyHistogram;
use crate::util::json::Json;

/// Monotonic counter handle. Cloning shares the underlying cell.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time gauge handle (signed: depths, deltas).
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Histogram handle over the serve-layer log-bucketed latency histogram.
#[derive(Clone)]
pub struct Hist(Arc<Mutex<LatencyHistogram>>);

impl Hist {
    pub fn record(&self, d: Duration) {
        self.0.lock().unwrap_or_else(|e| e.into_inner()).record(d);
    }

    pub fn record_us(&self, us: f64) {
        self.record(Duration::from_secs_f64(us.max(0.0) / 1e6));
    }

    pub fn snapshot(&self) -> LatencyHistogram {
        self.0.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }
}

/// Named metrics, created on first use and stable for the process
/// lifetime. Registration takes the map lock once per handle; updates
/// through the returned handles are lock-free (counters/gauges) or take
/// only that metric's own mutex (histograms).
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    hists: Mutex<BTreeMap<String, Hist>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.counters.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let mut m = self.gauges.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Hist {
        let mut m = self.hists.lock().unwrap_or_else(|e| e.into_inner());
        m.entry(name.to_string())
            .or_insert_with(|| Hist(Arc::new(Mutex::new(LatencyHistogram::new()))))
            .clone()
    }

    /// Prometheus-style text exposition: `# TYPE` lines plus samples;
    /// histograms render as summaries (quantile-labelled samples with
    /// `_sum`/`_count`).
    pub fn exposition(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", c.get());
        }
        for (name, g) in self.gauges.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {}", g.get());
        }
        for (name, h) in self.hists.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            let h = h.snapshot();
            let _ = writeln!(out, "# TYPE {name} summary");
            for (q, v) in [(0.5, h.p50_us()), (0.95, h.p95_us()), (0.99, h.p99_us())] {
                let _ = writeln!(out, "{name}{{quantile=\"{q}\"}} {v}");
            }
            let _ = writeln!(out, "{name}_sum {}", h.mean_us() * h.count() as f64);
            let _ = writeln!(out, "{name}_count {}", h.count());
        }
        out
    }

    /// JSON snapshot of every metric — the machine-readable twin of
    /// [`exposition`](Self::exposition).
    pub fn snapshot_json(&self) -> Json {
        let counters: Vec<(String, Json)> = self
            .counters
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(k, c)| (k.clone(), Json::Num(c.get() as f64)))
            .collect();
        let gauges: Vec<(String, Json)> = self
            .gauges
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(k, g)| (k.clone(), Json::Num(g.get() as f64)))
            .collect();
        let hists: Vec<(String, Json)> = self
            .hists
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(k, h)| {
                let h = h.snapshot();
                (
                    k.clone(),
                    Json::obj(vec![
                        ("count", Json::Num(h.count() as f64)),
                        ("mean_us", Json::Num(h.mean_us())),
                        ("min_us", Json::Num(h.min_us())),
                        ("max_us", Json::Num(h.max_us())),
                        ("p50_us", Json::Num(h.p50_us())),
                        ("p95_us", Json::Num(h.p95_us())),
                        ("p99_us", Json::Num(h.p99_us())),
                    ]),
                )
            })
            .collect();
        let as_obj = |pairs: Vec<(String, Json)>| {
            Json::obj(pairs.iter().map(|(k, v)| (k.as_str(), v.clone())).collect())
        };
        Json::obj(vec![
            ("counters", as_obj(counters)),
            ("gauges", as_obj(gauges)),
            ("histograms", as_obj(hists)),
        ])
    }

    /// Write the JSON snapshot to `path`.
    pub fn write_snapshot(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.snapshot_json()))
    }
}

/// The process-wide registry.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_shared_and_lock_free_to_update() {
        let r = Registry::new();
        let c = r.counter("reqs_total");
        c.inc();
        c.add(4);
        // a second lookup sees the same cell
        assert_eq!(r.counter("reqs_total").get(), 5);

        let g = r.gauge("depth");
        g.set(7);
        g.add(-3);
        assert_eq!(r.gauge("depth").get(), 4);

        let h = r.histogram("lat_us");
        h.record(Duration::from_micros(100));
        h.record_us(300.0);
        assert_eq!(r.histogram("lat_us").snapshot().count(), 2);
    }

    #[test]
    fn exposition_has_type_lines_and_samples() {
        let r = Registry::new();
        r.counter("a_total").add(2);
        r.gauge("b_depth").set(-1);
        r.histogram("c_us").record(Duration::from_micros(50));
        let text = r.exposition();
        assert!(text.contains("# TYPE a_total counter"));
        assert!(text.contains("a_total 2"));
        assert!(text.contains("# TYPE b_depth gauge"));
        assert!(text.contains("b_depth -1"));
        assert!(text.contains("# TYPE c_us summary"));
        assert!(text.contains("c_us{quantile=\"0.5\"}"));
        assert!(text.contains("c_us_count 1"));
        // every non-comment line is `name[{labels}] value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.rsplitn(2, ' ');
            let val = parts.next().unwrap();
            assert!(val.parse::<f64>().is_ok(), "bad sample line: {line}");
            assert!(parts.next().is_some(), "bad sample line: {line}");
        }
    }

    #[test]
    fn snapshot_json_round_trips() {
        let r = Registry::new();
        r.counter("n_total").add(3);
        r.histogram("h_us").record(Duration::from_micros(250));
        let text = r.snapshot_json().to_string();
        let parsed = crate::util::json::parse(&text).expect("snapshot parses");
        match parsed {
            Json::Obj(m) => {
                assert!(matches!(m.get("counters"), Some(Json::Obj(_))));
                assert!(matches!(m.get("histograms"), Some(Json::Obj(_))));
            }
            other => panic!("snapshot root not an object: {other:?}"),
        }
    }
}
