//! Telemetry: span tracing, a process-wide metrics registry, and the
//! shared stopwatch every wall-clock measurement in the crate goes
//! through.
//!
//! Three pieces:
//!
//! - [`trace`] — a low-overhead span tracer. Spans are pushed into
//!   per-thread buffers (no lock on the hot path) and drained into
//!   Chrome trace-event JSON loadable in `chrome://tracing` / Perfetto.
//!   Instrumented layers: per-node forward/backward in `runtime::exec` /
//!   `runtime::interp` (keyed by op kind and kernel kind), QASSO step
//!   phases in `optim::qasso` (projection, forgetting, saliency), `.geta`
//!   load/pack phases in `deploy`, and the request lifecycle in `serve`
//!   (enqueue-wait → batch-infer → reply).
//! - [`metrics`] — counters, gauges, and histograms (reusing the
//!   log-bucketed [`crate::serve::LatencyHistogram`]) with Prometheus-style
//!   text exposition and a JSON snapshot writer.
//! - [`Stopwatch`] — the one `Instant`-based timer; `report`, `util::bench`
//!   and the CLI all measure elapsed time through it.
//!
//! Tracing is **off by default** and costs one relaxed atomic load per
//! instrumentation point when disabled. It is enabled by `--trace <path>`
//! on the CLI or the `GETA_TRACE` environment variable. All timing lives
//! *outside* the numeric kernels: logits are bitwise identical traced vs
//! untraced (CI asserts this).

pub mod metrics;
pub mod trace;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;
use std::time::{Duration, Instant};

pub use trace::{span, span_owned, SpanGuard};

static ENABLED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

/// Whether span tracing is on. One `Once` fast-path check plus one relaxed
/// load — cheap enough to call per plan node. The first call folds in the
/// `GETA_TRACE` environment variable (any value other than empty or `0`
/// enables tracing; a `.json`-suffixed value also sets the default trace
/// output path, see [`env_trace_path`]).
pub fn enabled() -> bool {
    ENV_INIT.call_once(|| {
        if let Ok(v) = std::env::var("GETA_TRACE") {
            if !v.is_empty() && v != "0" {
                ENABLED.store(true, Ordering::Relaxed);
            }
        }
    });
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on/off; returns the previous state so callers that flip it
/// temporarily (e.g. the per-op pass in `report::bench_deploy`) can restore.
pub fn set_enabled(on: bool) -> bool {
    enabled(); // make sure the env fold-in has happened first
    ENABLED.swap(on, Ordering::Relaxed)
}

/// Trace output path implied by `GETA_TRACE` when the CLI got no explicit
/// `--trace`: a `.json`-suffixed value names the file, any other truthy
/// value means "enabled, default path".
pub fn env_trace_path() -> Option<String> {
    match std::env::var("GETA_TRACE") {
        Ok(v) if v.ends_with(".json") => Some(v),
        _ => None,
    }
}

/// The one stopwatch. Wraps `Instant` so elapsed-time measurement is
/// uniform across the CLI, `report`, and `util::bench` instead of each
/// call site re-deriving milliseconds its own way.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { t0: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.t0.elapsed()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.t0.elapsed().as_secs_f64() * 1e6
    }

    /// Elapsed time and restart — for sequential phase timing.
    pub fn lap_ms(&mut self) -> f64 {
        let ms = self.elapsed_ms();
        self.t0 = Instant::now();
        ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures_and_laps() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let ms = sw.lap_ms();
        assert!(ms >= 1.0, "lap too short: {ms}");
        // after a lap the clock restarts
        assert!(sw.elapsed_ms() < ms + 1000.0);
        assert!(sw.elapsed_us() >= sw.elapsed_ms()); // µs numerically >= ms
    }

    #[test]
    fn set_enabled_returns_previous_state() {
        let prev = set_enabled(false);
        assert!(!enabled());
        assert!(!set_enabled(true));
        assert!(enabled());
        set_enabled(false);
        set_enabled(prev);
    }
}
