//! Span tracer: per-thread buffers drained into Chrome trace-event JSON.
//!
//! Hot-path contract: when tracing is disabled (`obs::enabled()` false)
//! an instrumentation point costs one relaxed atomic load and nothing
//! else — no `Instant::now`, no allocation. When enabled, each span is
//! one `Instant::now` pair plus a push into a `thread_local` buffer; the
//! global mutex is only touched when a thread's buffer spills (every
//! [`LOCAL_SPILL`] events) or the thread exits. Timing always wraps the
//! numeric kernels from the *outside*: no span changes allocation order
//! or arithmetic, so traced and untraced runs produce bitwise-identical
//! logits.
//!
//! [`drain`] flushes the calling thread and takes everything spilled so
//! far. Worker threads flush on exit (TLS destructor), so drain after
//! joining them — the executor's scoped threads and `serve::Server`
//! workers are both joined before any CLI drain point runs.

use std::borrow::Cow;
use std::cell::RefCell;
use std::io::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// One completed span, timestamped in microseconds relative to the
/// process trace epoch (first span recorded).
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub name: String,
    pub cat: &'static str,
    pub ts_us: f64,
    pub dur_us: f64,
    pub tid: u64,
}

/// Cap on buffered events: a runaway traced loop degrades to counting
/// drops instead of eating all memory.
const MAX_EVENTS: usize = 2_000_000;
/// Local-buffer spill threshold (events).
const LOCAL_SPILL: usize = 4096;

static GLOBAL: Mutex<Vec<SpanEvent>> = Mutex::new(Vec::new());
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

struct LocalBuf {
    tid: u64,
    buf: Vec<SpanEvent>,
}

impl LocalBuf {
    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        let mut g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
        let room = MAX_EVENTS.saturating_sub(g.len());
        if room < self.buf.len() {
            DROPPED.fetch_add((self.buf.len() - room) as u64, Ordering::Relaxed);
            self.buf.truncate(room);
        }
        g.append(&mut self.buf);
    }
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = RefCell::new(LocalBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        buf: Vec::new(),
    });
}

fn push(cat: &'static str, name: Cow<'static, str>, start: Instant, end: Instant) {
    let e = epoch();
    let ts_us = start.checked_duration_since(e).unwrap_or_default().as_secs_f64() * 1e6;
    let dur_us = end.checked_duration_since(start).unwrap_or_default().as_secs_f64() * 1e6;
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let tid = l.tid;
        l.buf.push(SpanEvent { name: name.into_owned(), cat, ts_us, dur_us, tid });
        if l.buf.len() >= LOCAL_SPILL {
            l.flush();
        }
    });
}

/// Record a span that started at `start` and ends now. Caller is expected
/// to have checked `obs::enabled()` before taking the start timestamp.
pub fn record(cat: &'static str, name: String, start: Instant) {
    push(cat, Cow::Owned(name), start, Instant::now());
}

/// Record a span with both endpoints supplied — for lifecycle phases whose
/// boundaries were captured earlier (e.g. a request's enqueue instant).
pub fn record_between(cat: &'static str, name: String, start: Instant, end: Instant) {
    push(cat, Cow::Owned(name), start, end);
}

/// RAII span: records `cat`/`name` from construction to drop. A no-op
/// (no clock read) when tracing is disabled.
pub struct SpanGuard(Option<(&'static str, Cow<'static, str>, Instant)>);

impl SpanGuard {
    /// Explicitly-disabled guard, for call sites that hoist the enabled
    /// check out of a loop.
    pub fn off() -> SpanGuard {
        SpanGuard(None)
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((cat, name, start)) = self.0.take() {
            push(cat, name, start, Instant::now());
        }
    }
}

/// Span with a static name — zero allocation until the event is buffered.
pub fn span(cat: &'static str, name: &'static str) -> SpanGuard {
    if super::enabled() {
        SpanGuard(Some((cat, Cow::Borrowed(name), Instant::now())))
    } else {
        SpanGuard(None)
    }
}

/// Span with a computed name. The `String` is only built by the caller
/// when tracing is on — pair with an `obs::enabled()` check.
pub fn span_owned(cat: &'static str, name: String) -> SpanGuard {
    if super::enabled() {
        SpanGuard(Some((cat, Cow::Owned(name), Instant::now())))
    } else {
        SpanGuard(None)
    }
}

/// Flush the calling thread's buffer and take every event recorded so
/// far. Threads still running keep their unspilled tails — drain after
/// joining workers.
pub fn drain() -> Vec<SpanEvent> {
    LOCAL.with(|l| l.borrow_mut().flush());
    let mut g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    std::mem::take(&mut *g)
}

/// Events dropped at the [`MAX_EVENTS`] cap since process start.
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Put drained events back into the global buffer — used by callers that
/// [`drain`] to inspect a window of spans (e.g. the per-op pass in
/// `report::bench_deploy`) without losing events an enclosing `--trace`
/// session still wants written out.
pub fn inject(events: Vec<SpanEvent>) {
    if events.is_empty() {
        return;
    }
    let mut g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    let room = MAX_EVENTS.saturating_sub(g.len());
    let take = events.len().min(room);
    DROPPED.fetch_add((events.len() - take) as u64, Ordering::Relaxed);
    g.extend(events.into_iter().take(take));
}

/// Serialize events as Chrome trace-event JSON (the `{"traceEvents":[..]}`
/// object form), loadable in `chrome://tracing` and Perfetto.
pub fn chrome_trace_json(events: &[SpanEvent]) -> Json {
    let rows: Vec<Json> = events
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::str(&e.name)),
                ("cat", Json::str(e.cat)),
                ("ph", Json::str("X")),
                ("ts", Json::Num(e.ts_us)),
                ("dur", Json::Num(e.dur_us)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(e.tid as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(rows)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Write events to `path` as Chrome trace-event JSON.
pub fn write_chrome_trace(path: &std::path::Path, events: &[SpanEvent]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(f, "{}", chrome_trace_json(events))?;
    f.flush()
}

/// Per-name aggregate over a set of spans: call count and total self-time.
/// Exec-level spans are leaves (no nesting within a name), so summed
/// duration *is* self-time.
#[derive(Debug, Clone)]
pub struct OpAgg {
    pub name: String,
    pub calls: u64,
    pub total_us: f64,
}

impl OpAgg {
    pub fn mean_us(&self) -> f64 {
        if self.calls == 0 { 0.0 } else { self.total_us / self.calls as f64 }
    }
}

/// Aggregate spans by name (optionally restricted to one category),
/// sorted by total time descending.
pub fn aggregate(events: &[SpanEvent], cat: Option<&str>) -> Vec<OpAgg> {
    let mut by_name: std::collections::BTreeMap<&str, (u64, f64)> = std::collections::BTreeMap::new();
    for e in events {
        if let Some(c) = cat {
            if e.cat != c {
                continue;
            }
        }
        let slot = by_name.entry(&e.name).or_insert((0, 0.0));
        slot.0 += 1;
        slot.1 += e.dur_us;
    }
    let mut rows: Vec<OpAgg> = by_name
        .into_iter()
        .map(|(name, (calls, total_us))| OpAgg { name: name.to_string(), calls, total_us })
        .collect();
    rows.sort_by(|a, b| b.total_us.partial_cmp(&a.total_us).unwrap_or(std::cmp::Ordering::Equal));
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_record_drain_and_serialize() {
        let prev = crate::obs::set_enabled(true);
        {
            let _g = span("test-trace", "alpha_phase");
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        let t0 = Instant::now();
        std::thread::sleep(std::time::Duration::from_micros(100));
        record("test-trace", "beta_phase".to_string(), t0);
        // worker-thread events land in the global buffer via the TLS destructor
        std::thread::scope(|s| {
            s.spawn(|| {
                let _g = span("test-trace", "worker_phase");
            });
        });
        let events = drain();
        crate::obs::set_enabled(prev);
        let mine: Vec<&SpanEvent> = events.iter().filter(|e| e.cat == "test-trace").collect();
        assert!(mine.iter().any(|e| e.name == "alpha_phase"));
        assert!(mine.iter().any(|e| e.name == "beta_phase"));
        assert!(mine.iter().any(|e| e.name == "worker_phase"));
        for e in &mine {
            assert!(e.dur_us >= 0.0 && e.ts_us >= 0.0);
        }

        let own: Vec<SpanEvent> = mine.iter().map(|e| (*e).clone()).collect();
        let json = chrome_trace_json(&own);
        let text = json.to_string();
        let parsed = crate::util::json::parse(&text).expect("trace JSON parses");
        match parsed {
            Json::Obj(m) => match m.get("traceEvents") {
                Some(Json::Arr(rows)) => assert!(rows.len() >= 3),
                other => panic!("traceEvents not an array: {other:?}"),
            },
            other => panic!("trace root not an object: {other:?}"),
        }
    }

    #[test]
    fn disabled_spans_cost_nothing_and_record_nothing() {
        let prev = crate::obs::set_enabled(false);
        {
            let _g = span("test-trace-off", "should_not_appear");
        }
        let events = drain();
        crate::obs::set_enabled(prev);
        assert!(events.iter().all(|e| e.cat != "test-trace-off"));
    }

    #[test]
    fn aggregate_sums_and_sorts() {
        let evs = vec![
            SpanEvent { name: "a".into(), cat: "x", ts_us: 0.0, dur_us: 10.0, tid: 1 },
            SpanEvent { name: "b".into(), cat: "x", ts_us: 0.0, dur_us: 50.0, tid: 1 },
            SpanEvent { name: "a".into(), cat: "x", ts_us: 0.0, dur_us: 30.0, tid: 2 },
            SpanEvent { name: "c".into(), cat: "y", ts_us: 0.0, dur_us: 99.0, tid: 1 },
        ];
        let agg = aggregate(&evs, Some("x"));
        assert_eq!(agg.len(), 2);
        assert_eq!(agg[0].name, "b");
        assert_eq!(agg[1].name, "a");
        assert_eq!(agg[1].calls, 2);
        assert!((agg[1].total_us - 40.0).abs() < 1e-9);
        assert!((agg[1].mean_us() - 20.0).abs() < 1e-9);
    }
}
