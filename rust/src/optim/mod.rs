//! Optimizers and learning-rate schedules.
//!
//! `qasso` is the paper's contribution (Algorithm 2); the base optimizers
//! here supply the "SGD or any of its variants" steps that QASSO's
//! warm-up/important-group updates delegate to (eq. 8).

pub mod saliency;
pub mod qasso;

pub use qasso::{Qasso, QassoConfig, Stage};

use crate::tensor::ParamStore;

/// Pluggable base optimizer over a ParamStore.
pub trait Optimizer: Send {
    fn step(&mut self, params: &mut ParamStore, grads: &ParamStore, lr: f32);
    fn name(&self) -> &'static str;

    /// Per-parameter state stores (momentum/moment buffers), in a fixed
    /// order, for checkpointing and shrink-as-you-train slicing. Stateless
    /// optimizers (or ones whose lazy buffers are not yet allocated)
    /// return an empty vec.
    fn state_stores(&self) -> Vec<&ParamStore> {
        Vec::new()
    }

    /// Mutable access to the same stores, in the same order as
    /// [`Optimizer::state_stores`].
    fn state_stores_mut(&mut self) -> Vec<&mut ParamStore> {
        Vec::new()
    }

    /// Install restored state stores (checkpoint resume). The vec must
    /// have either zero length (no state yet) or exactly the length this
    /// optimizer's `state_stores` would return once allocated.
    fn set_state_stores(&mut self, _stores: Vec<ParamStore>) {}

    /// Scalar step-count state (e.g. Adam's `t`) for checkpointing.
    fn scalar_state(&self) -> u64 {
        0
    }

    /// Restore scalar state saved by [`Optimizer::scalar_state`].
    fn set_scalar_state(&mut self, _v: u64) {}
}

/// SGD with optional momentum and decoupled weight decay.
pub struct Sgd {
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: Option<ParamStore>,
}

impl Sgd {
    pub fn new(momentum: f32, weight_decay: f32) -> Sgd {
        Sgd {
            momentum,
            weight_decay,
            velocity: None,
        }
    }

    pub fn plain() -> Sgd {
        Sgd::new(0.0, 0.0)
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut ParamStore, grads: &ParamStore, lr: f32) {
        if self.momentum != 0.0 && self.velocity.is_none() {
            self.velocity = Some(params.zeros_like());
        }
        for (pi, p) in params.tensors.iter_mut().enumerate() {
            let g = &grads.tensors[pi];
            debug_assert_eq!(p.name, g.name);
            if self.momentum != 0.0 {
                let v = &mut self.velocity.as_mut().unwrap().tensors[pi];
                for i in 0..p.data.len() {
                    let grad = g.data[i] + self.weight_decay * p.data[i];
                    v.data[i] = self.momentum * v.data[i] + grad;
                    p.data[i] -= lr * v.data[i];
                }
            } else {
                for i in 0..p.data.len() {
                    let grad = g.data[i] + self.weight_decay * p.data[i];
                    p.data[i] -= lr * grad;
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }

    fn state_stores(&self) -> Vec<&ParamStore> {
        self.velocity.iter().collect()
    }

    fn state_stores_mut(&mut self) -> Vec<&mut ParamStore> {
        self.velocity.iter_mut().collect()
    }

    fn set_state_stores(&mut self, mut stores: Vec<ParamStore>) {
        self.velocity = stores.pop();
    }
}

/// Adam / AdamW (decoupled weight decay when `decoupled_wd` is set).
pub struct Adam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub decoupled_wd: bool,
    t: u64,
    m: Option<ParamStore>,
    v: Option<ParamStore>,
}

impl Adam {
    pub fn new(weight_decay: f32) -> Adam {
        Adam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay,
            decoupled_wd: false,
            t: 0,
            m: None,
            v: None,
        }
    }

    pub fn adamw(weight_decay: f32) -> Adam {
        let mut a = Adam::new(weight_decay);
        a.decoupled_wd = true;
        a
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut ParamStore, grads: &ParamStore, lr: f32) {
        if self.m.is_none() {
            self.m = Some(params.zeros_like());
            self.v = Some(params.zeros_like());
        }
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let m = self.m.as_mut().unwrap();
        let v = self.v.as_mut().unwrap();
        for (pi, p) in params.tensors.iter_mut().enumerate() {
            let g = &grads.tensors[pi];
            let mt = &mut m.tensors[pi];
            let vt = &mut v.tensors[pi];
            for i in 0..p.data.len() {
                let mut grad = g.data[i];
                if !self.decoupled_wd {
                    grad += self.weight_decay * p.data[i];
                }
                mt.data[i] = self.beta1 * mt.data[i] + (1.0 - self.beta1) * grad;
                vt.data[i] = self.beta2 * vt.data[i] + (1.0 - self.beta2) * grad * grad;
                let mhat = mt.data[i] / bc1;
                let vhat = vt.data[i] / bc2;
                let mut upd = mhat / (vhat.sqrt() + self.eps);
                if self.decoupled_wd {
                    upd += self.weight_decay * p.data[i];
                }
                p.data[i] -= lr * upd;
            }
        }
    }

    fn name(&self) -> &'static str {
        if self.decoupled_wd {
            "adamw"
        } else {
            "adam"
        }
    }

    fn state_stores(&self) -> Vec<&ParamStore> {
        self.m.iter().chain(self.v.iter()).collect()
    }

    fn state_stores_mut(&mut self) -> Vec<&mut ParamStore> {
        self.m.iter_mut().chain(self.v.iter_mut()).collect()
    }

    fn set_state_stores(&mut self, mut stores: Vec<ParamStore>) {
        // order matches state_stores(): [m, v]
        self.v = stores.pop();
        self.m = stores.pop();
    }

    fn scalar_state(&self) -> u64 {
        self.t
    }

    fn set_scalar_state(&mut self, v: u64) {
        self.t = v;
    }
}

pub fn make_optimizer(name: &str, weight_decay: f32, momentum: f32) -> Box<dyn Optimizer> {
    match name {
        "sgd" => Box::new(Sgd::new(momentum, weight_decay)),
        "adam" => Box::new(Adam::new(weight_decay)),
        "adamw" => Box::new(Adam::adamw(weight_decay)),
        other => panic!("unknown optimizer {other}"),
    }
}

/// Learning-rate schedules (paper Appendix C uses StepLR / constant).
#[derive(Debug, Clone)]
pub enum Schedule {
    Const(f32),
    /// lr * gamma^(step / every)
    Step { lr: f32, gamma: f32, every: usize },
    /// half-cosine from lr to lr*floor over total steps
    Cosine { lr: f32, floor: f32, total: usize },
}

impl Schedule {
    pub fn lr(&self, step: usize) -> f32 {
        match self {
            Schedule::Const(lr) => *lr,
            Schedule::Step { lr, gamma, every } => lr * gamma.powi((step / every) as i32),
            Schedule::Cosine { lr, floor, total } => {
                let p = (step as f32 / (*total).max(1) as f32).min(1.0);
                floor + (lr - floor) * 0.5 * (1.0 + (std::f32::consts::PI * p).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn store(vals: &[f32]) -> ParamStore {
        let mut s = ParamStore::new();
        s.push(Tensor::from_vec("w", &[vals.len()], vals.to_vec()));
        s
    }

    #[test]
    fn sgd_plain_step() {
        let mut p = store(&[1.0, 2.0]);
        let g = store(&[0.5, -0.5]);
        Sgd::plain().step(&mut p, &g, 0.1);
        assert_eq!(p.tensors[0].data, vec![0.95, 2.05]);
    }

    #[test]
    fn sgd_momentum_accumulates() {
        let mut p = store(&[0.0]);
        let g = store(&[1.0]);
        let mut opt = Sgd::new(0.9, 0.0);
        opt.step(&mut p, &g, 0.1);
        let x1 = p.tensors[0].data[0]; // -0.1
        opt.step(&mut p, &g, 0.1);
        let x2 = p.tensors[0].data[0]; // -0.1 - 0.19
        assert!((x1 + 0.1).abs() < 1e-6);
        assert!((x2 + 0.29).abs() < 1e-6);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // minimize (x-3)^2 with adam
        let mut p = store(&[0.0]);
        let mut opt = Adam::new(0.0);
        for _ in 0..500 {
            let x = p.tensors[0].data[0];
            let g = store(&[2.0 * (x - 3.0)]);
            opt.step(&mut p, &g, 0.05);
        }
        assert!((p.tensors[0].data[0] - 3.0).abs() < 0.05);
    }

    #[test]
    fn adamw_decay_differs_from_adam() {
        let run = |decoupled: bool| {
            let mut p = store(&[1.0]);
            let mut opt = Adam::new(0.1);
            opt.decoupled_wd = decoupled;
            let g = store(&[0.0]);
            for _ in 0..10 {
                opt.step(&mut p, &g, 0.01);
            }
            p.tensors[0].data[0]
        };
        // decoupled decay shrinks weight even with zero grad
        assert!(run(true) < 1.0);
        assert_ne!(run(true), run(false));
    }

    #[test]
    fn schedules() {
        let s = Schedule::Step { lr: 1.0, gamma: 0.1, every: 10 };
        assert_eq!(s.lr(0), 1.0);
        assert!((s.lr(10) - 0.1).abs() < 1e-6);
        assert!((s.lr(25) - 0.01).abs() < 1e-6);
        let c = Schedule::Cosine { lr: 1.0, floor: 0.0, total: 100 };
        assert!((c.lr(0) - 1.0).abs() < 1e-6);
        assert!(c.lr(50) < 0.6 && c.lr(50) > 0.4);
        assert!(c.lr(100) < 1e-6);
        assert_eq!(Schedule::Const(0.3).lr(99), 0.3);
    }
}
