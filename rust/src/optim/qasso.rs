//! QASSO — Quantization-Aware Structured Sparse Optimizer (Algorithm 2).
//!
//! Four sequential stages driven by the global step counter:
//!
//! 1. **Warm-up** (line 2): `K_w` base-optimizer steps over everything.
//! 2. **Projection** (lines 3-9): `B` periods of `K_b` steps; the upper
//!    bit bound decays `b_u ← b_u − b_r` each period (starting from the
//!    initialization bit width) and every (d,t,q_m) SGD update is followed
//!    by the PPSG projection of `d` (Algorithm 3).
//! 3. **Joint** (lines 10-21): `P` pruning periods of `K_p` steps. At each
//!    period start, saliency (line 11, [13]) partitions groups into
//!    important G_I / redundant G_R. Per step: (t,q_m) SGD (line 14), the
//!    forget rate γ per group via eq. (16), step size d per site via
//!    eq. (17) plus the Algorithm-4 adaptive correction, then the weight
//!    updates eq. (8)/(9) with the quantized forget term x^Q (eq. 12).
//!    Period ends hard-zero that period's redundant groups.
//! 4. **Cool-down** (line 22): quant params frozen, pruned groups pinned
//!    to zero, plain training of the surviving weights.

use std::collections::BTreeMap;

use crate::graph::PruneGroup;
use crate::optim::saliency::{self, GroupIndex, SaliencyWeights};
use crate::optim::Optimizer;
use crate::quant::{self, QParams};
use crate::tensor::ParamStore;

#[derive(Debug, Clone)]
pub struct QassoConfig {
    pub warmup_steps: usize,
    /// B — projection periods.
    pub proj_periods: usize,
    /// K_b — steps per projection period.
    pub proj_steps: usize,
    /// P — pruning periods.
    pub prune_periods: usize,
    /// K_p — steps per pruning period.
    pub prune_steps: usize,
    pub cooldown_steps: usize,
    /// b_r — bit-width reduction per projection period.
    pub bit_reduction: f32,
    /// [b_l, b_u] — the target bit range of eq. (7c).
    pub b_l: f32,
    pub b_u: f32,
    /// Bit width the quantizers are initialized at (32 CNN / 8 BERT).
    pub init_bits: f32,
    /// K as a fraction of prunable groups (eq. 7b).
    pub target_group_sparsity: f64,
    pub eta: f32,
    pub xi: f32,
    pub eps_clip: f32,
    /// Algorithm 4 shrink factor β.
    pub beta: f32,
    /// Learning rate for quantization parameters (Appendix C: 1e-4).
    pub lr_q: f32,
    pub saliency: SaliencyWeights,
}

impl Default for QassoConfig {
    fn default() -> Self {
        QassoConfig {
            warmup_steps: 20,
            proj_periods: 4,
            proj_steps: 20,
            prune_periods: 4,
            prune_steps: 20,
            cooldown_steps: 60,
            bit_reduction: 6.0,
            b_l: 4.0,
            b_u: 16.0,
            init_bits: 32.0,
            target_group_sparsity: 0.5,
            eta: 0.9,
            xi: 0.999,
            eps_clip: 1e-8,
            beta: 0.5,
            lr_q: 1e-4,
            saliency: SaliencyWeights::default(),
        }
    }
}

impl QassoConfig {
    pub fn total_steps(&self) -> usize {
        self.warmup_steps
            + self.proj_periods * self.proj_steps
            + self.prune_periods * self.prune_steps
            + self.cooldown_steps
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Warmup,
    Projection,
    Joint,
    Cooldown,
    Done,
}

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Warmup => "warmup",
            Stage::Projection => "projection",
            Stage::Joint => "joint",
            Stage::Cooldown => "cooldown",
            Stage::Done => "done",
        }
    }
}

/// Which stages run (for the Fig. 4a ablation).
#[derive(Debug, Clone, Copy)]
pub struct StageMask {
    pub warmup: bool,
    pub projection: bool,
    pub joint: bool,
    pub cooldown: bool,
}

impl Default for StageMask {
    fn default() -> Self {
        StageMask {
            warmup: true,
            projection: true,
            joint: true,
            cooldown: true,
        }
    }
}

pub struct Qasso {
    pub cfg: QassoConfig,
    pub mask: StageMask,
    groups: Vec<PruneGroup>,
    /// Pristine copy of the groups in ORIGINAL (dense) coordinates —
    /// [`Qasso::rebind`] always remaps from these, never from the current
    /// (possibly already-sliced) `groups`, so repeated re-plans compose.
    orig_groups: Vec<PruneGroup>,
    /// tensor index -> quant-site row (-1 for unquantized tensors); tensor
    /// order never changes across slicing, so this survives rebinds.
    tensor_site: Vec<i32>,
    gi: GroupIndex,
    /// Per group, aligned with gi.elems: the quant-site row of each element
    /// (-1 when the element's tensor is not a quant site).
    elem_site: Vec<Vec<i32>>,
    base: Box<dyn Optimizer>,
    step_count: usize,
    /// Projection-stage decaying upper bound (starts at init_bits).
    bu_cur: f32,
    pruned: Vec<bool>,
    /// Groups being forgotten during the current pruning period.
    redundant: Vec<usize>,
    /// eq. (16) γ per group (sparse: only redundant groups set).
    gamma: Vec<f32>,
    /// Algorithm 4 per-site γ scale for the current step.
    gamma_scale: Vec<f32>,
    // scratch buffers (allocation-free hot loop)
    buf_g: Vec<f32>,
    buf_b: Vec<f32>,
}

/// Mutable QASSO scheduling state captured in training checkpoints.
#[derive(Debug, Clone)]
pub struct QassoState {
    pub step_count: usize,
    pub bu_cur: f32,
    pub pruned: Vec<bool>,
    pub redundant: Vec<usize>,
    pub gamma: Vec<f32>,
    pub gamma_scale: Vec<f32>,
}

/// Everything the joint stage needs to know about a quant site.
#[derive(Debug, Clone)]
pub struct SiteSpec {
    pub name: String,
    /// Param tensor quantized at this site (None for activation sites).
    pub param: Option<String>,
}

impl Qasso {
    pub fn new(
        cfg: QassoConfig,
        groups: Vec<PruneGroup>,
        sites: &[SiteSpec],
        base: Box<dyn Optimizer>,
        params: &ParamStore,
    ) -> Qasso {
        let gi = GroupIndex::build(&groups, params);
        let site_of_tensor: BTreeMap<&str, i32> = sites
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.param.as_deref().map(|p| (p, i as i32)))
            .collect();
        // tensor index -> site
        let mut tensor_site = vec![-1i32; params.len()];
        for (name, site) in &site_of_tensor {
            if let Some(ti) = params.idx(name) {
                tensor_site[ti] = *site;
            }
        }
        let elem_site = gi
            .elems
            .iter()
            .map(|list| list.iter().map(|&(ti, _)| tensor_site[ti as usize]).collect())
            .collect();
        let ngroups = groups.len();
        Qasso {
            bu_cur: cfg.init_bits,
            cfg,
            mask: StageMask::default(),
            orig_groups: groups.clone(),
            tensor_site,
            groups,
            gi,
            elem_site,
            base,
            step_count: 0,
            pruned: vec![false; ngroups],
            redundant: Vec::new(),
            gamma: vec![0.0; ngroups],
            gamma_scale: vec![1.0; sites.len().max(1)],
            buf_g: Vec::new(),
            buf_b: Vec::new(),
        }
    }

    pub fn stage(&self) -> Stage {
        self.stage_at(self.step_count)
    }

    fn stage_at(&self, step: usize) -> Stage {
        let c = &self.cfg;
        let mut s = step;
        if s < c.warmup_steps {
            return Stage::Warmup;
        }
        s -= c.warmup_steps;
        if s < c.proj_periods * c.proj_steps {
            return Stage::Projection;
        }
        s -= c.proj_periods * c.proj_steps;
        if s < c.prune_periods * c.prune_steps {
            return Stage::Joint;
        }
        s -= c.prune_periods * c.prune_steps;
        if s < c.cooldown_steps {
            return Stage::Cooldown;
        }
        Stage::Done
    }

    pub fn step_count(&self) -> usize {
        self.step_count
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    pub fn pruned_count(&self) -> usize {
        self.pruned.iter().filter(|&&p| p).count()
    }

    pub fn group_sparsity(&self) -> f64 {
        if self.groups.is_empty() {
            return 0.0;
        }
        self.pruned_count() as f64 / self.groups.len() as f64
    }

    pub fn pruned_mask(&self) -> &[bool] {
        &self.pruned
    }

    pub fn groups(&self) -> &[PruneGroup] {
        &self.groups
    }

    pub fn group_index(&self) -> &GroupIndex {
        &self.gi
    }

    /// The prune groups in ORIGINAL (dense) coordinates, regardless of any
    /// rebinds — reporting and cumulative slice maps index through these.
    pub fn orig_groups(&self) -> &[PruneGroup] {
        &self.orig_groups
    }

    /// The base optimizer (momentum/moment state access for checkpointing
    /// and shrink-as-you-train slicing).
    pub fn base_optimizer(&self) -> &dyn Optimizer {
        self.base.as_ref()
    }

    pub fn base_optimizer_mut(&mut self) -> &mut dyn Optimizer {
        self.base.as_mut()
    }

    /// Re-index every group onto a sliced parameter store: member indices
    /// are remapped from original dense coordinates into kept-channel
    /// coordinates (removed indices drop out; survivors shift down by the
    /// number of removed indices below them). Fully-pruned groups end up
    /// with empty members, so zeroing/saliency over them degenerate to the
    /// exact no-ops the dense run performs on their all-zero elements —
    /// QASSO stepping stays bitwise identical after a re-plan.
    pub fn rebind(&mut self, kept: &crate::subnet::KeptMap, params: &ParamStore) {
        let mut groups = self.orig_groups.clone();
        for grp in groups.iter_mut() {
            for m in grp.members.iter_mut() {
                let Some(rm) = kept.removed.get(&m.tensor).and_then(|a| a.get(&m.axis))
                else {
                    continue;
                };
                m.indices = m
                    .indices
                    .iter()
                    .filter(|i| rm.binary_search(i).is_err())
                    .map(|&i| i - rm.partition_point(|&r| r < i))
                    .collect();
            }
        }
        self.gi = GroupIndex::build(&groups, params);
        self.elem_site = self
            .gi
            .elems
            .iter()
            .map(|list| {
                list.iter()
                    .map(|&(ti, _)| self.tensor_site[ti as usize])
                    .collect()
            })
            .collect();
        self.groups = groups;
    }

    // -------------------------------------------------- checkpoint state
    /// Snapshot the mutable scheduling state for `.getackpt` serialization.
    pub fn ckpt_state(&self) -> QassoState {
        QassoState {
            step_count: self.step_count,
            bu_cur: self.bu_cur,
            pruned: self.pruned.clone(),
            redundant: self.redundant.clone(),
            gamma: self.gamma.clone(),
            gamma_scale: self.gamma_scale.clone(),
        }
    }

    /// Restore state saved by [`Qasso::ckpt_state`]. Vec lengths must
    /// match this optimizer's group/site counts (the strict reader
    /// cross-checks them before calling this).
    pub fn restore_ckpt_state(&mut self, s: QassoState) {
        self.step_count = s.step_count;
        self.bu_cur = s.bu_cur;
        self.pruned = s.pruned;
        self.redundant = s.redundant;
        self.gamma = s.gamma;
        self.gamma_scale = s.gamma_scale;
    }

    /// Average learned bit width over sites (reporting).
    pub fn avg_bits(q: &[QParams]) -> f32 {
        if q.is_empty() {
            return 32.0;
        }
        q.iter().map(|s| s.bit_width()).sum::<f32>() / q.len() as f32
    }

    // ------------------------------------------------------------ stepping
    /// One QASSO step. `qgrads[i] = (∂f/∂d, ∂f/∂t, ∂f/∂q_m)` for site i
    /// (the summed STE gradients the AOT train step returns).
    pub fn step(
        &mut self,
        params: &mut ParamStore,
        q: &mut [QParams],
        grads: &ParamStore,
        qgrads: &[(f32, f32, f32)],
        lr: f32,
    ) {
        let stage = self.stage();
        match stage {
            Stage::Warmup => {
                self.base.step(params, grads, lr);
                if self.mask.warmup {
                    // ablation: without warm-up the quant params stay at
                    // their initialization until the projection stage.
                    self.sgd_q(q, qgrads, true, true, true);
                }
            }
            Stage::Projection => {
                let off = self.step_count - self.cfg.warmup_steps;
                let period = off / self.cfg.proj_steps.max(1);
                if self.mask.projection {
                    // line 4: decay the upper bound at each period start
                    if off % self.cfg.proj_steps.max(1) == 0 {
                        let target = self.cfg.init_bits
                            - self.cfg.bit_reduction * (period as f32 + 1.0);
                        self.bu_cur = target.max(self.cfg.b_u);
                    }
                    self.base.step(params, grads, lr);
                    self.sgd_q(q, qgrads, true, true, true);
                    let _prj = crate::obs::span("qasso", "ppsg_projection");
                    for site in q.iter_mut() {
                        quant::ppsg_project(site, self.cfg.b_l, self.bu_cur);
                    }
                } else {
                    // ablation: plain training, constraint enforced at once
                    // when the joint stage begins (bu snaps to b_u there)
                    self.base.step(params, grads, lr);
                    self.sgd_q(q, qgrads, true, true, true);
                }
            }
            Stage::Joint => {
                // after projection the operative range is [b_l, b_u]
                self.bu_cur = self.bu_cur.min(self.cfg.b_u).max(self.cfg.b_l);
                if self.mask.joint {
                    self.joint_step(params, q, grads, qgrads, lr);
                } else {
                    // ablation: skip forgetting; prune abruptly at the end
                    self.base.step(params, grads, lr);
                    for site in q.iter_mut() {
                        quant::ppsg_project(site, self.cfg.b_l, self.cfg.b_u);
                    }
                    let off = self.step_count
                        - self.cfg.warmup_steps
                        - self.cfg.proj_periods * self.cfg.proj_steps;
                    if off + 1 == self.cfg.prune_periods * self.cfg.prune_steps {
                        self.one_shot_prune(params, grads);
                    }
                }
                self.pin_pruned(params);
            }
            Stage::Cooldown | Stage::Done => {
                if self.mask.cooldown || stage == Stage::Done {
                    // line 22: fixed quant params, train surviving weights
                    self.base.step(params, grads, lr);
                    self.pin_pruned(params);
                } // ablation: no cooldown — do nothing (training ends)
            }
        }
        self.step_count += 1;
    }

    /// SGD on the quantization parameters (selected components).
    fn sgd_q(&self, q: &mut [QParams], qgrads: &[(f32, f32, f32)], upd_d: bool, upd_t: bool, upd_qm: bool) {
        let lr = self.cfg.lr_q;
        for (site, g) in q.iter_mut().zip(qgrads) {
            if upd_d {
                site.d = (site.d - lr * g.0).max(1e-8);
            }
            if upd_t {
                site.t = (site.t - lr * g.1).clamp(0.5, 2.0);
            }
            if upd_qm {
                site.qm = (site.qm - lr * g.2).max(1e-3);
            }
        }
    }

    /// Hard-zero every already-pruned group (idempotent).
    fn pin_pruned(&self, params: &mut ParamStore) {
        for g in 0..self.groups.len() {
            if self.pruned[g] {
                self.gi.zero_group(g, params);
            }
        }
    }

    /// Fallback for the no-joint-stage ablation: magnitude one-shot prune.
    fn one_shot_prune(&mut self, params: &mut ParamStore, grads: &ParamStore) {
        let scores = saliency::scores(&self.gi, params, grads, self.cfg.saliency);
        let eligible: Vec<bool> = self.pruned.iter().map(|p| !p).collect();
        let k = (self.cfg.target_group_sparsity * self.groups.len() as f64).round() as usize;
        for g in saliency::select_redundant(&scores, &eligible, k) {
            self.pruned[g] = true;
            self.gi.zero_group(g, params);
        }
    }

    // ------------------------------------------------------ the joint stage
    fn joint_step(
        &mut self,
        params: &mut ParamStore,
        q: &mut [QParams],
        grads: &ParamStore,
        qgrads: &[(f32, f32, f32)],
        lr: f32,
    ) {
        let c = self.cfg.clone();
        let off = self.step_count - c.warmup_steps - c.proj_periods * c.proj_steps;
        let period = off / c.prune_steps.max(1);
        let k = off % c.prune_steps.max(1);

        // ---- period start: lines 11-12, saliency partition
        if k == 0 {
            let _sal = crate::obs::span("qasso", "saliency_partition");
            let scores = saliency::scores(&self.gi, params, grads, c.saliency);
            let eligible: Vec<bool> = self.pruned.iter().map(|p| !p).collect();
            let total_target =
                (c.target_group_sparsity * self.groups.len() as f64).round() as usize;
            let cumulative =
                (total_target as f64 * (period as f64 + 1.0) / c.prune_periods as f64).round()
                    as usize;
            let already = self.pruned_count();
            let need = cumulative.saturating_sub(already);
            self.redundant = saliency::select_redundant(&scores, &eligible, need);
        }

        // ---- line 14: SGD on (t, q_m); d is rule-driven (eq. 17)
        self.sgd_q(q, qgrads, false, true, true);

        // ---- eq. (15)+(16): per-group clip mean, angle, forget rate γ
        let mut zero_now: Vec<usize> = Vec::new();
        for &g in &self.redundant.clone() {
            let (clip_mean, cos_gamma, norm_grad, norm_clipvec) =
                self.group_geometry(g, params, grads, q);
            let gamma = if clip_mean <= c.eps_clip as f64 {
                // negligible knowledge in the group: project to zero now
                zero_now.push(g);
                0.0
            } else if cos_gamma >= 0.0 {
                // uniform forgetting over the remaining steps of the period
                1.0 / (c.prune_steps - k) as f32
            } else {
                // descent-preserving magnitude (eq. 16 third branch)
                (-(1.0 - c.eta) as f64 * lr as f64 * norm_grad
                    / (cos_gamma * norm_clipvec).min(-1e-12)) as f32
            };
            self.gamma[g] = gamma.clamp(0.0, 1.0);
        }
        for g in zero_now {
            self.gi.zero_group(g, params);
        }

        // ---- eq. (17) + Algorithm 4: per-site step size d and γ scale
        self.update_site_d(params, grads, q, lr);

        // keep all sites feasible under (t,q_m) drift
        let prj_span = crate::obs::span("qasso", "ppsg_projection");
        for site in q.iter_mut() {
            quant::ppsg_project(site, c.b_l, c.b_u);
        }
        drop(prj_span);

        // ---- eq. (8): base step on everything (the -α∇ part of eq. (9))
        let base_span = crate::obs::span("qasso", "sgd_base");
        self.base.step(params, grads, lr);
        drop(base_span);

        // ---- eq. (9) second term: forget quantized knowledge in G_R
        let forget_span = crate::obs::span("qasso", "forgetting");
        for &g in &self.redundant {
            let gamma = self.gamma[g];
            if gamma == 0.0 {
                continue;
            }
            for (idx, &(ti, ei)) in self.gi.elems[g].iter().enumerate() {
                let site = self.elem_site[g][idx];
                let x = params.tensors[ti as usize].data[ei as usize];
                let (xq, scale) = if site >= 0 {
                    (
                        quant::fake_quant(x, &q[site as usize]),
                        self.gamma_scale[site as usize],
                    )
                } else {
                    (x, 1.0) // unquantized member: forget raw value
                };
                params.tensors[ti as usize].data[ei as usize] = x - gamma * scale * xq;
            }
        }
        drop(forget_span);

        // ---- period end: commit this period's redundant set
        if k + 1 == c.prune_steps {
            for &g in &self.redundant.clone() {
                self.pruned[g] = true;
                self.gi.zero_group(g, params);
            }
            self.redundant.clear();
        }
    }

    /// Gather group g and compute (mean clip, cos θ_γ, ||∇_g||, ||sgn·clip_g||).
    fn group_geometry(
        &mut self,
        g: usize,
        params: &ParamStore,
        grads: &ParamStore,
        q: &[QParams],
    ) -> (f64, f64, f64, f64) {
        self.buf_g.clear();
        self.buf_b.clear();
        let mut clip_sum = 0.0f64;
        for (idx, &(ti, ei)) in self.gi.elems[g].iter().enumerate() {
            let x = params.tensors[ti as usize].data[ei as usize];
            let gr = grads.tensors[ti as usize].data[ei as usize];
            let site = self.elem_site[g][idx];
            let clip = if site >= 0 {
                quant::clip_pow(x, &q[site as usize])
            } else {
                x.abs()
            };
            clip_sum += clip as f64;
            self.buf_g.push(gr);
            self.buf_b.push(quant::sign(x) * clip);
        }
        let n = self.gi.elems[g].len().max(1);
        let cos = crate::tensor::cosine(&self.buf_g, &self.buf_b);
        (
            clip_sum / n as f64,
            cos,
            crate::tensor::norm2(&self.buf_g),
            crate::tensor::norm2(&self.buf_b),
        )
    }

    /// Eq. (17) per quant site over its redundant elements, then the
    /// Algorithm-4 adjustment keeping the bit width in range. Sites with
    /// no redundant elements this period keep their current d.
    fn update_site_d(
        &mut self,
        params: &ParamStore,
        grads: &ParamStore,
        q: &mut [QParams],
        lr: f32,
    ) {
        let c = &self.cfg;
        for s in self.gamma_scale.iter_mut() {
            *s = 1.0;
        }
        if q.is_empty() {
            return;
        }
        // collect redundant elements per site
        let mut per_site: BTreeMap<usize, (Vec<f32>, Vec<f32>, f64, usize)> = BTreeMap::new();
        for &g in &self.redundant {
            let gamma = self.gamma[g] as f64;
            for (idx, &(ti, ei)) in self.gi.elems[g].iter().enumerate() {
                let site = self.elem_site[g][idx];
                if site < 0 {
                    continue;
                }
                let x = params.tensors[ti as usize].data[ei as usize];
                let gr = grads.tensors[ti as usize].data[ei as usize];
                let r = quant::sign(x) * quant::residual(x, &q[site as usize]);
                let e = per_site.entry(site as usize).or_insert_with(|| {
                    (Vec::new(), Vec::new(), 0.0, 0)
                });
                e.0.push(gr);
                e.1.push(r);
                e.2 += gamma;
                e.3 += 1;
            }
        }
        for (site, (gvec, rvec, gamma_sum, cnt)) in per_site {
            let cos_d = crate::tensor::cosine(&gvec, &rvec);
            let qm_t = q[site].qm.max(1e-12).powf(q[site].t);
            let gamma_bar = (gamma_sum / cnt.max(1) as f64).max(1e-8);
            let d_new = if cos_d >= 0.0 {
                // low-bit choice: d such that b == b_l (eq. 17 first branch)
                qm_t / (2f32.powf(c.b_l - 1.0) - 1.0)
            } else {
                let norm_g = crate::tensor::norm2(&gvec);
                let norm_r = crate::tensor::norm2(&rvec).max(1e-12);
                ((-(c.xi as f64) * c.eta as f64 * lr as f64 * norm_g)
                    / (gamma_bar * cos_d * norm_r)) as f32
            };
            if d_new.is_finite() && d_new > 0.0 {
                q[site].d = d_new;
            }
            // Algorithm 4: keep the bit width feasible, scaling γ along
            let (scale, _) = quant::adaptive_adjust(1.0, &mut q[site], c.b_l, c.b_u, c.beta);
            self.gamma_scale[site] = scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Member, Side};
    use crate::optim::Sgd;
    use crate::tensor::Tensor;

    fn toy() -> (ParamStore, Vec<PruneGroup>, Vec<SiteSpec>, Vec<QParams>) {
        let mut params = ParamStore::new();
        let mut rng = crate::util::rng::Rng::new(3);
        let mut w = vec![0.0f32; 4 * 6];
        rng.fill_normal(&mut w, 0.5);
        params.push(Tensor::from_vec("w", &[4, 6], w));
        let groups = (0..6)
            .map(|j| PruneGroup {
                id: j,
                label: format!("w:ch{j}"),
                members: vec![Member {
                    tensor: "w".into(),
                    axis: 1,
                    indices: vec![j],
                    side: Side::Out,
                }],
            })
            .collect();
        let sites = vec![SiteSpec {
            name: "w".into(),
            param: Some("w".into()),
        }];
        let q = vec![QParams::init(1.0, 16.0)];
        (params, groups, sites, q)
    }

    fn cfg_small() -> QassoConfig {
        QassoConfig {
            warmup_steps: 2,
            proj_periods: 2,
            proj_steps: 3,
            prune_periods: 2,
            prune_steps: 4,
            cooldown_steps: 3,
            bit_reduction: 4.0,
            b_l: 4.0,
            b_u: 8.0,
            init_bits: 16.0,
            target_group_sparsity: 0.5,
            ..Default::default()
        }
    }

    fn run(mask: StageMask) -> (Qasso, ParamStore, Vec<QParams>) {
        let (mut params, groups, sites, mut q) = toy();
        let cfg = cfg_small();
        let mut opt = Qasso::new(cfg.clone(), groups, &sites, Box::new(Sgd::plain()), &params);
        opt.mask = mask;
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..cfg.total_steps() {
            let mut grads = params.zeros_like();
            // pseudo-gradients pulling weights toward zero + noise
            for (ti, t) in params.tensors.iter().enumerate() {
                for (i, &x) in t.data.iter().enumerate() {
                    grads.tensors[ti].data[i] = 0.1 * x + rng.normal_f32(0.02);
                }
            }
            let qg = vec![(rng.normal_f32(0.01), rng.normal_f32(0.01), rng.normal_f32(0.01))];
            opt.step(&mut params, &mut q, &grads, &qg, 0.05);
        }
        (opt, params, q)
    }

    #[test]
    fn stages_progress_in_order() {
        let (mut params, groups, sites, mut q) = toy();
        let cfg = cfg_small();
        let mut opt = Qasso::new(cfg.clone(), groups, &sites, Box::new(Sgd::plain()), &params);
        let grads = params.zeros_like();
        let mut seen = Vec::new();
        for _ in 0..cfg.total_steps() {
            let s = opt.stage();
            if seen.last() != Some(&s) {
                seen.push(s);
            }
            opt.step(&mut params, &mut q, &grads, &[(0.0, 0.0, 0.0)], 0.01);
        }
        assert_eq!(
            seen,
            vec![Stage::Warmup, Stage::Projection, Stage::Joint, Stage::Cooldown]
        );
        assert_eq!(opt.stage(), Stage::Done);
    }

    #[test]
    fn sparsity_target_reached_and_groups_zeroed() {
        let (opt, params, _) = run(StageMask::default());
        assert_eq!(opt.pruned_count(), 3); // 50% of 6
        for (g, &pruned) in opt.pruned_mask().iter().enumerate() {
            if pruned {
                assert!(opt.group_index().group_norm(g, &params) < 1e-9, "group {g}");
            }
        }
    }

    #[test]
    fn bit_constraint_satisfied_after_projection() {
        let (_, _, q) = run(StageMask::default());
        for site in &q {
            let b = site.bit_width();
            assert!(
                (cfg_small().b_l - 1e-2..=cfg_small().b_u + 1e-2).contains(&b),
                "b={b}"
            );
        }
    }

    #[test]
    fn surviving_groups_keep_signal() {
        let (opt, params, _) = run(StageMask::default());
        let mut live = 0;
        for g in 0..opt.n_groups() {
            if !opt.pruned_mask()[g] && opt.group_index().group_norm(g, &params) > 1e-6 {
                live += 1;
            }
        }
        assert_eq!(live, 3);
    }

    #[test]
    fn ablation_no_joint_still_hits_sparsity() {
        let (opt, params, _) = run(StageMask {
            joint: false,
            ..Default::default()
        });
        assert_eq!(opt.pruned_count(), 3);
        for g in 0..opt.n_groups() {
            if opt.pruned_mask()[g] {
                assert!(opt.group_index().group_norm(g, &params) < 1e-9);
            }
        }
    }

    #[test]
    fn gamma_stays_in_unit_interval() {
        let (opt, _, _) = run(StageMask::default());
        for &g in &opt.gamma {
            assert!((0.0..=1.0).contains(&g), "gamma={g}");
        }
    }

    #[test]
    fn rebind_steps_bitwise_match_dense() {
        // Run two QASSO instances in lockstep: one dense-masked, one that
        // physically slices params after each prune commit and rebinds.
        // With grads exactly zero at pruned positions (what real backprop
        // produces), every surviving value must stay bitwise identical.
        use crate::subnet::KeptMap;
        let (params0, groups, sites, q0) = toy();
        let cfg = cfg_small();
        let mut dense_p = params0.clone();
        let mut shrink_p = params0.clone();
        let mut dense_q = q0.clone();
        let mut shrink_q = q0.clone();
        let mut dense = Qasso::new(
            cfg.clone(),
            groups.clone(),
            &sites,
            Box::new(Sgd::plain()),
            &dense_p,
        );
        let mut shrink = Qasso::new(
            cfg.clone(),
            groups.clone(),
            &sites,
            Box::new(Sgd::plain()),
            &shrink_p,
        );
        let mut rng = crate::util::rng::Rng::new(9);
        let mut kept = KeptMap::default();
        let mut pruned_seen = 0;
        let mut replans = 0;
        for step in 0..cfg.total_steps() {
            let mut grads = dense_p.zeros_like();
            for (ti, t) in dense_p.tensors.iter().enumerate() {
                for (i, &x) in t.data.iter().enumerate() {
                    grads.tensors[ti].data[i] = 0.1 * x + rng.normal_f32(0.02);
                }
            }
            // real backprop yields exact zeros at pruned positions
            let mask = dense.pruned_mask().to_vec();
            crate::subnet::zero_pruned(&mut grads, &groups, &mask);
            let mut sgrads = ParamStore::new();
            for t in &grads.tensors {
                sgrads.push(kept.slice(t));
            }
            let qg = vec![(
                rng.normal_f32(0.01),
                rng.normal_f32(0.01),
                rng.normal_f32(0.01),
            )];
            dense.step(&mut dense_p, &mut dense_q, &grads, &qg, 0.05);
            shrink.step(&mut shrink_p, &mut shrink_q, &sgrads, &qg, 0.05);
            if dense.pruned_count() > pruned_seen {
                pruned_seen = dense.pruned_count();
                let new_kept = KeptMap::from_groups(&groups, dense.pruned_mask());
                let mut sliced = ParamStore::new();
                for t in &shrink_p.tensors {
                    sliced.push(new_kept.slice(&kept.expand(t)));
                }
                shrink_p = sliced;
                shrink.rebind(&new_kept, &shrink_p);
                assert_eq!(shrink.pruned_count(), dense.pruned_count());
                kept = new_kept;
                replans += 1;
            }
            // the toy's groups are Out-only, so the full expanded store
            // (zeros at removed positions) must equal the dense store
            for (ts, td) in shrink_p.tensors.iter().zip(&dense_p.tensors) {
                let e = kept.expand(ts);
                assert_eq!(e.shape, td.shape, "step {step}: {}", td.name);
                for (i, (a, b)) in e.data.iter().zip(&td.data).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "step {step}: {}[{i}] shrink {a} vs dense {b}",
                        td.name
                    );
                }
            }
            for (a, b) in shrink_q.iter().zip(&dense_q) {
                assert_eq!(a.d.to_bits(), b.d.to_bits(), "step {step}: q.d");
                assert_eq!(a.t.to_bits(), b.t.to_bits(), "step {step}: q.t");
                assert_eq!(a.qm.to_bits(), b.qm.to_bits(), "step {step}: q.qm");
            }
        }
        assert!(replans >= 1, "prune commits should have triggered re-plans");
        assert_eq!(dense.pruned_count(), 3);
    }

    #[test]
    fn pruned_groups_stay_zero_through_cooldown() {
        // gradients try to regrow pruned weights; pinning must hold
        let (mut params, groups, sites, mut q) = toy();
        let cfg = cfg_small();
        let mut opt = Qasso::new(cfg.clone(), groups, &sites, Box::new(Sgd::plain()), &params);
        for _ in 0..cfg.total_steps() {
            let mut grads = params.zeros_like();
            for t in grads.tensors.iter_mut() {
                for v in t.data.iter_mut() {
                    *v = -1.0; // constant push away from zero
                }
            }
            opt.step(&mut params, &mut q, &grads, &[(0.0, 0.0, 0.0)], 0.05);
        }
        for g in 0..opt.n_groups() {
            if opt.pruned_mask()[g] {
                assert!(opt.group_index().group_norm(g, &params) < 1e-9);
            }
        }
    }
}
