//! Group saliency scoring (HESSO-style, paper line 11 / [13]).
//!
//! Each prune group receives a score combining two normalized criteria:
//!
//! * **magnitude**: RMS of the group's output-side weights — small weights
//!   contribute little to the forward signal;
//! * **gradient flow**: |<x_g, ∇_g f>| — the first-order Taylor estimate of
//!   the loss change if the group is removed (x_g -> 0).
//!
//! Scores are min-max normalized per criterion and blended; the K lowest
//! scores become the redundant set G_R (Algorithm 2 line 12).

use crate::graph::{PruneGroup, Side};
use crate::tensor::ParamStore;

/// Precomputed flat element indices per group (output-side members only),
/// built once per search space so the per-period scoring is index walks.
#[derive(Debug, Clone)]
pub struct GroupIndex {
    /// per group: (tensor index in store, flat element index)
    pub elems: Vec<Vec<(u32, u32)>>,
}

impl GroupIndex {
    pub fn build(groups: &[PruneGroup], params: &ParamStore) -> GroupIndex {
        let mut elems = Vec::with_capacity(groups.len());
        for g in groups {
            let mut list = Vec::new();
            for m in &g.members {
                if m.side != Side::Out {
                    continue;
                }
                let Some(ti) = params.idx(&m.tensor) else {
                    continue; // tensor may be absent (e.g. model without bias)
                };
                let t = &params.tensors[ti];
                let shape = &t.shape;
                debug_assert!(m.axis < shape.len(), "{}: axis {}", m.tensor, m.axis);
                // stride of the member axis and total outer repeats
                let axis_len = shape[m.axis];
                let inner: usize = shape[m.axis + 1..].iter().product();
                let outer: usize = shape[..m.axis].iter().product();
                for &idx in &m.indices {
                    debug_assert!(idx < axis_len, "{}: idx {} >= {}", m.tensor, idx, axis_len);
                    for o in 0..outer {
                        let base = o * axis_len * inner + idx * inner;
                        for k in 0..inner {
                            list.push((ti as u32, (base + k) as u32));
                        }
                    }
                }
            }
            elems.push(list);
        }
        GroupIndex { elems }
    }

    pub fn zero_group(&self, g: usize, params: &mut ParamStore) {
        for &(ti, ei) in &self.elems[g] {
            params.tensors[ti as usize].data[ei as usize] = 0.0;
        }
    }

    pub fn group_norm(&self, g: usize, params: &ParamStore) -> f64 {
        let mut s = 0.0f64;
        for &(ti, ei) in &self.elems[g] {
            let v = params.tensors[ti as usize].data[ei as usize] as f64;
            s += v * v;
        }
        s.sqrt()
    }
}

#[derive(Debug, Clone, Copy)]
pub struct SaliencyWeights {
    pub magnitude: f64,
    pub grad_flow: f64,
}

impl Default for SaliencyWeights {
    fn default() -> Self {
        SaliencyWeights {
            magnitude: 0.5,
            grad_flow: 0.5,
        }
    }
}

/// Score every group; higher = more important.
pub fn scores(
    gi: &GroupIndex,
    params: &ParamStore,
    grads: &ParamStore,
    w: SaliencyWeights,
) -> Vec<f64> {
    let n = gi.elems.len();
    let mut mag = vec![0.0f64; n];
    let mut flow = vec![0.0f64; n];
    for g in 0..n {
        let (mut m2, mut fl) = (0.0f64, 0.0f64);
        for &(ti, ei) in &gi.elems[g] {
            let x = params.tensors[ti as usize].data[ei as usize] as f64;
            let gr = grads.tensors[ti as usize].data[ei as usize] as f64;
            m2 += x * x;
            fl += x * gr;
        }
        let cnt = gi.elems[g].len().max(1) as f64;
        mag[g] = (m2 / cnt).sqrt(); // RMS: joint groups aren't penalized for size
        flow[g] = fl.abs();
    }
    normalize(&mut mag);
    normalize(&mut flow);
    (0..n)
        .map(|g| w.magnitude * mag[g] + w.grad_flow * flow[g])
        .collect()
}

fn normalize(v: &mut [f64]) {
    let max = v.iter().cloned().fold(0.0f64, f64::max);
    if max > 0.0 {
        for x in v.iter_mut() {
            *x /= max;
        }
    }
}

/// Pick the `k` lowest-scoring groups among `eligible` (not yet pruned).
pub fn select_redundant(scores: &[f64], eligible: &[bool], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).filter(|&i| eligible[i]).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Member;
    use crate::tensor::Tensor;

    fn setup() -> (Vec<PruneGroup>, ParamStore, ParamStore) {
        // linear [2,3]: groups = output columns
        let mut params = ParamStore::new();
        params.push(Tensor::from_vec(
            "w",
            &[2, 3],
            vec![1.0, 0.0, 5.0, 1.0, 0.0, 5.0],
        ));
        let mut grads = params.zeros_like();
        grads.tensors[0].data = vec![0.1, 0.0, 0.9, 0.1, 0.0, 0.9];
        let groups = (0..3)
            .map(|j| PruneGroup {
                id: j,
                label: format!("w:ch{j}"),
                members: vec![Member {
                    tensor: "w".into(),
                    axis: 1,
                    indices: vec![j],
                    side: Side::Out,
                }],
            })
            .collect();
        (groups, params, grads)
    }

    #[test]
    fn index_maps_columns() {
        let (groups, params, _) = setup();
        let gi = GroupIndex::build(&groups, &params);
        // column 2 = flat indices 2 and 5
        assert_eq!(gi.elems[2], vec![(0, 2), (0, 5)]);
    }

    #[test]
    fn zero_group_zeroes_only_its_column() {
        let (groups, mut params, _) = setup();
        let gi = GroupIndex::build(&groups, &params);
        gi.zero_group(0, &mut params);
        assert_eq!(params.tensors[0].data, vec![0.0, 0.0, 5.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn saliency_orders_by_importance() {
        let (groups, params, grads) = setup();
        let gi = GroupIndex::build(&groups, &params);
        let s = scores(&gi, &params, &grads, SaliencyWeights::default());
        // col1 (zeros) < col0 (small) < col2 (large)
        assert!(s[1] < s[0] && s[0] < s[2], "{s:?}");
        let red = select_redundant(&s, &[true, true, true], 2);
        assert_eq!(red, vec![1, 0]);
    }

    #[test]
    fn eligible_mask_respected() {
        let (groups, params, grads) = setup();
        let gi = GroupIndex::build(&groups, &params);
        let s = scores(&gi, &params, &grads, SaliencyWeights::default());
        let red = select_redundant(&s, &[true, false, true], 1);
        assert_eq!(red, vec![0]); // col1 excluded despite lowest score
    }

    #[test]
    fn conv_axis3_indexing() {
        // HWIO [1,1,2,2], prune cout 1 -> flat 1,3
        let mut params = ParamStore::new();
        params.push(Tensor::from_vec("c", &[1, 1, 2, 2], vec![1., 2., 3., 4.]));
        let groups = vec![PruneGroup {
            id: 0,
            label: "c:ch1".into(),
            members: vec![Member {
                tensor: "c".into(),
                axis: 3,
                indices: vec![1],
                side: Side::Out,
            }],
        }];
        let gi = GroupIndex::build(&groups, &params);
        assert_eq!(gi.elems[0], vec![(0, 1), (0, 3)]);
        assert!((gi.group_norm(0, &params) - (4.0f64 + 16.0).sqrt()).abs() < 1e-9);
    }
}
