//! Rust-native quantizer math — eqs. (1)-(6), (13)-(14) and eq. (3).
//!
//! The QASSO joint stage needs x^Q, clip and R(x) on the optimizer hot
//! path (eq. 9's forget term and the eq. 16/17 angle rules), so the
//! quantizer is reimplemented here and validated bit-for-bit against the
//! Layer-1 oracle via the golden vectors `artifacts/quant_vectors.json`
//! (see rust/tests/test_quant_vectors.rs).

pub mod ppsg;

pub use ppsg::{adaptive_adjust, d_range_for_bits, ppsg_project};

const EPS: f32 = 1e-12;

/// Per-site learnable quantization parameters (one row of the q array).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub d: f32,
    pub t: f32,
    pub qm: f32,
}

impl QParams {
    /// Paper Appendix C init: t = 1, q_m = max|w|, d inverted from eq. (3)
    /// for the requested initial bit width.
    pub fn init(max_abs_w: f32, target_bits: f32) -> QParams {
        let qm = max_abs_w.max(1e-3);
        let t = 1.0;
        let d = qm.powf(t) / (2f32.powf(target_bits - 1.0) - 1.0);
        QParams { d, t, qm }
    }

    /// Eq. (3): b = log2(q_m^t / d + 1) + 1.
    pub fn bit_width(&self) -> f32 {
        bit_width(self.d, self.t, self.qm)
    }
}

pub fn bit_width(d: f32, t: f32, qm: f32) -> f32 {
    (qm.max(EPS).powf(t) / d + 1.0).log2() + 1.0
}

/// Eq. (13): clip_{q_m}^t(|x|).
#[inline]
pub fn clip_pow(x: f32, q: &QParams) -> f32 {
    let ax = x.abs();
    if ax <= q.qm {
        ax.max(EPS).powf(q.t)
    } else {
        q.qm.max(EPS).powf(q.t)
    }
}

/// Eqs. (1)+(2): the full fake-quantization map x -> x^Q.
#[inline]
pub fn fake_quant(x: f32, q: &QParams) -> f32 {
    let c = clip_pow(x, q);
    let s = sign(x);
    q.d * (s * c / q.d).round()
}

/// Eq. (14): R(x) = round(c/d) - c/d.
#[inline]
pub fn residual(x: f32, q: &QParams) -> f32 {
    let cd = clip_pow(x, q) / q.d;
    cd.round() - cd
}

/// Eq. (12) decomposition check: x^Q = sgn(x)*clip + d*sgn(x)*R(x).
#[inline]
pub fn sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// Eq. (4): dx^Q/dd (per element).
pub fn grad_d(x: f32, q: &QParams) -> f32 {
    sign(x) * residual(x, q)
}

/// Eq. (5): dx^Q/dt (per element).
pub fn grad_t(x: f32, q: &QParams) -> f32 {
    let ax = x.abs();
    if ax <= EPS {
        return 0.0;
    }
    let g = if ax <= q.qm {
        ax.max(EPS).powf(q.t) * ax.max(EPS).ln()
    } else {
        q.qm.max(EPS).powf(q.t) * q.qm.max(EPS).ln()
    };
    sign(x) * g
}

/// Eq. (6): dx^Q/dq_m (per element).
pub fn grad_qm(x: f32, q: &QParams) -> f32 {
    if x.abs() <= q.qm {
        0.0
    } else {
        sign(x) * q.t * q.qm.max(EPS).powf(q.t - 1.0)
    }
}

/// The signed integer quantization level of `x`: `round(sgn(x)·clip/d)`.
/// This is the value the deployment path stores on disk; `level * d`
/// reconstructs [`fake_quant`]`(x)` exactly (IEEE multiplication is
/// commutative, so the two spellings are bit-identical).
#[inline]
pub fn quantize_level(x: f32, q: &QParams) -> i32 {
    (sign(x) * clip_pow(x, q) / q.d).round() as i32
}

/// Vectorized fake-quant into a reusable output buffer (joint-stage hot path).
pub fn fake_quant_slice(xs: &[f32], q: &QParams, out: &mut Vec<f32>) {
    out.clear();
    out.extend(xs.iter().map(|&x| fake_quant(x, q)));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(d: f32, t: f32, qm: f32) -> QParams {
        QParams { d, t, qm }
    }

    #[test]
    fn eq12_decomposition_holds() {
        // x^Q == sgn(x)*clip + d*sgn(x)*R(x) — the identity the joint
        // stage's angle rules rely on.
        let qp = q(0.05, 1.1, 1.2);
        for &x in &[-2.0f32, -1.0, -0.3, 0.0, 0.2, 0.9, 1.3, 5.0] {
            let lhs = fake_quant(x, &qp);
            let rhs = sign(x) * clip_pow(x, &qp) + qp.d * sign(x) * residual(x, &qp);
            assert!((lhs - rhs).abs() < 1e-5, "x={x}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn init_hits_target_bits() {
        for bits in [2.0f32, 4.0, 8.0, 16.0, 32.0] {
            let qp = QParams::init(0.73, bits);
            assert!((qp.bit_width() - bits).abs() < 1e-3, "bits={bits}");
        }
    }

    #[test]
    fn quant_output_on_grid() {
        let qp = q(0.25, 1.0, 1.0);
        for &x in &[0.1f32, -0.6, 0.77, 2.0] {
            let y = fake_quant(x, &qp);
            let ratio = y / qp.d;
            assert!((ratio - ratio.round()).abs() < 1e-6);
        }
    }

    #[test]
    fn saturation_beyond_qm() {
        let qp = q(0.1, 1.0, 0.5);
        assert_eq!(fake_quant(10.0, &qp), fake_quant(0.6, &qp));
        assert_eq!(fake_quant(-10.0, &qp), -fake_quant(10.0, &qp));
    }

    #[test]
    fn grad_d_is_residual_identity() {
        // eq. (4) is exactly sgn(x) * R(x) — the STE form, NOT the plain
        // derivative of d*round(c/d) (which would be round(c/d)).
        let qp = q(0.1, 1.1, 1.0);
        for &x in &[-1.3f32, -0.437, 0.2, 0.437, 2.0] {
            assert_eq!(grad_d(x, &qp), sign(x) * residual(x, &qp));
            assert!(grad_d(x, &qp).abs() <= 0.5 + 1e-6);
        }
    }

    #[test]
    fn grad_qm_matches_finite_difference_outside_clip() {
        // outside the clip range x^Q = d*round(qm^t/d) is smooth in qm
        // between round jumps; eq. (6) matches the STE-smoothed value
        // t*qm^(t-1) there.
        let qp = q(0.001, 1.1, 1.0);
        // h spans many round jumps so the staircase averages out:
        // fd error is +-d/(2h) = +-0.01
        let h = 0.05f32;
        let fd = (fake_quant(2.0, &q(qp.d, qp.t, qp.qm + h))
            - fake_quant(2.0, &q(qp.d, qp.t, qp.qm - h)))
            / (2.0 * h);
        assert!((grad_qm(2.0, &qp) - fd).abs() < 0.05, "{} vs {fd}", grad_qm(2.0, &qp));
        assert_eq!(grad_qm(0.3, &qp), 0.0);
    }

    #[test]
    fn grad_t_zero_at_origin() {
        let qp = q(0.1, 0.9, 1.0);
        assert_eq!(grad_t(0.0, &qp), 0.0);
        assert!(grad_t(0.5, &qp) < 0.0); // |x|<1 => log negative, sgn +
        assert!(grad_t(-0.5, &qp) > 0.0);
    }

    #[test]
    fn slice_matches_scalar() {
        let qp = q(0.07, 1.05, 0.9);
        let xs = [-1.5f32, -0.2, 0.0, 0.4, 2.2];
        let mut out = Vec::new();
        fake_quant_slice(&xs, &qp, &mut out);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(out[i], fake_quant(x, &qp));
        }
    }

    #[test]
    fn quantize_level_reconstructs_fake_quant() {
        let qp = q(0.05, 1.15, 1.1);
        for &x in &[-2.0f32, -0.73, -0.02, 0.0, 0.31, 0.99, 1.4] {
            let l = quantize_level(x, &qp);
            assert_eq!(l as f32 * qp.d, fake_quant(x, &qp), "x={x}");
        }
    }

    #[test]
    fn prop_fake_quant_idempotent_at_t1() {
        // with t = 1 (the Appendix-C init value, held by PTQ and uniform
        // QAT) the quantizer output is a fixed point: quantizing an
        // already-quantized value changes nothing. (For t != 1 the
        // nonlinear power map re-warps the grid, so idempotence is not
        // expected and not asserted.)
        crate::util::prop::check(
            120,
            |g| {
                (
                    g.f32_in(1e-3, 0.5),  // d
                    g.f32_in(0.1, 3.0),   // qm
                    g.f32_in(-4.0, 4.0),  // x
                )
            },
            |(d, qm, x)| {
                let qp = QParams { d: *d, t: 1.0, qm: *qm };
                let once = fake_quant(*x, &qp);
                let twice = fake_quant(once, &qp);
                if twice == once {
                    Ok(())
                } else {
                    Err(format!("fake_quant not idempotent: {once} -> {twice}"))
                }
            },
        );
    }

    #[test]
    fn prop_ste_gradients_match_finite_differences() {
        // The STE gradients are the derivatives of the *smoothed* quantizer
        // map (round treated as identity). Central differences with a probe
        // h spanning many rounding steps (h >> d) average the staircase
        // out, so they recover exactly those smoothed slopes:
        //  - d x^Q / dt  -> eq. (5), the smoothed sgn(x)·clip^t·ln-slope
        //  - d x^Q / dqm -> eq. (6) outside the clip range
        //  - d x^Q / dx  -> the clipped pass-through (1 inside, 0 outside)
        //    at t = 1, the regime the backward pass's STE mask models.
        // Probes within 2h of the clip boundary or near 0 (where the power
        // map is non-smooth) are regenerated away by construction.
        crate::util::prop::check(
            60,
            |g| {
                (
                    g.f32_in(1e-4, 1e-3), // d: fine grid, h/d >= 50
                    g.f32_in(0.8, 1.3),   // t
                    g.f32_in(0.5, 2.0),   // qm
                    g.f32_in(-3.0, 3.0),  // x
                )
            },
            |(d, t, qm, x)| {
                let (d, t, qm, x) = (*d, *t, *qm, *x);
                let h = 0.05f32;
                if (x.abs() - qm).abs() < 2.0 * h || x.abs() < 0.2 {
                    return Ok(()); // boundary/origin: STE legitimately differs
                }
                let qp = q(d, t, qm);
                // eq. (5) vs fd over t
                let fd_t = (fake_quant(x, &q(d, t + h, qm)) - fake_quant(x, &q(d, t - h, qm)))
                    / (2.0 * h);
                let gt = grad_t(x, &qp);
                if (fd_t - gt).abs() > 0.05 + 0.05 * gt.abs() {
                    return Err(format!("grad_t: analytic {gt} vs fd {fd_t}"));
                }
                // eq. (6) vs fd over qm (only bites outside the clip range;
                // keep the whole probe outside it)
                if x.abs() > qm + 2.0 * h {
                    let fd_qm = (fake_quant(x, &q(d, t, qm + h)) - fake_quant(x, &q(d, t, qm - h)))
                        / (2.0 * h);
                    let gqm = grad_qm(x, &qp);
                    if (fd_qm - gqm).abs() > 0.05 + 0.05 * gqm.abs() {
                        return Err(format!("grad_qm: analytic {gqm} vs fd {fd_qm}"));
                    }
                }
                // clipped pass-through vs fd over x at t = 1
                let qp1 = q(d, 1.0, qm);
                let fd_x = (fake_quant(x + h, &qp1) - fake_quant(x - h, &qp1)) / (2.0 * h);
                let want = if x.abs() + h < qm {
                    1.0
                } else if x.abs() - h > qm {
                    0.0
                } else {
                    return Ok(()); // probe straddles the boundary
                };
                if (fd_x - want).abs() > 0.05 {
                    return Err(format!("ste dx: want {want} vs fd {fd_x}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_projection_keeps_bits_in_bounds_under_drift() {
        // simulate the joint stage: random SGD-style drift on (d, t, q_m)
        // followed by the PPSG projection must keep eq. (3) inside
        // [b_l, b_u] at every step
        crate::util::prop::check(
            60,
            |g| {
                (
                    g.f32_in(0.05, 2.0), // init max|w|
                    g.f32_in(2.0, 6.0),  // b_l
                    g.f32_in(1.0, 10.0), // b_u - b_l
                    g.vec_normal(24, 0.05),
                )
            },
            |(maxw, bl, span, drift)| {
                let bu = bl + span.max(1.0);
                let mut qp = QParams::init(*maxw, (bl + bu) * 0.5);
                for ch in drift.chunks(3) {
                    qp.d = (qp.d + ch[0] * qp.d).max(1e-8);
                    qp.t = (qp.t + ch.get(1).copied().unwrap_or(0.0)).clamp(0.5, 2.0);
                    qp.qm = (qp.qm + ch.get(2).copied().unwrap_or(0.0)).max(1e-3);
                    ppsg_project(&mut qp, *bl, bu);
                    let b = qp.bit_width();
                    if b < bl - 1e-2 || b > bu + 1e-2 {
                        return Err(format!("b={b} outside [{bl}, {bu}] after drift"));
                    }
                }
                Ok(())
            },
        );
    }
}
