//! PPSG — Partial Projected Stochastic Gradient (paper Algorithm 3) and
//! the adaptive (γ, d) rule (paper Algorithm 4).
//!
//! The bit-width constraint b_i ∈ [b_l, b_u] (eq. 7c / 10b) has no
//! closed-form joint projection over (d, t, q_m); projecting q_m or t
//! destabilizes training through the exponential terms in eqs. (5)-(6).
//! PPSG therefore projects **only d**: inverting eq. (3),
//!
//! ```text
//! b ∈ [b_l, b_u]  ⇔  d ∈ [ q_m^t/(2^(b_u-1)-1),  q_m^t/(2^(b_l-1)-1) ]
//! ```

use super::{bit_width, QParams};

/// Feasible step-size interval [d_min, d_max] for bit range [b_l, b_u]
/// given the current (q_m, t) — Algorithm 3 line 3.
pub fn d_range_for_bits(qm: f32, t: f32, b_l: f32, b_u: f32) -> (f32, f32) {
    debug_assert!(b_u >= b_l);
    let top = qm.max(1e-12).powf(t);
    let d_min = top / (2f32.powf(b_u - 1.0) - 1.0);
    let d_max = top / (2f32.powf(b_l - 1.0) - 1.0);
    (d_min, d_max)
}

/// Algorithm 3 lines 3-4: project d onto the feasible interval after the
/// (d, t, q_m) SGD update has been applied. Returns the projected d.
pub fn ppsg_project(q: &mut QParams, b_l: f32, b_u: f32) -> f32 {
    let (d_min, d_max) = d_range_for_bits(q.qm, q.t, b_l, b_u);
    q.d = q.d.clamp(d_min, d_max);
    q.d
}

/// Algorithm 4: adaptively rescale the forget rate γ and step size d until
/// the computed bit width lies in [b_l, b_u]. Descent is preserved: when
/// the bit width is too high, γ shrinks by β while d grows by 1/β (their
/// product — the eq. (9) forget magnitude bound — is invariant); when too
/// low, d alone shrinks. Returns the adjusted (γ, d).
pub fn adaptive_adjust(mut gamma: f32, q: &mut QParams, b_l: f32, b_u: f32, beta: f32) -> (f32, f32) {
    debug_assert!((0.0..1.0).contains(&beta) && beta > 0.0);
    let mut iters = 0;
    loop {
        let b = bit_width(q.d, q.t, q.qm);
        if (b_l..=b_u).contains(&b) {
            break;
        }
        if b > b_u {
            gamma *= beta;
            q.d /= beta;
        } else {
            q.d *= beta;
        }
        iters += 1;
        // β-geometric steps always converge; the bound is defensive.
        if iters > 10_000 {
            // fall back to the exact projection
            ppsg_project(q, b_l, b_u);
            break;
        }
    }
    (gamma, q.d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn d_range_matches_eq3_inversion() {
        let (qm, t) = (1.5f32, 1.1f32);
        let (d_min, d_max) = d_range_for_bits(qm, t, 4.0, 16.0);
        assert!((bit_width(d_min, t, qm) - 16.0).abs() < 1e-3);
        assert!((bit_width(d_max, t, qm) - 4.0).abs() < 1e-3);
        assert!(d_min < d_max);
    }

    #[test]
    fn projection_enforces_constraint() {
        let mut q = QParams { d: 1e-6, t: 1.0, qm: 1.0 }; // ~21 bits
        ppsg_project(&mut q, 4.0, 8.0);
        let b = q.bit_width();
        assert!((4.0..=8.0).contains(&b), "b={b}");
        // feasible d untouched
        let mut q2 = QParams::init(1.0, 6.0);
        let before = q2.d;
        ppsg_project(&mut q2, 4.0, 8.0);
        assert_eq!(before, q2.d);
    }

    #[test]
    fn adaptive_converges_both_directions() {
        // too many bits
        let mut q = QParams { d: 1e-5, t: 1.0, qm: 1.0 };
        let (g, _) = adaptive_adjust(0.1, &mut q, 4.0, 8.0, 0.5);
        assert!((4.0..=8.0).contains(&q.bit_width()));
        assert!(g < 0.1); // gamma shrank
        // too few bits
        let mut q = QParams { d: 2.0, t: 1.0, qm: 1.0 };
        let (g, _) = adaptive_adjust(0.1, &mut q, 4.0, 8.0, 0.5);
        assert!((4.0..=8.0).contains(&q.bit_width()));
        assert_eq!(g, 0.1); // gamma untouched when raising bits
    }

    #[test]
    fn prop_projection_always_feasible() {
        prop::check(
            100,
            |g| {
                (
                    g.f32_in(1e-6, 2.0),  // d
                    g.f32_in(0.7, 1.4),   // t
                    g.f32_in(0.05, 4.0),  // qm
                    g.f32_in(2.0, 6.0),   // b_l
                    g.f32_in(0.5, 10.0),  // b_u - b_l
                )
            },
            |(d, t, qm, bl, span)| {
                let bu = bl + span.max(1.0);
                let mut q = QParams { d: *d, t: *t, qm: *qm };
                ppsg_project(&mut q, *bl, bu);
                let b = q.bit_width();
                // allow f32 slack at interval edges
                if b >= bl - 1e-3 && b <= bu + 1e-3 {
                    Ok(())
                } else {
                    Err(format!("b={b} outside [{bl}, {bu}]"))
                }
            },
        );
    }

    #[test]
    fn prop_adaptive_gamma_d_product_bounded() {
        // when bits are reduced, gamma*d never grows (descent preservation)
        prop::check(
            60,
            |g| (g.f32_in(1e-6, 1e-3), g.f32_in(0.5, 2.0), g.f32_in(0.01, 0.5)),
            |(d, qm, gamma0)| {
                let mut q = QParams { d: *d, t: 1.0, qm: *qm };
                let before = (*gamma0 as f64) * (*d as f64);
                let (g1, d1) = adaptive_adjust(*gamma0, &mut q, 4.0, 8.0, 0.5);
                let after = g1 as f64 * d1 as f64;
                if after <= before * 1.0001 {
                    Ok(())
                } else {
                    Err(format!("gamma*d grew: {before} -> {after}"))
                }
            },
        );
    }
}
