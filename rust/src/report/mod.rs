//! Paper-table / figure reproduction harnesses.
//!
//! One function per table/figure of the evaluation section (see DESIGN.md
//! per-experiment index). Each runs the relevant methods on the shared
//! substrate, prints the table, and returns markdown for EXPERIMENTS.md.
//! `--steps-scale` shrinks runs for smoke testing; default scale targets
//! single-core CPU wall clocks of a few minutes per table.

use anyhow::{Context, Result};

use crate::baselines::{self, LlmPruneStyle};
use crate::config::ExperimentConfig;
use crate::runtime::Backend as _;
use crate::coordinator::{Compressor as _, GetaCompressor, RunResult, Trainer};
use crate::deploy::{self, GetaEngine, KernelKind};
use crate::graph;
use crate::optim::qasso::StageMask;
use crate::util::table::Table;

pub struct ReportCtx {
    pub art_dir: std::path::PathBuf,
    pub scale: f64,
    pub verbose: bool,
    pub markdown: Vec<(String, String)>,
}

impl ReportCtx {
    pub fn new(art_dir: &std::path::Path, scale: f64, verbose: bool) -> ReportCtx {
        ReportCtx {
            art_dir: art_dir.to_path_buf(),
            scale,
            verbose,
            markdown: Vec::new(),
        }
    }

    fn exp(&self, model: &str) -> ExperimentConfig {
        let mut e = ExperimentConfig::defaults_for(model);
        e.scale_steps(self.scale);
        e
    }

    fn trainer(&self, exp: ExperimentConfig) -> Result<Trainer> {
        let mut t = Trainer::new(&self.art_dir, exp)?;
        t.verbose = self.verbose;
        t
            .engine
            .platform(); // touch
        Ok(t)
    }

    fn geta(&self, t: &Trainer) -> Result<GetaCompressor> {
        GetaCompressor::new(&t.engine, &t.exp, StageMask::default())
    }

    fn finish(&mut self, id: &str, tbl: Table) {
        tbl.print();
        self.markdown.push((id.to_string(), tbl.markdown()));
    }

    // ----------------------------------------------------------- table 1
    /// Qualitative capability matrix (paper Table 1) — self-reported from
    /// what this codebase implements.
    pub fn table1(&mut self) {
        let mut t = Table::new(
            "Table 1 — method capabilities",
            &["property", "GETA", "BB", "DJPQ", "QST", "Clip-Q", "ANNC"],
        );
        let row = |p: &str, v: [&str; 6]| {
            let mut cells = vec![p.to_string()];
            cells.extend(v.iter().map(|s| s.to_string()));
            cells
        };
        t.row(row("structured prune", ["yes", "yes", "yes", "no", "no", "no"]));
        t.row(row("one-shot", ["yes", "no", "no", "yes", "yes", "no"]));
        t.row(row("white-box", ["yes", "no", "no", "yes", "no", "yes"]));
        t.row(row("generalization", ["yes", "no", "no", "no", "no", "no"]));
        self.finish("table1", t);
    }

    // ----------------------------------------------------------- table 2
    /// ResNet20/CIFAR10 analog: GETA structured vs unstructured joint
    /// baselines (ANNC / QST-B analogs), weight quant only.
    pub fn table2(&mut self) -> Result<Vec<RunResult>> {
        let mut exp = self.exp("resnet_mini");
        // paper: 35% sparsity with learned bits collapsing toward b_l —
        // mirror that with a tighter upper bound for the joint run
        exp.qasso.target_group_sparsity = 0.5;
        exp.qasso.b_u = 8.0;
        exp.qasso.bit_reduction = 6.0;
        let t = self.trainer(exp)?;
        let mut rows = Vec::new();

        // full-precision baseline (uniform "32-bit QAT" = plain training)
        let steps = t.exp.total_steps();
        let mut base = baselines::UniformQat::new(32.0, baselines::base_opt(&t.exp), steps);
        rows.push(t.run(&mut base)?);

        let mut annc = baselines::UnstructuredJoint::new(
            0.5, 4.0, 16.0, baselines::base_opt(&t.exp), steps, "ANNC-like (unstructured)",
        );
        rows.push(t.run(&mut annc)?);

        let mut qst = baselines::UnstructuredJoint::new(
            0.35, 4.0, 16.0, baselines::base_opt(&t.exp), steps, "QST-B-like (unstructured)",
        );
        rows.push(t.run(&mut qst)?);

        let mut geta = self.geta(&t)?;
        rows.push(t.run(&mut geta)?);

        let mut tbl = Table::new(
            "Table 2 — resnet_mini / synth-CIFAR (weight quant only)",
            &["method", "pruning", "acc %", "rel BOPs %", "avg bits"],
        );
        for r in &rows {
            let kind = if r.method.contains("unstructured") {
                "unstructured"
            } else if r.method == "GETA" {
                "structured"
            } else {
                "none"
            };
            tbl.row(vec![
                r.method.clone(),
                kind.into(),
                format!("{:.2}", r.accuracy),
                format!("{:.2}", r.rel_bops),
                format!("{:.1}", r.avg_bits),
            ]);
        }
        self.finish("table2", tbl);
        Ok(rows)
    }

    // ----------------------------------------------------------- table 3
    /// BERT/SQuAD analog: GETA vs prune-then-PTQ at 10/30/50/70% sparsity.
    pub fn table3(&mut self) -> Result<Vec<RunResult>> {
        let mut rows = Vec::new();
        let mut tbl = Table::new(
            "Table 3 — bert_mini / synth-span-QA",
            &["method", "sparsity", "EM %", "F1 %", "rel BOPs %"],
        );
        for &sp in &[0.1, 0.3, 0.5, 0.7] {
            let mut exp = self.exp("bert_mini");
            // tighter data budget: the paper's SQuAD models are far from
            // overparameterized on their task; mirror that regime
            exp.n_train = 512;
            exp.qasso.target_group_sparsity = sp;
            let t = self.trainer(exp)?;
            // sequential baseline: HESSO-prune then 8-bit PTQ
            let space = graph::search_space_for(&t.engine.manifest().config)?;
            let params = t.engine.init_params(t.exp.seed);
            let mut seq = baselines::PruneThenPtq::new(
                t.exp.qasso.clone(),
                space.groups,
                t.engine.site_specs(),
                baselines::base_opt(&t.exp),
                &params,
                8.0,
                "HESSO+8b-PTQ",
            );
            let r1 = t.run(&mut seq)?;
            let mut geta = self.geta(&t)?;
            let r2 = t.run(&mut geta)?;
            for r in [r1, r2] {
                tbl.row(vec![
                    r.method.clone(),
                    format!("{:.0}%", sp * 100.0),
                    format!("{:.2}", r.em.unwrap_or(0.0)),
                    format!("{:.2}", r.f1.unwrap_or(0.0)),
                    format!("{:.2}", r.rel_bops),
                ]);
                rows.push(r);
            }
        }
        self.finish("table3", tbl);
        Ok(rows)
    }

    // ----------------------------------------------------------- table 4
    /// VGG7/CIFAR10 analog, weight+act quant: GETA vs DJPQ-like, BB-like.
    pub fn table4(&mut self) -> Result<Vec<RunResult>> {
        let mut exp = self.exp("vgg7_mini");
        exp.qasso.target_group_sparsity = 0.5;
        let t = self.trainer(exp)?;
        let steps = t.exp.total_steps();
        let mut rows = Vec::new();

        let mut base = baselines::UniformQat::new(32.0, baselines::base_opt(&t.exp), steps);
        rows.push(t.run(&mut base)?);

        let space = graph::search_space_for(&t.engine.manifest().config)?;
        let params = t.engine.init_params(t.exp.seed);
        let mut djpq = baselines::RegularizedJoint::new(
            0.5, 0.02, 0.02, 4.0, 16.0,
            baselines::base_opt(&t.exp), steps,
            space.groups.clone(), &params, false, "DJPQ-like",
        );
        rows.push(t.run(&mut djpq)?);

        let mut bb = baselines::RegularizedJoint::new(
            0.8, 0.03, 0.03, 2.0, 16.0,
            baselines::base_opt(&t.exp), steps,
            space.groups, &params, true, "BB-like",
        );
        rows.push(t.run(&mut bb)?);

        let mut geta = self.geta(&t)?;
        rows.push(t.run(&mut geta)?);

        let mut tbl = Table::new(
            "Table 4 — vgg7_mini / synth-CIFAR (weight+act quant)",
            &["method", "acc %", "rel BOPs %", "avg bits", "grp sparsity"],
        );
        for r in &rows {
            tbl.row(vec![
                r.method.clone(),
                format!("{:.2}", r.accuracy),
                format!("{:.2}", r.rel_bops),
                format!("{:.1}", r.avg_bits),
                format!("{:.2}", r.group_sparsity),
            ]);
        }
        self.finish("table4", tbl);
        Ok(rows)
    }

    // ----------------------------------------------------------- table 5
    /// ResNet50/ImageNet analog: GETA vs OBC-like, Clip-Q-like.
    pub fn table5(&mut self) -> Result<Vec<RunResult>> {
        let mut exp = self.exp("resnet_mini_l");
        exp.n_train = 2048;
        exp.qasso.target_group_sparsity = 0.4;
        let t = self.trainer(exp)?;
        let steps = t.exp.total_steps();
        let mut rows = Vec::new();

        let mut base = baselines::UniformQat::new(32.0, baselines::base_opt(&t.exp), steps);
        rows.push(t.run(&mut base)?);

        let mut obc = baselines::PostTrainPruneQuant::new(
            0.5, 6.0, baselines::base_opt(&t.exp), steps, t.engine.site_specs(), "OBC-like",
        );
        rows.push(t.run(&mut obc)?);

        let mut clipq = baselines::ClipQLike::new(0.5, 6.0, baselines::base_opt(&t.exp), steps);
        rows.push(t.run(&mut clipq)?);

        for &sp in &[0.4, 0.5] {
            let mut exp = self.exp("resnet_mini_l");
            exp.n_train = 2048;
            exp.qasso.target_group_sparsity = sp;
            let t2 = self.trainer(exp)?;
            let mut geta = self.geta(&t2)?;
            let mut r = t2.run(&mut geta)?;
            r.method = format!("GETA ({:.0}% sparsity)", sp * 100.0);
            rows.push(r);
        }

        let mut tbl = Table::new(
            "Table 5 — resnet_mini_l / synth-ImageNet-100c",
            &["method", "acc %", "rel BOPs %", "avg bits"],
        );
        for r in &rows {
            tbl.row(vec![
                r.method.clone(),
                format!("{:.2}", r.accuracy),
                format!("{:.2}", r.rel_bops),
                format!("{:.1}", r.avg_bits),
            ]);
        }
        self.finish("table5", tbl);
        Ok(rows)
    }

    // ----------------------------------------------------------- table 6
    /// Vision transformers: GETA across ViT variants.
    pub fn table6(&mut self) -> Result<Vec<RunResult>> {
        let mut rows = Vec::new();
        let mut tbl = Table::new(
            "Table 6 — vision transformer variants",
            &["model", "base acc %", "acc %", "rel BOPs %"],
        );
        for model in ["simplevit_mini", "vit_mini", "swin_mini"] {
            let exp = self.exp(model);
            let t = self.trainer(exp)?;
            let steps = t.exp.total_steps();
            let mut base = baselines::UniformQat::new(32.0, baselines::base_opt(&t.exp), steps);
            let rb = t.run(&mut base)?;
            let mut geta = self.geta(&t)?;
            let rg = t.run(&mut geta)?;
            tbl.row(vec![
                model.into(),
                format!("{:.2}", rb.accuracy),
                format!("{:.2}", rg.accuracy),
                format!("{:.2}", rg.rel_bops),
            ]);
            rows.push(rb);
            rows.push(rg);
        }
        self.finish("table6", tbl);
        Ok(rows)
    }

    // ------------------------------------------------------------- fig 3
    /// Phi2 common-sense analog: gpt_mini, GETA (avg ~8 bits) vs three
    /// LLM prune-then-PTQ pipelines, per-family task scores.
    pub fn fig3(&mut self) -> Result<Vec<RunResult>> {
        let mut exp = self.exp("gpt_mini");
        exp.qasso.target_group_sparsity = 0.3;
        exp.qasso.b_l = 4.0;
        exp.qasso.b_u = 8.0;
        let t = self.trainer(exp)?;
        let steps = t.exp.total_steps();
        let space = graph::search_space_for(&t.engine.manifest().config)?;
        let params = t.engine.init_params(t.exp.seed);
        let mut rows = Vec::new();
        for style in [LlmPruneStyle::Slice, LlmPruneStyle::Shear, LlmPruneStyle::GradMag] {
            let mut m = baselines::LlmPruneThenPtq::new(
                style, 0.3, 8.0,
                baselines::base_opt(&t.exp), steps,
                space.groups.clone(), &params, t.engine.site_specs(),
            );
            rows.push(t.run(&mut m)?);
        }
        let mut geta = self.geta(&t)?;
        rows.push(t.run(&mut geta)?);

        let nfam = rows[0].per_family.len();
        let mut headers: Vec<String> = vec!["method".into(), "avg acc %".into()];
        headers.extend((0..nfam).map(|f| format!("task{f}")));
        let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut tbl = Table::new("Fig. 3 — gpt_mini / synth common-sense suite", &hrefs);
        for r in &rows {
            let avg = r.per_family.iter().sum::<f64>() / r.per_family.len().max(1) as f64;
            let mut cells = vec![r.method.clone(), format!("{avg:.2}")];
            cells.extend(r.per_family.iter().map(|a| format!("{a:.1}")));
            tbl.row(cells);
        }
        self.finish("fig3", tbl);
        Ok(rows)
    }

    // ------------------------------------------------------------ fig 4a
    /// Stage ablation: disable each QASSO stage in turn.
    pub fn fig4a(&mut self) -> Result<Vec<RunResult>> {
        let masks: Vec<(&str, StageMask)> = vec![
            ("full", StageMask::default()),
            ("-warmup", StageMask { warmup: false, ..Default::default() }),
            ("-projection", StageMask { projection: false, ..Default::default() }),
            ("-joint", StageMask { joint: false, ..Default::default() }),
            ("-cooldown", StageMask { cooldown: false, ..Default::default() }),
        ];
        let mut rows = Vec::new();
        let mut tbl = Table::new(
            "Fig. 4a — QASSO stage ablation",
            &["variant", "resnet_mini acc %", "gpt_mini acc %"],
        );
        for (label, mask) in &masks {
            let mut accs = Vec::new();
            for model in ["resnet_mini", "gpt_mini"] {
                let mut exp = self.exp(model);
                exp.qasso.target_group_sparsity = 0.35;
                let t = self.trainer(exp)?;
                let mut geta = GetaCompressor::new(&t.engine, &t.exp, *mask)?;
                let mut r = t.run(&mut geta)?;
                r.method = format!("GETA {label}");
                accs.push(r.accuracy);
                rows.push(r);
            }
            tbl.row(vec![
                label.to_string(),
                format!("{:.2}", accs[0]),
                format!("{:.2}", accs[1]),
            ]);
        }
        self.finish("fig4a", tbl);
        Ok(rows)
    }

    // ------------------------------------------------------------ fig 4b
    /// Sparsity × bit-range frontier on resnet_mini.
    pub fn fig4b(&mut self) -> Result<Vec<RunResult>> {
        let sparsities = [0.3, 0.45, 0.6, 0.75];
        let ranges = [(2.0, 4.0), (4.0, 6.0), (6.0, 8.0)];
        let mut rows = Vec::new();
        let mut tbl = Table::new(
            "Fig. 4b — sparsity x bit-range frontier (resnet_mini acc %)",
            &["sparsity", "bits [2,4]", "bits [4,6]", "bits [6,8]"],
        );
        for &sp in &sparsities {
            let mut cells = vec![format!("{sp:.2}")];
            for &(bl, bu) in &ranges {
                let mut exp = self.exp("resnet_mini");
                exp.qasso.target_group_sparsity = sp;
                exp.qasso.b_l = bl;
                exp.qasso.b_u = bu;
                let t = self.trainer(exp)?;
                let mut geta = self.geta(&t)?;
                let mut r = t.run(&mut geta)?;
                r.method = format!("GETA sp={sp} b=[{bl},{bu}]");
                cells.push(format!("{:.2}", r.accuracy));
                rows.push(r);
            }
            tbl.row(cells);
        }
        self.finish("fig4b", tbl);
        Ok(rows)
    }

    // ------------------------------------------------------------ deploy
    /// Measured deployment table: on-disk `.geta` bytes and inference
    /// wall-clock next to the theoretical rel-BOPs, dense-f32 vs
    /// compressed, through the same executor (`deploy::GetaEngine`) —
    /// one row per compute kernel (f32-dequant, int8, and nibble-packed
    /// int4).
    pub fn deploy(&mut self) -> Result<Vec<DeployBench>> {
        let mut rows = Vec::new();
        let mut tbl = Table::new(
            "Deployment — .geta artifact vs dense f32 (measured)",
            &[
                "model", "kernel", "rel BOPs %", "dense KiB", ".geta KiB", "size x",
                "dense ms/b", "geta ms/b", "speedup",
            ],
        );
        for model in ["mlp_tiny", "resnet_mini"] {
            for r in bench_deploy(&self.art_dir, model, self.scale, 0.5, 5, 1)? {
                tbl.row(vec![
                    r.model.clone(),
                    r.kernel.clone(),
                    format!("{:.2}", r.rel_bops),
                    format!("{:.1}", r.dense_bytes as f64 / 1024.0),
                    format!("{:.1}", r.disk_bytes as f64 / 1024.0),
                    format!("{:.2}", r.dense_bytes as f64 / r.disk_bytes.max(1) as f64),
                    format!("{:.2}", r.dense_ms),
                    format!("{:.2}", r.compressed_ms),
                    format!("{:.2}", r.dense_ms / r.compressed_ms.max(1e-9)),
                ]);
                rows.push(r);
            }
        }
        self.finish("deploy", tbl);
        Ok(rows)
    }

    /// Write accumulated markdown to reports/.
    pub fn write_markdown(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        for (id, md) in &self.markdown {
            std::fs::write(dir.join(format!("{id}.md")), md)?;
        }
        Ok(())
    }
}

/// One measured deployment comparison (the `geta bench-infer` payload) —
/// one row per (model, compute kernel).
#[derive(Debug, Clone)]
pub struct DeployBench {
    pub model: String,
    /// Compute path of the compressed engine: `"f32"` (dequantize at
    /// load), `"int8"` (resident i8 levels, integer GEMMs), or `"int4"`
    /// (nibble-packed u4 panels, falling back to i8 then f32 per
    /// tensor). Stable machine-readable discriminator for downstream
    /// tooling.
    pub kernel: String,
    /// Theoretical relative BOPs of the exported subnet (%).
    pub rel_bops: f64,
    /// Dense f32 parameter bytes of the original architecture.
    pub dense_bytes: usize,
    /// On-disk size of the `.geta` artifact.
    pub disk_bytes: usize,
    /// Best-of-iters wall-clock per eval batch, dense-f32 engine.
    pub dense_ms: f64,
    /// Best-of-iters wall-clock per eval batch, compressed engine.
    pub compressed_ms: f64,
    pub batch: usize,
    /// Micro-batch worker threads both engines ran with.
    pub threads: usize,
    pub group_sparsity: f64,
    pub avg_bits: f64,
    /// Weight tensors resident as i8 levels (0 on the f32 kernel).
    pub int_sites: usize,
    /// Weight tensors resident as nibble-packed u4 panels (0 on every
    /// kernel but int4).
    pub u4_sites: usize,
    /// Per-op self-time breakdown of one traced inference through the
    /// compressed engine (aggregated per op kind × kernel), sorted by
    /// total time descending. Measured in a separate traced pass so the
    /// `compressed_ms` wall-clocks above stay untraced.
    pub per_op: Vec<OpBreakdown>,
}

/// One row of the per-op breakdown attached to a [`DeployBench`] row and
/// printed by `geta profile`: spans aggregated by name, where the name is
/// the op kind alone (`Relu`) or `op/kernel` for GEMM ops
/// (`Linear/int8`, `Conv2d/f32+simd`).
#[derive(Debug, Clone)]
pub struct OpBreakdown {
    pub name: String,
    pub calls: u64,
    pub total_ms: f64,
}

/// Run one traced inference through `e` and aggregate the executor spans
/// per (op kind, kernel). Tracing is flipped on just for this call and
/// restored after; spans buffered by an enclosing `--trace` session are
/// preserved (and, if one is active, the pass's own spans stay in its
/// trace too).
pub fn profile_per_op(
    e: &GetaEngine,
    x: &crate::runtime::HostArray,
) -> Result<Vec<OpBreakdown>> {
    let stash = crate::obs::trace::drain();
    let was_on = crate::obs::set_enabled(true);
    let res = e.infer(x);
    crate::obs::set_enabled(was_on);
    let mine = crate::obs::trace::drain();
    let agg = crate::obs::trace::aggregate(&mine, Some("exec"));
    let mut back = stash;
    if was_on {
        back.extend(mine);
    }
    crate::obs::trace::inject(back);
    let _ = res?;
    Ok(agg
        .into_iter()
        .map(|a| OpBreakdown {
            name: a.name,
            calls: a.calls,
            total_ms: a.total_us / 1e3,
        })
        .collect())
}

/// Outcome of the shared train→export preamble behind `bench-infer`,
/// `bench-serve`, and the serving demo: a short GETA run exported to an
/// in-memory `.geta` container, plus everything the caller needs to
/// build engines and loads from it.
pub struct TrainedArtifact {
    /// The trainer (its `eval_data` is the request source for serving).
    pub trainer: Trainer,
    pub container: crate::deploy::GetaContainer,
    pub compressed: crate::subnet::CompressedModel,
    /// Trained parameters **before** export zeroed the pruned groups —
    /// what the dense-f32 baseline engine runs.
    pub dense_params: crate::tensor::ParamStore,
    pub result: RunResult,
}

/// Train briefly with GETA and export a `.geta` container, with data and
/// bit bounds capped for bench wall-clocks.
///
/// `max_bits` caps the learned bit bounds (and the init) for the run: the
/// integer deployment comparison is about the resident-integer regime — a
/// site trained past the cap would silently fall back to f32 and measure
/// nothing. Pass 8.0 for the i8 regime (`bench-serve`, the serving demo)
/// and 4.0 when the container must also exercise the nibble-packed u4
/// residency ladder (`bench_deploy`, so the same artifact yields
/// u4-resident sites under `KernelKind::Int4`).
pub fn train_export(
    art_dir: &std::path::Path,
    model: &str,
    steps_scale: f64,
    sparsity: f64,
    max_bits: f64,
) -> Result<TrainedArtifact> {
    train_export_opts(art_dir, model, steps_scale, sparsity, max_bits, false)
}

/// [`train_export`] with the shrink-as-you-train re-planner switchable:
/// `replan` trains on sliced kept-channel plans after every prune commit
/// (bitwise identical results; `geta profile --replan` uses this to put
/// real `replan` spans in the trace).
pub fn train_export_opts(
    art_dir: &std::path::Path,
    model: &str,
    steps_scale: f64,
    sparsity: f64,
    max_bits: f64,
    replan: bool,
) -> Result<TrainedArtifact> {
    let mut exp = ExperimentConfig::defaults_for(model);
    exp.scale_steps(steps_scale);
    exp.n_train = exp.n_train.min(512);
    exp.n_eval = exp.n_eval.min(256);
    if sparsity > 0.0 {
        exp.qasso.target_group_sparsity = sparsity;
    }
    exp.qasso.b_u = exp.qasso.b_u.min(max_bits);
    exp.qasso.b_l = exp.qasso.b_l.min(exp.qasso.b_u);
    exp.qasso.init_bits = exp.qasso.init_bits.min(max_bits);
    let t = Trainer::new(art_dir, exp)?;
    let mut geta = GetaCompressor::new(&*t.engine, &t.exp, StageMask::default())?;
    let opts = crate::coordinator::TrainOpts {
        replan,
        ..Default::default()
    };
    let mut trained = t.run_trained_opts(&mut geta, &opts)?;
    let dense_params = trained.params.clone();
    let cfg = t.engine.manifest().config.clone();
    let space = graph::search_space_for(&cfg)?;
    let pruned: Vec<bool> = geta
        .pruned_mask()
        .map(|m| m.to_vec())
        .unwrap_or_else(|| vec![false; space.groups.len()]);
    let (container, cm) = deploy::export_model(
        &cfg,
        &t.engine.site_specs(),
        &space.groups,
        &pruned,
        &t.costs,
        &mut trained.params,
        &trained.q,
    )?;
    Ok(TrainedArtifact {
        trainer: t,
        container,
        compressed: cm,
        dense_params,
        result: trained.result,
    })
}

/// Train briefly, export a `.geta` artifact, and time one eval batch
/// through the dense-f32 engine vs the compressed engine (same executor,
/// same micro-batch, best of `iters` runs) — once per compute kernel, so
/// the returned rows compare dense vs f32-dequant vs int8 on the same
/// container. This is the measured counterpart to the BOPs column in
/// every paper table.
pub fn bench_deploy(
    art_dir: &std::path::Path,
    model: &str,
    steps_scale: f64,
    sparsity: f64,
    iters: usize,
    threads: usize,
) -> Result<Vec<DeployBench>> {
    // 4-bit cap: the same container then exercises every rung of the
    // residency ladder — u4 under Int4, i8 under Int8, dequant under F32
    let art = train_export(art_dir, model, steps_scale, sparsity, 4.0)?;
    let TrainedArtifact {
        trainer: t,
        container,
        compressed: cm,
        dense_params,
        result,
    } = art;
    let cfg = t.engine.manifest().config.clone();
    let disk_bytes = container.to_bytes().len();
    let mut dense = GetaEngine::dense(&cfg, dense_params)?;
    dense.threads = threads;
    let batch = t.batch_size();
    // one micro-batch per worker: a single batch would collapse to one
    // chunk and silently clamp the thread count back to 1
    let n_batches = threads.max(1);
    let idxs: Vec<usize> = (0..batch * n_batches).map(|i| i % t.eval_data.len()).collect();
    let (x, _y) = t.eval_data.batch(&idxs);
    let time_ms = |e: &GetaEngine| -> Result<f64> {
        crate::util::bench::black_box(e.infer(&x)?); // warm
        let mut best = f64::INFINITY;
        for _ in 0..iters.max(1) {
            let sw = crate::obs::Stopwatch::start();
            crate::util::bench::black_box(e.infer(&x)?);
            best = best.min(sw.elapsed_ms() / n_batches as f64);
        }
        Ok(best)
    };
    let dense_ms = time_ms(&dense)?;
    let mut rows = Vec::with_capacity(3);
    for kernel in [KernelKind::F32, KernelKind::Int8, KernelKind::Int4] {
        let mut comp = GetaEngine::from_container_kernel(&container, kernel)?;
        comp.threads = threads;
        let compressed_ms = time_ms(&comp)?;
        // separate traced pass, after the timed sweep: the wall-clocks
        // above never run with tracing on
        let per_op = profile_per_op(&comp, &x)?;
        rows.push(DeployBench {
            model: model.to_string(),
            kernel: kernel.label().to_string(),
            rel_bops: result.rel_bops,
            dense_bytes: cm.size_fp32_before,
            disk_bytes,
            dense_ms,
            compressed_ms,
            batch,
            threads,
            group_sparsity: result.group_sparsity,
            avg_bits: result.avg_bits,
            int_sites: comp.int_sites(),
            u4_sites: comp.u4_sites(),
            per_op,
        });
    }
    Ok(rows)
}

/// One GEMM-kernel comparison: the forward contraction shapes a model's
/// lowered program produces at `batch`, timed through the naive reference
/// triple loops vs the tiled multi-threaded kernels, plus a bitwise
/// thread-invariance check. This is the machine-readable evidence behind
/// the "tiled + threaded kernels are ≥ 2× the naive baseline" claim in
/// `BENCH_runtime.json`.
#[derive(Debug, Clone)]
pub struct GemmBench {
    pub model: String,
    pub batch: usize,
    /// Worker budget the tiled sweep ran with (`tensor::configured_threads`).
    pub threads: usize,
    /// Best-of-iters wall-clock of one full naive sweep over every shape.
    pub naive_ms: f64,
    /// Best-of-iters wall-clock of the same sweep through the tiled kernels.
    pub tiled_ms: f64,
    /// Tiled results bitwise identical at 1/2/4 worker threads.
    pub thread_invariant: bool,
}

/// Time every forward GEMM shape of `model`'s lowered program at `batch`
/// (linear rows × din × dout; conv im2col rows × k²cin × cout) through the
/// naive reference and the tiled kernels, on random normal data.
pub fn bench_gemm_kernels(model: &str, batch: usize, iters: usize) -> Result<GemmBench> {
    use crate::graph::builders;
    use crate::runtime::lowering;
    let cfg = crate::runtime::native::embedded_config(model)
        .with_context(|| format!("no embedded config for model `{model}`"))?;
    let sites = builders::quant_site_specs(&cfg)?;
    let prog = lowering::lower(&cfg, &sites, batch)?;
    let mut shapes: Vec<(usize, usize, usize)> = Vec::new();
    for node in &prog.nodes {
        match &node.op {
            lowering::OpKind::Linear { .. } => {
                let din = *prog.nodes[node.inputs[0]].shape.last().unwrap();
                let dout = *node.shape.last().unwrap();
                let rows: usize = node.shape.iter().product::<usize>() / dout;
                shapes.push((rows, din, dout));
            }
            lowering::OpKind::Conv2d { k, .. } => {
                let cin = *prog.nodes[node.inputs[0]].shape.last().unwrap();
                let (ho, wo, cout) = (node.shape[1], node.shape[2], node.shape[3]);
                shapes.push((batch * ho * wo, k * k * cin, cout));
            }
            _ => {}
        }
    }
    anyhow::ensure!(!shapes.is_empty(), "model `{model}` lowers to no GEMM nodes");
    let mut rng = crate::util::rng::Rng::new(42);
    let data: Vec<(Vec<f32>, Vec<f32>)> = shapes
        .iter()
        .map(|&(m, k, n)| {
            let mut a = vec![0.0f32; m * k];
            rng.fill_normal(&mut a, 1.0);
            let mut b = vec![0.0f32; k * n];
            rng.fill_normal(&mut b, 1.0);
            (a, b)
        })
        .collect();
    let sweep = |tiled: bool| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..iters.max(1) {
            let sw = crate::obs::Stopwatch::start();
            for (&(m, k, n), (a, b)) in shapes.iter().zip(&data) {
                let out = if tiled {
                    crate::tensor::matmul(a, b, m, k, n)
                } else {
                    crate::tensor::matmul_naive(a, b, m, k, n)
                };
                crate::util::bench::black_box(out);
            }
            best = best.min(sw.elapsed_ms());
        }
        best
    };
    sweep(true); // warm caches and the thread plumbing
    let naive_ms = sweep(false);
    let tiled_ms = sweep(true);
    // bitwise invariance across worker counts, on the largest shape
    let prev = crate::tensor::configured_threads();
    let (mi, _) = shapes
        .iter()
        .enumerate()
        .max_by_key(|(_, &(m, k, n))| m * k * n)
        .expect("shapes non-empty");
    let (m, k, n) = shapes[mi];
    let (a, b) = &data[mi];
    crate::tensor::set_threads(1);
    let base = crate::tensor::matmul(a, b, m, k, n);
    let mut thread_invariant = true;
    for t in [2usize, 4] {
        crate::tensor::set_threads(t);
        thread_invariant &= crate::tensor::matmul(a, b, m, k, n) == base;
    }
    crate::tensor::set_threads(prev);
    Ok(GemmBench {
        model: model.to_string(),
        batch,
        threads: prev,
        naive_ms,
        tiled_ms,
        thread_invariant,
    })
}

/// The standard kernel section of `BENCH_runtime.json`: resnet + vit at
/// batch 32 — the shapes the acceptance bar ("tiled ≥ 2× naive") is
/// stated over. Shared by `geta bench-infer --json` and the
/// `bench_runtime` bench so the two writers cannot diverge. Models whose
/// bench fails are reported on stderr and skipped.
pub fn standard_gemm_suite(iters: usize) -> Vec<GemmBench> {
    let mut rows = Vec::new();
    for model in ["resnet_mini", "vit_mini"] {
        match bench_gemm_kernels(model, 32, iters) {
            Ok(g) => rows.push(g),
            Err(e) => eprintln!("skipping gemm bench {model}: {e}"),
        }
    }
    rows
}

/// A bench-output file at the build checkout's repo root when this binary
/// still runs next to it (the `make bench-json` / CI case — identified by
/// its `Cargo.toml`, not mere directory existence), else in the current
/// directory (installed / relocated binaries).
fn repo_root_file(name: &str) -> std::path::PathBuf {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    if root.join("Cargo.toml").is_file() {
        root.join(name)
    } else {
        std::path::PathBuf::from(name)
    }
}

/// Where `BENCH_runtime.json` goes (see [`repo_root_file`]).
pub fn bench_json_path() -> std::path::PathBuf {
    repo_root_file("BENCH_runtime.json")
}

/// Write the machine-readable perf log (`BENCH_runtime.json`, see
/// [`bench_json_path`]): the GEMM naive-vs-tiled comparisons and the
/// per-family dense vs compressed inference rows, so the perf trajectory
/// is tracked across PRs instead of living in scrollback.
pub fn write_bench_runtime_json(
    path: &std::path::Path,
    gemm: &[GemmBench],
    deploy: &[DeployBench],
) -> Result<()> {
    use crate::util::json::Json;
    let gemm_rows: Vec<Json> = gemm
        .iter()
        .map(|g| {
            Json::obj(vec![
                ("model", Json::str(&g.model)),
                ("batch", Json::Num(g.batch as f64)),
                ("threads", Json::Num(g.threads as f64)),
                ("naive_ms", Json::Num(g.naive_ms)),
                ("tiled_ms", Json::Num(g.tiled_ms)),
                ("speedup", Json::Num(g.naive_ms / g.tiled_ms.max(1e-9))),
                ("thread_invariant", Json::Bool(g.thread_invariant)),
            ])
        })
        .collect();
    let deploy_rows: Vec<Json> = deploy.iter().map(deploy_row_json).collect();
    let doc = Json::obj(vec![
        ("threads", Json::Num(crate::tensor::configured_threads() as f64)),
        ("gemm", Json::Arr(gemm_rows)),
        ("deploy", Json::Arr(deploy_rows)),
    ]);
    std::fs::write(path, doc.to_string())?;
    Ok(())
}

/// One `deploy` row as JSON — shared by `BENCH_runtime.json` and
/// `BENCH_deploy.json` so the two files cannot disagree on field names.
/// `kernel` is the machine-readable `"f32" | "int8" | "int4"`
/// discriminator.
fn deploy_row_json(r: &DeployBench) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("model", Json::str(&r.model)),
        ("kernel", Json::str(&r.kernel)),
        ("batch", Json::Num(r.batch as f64)),
        ("threads", Json::Num(r.threads as f64)),
        ("dense_ms", Json::Num(r.dense_ms)),
        ("compressed_ms", Json::Num(r.compressed_ms)),
        ("speedup", Json::Num(r.dense_ms / r.compressed_ms.max(1e-9))),
        ("dense_bytes", Json::Num(r.dense_bytes as f64)),
        ("disk_bytes", Json::Num(r.disk_bytes as f64)),
        ("rel_bops", Json::Num(r.rel_bops)),
        ("avg_bits", Json::Num(r.avg_bits)),
        ("group_sparsity", Json::Num(r.group_sparsity)),
        ("int_sites", Json::Num(r.int_sites as f64)),
        ("u4_sites", Json::Num(r.u4_sites as f64)),
        (
            "per_op",
            Json::Arr(
                r.per_op
                    .iter()
                    .map(|o| {
                        Json::obj(vec![
                            ("op", Json::str(&o.name)),
                            ("calls", Json::Num(o.calls as f64)),
                            ("total_ms", Json::Num(o.total_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Where the deployment perf summary goes (see [`repo_root_file`]).
/// Unlike `BENCH_runtime.json` this file is **checked in**, so the
/// int-vs-f32 trajectory is diffable across PRs.
pub fn bench_deploy_json_path() -> std::path::PathBuf {
    repo_root_file("BENCH_deploy.json")
}

/// The fixed `note` field of `BENCH_deploy.json` — emitted verbatim on
/// every write so the checked-in copy regenerates byte-stable apart from
/// genuinely new measurements.
const BENCH_DEPLOY_NOTE: &str =
    "deployment inference summary; regenerate with `make bench-json` or `geta bench-infer \
     --json` (ms values are machine-dependent). Rows carry model, kernel (\"f32\" | \"int8\" | \
     \"int4\"), batch, threads, dense_ms, compressed_ms, speedup, dense_bytes, disk_bytes, \
     rel_bops, avg_bits, group_sparsity, int_sites, u4_sites, a per_op self-time breakdown \
     (op/kernel, calls, total_ms — from one traced pass separate from the timed sweep), and \
     (integer rows) speedup_vs_f32. Writers merge by model: a single-model `bench-infer \
     --json` run updates \
     only its own rows. CI regenerates the full file every run, uploads it, and asserts int8 \
     throughput >= f32-dequant and int4 >= int8 (with u4-resident sites) on mlp_tiny and \
     resnet_mini.";

/// Write the checked-in deployment summary (`BENCH_deploy.json`): the
/// per-(model, kernel) rows plus, for each integer-kernel row, its
/// throughput ratio against the f32-dequant row of the same model — the
/// headline number of the integer compute path.
///
/// **Merge-on-write:** `geta bench-infer --json` benches one model, but
/// the file tracks every benched model across PRs — rows for models not in
/// this run are carried over from the existing file instead of being
/// silently truncated. Rows are sorted by (model, kernel) so regeneration
/// diffs cleanly.
pub fn write_bench_deploy_json(path: &std::path::Path, deploy: &[DeployBench]) -> Result<()> {
    use crate::util::json::{self, Json};
    let fresh: std::collections::BTreeSet<&str> = deploy.iter().map(|r| r.model.as_str()).collect();
    let mut rows: Vec<Json> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(doc) = json::parse(&text) {
            if let Some(arr) = doc.get("deploy").and_then(|d| d.as_arr()) {
                for row in arr {
                    if !fresh.contains(row.str_or("model", "").as_str()) {
                        rows.push(row.clone());
                    }
                }
            }
        }
    }
    rows.extend(deploy.iter().map(|r| {
        let mut row = deploy_row_json(r);
        if r.kernel != "f32" {
            if let Some(f) = deploy
                .iter()
                .find(|o| o.model == r.model && o.kernel == "f32")
            {
                if let Json::Obj(m) = &mut row {
                    m.insert(
                        "speedup_vs_f32".to_string(),
                        Json::Num(f.compressed_ms / r.compressed_ms.max(1e-9)),
                    );
                }
            }
        }
        row
    }));
    rows.sort_by_key(|r| (r.str_or("model", ""), r.str_or("kernel", "")));
    let doc = Json::obj(vec![
        ("note", Json::str(BENCH_DEPLOY_NOTE)),
        ("deploy", Json::Arr(rows)),
    ]);
    std::fs::write(path, doc.to_string())?;
    Ok(())
}

/// One point of the `geta bench-serve` sweep: a served load run at a
/// fixed (workers, batch window, target RPS) with its measured latency
/// quantiles and throughput.
#[derive(Debug, Clone)]
pub struct ServeBench {
    pub model: String,
    /// Engine compute path behind the server (`"f32" | "int8"`).
    pub kernel: String,
    pub workers: usize,
    /// Coalescing latency budget; 0 = unbatched (`max_batch` 1).
    pub batch_window_us: u64,
    /// Most requests merged into one `infer_many` call.
    pub max_batch: usize,
    pub queue_depth: usize,
    /// Open-loop target submissions/s; 0 = saturation (pressure mode:
    /// clients retry shed requests until admitted).
    pub rps_target: f64,
    /// Requests the load generator attempted.
    pub requests: usize,
    /// Requests admitted to the queue.
    pub accepted: u64,
    /// Every client `submit` call, retries included — in saturation mode
    /// `attempts - requests` is pure retry traffic. Kept separate from
    /// `completed` so retried requests can never inflate throughput rows.
    pub attempts: usize,
    /// `QueueFull` rejections (open-loop: lost; saturation: retried).
    pub shed: u64,
    /// Requests answered with logits.
    pub completed: usize,
    /// `infer_many` calls the workers issued.
    pub batches: u64,
    /// Achieved requests per coalesced batch (`completed / batches`).
    pub avg_batch: f64,
    /// Completions per second of wall clock, client-side.
    pub achieved_rps: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    pub max_us: f64,
}

/// Train + export `model` once, then sweep the serving stack over
/// `workers × windows_us × rps` with single-sample requests drawn from
/// the eval set — one [`ServeBench`] row per grid point. A window of 0
/// serves unbatched (`max_batch` 1): the baseline the coalescer's rows
/// are compared against. `rps <= 0` grid points run the saturation probe
/// (pressure-mode clients), whose `achieved_rps` is the sustainable
/// throughput number.
///
/// The engine serves with `threads = 1`: the server parallelizes across
/// workers, and the smoke-job comparison ("batched ≥ unbatched at the
/// same worker count") needs both modes spending their threads the same
/// way.
pub fn bench_serve(
    art_dir: &std::path::Path,
    model: &str,
    steps_scale: f64,
    sparsity: f64,
    kernel: KernelKind,
    workers_sweep: &[usize],
    windows_us: &[u64],
    rps_sweep: &[f64],
    requests: usize,
    queue_depth: usize,
    max_batch: usize,
) -> Result<Vec<ServeBench>> {
    use crate::serve::{loadgen, ServeConfig, Server};
    let art = train_export(art_dir, model, steps_scale, sparsity, 8.0)?;
    let mut engine = GetaEngine::from_container_kernel(&art.container, kernel)?;
    engine.threads = 1;
    let engine = std::sync::Arc::new(engine);
    let inputs = loadgen::single_sample_inputs(&art.trainer.eval_data, 64);
    let mut rows = Vec::new();
    for &workers in workers_sweep {
        for &window_us in windows_us {
            for &rps in rps_sweep {
                let (window, batch) = if window_us == 0 {
                    (std::time::Duration::ZERO, 1)
                } else {
                    (std::time::Duration::from_micros(window_us), max_batch.max(2))
                };
                let server = Server::start(
                    engine.clone(),
                    ServeConfig {
                        workers,
                        queue_depth,
                        batch_window: window,
                        max_batch: batch,
                    },
                );
                let spec = loadgen::LoadSpec {
                    rps,
                    requests,
                    clients: if rps > 0.0 { 1 } else { 4 },
                    ..Default::default()
                };
                let load = loadgen::run(&server, &inputs, &spec);
                let report = server.shutdown();
                let h = &report.histogram;
                rows.push(ServeBench {
                    model: model.to_string(),
                    kernel: kernel.label().to_string(),
                    workers,
                    batch_window_us: window_us,
                    max_batch: batch,
                    queue_depth,
                    rps_target: rps.max(0.0),
                    requests,
                    accepted: report.stats.accepted,
                    attempts: load.attempts,
                    shed: report.stats.shed,
                    completed: load.completed,
                    batches: report.stats.batches,
                    avg_batch: load.completed as f64 / report.stats.batches.max(1) as f64,
                    achieved_rps: load.achieved_rps,
                    p50_us: h.p50_us(),
                    p95_us: h.p95_us(),
                    p99_us: h.p99_us(),
                    mean_us: h.mean_us(),
                    max_us: h.max_us(),
                });
            }
        }
    }
    Ok(rows)
}

/// One `serve` row as JSON (field names are the `BENCH_serve.json`
/// schema).
fn serve_row_json(r: &ServeBench) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("model", Json::str(&r.model)),
        ("kernel", Json::str(&r.kernel)),
        ("workers", Json::Num(r.workers as f64)),
        ("batch_window_us", Json::Num(r.batch_window_us as f64)),
        ("max_batch", Json::Num(r.max_batch as f64)),
        ("queue_depth", Json::Num(r.queue_depth as f64)),
        ("rps_target", Json::Num(r.rps_target)),
        ("requests", Json::Num(r.requests as f64)),
        ("accepted", Json::Num(r.accepted as f64)),
        ("attempts", Json::Num(r.attempts as f64)),
        ("shed", Json::Num(r.shed as f64)),
        ("completed", Json::Num(r.completed as f64)),
        ("batches", Json::Num(r.batches as f64)),
        ("avg_batch", Json::Num(r.avg_batch)),
        ("achieved_rps", Json::Num(r.achieved_rps)),
        ("p50_us", Json::Num(r.p50_us)),
        ("p95_us", Json::Num(r.p95_us)),
        ("p99_us", Json::Num(r.p99_us)),
        ("mean_us", Json::Num(r.mean_us)),
        ("max_us", Json::Num(r.max_us)),
    ])
}

/// Where the serving latency/throughput summary goes (see
/// [`repo_root_file`]). Checked in like `BENCH_deploy.json`, so the
/// serving trajectory is diffable across PRs.
pub fn bench_serve_json_path() -> std::path::PathBuf {
    repo_root_file("BENCH_serve.json")
}

/// The fixed `note` field of `BENCH_serve.json` — emitted verbatim on
/// every write so the checked-in copy regenerates byte-stable apart from
/// genuinely new measurements.
const BENCH_SERVE_NOTE: &str =
    "serving latency/throughput sweep; regenerate with `make bench-serve` or `geta bench-serve \
     --json` (latencies are machine-dependent). Rows carry model, kernel, workers, \
     batch_window_us (0 = unbatched, max_batch 1), max_batch, queue_depth, rps_target (0 = \
     saturation probe with backpressure-aware clients), requests, accepted, attempts (every \
     submit call, retries included — attempts > requests means the saturation probe retried shed \
     submissions; completed and achieved_rps count unique completions only, never retry \
     traffic), shed, completed, batches, avg_batch, achieved_rps, and latency quantiles \
     p50_us/p95_us/p99_us/mean_us/max_us from the server's log-bucketed histogram. Writers merge by model: a single-model run \
     updates only its own rows. CI regenerates the file on mlp_tiny every run, validates this \
     schema, and asserts saturation throughput with coalescing >= unbatched at the same worker \
     count.";

/// Write the checked-in serving summary (`BENCH_serve.json`).
/// **Merge-on-write** by model, like [`write_bench_deploy_json`]; rows
/// sort by (model, kernel, workers, batch_window_us, rps_target) so
/// regeneration diffs cleanly.
pub fn write_bench_serve_json(path: &std::path::Path, serve: &[ServeBench]) -> Result<()> {
    use crate::util::json::{self, Json};
    let fresh: std::collections::BTreeSet<&str> = serve.iter().map(|r| r.model.as_str()).collect();
    let mut rows: Vec<Json> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(doc) = json::parse(&text) {
            if let Some(arr) = doc.get("serve").and_then(|d| d.as_arr()) {
                for row in arr {
                    if !fresh.contains(row.str_or("model", "").as_str()) {
                        rows.push(row.clone());
                    }
                }
            }
        }
    }
    rows.extend(serve.iter().map(serve_row_json));
    rows.sort_by(|a, b| {
        let key = |r: &Json| {
            (
                r.str_or("model", ""),
                r.str_or("kernel", ""),
                r.get("workers").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
                r.get("batch_window_us").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
                r.get("rps_target").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            )
        };
        key(a).cmp(&key(b))
    });
    let doc = Json::obj(vec![
        ("note", Json::str(BENCH_SERVE_NOTE)),
        ("serve", Json::Arr(rows)),
    ]);
    std::fs::write(path, doc.to_string())?;
    Ok(())
}

/// The fixed `note` field of a chaos soak summary.
const CHAOS_NOTE: &str =
    "chaos soak summary from `geta bench-serve --faults <spec> --seed N`: requests driven \
     against a fault-armed server (injected worker panics, latency spikes, poisoned inputs, \
     transient model errors). Every field is a deterministic function of (model, seed, spec, \
     requests) — shed totals, batch shapes and raw restart counts depend on thread scheduling \
     and are deliberately excluded, so two same-seed runs serialize byte-identically (the CI \
     chaos-smoke contract). mismatched_logits and unresolved must be 0: faults may fail a \
     request typed, never corrupt a survivor or leak a ticket.";

/// One chaos soak summary as JSON (see [`CHAOS_NOTE`] for the
/// determinism contract CI byte-diffs against).
pub fn chaos_json(r: &crate::serve::ChaosReport) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("model", Json::str(&r.model)),
        ("seed", Json::Num(r.seed as f64)),
        ("spec", Json::str(&r.spec)),
        ("requests", Json::Num(r.requests as f64)),
        ("completed", Json::Num(r.completed as f64)),
        ("failed_worker_panic", Json::Num(r.failed_worker_panic as f64)),
        ("failed_model", Json::Num(r.failed_model as f64)),
        ("failed_deadline", Json::Num(r.failed_deadline as f64)),
        ("failed_other", Json::Num(r.failed_other as f64)),
        ("injected_panic", Json::Num(r.injected_panic as f64)),
        ("injected_slow", Json::Num(r.injected_slow as f64)),
        ("injected_poison", Json::Num(r.injected_poison as f64)),
        ("injected_transient", Json::Num(r.injected_transient as f64)),
        ("mismatched_logits", Json::Num(r.mismatched_logits as f64)),
        ("unresolved", Json::Num(r.unresolved as f64)),
        ("worker_restarts_positive", Json::Bool(r.worker_restarts_positive)),
        ("server_live_after", Json::Bool(r.server_live_after)),
    ])
}

/// Write one chaos soak summary to `path` (default `chaos_serve.json`,
/// gitignored — unlike the BENCH files this is a CI scratch artifact).
pub fn write_chaos_json(path: &std::path::Path, r: &crate::serve::ChaosReport) -> Result<()> {
    use crate::util::json::Json;
    let doc = Json::obj(vec![
        ("note", Json::str(CHAOS_NOTE)),
        ("chaos", chaos_json(r)),
    ]);
    std::fs::write(path, doc.to_string())?;
    Ok(())
}

/// One point of the `geta bench-train` sweep: a full GETA training run at
/// a fixed (mode, threads), measured over the training loop's own spans.
/// `mode` is `"dense"` (masked-dense loop, the baseline) or `"shrink"`
/// (`TrainOpts::replan`: the executor Plan is rebuilt on the sliced
/// subnet after every prune commit). The two modes train bitwise
/// identically — the comparison is pure wall-clock.
#[derive(Debug, Clone)]
pub struct TrainBench {
    pub model: String,
    /// `"dense" | "shrink"`.
    pub mode: String,
    pub threads: usize,
    /// Training steps the run executed.
    pub steps: usize,
    /// Plan rebuilds the run performed (0 in dense mode).
    pub replans: usize,
    /// First step the *shrink* run re-planned after — both modes report
    /// their tail throughput over the steps from here on, so the tail
    /// window compares sliced GEMMs against masked-dense GEMMs over the
    /// same schedule suffix.
    pub tail_from_step: usize,
    /// Whole-run training throughput (first to last train step).
    pub steps_per_s: f64,
    /// Throughput over the post-shrink tail window.
    pub tail_steps_per_s: f64,
    /// Mean forward+backward wall-clock per step.
    pub train_step_ms: f64,
    /// Mean optimizer (QASSO) wall-clock per step.
    pub optim_step_ms: f64,
    /// Total re-plan cost over the run (finalize + slice + rebuild spans).
    pub replan_ms: f64,
    pub group_sparsity: f64,
}

/// Timing pulled off one traced training run's spans.
struct TrainTiming {
    steps: usize,
    replans: usize,
    first_replan: usize,
    steps_per_s: f64,
    tail_steps_per_s: f64,
    train_step_ms: f64,
    optim_step_ms: f64,
    replan_ms: f64,
    group_sparsity: f64,
}

/// Run one GETA training pass (dense-masked or shrink-enabled) with the
/// span tracer on and distill its timing. `tail_from` fixes the tail
/// window start; pass `None` to start it at the run's own first re-plan.
fn timed_train_run(
    art_dir: &std::path::Path,
    model: &str,
    steps_scale: f64,
    sparsity: f64,
    replan: bool,
    tail_from: Option<usize>,
) -> Result<TrainTiming> {
    let mut exp = ExperimentConfig::defaults_for(model);
    exp.scale_steps(steps_scale);
    exp.n_train = exp.n_train.min(512);
    exp.n_eval = exp.n_eval.min(256);
    if sparsity > 0.0 {
        exp.qasso.target_group_sparsity = sparsity;
    }
    let t = Trainer::new(art_dir, exp)?;
    let mut geta = GetaCompressor::new(&*t.engine, &t.exp, StageMask::default())?;
    let opts = crate::coordinator::TrainOpts {
        replan,
        ..Default::default()
    };
    // trace the run: the loop's own train_step/optim_step/replan spans are
    // the measurement (span overhead is one Instant + push per phase per
    // step, identical in both modes). Drain first so stale spans from the
    // caller's session can't leak into this run's aggregate.
    let prev = crate::obs::set_enabled(true);
    crate::obs::trace::drain();
    let trained = t.run_trained_opts(&mut geta, &opts)?;
    let events = crate::obs::trace::drain();
    crate::obs::set_enabled(prev);
    let steps = trained.losses.len();
    let mut step_spans: Vec<&crate::obs::trace::SpanEvent> = events
        .iter()
        .filter(|e| e.cat == "train" && e.name == "train_step")
        .collect();
    step_spans.sort_by(|a, b| a.ts_us.partial_cmp(&b.ts_us).unwrap_or(std::cmp::Ordering::Equal));
    anyhow::ensure!(
        step_spans.len() == steps,
        "traced {} train_step spans over {} steps (tracer buffer overflow?)",
        step_spans.len(),
        steps
    );
    let window_s = |spans: &[&crate::obs::trace::SpanEvent]| -> f64 {
        match (spans.first(), spans.last()) {
            (Some(f), Some(l)) => ((l.ts_us + l.dur_us) - f.ts_us) / 1e6,
            _ => 0.0,
        }
    };
    let first_replan = trained.replans.first().copied().unwrap_or(steps);
    let tail_from = tail_from.unwrap_or(first_replan).min(steps);
    let tail = &step_spans[tail_from.min(steps.saturating_sub(1))..];
    let steps_per_s = steps as f64 / window_s(&step_spans).max(1e-9);
    let tail_steps_per_s = if tail.len() >= 2 {
        tail.len() as f64 / window_s(tail).max(1e-9)
    } else {
        steps_per_s
    };
    let phase_ms = |cat: &str, name: &str| -> f64 {
        let (calls, total_us) = events
            .iter()
            .filter(|e| e.cat == cat && e.name == name)
            .fold((0u64, 0.0f64), |(c, t), e| (c + 1, t + e.dur_us));
        total_us / 1e3 / calls.max(1) as f64
    };
    let replan_ms: f64 = events
        .iter()
        .filter(|e| e.cat == "replan")
        .map(|e| e.dur_us / 1e3)
        .sum();
    Ok(TrainTiming {
        steps,
        replans: trained.replans.len(),
        first_replan,
        steps_per_s,
        tail_steps_per_s,
        train_step_ms: phase_ms("train", "train_step"),
        optim_step_ms: phase_ms("train", "optim_step"),
        replan_ms,
        group_sparsity: trained.result.group_sparsity,
    })
}

/// Train `model` twice per thread count — once masked-dense, once with
/// shrink-as-you-train re-planning — and compare training throughput.
/// The shrink run goes first so its first re-plan step can anchor BOTH
/// modes' tail windows: `tail_steps_per_s` then measures sliced-subnet
/// GEMMs vs masked-dense GEMMs over the same schedule suffix, which is
/// the number the "pruning pays during training" claim is about.
pub fn bench_train(
    art_dir: &std::path::Path,
    model: &str,
    steps_scale: f64,
    sparsity: f64,
    threads_sweep: &[usize],
) -> Result<Vec<TrainBench>> {
    let prev_threads = crate::tensor::configured_threads();
    let mut rows = Vec::new();
    for &threads in threads_sweep {
        crate::tensor::set_threads(threads);
        let shrink = timed_train_run(art_dir, model, steps_scale, sparsity, true, None)?;
        let dense = timed_train_run(
            art_dir,
            model,
            steps_scale,
            sparsity,
            false,
            Some(shrink.first_replan),
        )?;
        for (mode, t) in [("dense", &dense), ("shrink", &shrink)] {
            rows.push(TrainBench {
                model: model.to_string(),
                mode: mode.to_string(),
                threads,
                steps: t.steps,
                replans: t.replans,
                tail_from_step: shrink.first_replan,
                steps_per_s: t.steps_per_s,
                tail_steps_per_s: t.tail_steps_per_s,
                train_step_ms: t.train_step_ms,
                optim_step_ms: t.optim_step_ms,
                replan_ms: t.replan_ms,
                group_sparsity: t.group_sparsity,
            });
        }
    }
    crate::tensor::set_threads(prev_threads);
    Ok(rows)
}

/// One `train` row as JSON (field names are the `BENCH_train.json`
/// schema).
fn train_row_json(r: &TrainBench) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("model", Json::str(&r.model)),
        ("mode", Json::str(&r.mode)),
        ("threads", Json::Num(r.threads as f64)),
        ("steps", Json::Num(r.steps as f64)),
        ("replans", Json::Num(r.replans as f64)),
        ("tail_from_step", Json::Num(r.tail_from_step as f64)),
        ("steps_per_s", Json::Num(r.steps_per_s)),
        ("tail_steps_per_s", Json::Num(r.tail_steps_per_s)),
        ("train_step_ms", Json::Num(r.train_step_ms)),
        ("optim_step_ms", Json::Num(r.optim_step_ms)),
        ("replan_ms", Json::Num(r.replan_ms)),
        ("group_sparsity", Json::Num(r.group_sparsity)),
    ])
}

/// Where the training-throughput summary goes (see [`repo_root_file`]).
/// Checked in like `BENCH_serve.json`, so the shrink-vs-dense training
/// speed trajectory is diffable across PRs.
pub fn bench_train_json_path() -> std::path::PathBuf {
    repo_root_file("BENCH_train.json")
}

/// The fixed `note` field of `BENCH_train.json` — emitted verbatim on
/// every write so the checked-in copy regenerates byte-stable apart from
/// genuinely new measurements.
const BENCH_TRAIN_NOTE: &str =
    "training throughput, masked-dense vs shrink-as-you-train; regenerate with `make bench-train` \
     or `geta bench-train --json` (wall-clocks are machine-dependent). Rows carry model, mode \
     (dense = masked-dense loop, shrink = executor Plan rebuilt on the sliced subnet after every \
     prune commit; both train bitwise identically), threads, steps, replans, tail_from_step (the \
     shrink run's first re-plan step — both modes report tail_steps_per_s over the steps from \
     there on), steps_per_s, tail_steps_per_s, mean train_step_ms / optim_step_ms per step, total \
     replan_ms, and group_sparsity. Writers merge by model: a single-model run updates only its \
     own rows. CI regenerates the file on a high-sparsity run every push, validates this schema, \
     and asserts shrink tail_steps_per_s >= dense at the same thread count.";

/// Write the checked-in training-throughput summary (`BENCH_train.json`).
/// **Merge-on-write** by model, like [`write_bench_serve_json`]; rows
/// sort by (model, threads, mode) so regeneration diffs cleanly.
pub fn write_bench_train_json(path: &std::path::Path, train: &[TrainBench]) -> Result<()> {
    use crate::util::json::{self, Json};
    let fresh: std::collections::BTreeSet<&str> = train.iter().map(|r| r.model.as_str()).collect();
    let mut rows: Vec<Json> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(doc) = json::parse(&text) {
            if let Some(arr) = doc.get("train").and_then(|d| d.as_arr()) {
                for row in arr {
                    if !fresh.contains(row.str_or("model", "").as_str()) {
                        rows.push(row.clone());
                    }
                }
            }
        }
    }
    rows.extend(train.iter().map(train_row_json));
    rows.sort_by(|a, b| {
        let key = |r: &Json| {
            (
                r.str_or("model", ""),
                r.get("threads").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
                r.str_or("mode", ""),
            )
        };
        key(a).cmp(&key(b))
    });
    let doc = Json::obj(vec![
        ("note", Json::str(BENCH_TRAIN_NOTE)),
        ("train", Json::Arr(rows)),
    ]);
    std::fs::write(path, doc.to_string())?;
    Ok(())
}
