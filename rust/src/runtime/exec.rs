//! Planned executor: the single forward core shared by training and
//! deployment.
//!
//! Historically the training interpreter (`runtime/interp.rs`) and the
//! `.geta` inference engine (`deploy/engine.rs`) each carried their own
//! copy of every op's forward kernel, re-walking shapes and re-allocating
//! buffers on every call. This module folds both onto one path:
//!
//! * [`Plan`] — shape resolution done **once** per (program, batch size):
//!   per-node output shapes with the runtime batch substituted, element
//!   counts, and conv scratch sizes. Built once per model and reused for
//!   every step / micro-batch.
//! * [`Arena`] — a free-list of f32 buffers. Node outputs, conv scratch
//!   and backward GEMM buffers come out of it and are reclaimed after
//!   each pass, so the dominant allocations of steady-state training
//!   steps and inference micro-batches disappear (norm internals and the
//!   gradient store still allocate per step).
//! * [`ParamSource`] — where tensors come from. [`TrainParams`] serves
//!   dense f32 parameters and fake-quantizes weights at their sites on
//!   the fly; [`DeployParams`] serves the already-dequantized packed
//!   weights of a `.geta` container and its learned activation-site
//!   quantizers. The forward core cannot tell the two apart.
//! * [`forward`] — the op-by-op forward pass over the lowered program,
//!   optionally retaining the per-node [`Aux`] state the training
//!   backward pass consumes.
//!
//! Numeric conventions are unchanged from the split implementations: f32
//! storage, f64 accumulation in every contraction (`tensor/ops.rs` —
//! tiled, multi-threaded, bitwise thread-count-invariant), per-micro-batch
//! batch-statistics normalization.
//!
//! # The integer compute path
//!
//! A third source, [`QuantizedParams`], keeps eligible weight sites
//! resident as **i8 level tensors** ([`tensor::IntWeight`]) instead of
//! dequantized f32 and serves them through [`ParamSource::weight_i8`].
//! When a Linear/Conv node has such a weight, [`forward`] selects an
//! integer kernel (`tensor/iops.rs`) instead of the f32 GEMM:
//!
//! * **i8 × i8, i32-accumulated** when the node's input provably carries
//!   exact quantization levels — it is (transitively through the
//!   grid-preserving `Reshape` and `MaxPool2` ops) the output of an
//!   `ActQuant` site whose levels fit i8 and whose contraction cannot
//!   overflow i32. The input activations are re-quantized to their integer
//!   levels at run time (`tensor::levels_from_grid` — exact, because
//!   `fake_quant` already put them on the `d_a` grid) and the epilogue
//!   folds `d_w · d_a` plus the bias in f64. Since levels are exact
//!   integers by construction (`quant::quantize_level`), the i32
//!   accumulation is **exact** and the epilogue holds the only rounding of
//!   the path.
//! * **f32 × i8 (mixed)** otherwise — weight-only quantization (resnet,
//!   the transformers' projection/MLP weights) or an activation site
//!   beyond 8 bits: f32 activations against the resident i8 levels, f64
//!   accumulation in the f32 kernels' exact per-row order, `d_w` folded
//!   into the epilogue.
//!
//! Norms, softmax, losses, and weight sites beyond i8 stay on the f32
//! path unchanged. Training ([`TrainParams`]) and the f32 deploy engine
//! ([`DeployParams`]) never return an `IntWeight`, so their numerics are
//! byte-for-byte untouched by the selection logic.

use std::borrow::Cow;
use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::lowering::{Node, OpKind, Program};
use crate::quant::{self, QParams};
use crate::tensor::{
    self, batchnorm_rows, gelu, layernorm_rows, softmax_rows, IntWeight, NormAux, ParamStore,
    U4Weight,
};

pub const NORM_EPS: f32 = 1e-5;

/// Borrowed micro-batch input (pixels or token ids).
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// Where the executor's tensors come from — the seam between training
/// (dense fake-quant parameters) and deployment (dequantized packed
/// weights from a `.geta` container).
pub trait ParamSource {
    /// Raw named tensor (biases, norm params, embedding tables, ...).
    fn tensor(&self, name: &str) -> Result<&[f32]>;

    /// Effective multiply weight for a weight-carrying node. The training
    /// source fake-quantizes at `site` (returning an owned copy); the
    /// deployment source hands back the already-dequantized weight.
    fn weight(&self, name: &str, site: Option<usize>) -> Result<Cow<'_, [f32]>>;

    /// Activation-site quantizer; `None` = pass activations through
    /// unquantized (the dense-f32 baseline engine). `node` names the op
    /// for error messages.
    fn act_q(&self, site: usize, node: &str) -> Result<Option<QParams>>;

    /// Resident integer-domain weight for a weight-carrying node, when the
    /// source keeps one (the deployment int8 engine). `None` — the default
    /// for every training/f32 source — routes the node to the f32 `weight`
    /// path. `site` is validated like [`weight`](Self::weight).
    fn weight_i8(&self, _name: &str, _site: Option<usize>) -> Result<Option<&IntWeight>> {
        Ok(None)
    }

    /// Resident nibble-packed 4-bit weight for a weight-carrying node,
    /// when the source keeps one (the deployment int4 engine). Checked
    /// *before* [`weight_i8`](Self::weight_i8): a site resident in both
    /// forms takes the packed path. `None` — the default — falls through
    /// to `weight_i8`, then to the f32 `weight` path.
    fn weight_u4(&self, _name: &str, _site: Option<usize>) -> Result<Option<&U4Weight>> {
        Ok(None)
    }
}

/// Strict-reader discipline extended to the executor seam: when the
/// program consumes a weight at quant site `site`, the container's
/// recorded site for that tensor (if any) must agree — a silent mismatch
/// would dequantize with the wrong step `d` and produce wrong outputs with
/// no error anywhere.
fn check_weight_site(
    recorded: &BTreeMap<String, usize>,
    name: &str,
    site: Option<usize>,
) -> Result<()> {
    match (site, recorded.get(name)) {
        (Some(s), Some(&rec)) if rec != s => anyhow::bail!(
            "weight `{name}`: program requests quant site {s} but the container recorded site {rec}"
        ),
        (None, Some(&rec)) => anyhow::bail!(
            "weight `{name}`: program treats it as unquantized but the container packed it at site {rec}"
        ),
        _ => Ok(()),
    }
}

/// Training-time source: dense f32 parameters, per-site fake quantization
/// with the current learned (d, t, q_m) rows.
pub struct TrainParams<'a> {
    pub params: &'a ParamStore,
    pub q: &'a [QParams],
}

impl ParamSource for TrainParams<'_> {
    fn tensor(&self, name: &str) -> Result<&[f32]> {
        self.params
            .get(name)
            .map(|t| t.data.as_slice())
            .with_context(|| format!("missing parameter `{name}`"))
    }

    fn weight(&self, name: &str, site: Option<usize>) -> Result<Cow<'_, [f32]>> {
        let raw = self.tensor(name)?;
        Ok(match site {
            Some(s) => {
                Cow::Owned(raw.iter().map(|&v| quant::fake_quant(v, &self.q[s])).collect())
            }
            None => Cow::Borrowed(raw),
        })
    }

    fn act_q(&self, site: usize, _node: &str) -> Result<Option<QParams>> {
        Ok(Some(self.q[site]))
    }
}

/// Deployment source: weights were dequantized once at load
/// (`level * d`), activation sites carry the container's learned rows
/// (`None` rows = quantization disabled, as in the dense-f32 baseline).
pub struct DeployParams<'a> {
    pub weights: &'a ParamStore,
    pub act_q: &'a [Option<QParams>],
    pub apply_act_quant: bool,
    /// Quant site recorded per packed tensor by the container (empty for
    /// the dense baseline) — requests are validated against it.
    pub weight_sites: &'a BTreeMap<String, usize>,
}

impl ParamSource for DeployParams<'_> {
    fn tensor(&self, name: &str) -> Result<&[f32]> {
        self.weights
            .get(name)
            .map(|t| t.data.as_slice())
            .with_context(|| format!("engine missing tensor `{name}`"))
    }

    fn weight(&self, name: &str, site: Option<usize>) -> Result<Cow<'_, [f32]>> {
        check_weight_site(self.weight_sites, name, site)?;
        Ok(Cow::Borrowed(self.tensor(name)?))
    }

    fn act_q(&self, site: usize, node: &str) -> Result<Option<QParams>> {
        if !self.apply_act_quant {
            return Ok(None);
        }
        match self.act_q.get(site).copied().flatten() {
            Some(qp) => Ok(Some(qp)),
            None => anyhow::bail!("{node}: activation site {site} missing from container"),
        }
    }
}

/// Deployment source for the **integer compute path**: eligible weight
/// sites stay resident as i8 level tensors and reach the integer kernels
/// through [`ParamSource::weight_i8`]; everything else (biases, norms,
/// embeddings, weight sites beyond 8 bits) is served as f32 exactly like
/// [`DeployParams`]. Activation sites always apply their container rows —
/// the integer engine has no dense-baseline mode.
pub struct QuantizedParams<'a> {
    pub weights: &'a ParamStore,
    /// i8-resident weights by tensor name (`tensor/iops.rs` layout).
    pub iweights: &'a BTreeMap<String, IntWeight>,
    /// Nibble-packed 4-bit resident weights by tensor name
    /// (`tensor/u4.rs` layout). Disjoint from `iweights` by construction
    /// (the engine packs each site in exactly one form); empty for the
    /// int8 kernel.
    pub uweights: &'a BTreeMap<String, U4Weight>,
    /// Quant site recorded per packed tensor by the container.
    pub weight_sites: &'a BTreeMap<String, usize>,
    pub act_q: &'a [Option<QParams>],
}

impl ParamSource for QuantizedParams<'_> {
    fn tensor(&self, name: &str) -> Result<&[f32]> {
        self.weights
            .get(name)
            .map(|t| t.data.as_slice())
            .with_context(|| format!("engine missing tensor `{name}`"))
    }

    fn weight(&self, name: &str, site: Option<usize>) -> Result<Cow<'_, [f32]>> {
        check_weight_site(self.weight_sites, name, site)?;
        // Defensive dequantize-on-demand for an i8-resident weight. The
        // current `forward` never reaches this: it calls `weight` only
        // when `weight_i8` returned None (name absent from `iweights`),
        // and the engine never runs this source with `with_aux`. It keeps
        // any future caller that *does* want the f32 view of an
        // i8-resident weight correct instead of erroring on the
        // shape-only store placeholder.
        if let Some(iw) = self.iweights.get(name) {
            let mut v = Vec::with_capacity(iw.levels.len());
            for row in iw.levels.chunks_exact(iw.n) {
                for (j, &l) in row.iter().enumerate() {
                    v.push(l as f32 * iw.scale[j]);
                }
            }
            return Ok(Cow::Owned(v));
        }
        if let Some(uw) = self.uweights.get(name) {
            let levels = uw.unpack_levels();
            let mut v = Vec::with_capacity(levels.len());
            for row in levels.chunks_exact(uw.n) {
                for (j, &l) in row.iter().enumerate() {
                    v.push(l as f32 * uw.scale[j]);
                }
            }
            return Ok(Cow::Owned(v));
        }
        Ok(Cow::Borrowed(self.tensor(name)?))
    }

    fn act_q(&self, site: usize, node: &str) -> Result<Option<QParams>> {
        match self.act_q.get(site).copied().flatten() {
            Some(qp) => Ok(Some(qp)),
            None => anyhow::bail!("{node}: activation site {site} missing from container"),
        }
    }

    fn weight_i8(&self, name: &str, site: Option<usize>) -> Result<Option<&IntWeight>> {
        check_weight_site(self.weight_sites, name, site)?;
        Ok(self.iweights.get(name))
    }

    fn weight_u4(&self, name: &str, site: Option<usize>) -> Result<Option<&U4Weight>> {
        check_weight_site(self.weight_sites, name, site)?;
        Ok(self.uweights.get(name))
    }
}

/// Shape-resolved execution plan, built once per (program, batch size):
/// every per-op shape computation the old forward passes redid on each
/// call lives here instead.
#[derive(Debug, Clone)]
pub struct Plan {
    /// The batch size substituted into every node's leading dim.
    pub bsz: usize,
    /// Per-node output shape with the batch dim resolved.
    pub shapes: Vec<Vec<usize>>,
    /// Per-node output element count.
    pub numels: Vec<usize>,
    /// Per-node conv scratch size (column-matrix elements; 0 for
    /// non-conv ops) — sized here so the arena can serve it directly.
    pub col_sizes: Vec<usize>,
}

impl Plan {
    pub fn new(prog: &Program, bsz: usize) -> Plan {
        let n = prog.nodes.len();
        let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(n);
        let mut numels = Vec::with_capacity(n);
        let mut col_sizes = Vec::with_capacity(n);
        for node in &prog.nodes {
            let mut shape = node.shape.clone();
            if !shape.is_empty() {
                shape[0] = bsz;
            }
            let numel: usize = shape.iter().product();
            let cols = match &node.op {
                OpKind::Conv2d { k, .. } => {
                    let cin = *shapes[node.inputs[0]].last().unwrap_or(&0);
                    bsz * shape[1] * shape[2] * k * k * cin
                }
                _ => 0,
            };
            shapes.push(shape);
            numels.push(numel);
            col_sizes.push(cols);
        }
        Plan { bsz, shapes, numels, col_sizes }
    }
}

/// Free-list of f32 buffers reused across steps / micro-batches.
/// Capacities converge to the pass's peak sizes after the first few uses,
/// after which the hot loop stops allocating.
///
/// The pool is **capped** at [`Arena::MAX_FREE`] buffers: consumers also
/// reclaim buffers that were produced *outside* the arena (kernel return
/// values, norm aux, fake-quant weight copies, cotangents), so an
/// unbounded pool would grow by dozens of buffers every training step.
/// The cap is sized to roughly one full pass's working set of the largest
/// programs; overflow buffers are simply dropped.
#[derive(Debug, Default)]
pub struct Arena {
    free: Vec<Vec<f32>>,
    /// Level-tensor pool for the integer path (activation levels, i8
    /// im2col scratch) — much smaller buffers, same recycling discipline.
    free_i8: Vec<Vec<i8>>,
}

impl Arena {
    /// Pool-size cap (see type docs): beyond this, reclaimed buffers are
    /// dropped instead of pooled.
    pub const MAX_FREE: usize = 512;

    pub fn new() -> Arena {
        Default::default()
    }

    /// A zeroed buffer of `n` elements, recycling capacity when available.
    pub fn alloc(&mut self, n: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.resize(n, 0.0);
        v
    }

    /// A buffer of `n` elements with **unspecified contents** — for
    /// consumers that overwrite or re-zero every element themselves (the
    /// conv column scratch: `im2col_into` zeroes its target). Recycled
    /// buffers keep their stale values, so the steady-state path skips the
    /// memset [`alloc`](Self::alloc) pays; only a too-short buffer is
    /// zero-extended.
    pub fn alloc_uninit(&mut self, n: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        if v.len() < n {
            v.resize(n, 0.0);
        } else {
            v.truncate(n);
        }
        v
    }

    /// An i8 buffer of `n` elements with **unspecified contents** — the
    /// integer path's consumers overwrite every element
    /// (`levels_from_grid`) or re-zero it themselves (`im2col_i8_into`).
    pub fn alloc_i8(&mut self, n: usize) -> Vec<i8> {
        let mut v = self.free_i8.pop().unwrap_or_default();
        if v.len() < n {
            v.resize(n, 0);
        } else {
            v.truncate(n);
        }
        v
    }

    /// Return an i8 buffer to the pool (dropped once the pool is full).
    pub fn reclaim_i8(&mut self, v: Vec<i8>) {
        if v.capacity() > 0 && self.free_i8.len() < Self::MAX_FREE {
            self.free_i8.push(v);
        }
    }

    /// Return a buffer to the pool (dropped once the pool is full).
    pub fn reclaim(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 && self.free.len() < Self::MAX_FREE {
            self.free.push(v);
        }
    }

    pub fn reclaim_all(&mut self, vs: impl IntoIterator<Item = Vec<f32>>) {
        for v in vs {
            self.reclaim(v);
        }
    }
}

/// Shared pool of [`Arena`]s for callers whose forward passes may run from
/// many threads at once (a serving engine). The pool's lock is held only to
/// pop or push an arena — **never across a forward pass** — so concurrent
/// callers contend for nanoseconds, not for each other's compute, while
/// steady-state buffer reuse still converges exactly like a single owned
/// arena: each caller warms whichever arena it drew, and after a few calls
/// every pooled arena carries the pass's peak working set.
#[derive(Debug, Default)]
pub struct ArenaPool {
    free: std::sync::Mutex<Vec<Arena>>,
}

impl ArenaPool {
    /// Arenas kept across calls; beyond this, returned arenas are dropped.
    /// Sized for "many workers", not "one per request": a serving engine
    /// needs at most one arena per physically concurrent caller.
    pub const MAX_POOLED: usize = 64;

    pub fn new() -> ArenaPool {
        Default::default()
    }

    /// Take an arena — a warmed pooled one when available, else fresh.
    pub fn take(&self) -> Arena {
        self.free
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_default()
    }

    /// Return an arena for later calls to reuse (dropped once the pool
    /// holds [`MAX_POOLED`](Self::MAX_POOLED)).
    pub fn give(&self, arena: Arena) {
        let mut free = self.free.lock().unwrap_or_else(|e| e.into_inner());
        if free.len() < Self::MAX_POOLED {
            free.push(arena);
        }
    }

    /// Arenas currently pooled (test/diagnostic visibility).
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

/// Per-node saved forward state the training backward pass consumes.
pub enum Aux {
    None,
    /// The fake-quantized weight that was multiplied (`None` when the
    /// weight has no quant site — backward then reads the raw parameter).
    W(Option<Vec<f32>>),
    Norm(NormAux),
    /// Attention probabilities `[B * heads * S * S]`.
    Att(Vec<f32>),
    /// Max-pool argmax: flat input index per output element.
    Pool(Vec<usize>),
}

/// Return an [`Aux`]'s buffers to the arena (shared with `interp::run`,
/// which reclaims retained aux after the backward pass).
pub(crate) fn reclaim_aux(arena: &mut Arena, ax: Aux) {
    match ax {
        Aux::None | Aux::Pool(_) => {}
        Aux::W(w) => {
            if let Some(w) = w {
                arena.reclaim(w);
            }
        }
        Aux::Norm(na) => {
            arena.reclaim(na.xhat);
            arena.reclaim(na.inv);
        }
        Aux::Att(p) => arena.reclaim(p),
    }
}

fn site_copy(w: Cow<'_, [f32]>) -> Option<Vec<f32>> {
    match w {
        Cow::Owned(v) => Some(v),
        Cow::Borrowed(_) => None,
    }
}

/// Walk back from node `id` through **grid-preserving** ops to the
/// ActQuant site whose exact quantization levels the buffer still carries:
/// `Reshape` copies values and `MaxPool2` selects one of them, so both
/// leave every element on the quantizer's `d·ℤ` grid. (`GlobalAvgPool`
/// averages and is deliberately excluded — its outputs leave the grid.)
fn grid_site(prog: &Program, mut id: usize) -> Option<usize> {
    loop {
        match &prog.nodes[id].op {
            OpKind::Reshape | OpKind::MaxPool2 => id = prog.nodes[id].inputs[0],
            OpKind::ActQuant { site } => return Some(*site),
            _ => return None,
        }
    }
}

/// Decide whether a weight-carrying node with an integer-resident weight
/// (largest |level| = `max_w`, i8 or nibble-packed u4) can take the exact
/// integer path: its input must carry the levels of an ActQuant site (see
/// [`grid_site`]), those levels must fit i8, and the `k_dim`-long
/// contraction must be guaranteed not to overflow the i32 accumulator.
/// Returns the activation quantizer to recover levels with, or `None` for
/// the mixed f32×int path.
fn int_act_quant(
    prog: &Program,
    src: &dyn ParamSource,
    node: &Node,
    k_dim: usize,
    max_w: i32,
) -> Result<Option<QParams>> {
    let Some(site) = grid_site(prog, node.inputs[0]) else {
        return Ok(None);
    };
    let Some(qp) = src.act_q(site, &node.name)? else {
        return Ok(None);
    };
    // the largest level the site can emit: round(clip_max / d) with
    // clip_max = qm^t (see quant::clip_pow / eq. (3))
    let max_a = (qp.qm.max(1e-12).powf(qp.t) / qp.d).round();
    let ok = max_a.is_finite()
        && max_a >= 0.0
        && max_a <= i8::MAX as f32
        && tensor::i8_gemm_fits_i32(k_dim, max_a as i32, max_w);
    Ok(if ok { Some(qp) } else { None })
}

/// Execute the program's forward pass over `plan`-resolved shapes. Returns
/// the per-node output buffers and, when `with_aux`, the saved state the
/// backward pass needs (otherwise every entry is [`Aux::None`] and the
/// would-be aux buffers go straight back to the arena).
pub fn forward(
    prog: &Program,
    plan: &Plan,
    src: &dyn ParamSource,
    x: &Input<'_>,
    with_aux: bool,
    arena: &mut Arena,
) -> Result<(Vec<Vec<f32>>, Vec<Aux>)> {
    let nodes = &prog.nodes;
    anyhow::ensure!(
        plan.shapes.len() == nodes.len(),
        "plan was built for a different program ({} vs {} nodes)",
        plan.shapes.len(),
        nodes.len()
    );
    let bsz = plan.bsz;
    let mut vals: Vec<Vec<f32>> = Vec::with_capacity(nodes.len());
    let mut aux: Vec<Aux> = Vec::with_capacity(nodes.len());

    // Per-node span tracing: one relaxed load when off; when on, the
    // clock is read outside the kernel bodies and nothing about
    // allocation or arithmetic order changes, so logits stay bitwise
    // identical traced vs untraced.
    let trace_on = crate::obs::enabled();
    let simd_tag = if trace_on && tensor::simd_active() { "+simd" } else { "" };

    for (id, node) in nodes.iter().enumerate() {
        let dims = &plan.shapes[id];
        let numel = plan.numels[id];
        let t0 = if trace_on { Some(std::time::Instant::now()) } else { None };
        let mut kern: &'static str = "";
        let (out, ax): (Vec<f32>, Aux) = match &node.op {
            OpKind::Input => {
                let Input::F32(xv) = x else {
                    anyhow::bail!("image task expects f32 inputs")
                };
                anyhow::ensure!(xv.len() == numel, "input batch size mismatch");
                let mut out = arena.alloc_uninit(numel);
                out.copy_from_slice(xv);
                (out, Aux::None)
            }
            OpKind::Embed { tok, pos } => {
                let Input::I32(toks) = x else {
                    anyhow::bail!("token task expects i32 inputs")
                };
                let (seq, dim) = (dims[1], dims[2]);
                anyhow::ensure!(toks.len() == bsz * seq, "token batch size mismatch");
                let tokw = src.tensor(tok)?;
                let posw = src.tensor(pos)?;
                let vocab = tokw.len() / dim;
                let mut out = arena.alloc_uninit(numel);
                for (r, &tid) in toks.iter().enumerate() {
                    anyhow::ensure!(
                        (0..vocab as i32).contains(&tid),
                        "token id {tid} outside vocab {vocab}"
                    );
                    let dst = &mut out[r * dim..(r + 1) * dim];
                    dst.copy_from_slice(&tokw[tid as usize * dim..(tid as usize + 1) * dim]);
                    tensor::axpy(1.0, &posw[(r % seq) * dim..(r % seq + 1) * dim], dst);
                }
                (out, Aux::None)
            }
            OpKind::Linear { w, site } => {
                let wname = format!("{w}.weight");
                let bias = src.tensor(&format!("{w}.bias"))?;
                let din = *plan.shapes[node.inputs[0]].last().unwrap();
                let dout = *dims.last().unwrap();
                let rows = numel / dout;
                // the integer paths serve forward-only consumers; training
                // (with_aux) always multiplies the fake-quantized f32 copy
                let uw = if with_aux { None } else { src.weight_u4(&wname, *site)? };
                let iw =
                    if with_aux || uw.is_some() { None } else { src.weight_i8(&wname, *site)? };
                if let Some(uw) = uw {
                    kern = "int4";
                    anyhow::ensure!(
                        uw.k == din && uw.n == dout,
                        "{}: u4 weight is {}x{}, program expects {din}x{dout}",
                        node.name,
                        uw.k,
                        uw.n
                    );
                    let xin = &vals[node.inputs[0]];
                    let mut out = arena.alloc_uninit(numel);
                    match int_act_quant(prog, src, node, din, uw.max_abs)? {
                        Some(qa) => {
                            let mut la = arena.alloc_i8(rows * din);
                            tensor::levels_from_grid(xin, qa.d, &mut la);
                            tensor::matmul_i8u4_scaled_into(
                                &mut out, &la, uw, rows, qa.d, Some(bias),
                            );
                            arena.reclaim_i8(la);
                        }
                        None => {
                            tensor::matmul_f32u4_scaled_into(&mut out, xin, uw, rows, Some(bias))
                        }
                    }
                    (out, Aux::None)
                } else if let Some(iw) = iw {
                    kern = "int8";
                    anyhow::ensure!(
                        iw.k == din && iw.n == dout,
                        "{}: int weight is {}x{}, program expects {din}x{dout}",
                        node.name,
                        iw.k,
                        iw.n
                    );
                    let xin = &vals[node.inputs[0]];
                    let mut out = arena.alloc_uninit(numel);
                    match int_act_quant(prog, src, node, din, iw.max_abs)? {
                        Some(qa) => {
                            let mut la = arena.alloc_i8(rows * din);
                            tensor::levels_from_grid(xin, qa.d, &mut la);
                            tensor::matmul_i8_scaled_into(
                                &mut out, &la, &iw.levels, rows, din, dout, &iw.scale, qa.d,
                                Some(bias),
                            );
                            arena.reclaim_i8(la);
                        }
                        None => tensor::matmul_f32i8_scaled_into(
                            &mut out, xin, &iw.levels, rows, din, dout, &iw.scale, Some(bias),
                        ),
                    }
                    (out, Aux::None)
                } else {
                    kern = "f32";
                    let wq = src.weight(&wname, *site)?;
                    let mut out = arena.alloc_uninit(numel);
                    tensor::matmul_into(&mut out, &vals[node.inputs[0]], &wq, rows, din, dout);
                    for r in 0..rows {
                        tensor::axpy(1.0, bias, &mut out[r * dout..(r + 1) * dout]);
                    }
                    (out, Aux::W(site_copy(wq)))
                }
            }
            OpKind::Conv2d { w, site, k, stride, pad } => {
                let wname = format!("{w}.weight");
                let bias = src.tensor(&format!("{w}.bias"))?;
                let is = &plan.shapes[node.inputs[0]];
                let (h, wd, cin) = (is[1], is[2], is[3]);
                let (ho, wo, cout) = (dims[1], dims[2], dims[3]);
                let rows = bsz * ho * wo;
                let kdim = k * k * cin;
                let uw = if with_aux { None } else { src.weight_u4(&wname, *site)? };
                let iw =
                    if with_aux || uw.is_some() { None } else { src.weight_i8(&wname, *site)? };
                if let Some(uw) = uw {
                    kern = "int4";
                    anyhow::ensure!(
                        uw.k == kdim && uw.n == cout,
                        "{}: u4 weight is {}x{}, program expects {kdim}x{cout}",
                        node.name,
                        uw.k,
                        uw.n
                    );
                    let xin = &vals[node.inputs[0]];
                    let mut out = arena.alloc_uninit(numel);
                    match int_act_quant(prog, src, node, kdim, uw.max_abs)? {
                        Some(qa) => {
                            // exact path: image → levels → i8 im2col → u4 GEMM
                            let mut lx = arena.alloc_i8(xin.len());
                            tensor::levels_from_grid(xin, qa.d, &mut lx);
                            let mut cols = arena.alloc_i8(plan.col_sizes[id]);
                            tensor::im2col_i8_into(
                                &mut cols, &lx, bsz, h, wd, cin, *k, *stride, *pad, ho, wo,
                            );
                            arena.reclaim_i8(lx);
                            tensor::matmul_i8u4_scaled_into(
                                &mut out, &cols, uw, rows, qa.d, Some(bias),
                            );
                            arena.reclaim_i8(cols);
                        }
                        None => {
                            // mixed path: f32 im2col against resident u4 panels
                            let mut cols = arena.alloc_uninit(plan.col_sizes[id]);
                            tensor::im2col_into(
                                &mut cols, xin, bsz, h, wd, cin, *k, *stride, *pad, ho, wo,
                            );
                            tensor::matmul_f32u4_scaled_into(&mut out, &cols, uw, rows, Some(bias));
                            arena.reclaim(cols);
                        }
                    }
                    (out, Aux::None)
                } else if let Some(iw) = iw {
                    kern = "int8";
                    anyhow::ensure!(
                        iw.k == kdim && iw.n == cout,
                        "{}: int weight is {}x{}, program expects {kdim}x{cout}",
                        node.name,
                        iw.k,
                        iw.n
                    );
                    let xin = &vals[node.inputs[0]];
                    let mut out = arena.alloc_uninit(numel);
                    match int_act_quant(prog, src, node, kdim, iw.max_abs)? {
                        Some(qa) => {
                            // exact path: image → levels → i8 im2col → i8 GEMM
                            let mut lx = arena.alloc_i8(xin.len());
                            tensor::levels_from_grid(xin, qa.d, &mut lx);
                            let mut cols = arena.alloc_i8(plan.col_sizes[id]);
                            tensor::im2col_i8_into(
                                &mut cols, &lx, bsz, h, wd, cin, *k, *stride, *pad, ho, wo,
                            );
                            arena.reclaim_i8(lx);
                            tensor::matmul_i8_scaled_into(
                                &mut out, &cols, &iw.levels, rows, kdim, cout, &iw.scale, qa.d,
                                Some(bias),
                            );
                            arena.reclaim_i8(cols);
                        }
                        None => {
                            // mixed path: f32 im2col against resident i8 levels
                            let mut cols = arena.alloc_uninit(plan.col_sizes[id]);
                            tensor::im2col_into(
                                &mut cols, xin, bsz, h, wd, cin, *k, *stride, *pad, ho, wo,
                            );
                            tensor::matmul_f32i8_scaled_into(
                                &mut out, &cols, &iw.levels, rows, kdim, cout, &iw.scale,
                                Some(bias),
                            );
                            arena.reclaim(cols);
                        }
                    }
                    (out, Aux::None)
                } else {
                    kern = "f32";
                    let wq = src.weight(&wname, *site)?;
                    let mut cols = arena.alloc_uninit(plan.col_sizes[id]);
                    tensor::im2col_into(
                        &mut cols,
                        &vals[node.inputs[0]],
                        bsz,
                        h,
                        wd,
                        cin,
                        *k,
                        *stride,
                        *pad,
                        ho,
                        wo,
                    );
                    let mut out = arena.alloc_uninit(numel);
                    tensor::matmul_into(&mut out, &cols, &wq, rows, kdim, cout);
                    arena.reclaim(cols);
                    for r in 0..rows {
                        tensor::axpy(1.0, bias, &mut out[r * cout..(r + 1) * cout]);
                    }
                    (out, Aux::W(site_copy(wq)))
                }
            }
            OpKind::BatchNorm { p } | OpKind::LayerNorm { p } => {
                let gamma = src.tensor(&format!("{p}.gamma"))?;
                let beta = src.tensor(&format!("{p}.beta"))?;
                let c = *dims.last().unwrap();
                let rows = numel / c;
                let (out, na) = if matches!(node.op, OpKind::BatchNorm { .. }) {
                    batchnorm_rows(&vals[node.inputs[0]], gamma, beta, rows, c, NORM_EPS)
                } else {
                    layernorm_rows(&vals[node.inputs[0]], gamma, beta, rows, c, NORM_EPS)
                };
                (out, Aux::Norm(na))
            }
            OpKind::Relu => {
                let mut out = arena.alloc_uninit(numel);
                for (o, &v) in out.iter_mut().zip(&vals[node.inputs[0]]) {
                    *o = v.max(0.0);
                }
                (out, Aux::None)
            }
            OpKind::Gelu => {
                let mut out = arena.alloc_uninit(numel);
                for (o, &v) in out.iter_mut().zip(&vals[node.inputs[0]]) {
                    *o = gelu(v);
                }
                (out, Aux::None)
            }
            OpKind::ActQuant { site } => {
                let qp = src.act_q(*site, &node.name)?;
                let mut out = arena.alloc_uninit(numel);
                match qp {
                    Some(qp) => {
                        for (o, &v) in out.iter_mut().zip(&vals[node.inputs[0]]) {
                            *o = quant::fake_quant(v, &qp);
                        }
                    }
                    None => out.copy_from_slice(&vals[node.inputs[0]]),
                }
                (out, Aux::None)
            }
            OpKind::Add => {
                let mut out = arena.alloc_uninit(numel);
                out.copy_from_slice(&vals[node.inputs[0]]);
                tensor::axpy(1.0, &vals[node.inputs[1]], &mut out);
                (out, Aux::None)
            }
            OpKind::MaxPool2 => {
                let is = &plan.shapes[node.inputs[0]];
                let (h, wd, c) = (is[1], is[2], is[3]);
                let (ho, wo) = (dims[1], dims[2]);
                let xin = &vals[node.inputs[0]];
                let mut out = arena.alloc_uninit(numel);
                let mut arg = if with_aux { vec![0usize; numel] } else { Vec::new() };
                for b in 0..bsz {
                    for oh in 0..ho {
                        for ow in 0..wo {
                            for ch in 0..c {
                                let mut best = f32::NEG_INFINITY;
                                let mut best_i = 0usize;
                                for dh in 0..2 {
                                    for dw in 0..2 {
                                        let idx =
                                            ((b * h + oh * 2 + dh) * wd + ow * 2 + dw) * c + ch;
                                        if xin[idx] > best {
                                            best = xin[idx];
                                            best_i = idx;
                                        }
                                    }
                                }
                                let o = ((b * ho + oh) * wo + ow) * c + ch;
                                out[o] = best;
                                if with_aux {
                                    arg[o] = best_i;
                                }
                            }
                        }
                    }
                }
                (out, if with_aux { Aux::Pool(arg) } else { Aux::None })
            }
            OpKind::GlobalAvgPool => {
                let is = &plan.shapes[node.inputs[0]];
                let (h, wd, c) = (is[1], is[2], is[3]);
                let xin = &vals[node.inputs[0]];
                let mut out = arena.alloc(numel);
                for b in 0..bsz {
                    for pix in 0..h * wd {
                        tensor::axpy(
                            1.0,
                            &xin[(b * h * wd + pix) * c..(b * h * wd + pix + 1) * c],
                            &mut out[b * c..(b + 1) * c],
                        );
                    }
                }
                let scale = 1.0 / (h * wd) as f32;
                for v in out.iter_mut() {
                    *v *= scale;
                }
                (out, Aux::None)
            }
            OpKind::Reshape => {
                let mut out = arena.alloc_uninit(numel);
                out.copy_from_slice(&vals[node.inputs[0]]);
                (out, Aux::None)
            }
            OpKind::ConcatCls { cls } => {
                let clsw = src.tensor(cls)?;
                let (t1, dim) = (dims[1], dims[2]);
                let xin = &vals[node.inputs[0]];
                let mut out = arena.alloc_uninit(numel);
                for b in 0..bsz {
                    out[b * t1 * dim..b * t1 * dim + dim].copy_from_slice(clsw);
                    out[b * t1 * dim + dim..(b + 1) * t1 * dim]
                        .copy_from_slice(&xin[b * (t1 - 1) * dim..(b + 1) * (t1 - 1) * dim]);
                }
                (out, Aux::None)
            }
            OpKind::AddPos { pos } => {
                let posw = src.tensor(pos)?;
                let rest = numel / bsz;
                anyhow::ensure!(posw.len() == rest, "pos table size mismatch");
                let mut out = arena.alloc_uninit(numel);
                out.copy_from_slice(&vals[node.inputs[0]]);
                for b in 0..bsz {
                    tensor::axpy(1.0, posw, &mut out[b * rest..(b + 1) * rest]);
                }
                (out, Aux::None)
            }
            OpKind::Attention { heads, causal } => {
                let (s, d) = (dims[1], dims[2]);
                let hd = d / heads;
                let scale = 1.0 / (hd as f32).sqrt();
                let mut out = arena.alloc_uninit(numel);
                let mut probs = if with_aux {
                    arena.alloc_uninit(bsz * heads * s * s)
                } else {
                    Vec::new()
                };
                let mut qh = arena.alloc_uninit(s * hd);
                let mut kh = arena.alloc_uninit(s * hd);
                let mut vh = arena.alloc_uninit(s * hd);
                let mut att = arena.alloc_uninit(s * s);
                let mut yh = arena.alloc_uninit(s * hd);
                {
                    let (qv, kv, vv) = (
                        &vals[node.inputs[0]],
                        &vals[node.inputs[1]],
                        &vals[node.inputs[2]],
                    );
                    for b in 0..bsz {
                        for head in 0..*heads {
                            let off = head * hd;
                            for t in 0..s {
                                let sidx = (b * s + t) * d + off;
                                qh[t * hd..(t + 1) * hd].copy_from_slice(&qv[sidx..sidx + hd]);
                                kh[t * hd..(t + 1) * hd].copy_from_slice(&kv[sidx..sidx + hd]);
                                vh[t * hd..(t + 1) * hd].copy_from_slice(&vv[sidx..sidx + hd]);
                            }
                            tensor::matmul_nt_into(&mut att, &qh, &kh, s, hd, s);
                            for v in att.iter_mut() {
                                *v *= scale;
                            }
                            if *causal {
                                for i in 0..s {
                                    for j in i + 1..s {
                                        att[i * s + j] = -1e9;
                                    }
                                }
                            }
                            softmax_rows(&mut att, s, s);
                            tensor::matmul_into(&mut yh, &att, &vh, s, s, hd);
                            if with_aux {
                                let pdst = (b * heads + head) * s * s;
                                probs[pdst..pdst + s * s].copy_from_slice(&att);
                            }
                            for t in 0..s {
                                let dst = (b * s + t) * d + off;
                                out[dst..dst + hd].copy_from_slice(&yh[t * hd..(t + 1) * hd]);
                            }
                        }
                    }
                }
                arena.reclaim(qh);
                arena.reclaim(kh);
                arena.reclaim(vh);
                arena.reclaim(att);
                arena.reclaim(yh);
                (out, if with_aux { Aux::Att(probs) } else { Aux::None })
            }
            OpKind::PatchMerge { side } => {
                let dim4 = dims[2];
                let dim = dim4 / 4;
                let half = side / 2;
                let xin = &vals[node.inputs[0]];
                let mut out = arena.alloc_uninit(numel);
                for b in 0..bsz {
                    for i in 0..half {
                        for j in 0..half {
                            let o = (b * half * half + i * half + j) * dim4;
                            for (slot, (di, dj)) in
                                [(0, 0), (1, 0), (0, 1), (1, 1)].iter().enumerate()
                            {
                                let sidx =
                                    (b * side * side + (2 * i + di) * side + (2 * j + dj)) * dim;
                                out[o + slot * dim..o + (slot + 1) * dim]
                                    .copy_from_slice(&xin[sidx..sidx + dim]);
                            }
                        }
                    }
                }
                (out, Aux::None)
            }
            OpKind::TokenPoolCls => {
                let is = &plan.shapes[node.inputs[0]];
                let (t, dim) = (is[1], is[2]);
                let xin = &vals[node.inputs[0]];
                let mut out = arena.alloc_uninit(numel);
                for b in 0..bsz {
                    out[b * dim..(b + 1) * dim]
                        .copy_from_slice(&xin[b * t * dim..b * t * dim + dim]);
                }
                (out, Aux::None)
            }
            OpKind::TokenPoolMean => {
                let is = &plan.shapes[node.inputs[0]];
                let (t, dim) = (is[1], is[2]);
                let xin = &vals[node.inputs[0]];
                let mut out = arena.alloc(numel);
                for b in 0..bsz {
                    for tok in 0..t {
                        tensor::axpy(
                            1.0,
                            &xin[(b * t + tok) * dim..(b * t + tok + 1) * dim],
                            &mut out[b * dim..(b + 1) * dim],
                        );
                    }
                }
                let scale = 1.0 / t as f32;
                for v in out.iter_mut() {
                    *v *= scale;
                }
                (out, Aux::None)
            }
        };
        if let Some(t0) = t0 {
            let phase = if with_aux { "fwd" } else { "exec" };
            let name = if kern.is_empty() {
                node.op.label().to_string()
            } else {
                format!("{}/{}{}", node.op.label(), kern, simd_tag)
            };
            crate::obs::trace::record(phase, name, t0);
        }
        debug_assert_eq!(out.len(), numel, "{}: shape/val mismatch", node.name);
        vals.push(out);
        if with_aux {
            aux.push(ax);
        } else {
            reclaim_aux(arena, ax);
            aux.push(Aux::None);
        }
    }
    Ok((vals, aux))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builders;
    use crate::runtime::lowering;
    use crate::util::json;

    fn vgg_cfg() -> json::Json {
        json::parse(
            r#"{"name": "t_vgg", "family": "vgg", "task": "image_cls",
                "image": {"size": 8, "channels": 2}, "conv_channels": [4, 4],
                "pool_every": 2, "fc_dims": [6], "num_classes": 3,
                "quant": {"weight": true, "act": true}}"#,
        )
        .unwrap()
    }

    #[test]
    fn plan_substitutes_the_batch_dim_and_sizes_conv_scratch() {
        let cfg = vgg_cfg();
        let sites = builders::quant_site_specs(&cfg).unwrap();
        let prog = lowering::lower(&cfg, &sites, 1).unwrap();
        for bsz in [1usize, 3, 8] {
            let plan = Plan::new(&prog, bsz);
            assert_eq!(plan.shapes.len(), prog.nodes.len());
            for (i, node) in prog.nodes.iter().enumerate() {
                assert_eq!(plan.shapes[i][0], bsz, "{}", node.name);
                assert_eq!(plan.shapes[i][1..], node.shape[1..], "{}", node.name);
                assert_eq!(
                    plan.numels[i],
                    plan.shapes[i].iter().product::<usize>(),
                    "{}",
                    node.name
                );
                match &node.op {
                    lowering::OpKind::Conv2d { k, .. } => {
                        let cin = *prog.nodes[node.inputs[0]].shape.last().unwrap();
                        let want = bsz * node.shape[1] * node.shape[2] * k * k * cin;
                        assert_eq!(plan.col_sizes[i], want, "{}", node.name);
                    }
                    _ => assert_eq!(plan.col_sizes[i], 0, "{}", node.name),
                }
            }
        }
    }

    #[test]
    fn arena_recycles_capacity_and_zeroes() {
        let mut arena = Arena::new();
        let mut v = arena.alloc(128);
        v.iter_mut().for_each(|x| *x = 3.0);
        arena.reclaim(v);
        let v2 = arena.alloc(64);
        assert!(v2.capacity() >= 128, "capacity not recycled");
        assert!(v2.iter().all(|&x| x == 0.0), "stale values leaked");
        assert_eq!(v2.len(), 64);
        // growing past the recycled capacity still works
        arena.reclaim(v2);
        let v3 = arena.alloc(256);
        assert_eq!(v3.len(), 256);
        assert!(v3.iter().all(|&x| x == 0.0));
        // alloc_uninit sizes correctly (shrink and zero-extend paths)
        // without promising contents
        let mut v3 = v3;
        v3.iter_mut().for_each(|x| *x = 9.0);
        arena.reclaim(v3);
        let v4 = arena.alloc_uninit(100);
        assert_eq!(v4.len(), 100);
        arena.reclaim(v4);
        let v5 = arena.alloc_uninit(300);
        assert_eq!(v5.len(), 300);
        assert!(v5[100..].iter().all(|&x| x == 0.0), "extension not zeroed");
    }

    #[test]
    fn arena_pool_is_capped() {
        // consumers reclaim buffers the arena never handed out (kernel
        // outputs, aux); the pool must not grow without bound from them
        let mut arena = Arena::new();
        for _ in 0..Arena::MAX_FREE + 100 {
            arena.reclaim(vec![0.0f32; 4]);
        }
        assert_eq!(arena.free.len(), Arena::MAX_FREE);
        // pooled buffers still recycle normally at the cap
        let v = arena.alloc(4);
        assert_eq!(arena.free.len(), Arena::MAX_FREE - 1);
        arena.reclaim(v);
        assert_eq!(arena.free.len(), Arena::MAX_FREE);
    }

    #[test]
    fn arena_pool_reuses_warmed_arenas_and_caps() {
        let pool = ArenaPool::new();
        let mut a = pool.take();
        let v = a.alloc(64);
        a.reclaim(v);
        pool.give(a);
        assert_eq!(pool.pooled(), 1);
        // the warmed arena comes back with its free list intact
        let mut b = pool.take();
        assert_eq!(pool.pooled(), 0);
        let v = b.alloc(32);
        assert!(v.capacity() >= 64, "pooled arena lost its warmed buffers");
        b.reclaim(v);
        pool.give(b);
        for _ in 0..ArenaPool::MAX_POOLED + 8 {
            pool.give(Arena::new());
        }
        assert_eq!(pool.pooled(), ArenaPool::MAX_POOLED);
    }

    #[test]
    fn train_source_quantizes_only_sited_weights() {
        use crate::quant::QParams;
        use crate::tensor::{ParamStore, Tensor};
        let mut params = ParamStore::new();
        params.push(Tensor::from_vec("w", &[2, 2], vec![0.11, -0.52, 0.93, 0.24]));
        let q = vec![QParams { d: 0.5, t: 1.0, qm: 1.0 }];
        let src = TrainParams { params: &params, q: &q };
        let quantized = src.weight("w", Some(0)).unwrap();
        assert!(matches!(quantized, Cow::Owned(_)));
        for (a, &b) in quantized.iter().zip(&params.get("w").unwrap().data) {
            assert_eq!(*a, quant::fake_quant(b, &q[0]));
        }
        let raw = src.weight("w", None).unwrap();
        assert!(matches!(raw, Cow::Borrowed(_)));
        assert_eq!(raw.as_ref(), params.get("w").unwrap().data.as_slice());
        assert!(src.tensor("missing").is_err());
    }

    #[test]
    fn deploy_source_act_rows_gate_quantization() {
        use crate::quant::QParams;
        use crate::tensor::ParamStore;
        let weights = ParamStore::new();
        let sites = BTreeMap::new();
        let rows = vec![None, Some(QParams { d: 0.1, t: 1.0, qm: 1.0 })];
        let on = DeployParams {
            weights: &weights,
            act_q: &rows,
            apply_act_quant: true,
            weight_sites: &sites,
        };
        assert!(on.act_q(1, "n").unwrap().is_some());
        // a weight-site row consulted as an activation site is a hard error
        assert!(on.act_q(0, "n").is_err());
        assert!(on.act_q(7, "n").is_err());
        let off = DeployParams {
            weights: &weights,
            act_q: &rows,
            apply_act_quant: false,
            weight_sites: &sites,
        };
        assert!(off.act_q(1, "n").unwrap().is_none());
        assert!(off.act_q(0, "n").unwrap().is_none());
    }

    #[test]
    fn deploy_source_validates_requested_weight_site() {
        use crate::tensor::{ParamStore, Tensor};
        let mut weights = ParamStore::new();
        weights.push(Tensor::from_vec("fc0.weight", &[2, 2], vec![0.5, -0.5, 0.25, 0.0]));
        let mut sites = BTreeMap::new();
        sites.insert("fc0.weight".to_string(), 3usize);
        let src = DeployParams {
            weights: &weights,
            act_q: &[],
            apply_act_quant: false,
            weight_sites: &sites,
        };
        // matching site: fine
        assert!(src.weight("fc0.weight", Some(3)).is_ok());
        // mismatched site: named error, never a silent wrong-step dequant
        let err = src.weight("fc0.weight", Some(1)).unwrap_err().to_string();
        assert!(err.contains("fc0.weight") && err.contains("site 1") && err.contains("site 3"), "{err}");
        // program says unquantized but container packed it: also an error
        let err = src.weight("fc0.weight", None).unwrap_err().to_string();
        assert!(err.contains("unquantized"), "{err}");
        // unrecorded tensors (dense baseline) accept any requested site
        let dense = DeployParams {
            weights: &weights,
            act_q: &[],
            apply_act_quant: false,
            weight_sites: &BTreeMap::new(),
        };
        assert!(dense.weight("fc0.weight", Some(7)).is_ok());
        assert!(dense.weight("fc0.weight", None).is_ok());
    }

    #[test]
    fn quantized_source_serves_i8_and_dequantizes_on_fallback() {
        use crate::tensor::ParamStore;
        let weights = ParamStore::new();
        let mut iweights = BTreeMap::new();
        // [k=2, n=2] levels with step 0.25
        iweights.insert(
            "fc0.weight".to_string(),
            IntWeight::from_levels(&[-2, 1, 4, -3], 2, 0.25).unwrap(),
        );
        let mut uweights = BTreeMap::new();
        // [k=2, n=3] nibble-packed levels with step 0.5 (odd n: padded tail)
        uweights.insert(
            "fc1.weight".to_string(),
            U4Weight::from_levels(&[-7, 3, 0, 5, -1, 7], 3, 0.5).unwrap(),
        );
        let mut sites = BTreeMap::new();
        sites.insert("fc0.weight".to_string(), 0usize);
        sites.insert("fc1.weight".to_string(), 1usize);
        let src = QuantizedParams {
            weights: &weights,
            iweights: &iweights,
            uweights: &uweights,
            weight_sites: &sites,
            act_q: &[],
        };
        let iw = src.weight_i8("fc0.weight", Some(0)).unwrap().unwrap();
        assert_eq!(iw.levels, vec![-2, 1, 4, -3]);
        assert_eq!(iw.max_abs, 4);
        // f32 fallback dequantizes levels × per-channel scale
        let w = src.weight("fc0.weight", Some(0)).unwrap();
        assert_eq!(w.as_ref(), &[-0.5, 0.25, 1.0, -0.75]);
        // the packed-u4 entry points mirror the i8 ones
        let uw = src.weight_u4("fc1.weight", Some(1)).unwrap().unwrap();
        assert_eq!(uw.unpack_levels(), vec![-7, 3, 0, 5, -1, 7]);
        assert_eq!(uw.max_abs, 7);
        let w = src.weight("fc1.weight", Some(1)).unwrap();
        assert_eq!(w.as_ref(), &[-3.5, 1.5, 0.0, 2.5, -0.5, 3.5]);
        // site validation bites on every entry point
        assert!(src.weight_i8("fc0.weight", Some(2)).is_err());
        assert!(src.weight_u4("fc1.weight", Some(2)).is_err());
        assert!(src.weight("fc0.weight", Some(2)).is_err());
        // a name without an int weight falls through to the f32 store
        assert!(src.weight_i8("other.weight", Some(1)).unwrap().is_none());
        assert!(src.weight_u4("other.weight", Some(1)).unwrap().is_none());
        assert!(src.weight("other.weight", Some(1)).is_err()); // not in store either
    }

    #[test]
    fn grid_site_walks_reshape_and_maxpool_only() {
        // vgg: conv -> bn -> relu -> act -> pool -> ... -> flatten -> fc
        let cfg = vgg_cfg();
        let sites = builders::quant_site_specs(&cfg).unwrap();
        let prog = lowering::lower(&cfg, &sites, 1).unwrap();
        for (id, node) in prog.nodes.iter().enumerate() {
            match &node.op {
                // every ActQuant resolves to itself
                lowering::OpKind::ActQuant { site } => {
                    assert_eq!(grid_site(&prog, id), Some(*site), "{}", node.name);
                }
                _ => {}
            }
        }
        // the second conv's input chain reaches the first conv's act site
        let c1 = prog
            .nodes
            .iter()
            .position(|n| matches!(&n.op, lowering::OpKind::Conv2d { w, .. } if w == "features.1"))
            .expect("features.1 lowered");
        let got = grid_site(&prog, prog.nodes[c1].inputs[0]).expect("grid source");
        assert_eq!(sites[got].name, "features.0.act");
        // the first conv sees raw pixels: no grid source
        let c0 = prog
            .nodes
            .iter()
            .position(|n| matches!(&n.op, lowering::OpKind::Conv2d { w, .. } if w == "features.0"))
            .unwrap();
        assert_eq!(grid_site(&prog, prog.nodes[c0].inputs[0]), None);
        // the fc after flatten+pool still reaches the last conv act site
        let fc = prog
            .nodes
            .iter()
            .position(|n| matches!(&n.op, lowering::OpKind::Linear { w, .. } if w == "fc0"))
            .unwrap();
        let got = grid_site(&prog, prog.nodes[fc].inputs[0]).expect("through flatten/pool");
        assert_eq!(sites[got].name, "features.1.act");
    }

    #[test]
    fn arena_recycles_i8_buffers() {
        let mut arena = Arena::new();
        let mut v = arena.alloc_i8(64);
        assert_eq!(v.len(), 64);
        v.iter_mut().for_each(|x| *x = 3);
        arena.reclaim_i8(v);
        let v2 = arena.alloc_i8(32);
        assert!(v2.capacity() >= 64, "capacity not recycled");
        assert_eq!(v2.len(), 32);
        arena.reclaim_i8(v2);
        let v3 = arena.alloc_i8(128);
        assert_eq!(v3.len(), 128);
        assert!(v3[64..].iter().all(|&x| x == 0), "extension not zeroed");
    }
}
