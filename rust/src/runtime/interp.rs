//! Generic executor for lowered programs (`runtime/lowering.rs`): forward
//! + backward over the typed op IR with per-site fake-quantization.
//!
//! The contract matches the PJRT engine exactly: weights are fake-quantized
//! at their sites on the forward pass, activation sites quantize in place,
//! and the backward pass produces clipped-STE parameter gradients plus the
//! eq. (4)-(6) scalar (d, t, q_m) gradients per site. Losses are the zoo's
//! task heads: softmax cross-entropy (image_cls), start+end span
//! cross-entropy (span_qa, python `bert_loss`) and masked next-token
//! cross-entropy (lm, python `lm_loss`).
//!
//! Numeric conventions: f32 storage, f64 accumulation in every contraction
//! (see `tensor/ops.rs`), so results are deterministic and stable at the
//! im2col row counts the conv families produce.

use anyhow::{Context, Result};

use super::lowering::{OpKind, Program};
use super::HostArray;
use crate::quant::{self, QParams};
use crate::tensor::{
    self, batchnorm_bwd_rows, batchnorm_rows, col2im, gelu, gelu_grad, im2col,
    layernorm_bwd_rows, layernorm_rows, matmul, matmul_nt, matmul_tn, softmax_bwd_rows,
    softmax_rows, NormAux, ParamStore,
};

const NORM_EPS: f32 = 1e-5;

/// Everything one interpreter pass produces. `grads` is present only for
/// training passes; `extra` only for eval passes (task-dependent outputs
/// after loss+metric, in manifest `eval_outputs` order). `logits` is the
/// output node's raw values — the deployment path's parity reference
/// (compressed-engine output must match these on the masked model).
pub struct RunOut {
    pub loss: f32,
    pub metric: f32,
    pub extra: Vec<Vec<f32>>,
    pub logits: Vec<f32>,
    pub grads: Option<(ParamStore, Vec<(f32, f32, f32)>)>,
}

/// Per-node saved forward state the backward pass consumes. Eval passes
/// (`with_grads = false`) retain none of it.
enum Aux {
    None,
    /// The fake-quantized weight that was multiplied (None when the weight
    /// has no quant site — the backward pass then reads the raw parameter).
    W(Option<Vec<f32>>),
    Norm(NormAux),
    /// Attention probabilities `[B * heads * S * S]`.
    Att(Vec<f32>),
    /// Max-pool argmax: flat input index per output element.
    Pool(Vec<usize>),
}

fn tensor_data<'a>(params: &'a ParamStore, name: &str) -> Result<&'a [f32]> {
    params
        .get(name)
        .map(|t| t.data.as_slice())
        .with_context(|| format!("missing parameter `{name}`"))
}

/// Fake-quantize a weight at its site; None when the site is absent (the
/// raw parameter is used directly, no copy).
fn quantized_weight(raw: &[f32], site: Option<usize>, q: &[QParams]) -> Option<Vec<f32>> {
    site.map(|s| raw.iter().map(|&v| quant::fake_quant(v, &q[s])).collect())
}

/// Accumulate eq. (4)-(6) site gradients from `values` (the quantizer
/// inputs) against `g` (the cotangent of the quantizer output), then apply
/// the clipped STE: zero the pass-through gradient outside the clip range.
fn ste_site_backward(values: &[f32], g: &mut [f32], qp: &QParams, acc: &mut (f32, f32, f32)) {
    debug_assert_eq!(values.len(), g.len());
    let (mut gd, mut gt, mut gqm) = (0.0f64, 0.0f64, 0.0f64);
    for (i, &v) in values.iter().enumerate() {
        let gi = g[i];
        gd += (gi * quant::grad_d(v, qp)) as f64;
        gt += (gi * quant::grad_t(v, qp)) as f64;
        gqm += (gi * quant::grad_qm(v, qp)) as f64;
        if v.abs() > qp.qm {
            g[i] = 0.0;
        }
    }
    acc.0 += gd as f32;
    acc.1 += gt as f32;
    acc.2 += gqm as f32;
}

/// Execute one batch through `prog`. `n_sites` sizes the qgrad vector
/// (= manifest qsites count; every node site index lies below it).
pub fn run(
    prog: &Program,
    n_sites: usize,
    params: &ParamStore,
    q: &[QParams],
    x: &HostArray,
    y: &HostArray,
    with_grads: bool,
) -> Result<RunOut> {
    anyhow::ensure!(q.len() == n_sites, "qparam count mismatch: {} vs {n_sites}", q.len());
    let nodes = &prog.nodes;
    let mut vals: Vec<Vec<f32>> = Vec::with_capacity(nodes.len());
    let mut aux: Vec<Aux> = Vec::with_capacity(nodes.len());

    let xi32: Option<&Vec<i32>> = match x {
        HostArray::I32(v) => Some(v),
        HostArray::F32(_) => None,
    };

    // ------------------------------------------------------------ forward
    for node in nodes.iter() {
        let numel: usize = node.shape.iter().product();
        let in_shape = |k: usize| -> &Vec<usize> { &nodes[node.inputs[k]].shape };
        let (out, ax): (Vec<f32>, Aux) = match &node.op {
            OpKind::Input => {
                let HostArray::F32(xv) = x else {
                    anyhow::bail!("image task expects f32 inputs")
                };
                anyhow::ensure!(xv.len() == numel, "input batch size mismatch");
                (xv.clone(), Aux::None)
            }
            OpKind::Embed { tok, pos } => {
                let toks = xi32.context("token task expects i32 inputs")?;
                let (bsz, seq, dim) = (node.shape[0], node.shape[1], node.shape[2]);
                anyhow::ensure!(toks.len() == bsz * seq, "token batch size mismatch");
                let tokw = tensor_data(params, tok)?;
                let posw = tensor_data(params, pos)?;
                let vocab = tokw.len() / dim;
                let mut out = vec![0.0f32; numel];
                for b in 0..bsz {
                    for s in 0..seq {
                        let id = toks[b * seq + s];
                        anyhow::ensure!(
                            (0..vocab as i32).contains(&id),
                            "token id {id} outside vocab {vocab}"
                        );
                        let dst = &mut out[(b * seq + s) * dim..(b * seq + s + 1) * dim];
                        dst.copy_from_slice(&tokw[id as usize * dim..(id as usize + 1) * dim]);
                        tensor::axpy(1.0, &posw[s * dim..(s + 1) * dim], dst);
                    }
                }
                (out, Aux::None)
            }
            OpKind::Linear { w, site } => {
                let raw = tensor_data(params, &format!("{w}.weight"))?;
                let bias = tensor_data(params, &format!("{w}.bias"))?;
                let wqo = quantized_weight(raw, *site, q);
                let wq: &[f32] = wqo.as_deref().unwrap_or(raw);
                let din = *in_shape(0).last().unwrap();
                let dout = *node.shape.last().unwrap();
                let rows = numel / dout;
                let mut out = matmul(&vals[node.inputs[0]], wq, rows, din, dout);
                for r in 0..rows {
                    tensor::axpy(1.0, bias, &mut out[r * dout..(r + 1) * dout]);
                }
                (out, Aux::W(wqo))
            }
            OpKind::Conv2d { w, site, k, stride, pad } => {
                let raw = tensor_data(params, &format!("{w}.weight"))?;
                let bias = tensor_data(params, &format!("{w}.bias"))?;
                let wqo = quantized_weight(raw, *site, q);
                let wq: &[f32] = wqo.as_deref().unwrap_or(raw);
                let is = in_shape(0);
                let (bsz, h, wd, cin) = (is[0], is[1], is[2], is[3]);
                let (ho, wo, cout) = (node.shape[1], node.shape[2], node.shape[3]);
                let cols = im2col(&vals[node.inputs[0]], bsz, h, wd, cin, *k, *stride, *pad, ho, wo);
                let rows = bsz * ho * wo;
                let mut out = matmul(&cols, wq, rows, k * k * cin, cout);
                for r in 0..rows {
                    tensor::axpy(1.0, bias, &mut out[r * cout..(r + 1) * cout]);
                }
                (out, Aux::W(wqo))
            }
            OpKind::BatchNorm { p } | OpKind::LayerNorm { p } => {
                let gamma = tensor_data(params, &format!("{p}.gamma"))?;
                let beta = tensor_data(params, &format!("{p}.beta"))?;
                let c = *node.shape.last().unwrap();
                let rows = numel / c;
                let (out, na) = if matches!(node.op, OpKind::BatchNorm { .. }) {
                    batchnorm_rows(&vals[node.inputs[0]], gamma, beta, rows, c, NORM_EPS)
                } else {
                    layernorm_rows(&vals[node.inputs[0]], gamma, beta, rows, c, NORM_EPS)
                };
                (out, Aux::Norm(na))
            }
            OpKind::Relu => (
                vals[node.inputs[0]].iter().map(|&v| v.max(0.0)).collect(),
                Aux::None,
            ),
            OpKind::Gelu => (
                vals[node.inputs[0]].iter().map(|&v| gelu(v)).collect(),
                Aux::None,
            ),
            OpKind::ActQuant { site } => (
                vals[node.inputs[0]]
                    .iter()
                    .map(|&v| quant::fake_quant(v, &q[*site]))
                    .collect(),
                Aux::None,
            ),
            OpKind::Add => {
                let mut out = vals[node.inputs[0]].clone();
                tensor::axpy(1.0, &vals[node.inputs[1]], &mut out);
                (out, Aux::None)
            }
            OpKind::MaxPool2 => {
                let is = in_shape(0);
                let (bsz, h, wd, c) = (is[0], is[1], is[2], is[3]);
                let (ho, wo) = (node.shape[1], node.shape[2]);
                let xin = &vals[node.inputs[0]];
                let mut out = vec![0.0f32; numel];
                let mut arg = vec![0usize; numel];
                for b in 0..bsz {
                    for oh in 0..ho {
                        for ow in 0..wo {
                            for ch in 0..c {
                                let mut best = f32::NEG_INFINITY;
                                let mut best_i = 0usize;
                                for dh in 0..2 {
                                    for dw in 0..2 {
                                        let idx =
                                            ((b * h + oh * 2 + dh) * wd + ow * 2 + dw) * c + ch;
                                        if xin[idx] > best {
                                            best = xin[idx];
                                            best_i = idx;
                                        }
                                    }
                                }
                                let o = ((b * ho + oh) * wo + ow) * c + ch;
                                out[o] = best;
                                arg[o] = best_i;
                            }
                        }
                    }
                }
                (out, Aux::Pool(arg))
            }
            OpKind::GlobalAvgPool => {
                let is = in_shape(0);
                let (bsz, h, wd, c) = (is[0], is[1], is[2], is[3]);
                let xin = &vals[node.inputs[0]];
                let mut out = vec![0.0f32; bsz * c];
                for b in 0..bsz {
                    for pix in 0..h * wd {
                        tensor::axpy(
                            1.0,
                            &xin[(b * h * wd + pix) * c..(b * h * wd + pix + 1) * c],
                            &mut out[b * c..(b + 1) * c],
                        );
                    }
                }
                let scale = 1.0 / (h * wd) as f32;
                for v in out.iter_mut() {
                    *v *= scale;
                }
                (out, Aux::None)
            }
            OpKind::Reshape => (vals[node.inputs[0]].clone(), Aux::None),
            OpKind::ConcatCls { cls } => {
                let clsw = tensor_data(params, cls)?;
                let (bsz, t1, dim) = (node.shape[0], node.shape[1], node.shape[2]);
                let xin = &vals[node.inputs[0]];
                let mut out = vec![0.0f32; numel];
                for b in 0..bsz {
                    out[b * t1 * dim..b * t1 * dim + dim].copy_from_slice(clsw);
                    out[b * t1 * dim + dim..(b + 1) * t1 * dim]
                        .copy_from_slice(&xin[b * (t1 - 1) * dim..(b + 1) * (t1 - 1) * dim]);
                }
                (out, Aux::None)
            }
            OpKind::AddPos { pos } => {
                let posw = tensor_data(params, pos)?;
                let (bsz, rest) = (node.shape[0], numel / node.shape[0]);
                anyhow::ensure!(posw.len() == rest, "pos table size mismatch");
                let mut out = vals[node.inputs[0]].clone();
                for b in 0..bsz {
                    tensor::axpy(1.0, posw, &mut out[b * rest..(b + 1) * rest]);
                }
                (out, Aux::None)
            }
            OpKind::Attention { heads, causal } => {
                let (bsz, s, d) = (node.shape[0], node.shape[1], node.shape[2]);
                let hd = d / heads;
                let scale = 1.0 / (hd as f32).sqrt();
                let (qv, kv, vv) = (
                    &vals[node.inputs[0]],
                    &vals[node.inputs[1]],
                    &vals[node.inputs[2]],
                );
                let mut out = vec![0.0f32; numel];
                let mut probs = vec![0.0f32; bsz * heads * s * s];
                let mut qh = vec![0.0f32; s * hd];
                let mut kh = vec![0.0f32; s * hd];
                let mut vh = vec![0.0f32; s * hd];
                for b in 0..bsz {
                    for head in 0..*heads {
                        let off = head * hd;
                        for t in 0..s {
                            let src = (b * s + t) * d + off;
                            qh[t * hd..(t + 1) * hd].copy_from_slice(&qv[src..src + hd]);
                            kh[t * hd..(t + 1) * hd].copy_from_slice(&kv[src..src + hd]);
                            vh[t * hd..(t + 1) * hd].copy_from_slice(&vv[src..src + hd]);
                        }
                        let mut att = matmul_nt(&qh, &kh, s, hd, s);
                        for v in att.iter_mut() {
                            *v *= scale;
                        }
                        if *causal {
                            for i in 0..s {
                                for j in i + 1..s {
                                    att[i * s + j] = -1e9;
                                }
                            }
                        }
                        softmax_rows(&mut att, s, s);
                        let yh = matmul(&att, &vh, s, s, hd);
                        let pdst = (b * heads + head) * s * s;
                        probs[pdst..pdst + s * s].copy_from_slice(&att);
                        for t in 0..s {
                            let dst = (b * s + t) * d + off;
                            out[dst..dst + hd].copy_from_slice(&yh[t * hd..(t + 1) * hd]);
                        }
                    }
                }
                (out, Aux::Att(probs))
            }
            OpKind::PatchMerge { side } => {
                let (bsz, dim4) = (node.shape[0], node.shape[2]);
                let dim = dim4 / 4;
                let half = side / 2;
                let xin = &vals[node.inputs[0]];
                let mut out = vec![0.0f32; numel];
                for b in 0..bsz {
                    for i in 0..half {
                        for j in 0..half {
                            let o = (b * half * half + i * half + j) * dim4;
                            for (slot, (di, dj)) in
                                [(0, 0), (1, 0), (0, 1), (1, 1)].iter().enumerate()
                            {
                                let src =
                                    (b * side * side + (2 * i + di) * side + (2 * j + dj)) * dim;
                                out[o + slot * dim..o + (slot + 1) * dim]
                                    .copy_from_slice(&xin[src..src + dim]);
                            }
                        }
                    }
                }
                (out, Aux::None)
            }
            OpKind::TokenPoolCls => {
                let is = in_shape(0);
                let (bsz, t, dim) = (is[0], is[1], is[2]);
                let xin = &vals[node.inputs[0]];
                let mut out = vec![0.0f32; bsz * dim];
                for b in 0..bsz {
                    out[b * dim..(b + 1) * dim]
                        .copy_from_slice(&xin[b * t * dim..b * t * dim + dim]);
                }
                (out, Aux::None)
            }
            OpKind::TokenPoolMean => {
                let is = in_shape(0);
                let (bsz, t, dim) = (is[0], is[1], is[2]);
                let xin = &vals[node.inputs[0]];
                let mut out = vec![0.0f32; bsz * dim];
                for b in 0..bsz {
                    for tok in 0..t {
                        tensor::axpy(
                            1.0,
                            &xin[(b * t + tok) * dim..(b * t + tok + 1) * dim],
                            &mut out[b * dim..(b + 1) * dim],
                        );
                    }
                }
                let scale = 1.0 / t as f32;
                for v in out.iter_mut() {
                    *v *= scale;
                }
                (out, Aux::None)
            }
        };
        debug_assert_eq!(out.len(), numel, "{}: shape/val mismatch", node.name);
        vals.push(out);
        // eval passes never run backward: drop the saved state immediately
        aux.push(if with_grads { ax } else { Aux::None });
    }

    // --------------------------------------------------------- loss heads
    let out_id = prog.output();
    let logits = &vals[out_id];
    let out_shape = &nodes[out_id].shape;
    let (loss, metric, extra, mut out_cot) = match prog.task.as_str() {
        "image_cls" => image_loss(logits, out_shape, y, with_grads)?,
        "span_qa" => span_loss(logits, out_shape, y, with_grads)?,
        "lm" => lm_loss(logits, out_shape, y, with_grads)?,
        other => anyhow::bail!("unknown task `{other}`"),
    };
    if !with_grads {
        // vals is dropped on return: hand the output buffer over instead of
        // copying it
        return Ok(RunOut {
            loss,
            metric,
            extra,
            logits: std::mem::take(&mut vals[out_id]),
            grads: None,
        });
    }

    // ----------------------------------------------------------- backward
    let mut grads = params.zeros_like();
    let mut qgrads = vec![(0.0f32, 0.0f32, 0.0f32); n_sites];
    let mut cots: Vec<Vec<f32>> = (0..nodes.len()).map(|_| Vec::new()).collect();
    cots[out_id] = out_cot.take().expect("training pass produced a cotangent");

    for i in (0..nodes.len()).rev() {
        let cot = std::mem::take(&mut cots[i]);
        if cot.is_empty() {
            continue;
        }
        let node = &nodes[i];
        // accumulate into an input's cotangent buffer
        macro_rules! acc {
            ($j:expr, $g:expr) => {{
                let j: usize = $j;
                let g: Vec<f32> = $g;
                if cots[j].is_empty() {
                    cots[j] = g;
                } else {
                    tensor::axpy(1.0, &g, &mut cots[j]);
                }
            }};
        }
        match &node.op {
            OpKind::Input => {}
            OpKind::Embed { tok, pos } => {
                let toks = xi32.context("token task expects i32 inputs")?;
                let (bsz, seq, dim) = (node.shape[0], node.shape[1], node.shape[2]);
                let gtok = &mut grads
                    .get_mut(tok)
                    .with_context(|| format!("grad store missing {tok}"))?
                    .data;
                for b in 0..bsz {
                    for s in 0..seq {
                        let id = toks[b * seq + s] as usize;
                        tensor::axpy(
                            1.0,
                            &cot[(b * seq + s) * dim..(b * seq + s + 1) * dim],
                            &mut gtok[id * dim..(id + 1) * dim],
                        );
                    }
                }
                let gpos = &mut grads
                    .get_mut(pos)
                    .with_context(|| format!("grad store missing {pos}"))?
                    .data;
                for b in 0..bsz {
                    tensor::axpy(1.0, &cot[b * seq * dim..(b + 1) * seq * dim], gpos);
                }
            }
            OpKind::Linear { w, site } => {
                let Aux::W(wqo) = &aux[i] else { unreachable!() };
                let raw = tensor_data(params, &format!("{w}.weight"))?;
                let wq: &[f32] = wqo.as_deref().unwrap_or(raw);
                let din = *nodes[node.inputs[0]].shape.last().unwrap();
                let dout = *node.shape.last().unwrap();
                let rows = cot.len() / dout;
                let xin = &vals[node.inputs[0]];
                let mut gw = matmul_tn(xin, &cot, rows, din, dout);
                if let Some(s) = site {
                    ste_site_backward(raw, &mut gw, &q[*s], &mut qgrads[*s]);
                }
                tensor::axpy(
                    1.0,
                    &gw,
                    &mut grads
                        .get_mut(&format!("{w}.weight"))
                        .with_context(|| format!("grad store missing {w}.weight"))?
                        .data,
                );
                let gb = &mut grads
                    .get_mut(&format!("{w}.bias"))
                    .with_context(|| format!("grad store missing {w}.bias"))?
                    .data;
                for r in 0..rows {
                    tensor::axpy(1.0, &cot[r * dout..(r + 1) * dout], gb);
                }
                acc!(node.inputs[0], matmul_nt(&cot, wq, rows, dout, din));
            }
            OpKind::Conv2d { w, site, k, stride, pad } => {
                let Aux::W(wqo) = &aux[i] else { unreachable!() };
                let raw = tensor_data(params, &format!("{w}.weight"))?;
                let wq: &[f32] = wqo.as_deref().unwrap_or(raw);
                let is = &nodes[node.inputs[0]].shape;
                let (bsz, h, wd, cin) = (is[0], is[1], is[2], is[3]);
                let (ho, wo, cout) = (node.shape[1], node.shape[2], node.shape[3]);
                let rows = bsz * ho * wo;
                let kkc = k * k * cin;
                // cols are recomputed rather than kept from the forward:
                // one im2col is far cheaper than holding every conv's
                // column matrix across the whole step
                let cols =
                    im2col(&vals[node.inputs[0]], bsz, h, wd, cin, *k, *stride, *pad, ho, wo);
                let mut gw = matmul_tn(&cols, &cot, rows, kkc, cout);
                if let Some(s) = site {
                    ste_site_backward(raw, &mut gw, &q[*s], &mut qgrads[*s]);
                }
                tensor::axpy(
                    1.0,
                    &gw,
                    &mut grads
                        .get_mut(&format!("{w}.weight"))
                        .with_context(|| format!("grad store missing {w}.weight"))?
                        .data,
                );
                let gb = &mut grads
                    .get_mut(&format!("{w}.bias"))
                    .with_context(|| format!("grad store missing {w}.bias"))?
                    .data;
                for r in 0..rows {
                    tensor::axpy(1.0, &cot[r * cout..(r + 1) * cout], gb);
                }
                let gcols = matmul_nt(&cot, wq, rows, cout, kkc);
                acc!(
                    node.inputs[0],
                    col2im(&gcols, bsz, h, wd, cin, *k, *stride, *pad, ho, wo)
                );
            }
            OpKind::BatchNorm { p } | OpKind::LayerNorm { p } => {
                let Aux::Norm(na) = &aux[i] else { unreachable!() };
                let gamma = tensor_data(params, &format!("{p}.gamma"))?;
                let c = *node.shape.last().unwrap();
                let rows = cot.len() / c;
                let (gx, gg, gb) = if matches!(node.op, OpKind::BatchNorm { .. }) {
                    batchnorm_bwd_rows(gamma, &cot, na, rows, c)
                } else {
                    layernorm_bwd_rows(gamma, &cot, na, rows, c)
                };
                tensor::axpy(
                    1.0,
                    &gg,
                    &mut grads
                        .get_mut(&format!("{p}.gamma"))
                        .with_context(|| format!("grad store missing {p}.gamma"))?
                        .data,
                );
                tensor::axpy(
                    1.0,
                    &gb,
                    &mut grads
                        .get_mut(&format!("{p}.beta"))
                        .with_context(|| format!("grad store missing {p}.beta"))?
                        .data,
                );
                acc!(node.inputs[0], gx);
            }
            OpKind::Relu => {
                let mut g = cot;
                for (gi, &xi) in g.iter_mut().zip(&vals[node.inputs[0]]) {
                    if xi <= 0.0 {
                        *gi = 0.0;
                    }
                }
                acc!(node.inputs[0], g);
            }
            OpKind::Gelu => {
                let mut g = cot;
                for (gi, &xi) in g.iter_mut().zip(&vals[node.inputs[0]]) {
                    *gi *= gelu_grad(xi);
                }
                acc!(node.inputs[0], g);
            }
            OpKind::ActQuant { site } => {
                let mut g = cot;
                ste_site_backward(&vals[node.inputs[0]], &mut g, &q[*site], &mut qgrads[*site]);
                acc!(node.inputs[0], g);
            }
            OpKind::Add => {
                acc!(node.inputs[0], cot.clone());
                acc!(node.inputs[1], cot);
            }
            OpKind::MaxPool2 => {
                let Aux::Pool(arg) = &aux[i] else { unreachable!() };
                let mut g = vec![0.0f32; vals[node.inputs[0]].len()];
                for (o, &src) in arg.iter().enumerate() {
                    g[src] += cot[o];
                }
                acc!(node.inputs[0], g);
            }
            OpKind::GlobalAvgPool => {
                let is = &nodes[node.inputs[0]].shape;
                let (bsz, h, wd, c) = (is[0], is[1], is[2], is[3]);
                let scale = 1.0 / (h * wd) as f32;
                let mut g = vec![0.0f32; bsz * h * wd * c];
                for b in 0..bsz {
                    for pix in 0..h * wd {
                        for ch in 0..c {
                            g[(b * h * wd + pix) * c + ch] = cot[b * c + ch] * scale;
                        }
                    }
                }
                acc!(node.inputs[0], g);
            }
            OpKind::Reshape => {
                acc!(node.inputs[0], cot);
            }
            OpKind::ConcatCls { cls } => {
                let (bsz, t1, dim) = (node.shape[0], node.shape[1], node.shape[2]);
                let gcls = &mut grads
                    .get_mut(cls)
                    .with_context(|| format!("grad store missing {cls}"))?
                    .data;
                let mut g = vec![0.0f32; bsz * (t1 - 1) * dim];
                for b in 0..bsz {
                    tensor::axpy(1.0, &cot[b * t1 * dim..b * t1 * dim + dim], gcls);
                    g[b * (t1 - 1) * dim..(b + 1) * (t1 - 1) * dim]
                        .copy_from_slice(&cot[b * t1 * dim + dim..(b + 1) * t1 * dim]);
                }
                acc!(node.inputs[0], g);
            }
            OpKind::AddPos { pos } => {
                let (bsz, rest) = (node.shape[0], cot.len() / node.shape[0]);
                let gpos = &mut grads
                    .get_mut(pos)
                    .with_context(|| format!("grad store missing {pos}"))?
                    .data;
                for b in 0..bsz {
                    tensor::axpy(1.0, &cot[b * rest..(b + 1) * rest], gpos);
                }
                acc!(node.inputs[0], cot);
            }
            OpKind::Attention { heads, .. } => {
                let Aux::Att(probs) = &aux[i] else { unreachable!() };
                let (bsz, s, d) = (node.shape[0], node.shape[1], node.shape[2]);
                let hd = d / heads;
                let scale = 1.0 / (hd as f32).sqrt();
                let (qv, kv, vv) = (
                    &vals[node.inputs[0]],
                    &vals[node.inputs[1]],
                    &vals[node.inputs[2]],
                );
                let mut gq = vec![0.0f32; qv.len()];
                let mut gk = vec![0.0f32; kv.len()];
                let mut gv = vec![0.0f32; vv.len()];
                let mut qh = vec![0.0f32; s * hd];
                let mut kh = vec![0.0f32; s * hd];
                let mut vh = vec![0.0f32; s * hd];
                let mut dyh = vec![0.0f32; s * hd];
                for b in 0..bsz {
                    for head in 0..*heads {
                        let off = head * hd;
                        for t in 0..s {
                            let src = (b * s + t) * d + off;
                            qh[t * hd..(t + 1) * hd].copy_from_slice(&qv[src..src + hd]);
                            kh[t * hd..(t + 1) * hd].copy_from_slice(&kv[src..src + hd]);
                            vh[t * hd..(t + 1) * hd].copy_from_slice(&vv[src..src + hd]);
                            dyh[t * hd..(t + 1) * hd].copy_from_slice(&cot[src..src + hd]);
                        }
                        let p = &probs[(b * heads + head) * s * s..(b * heads + head + 1) * s * s];
                        // dP = dY @ V^T ; dV = P^T @ dY
                        let dp = matmul_nt(&dyh, &vh, s, hd, s);
                        let dvh = matmul_tn(p, &dyh, s, s, hd);
                        // dS = softmax'(P, dP) * scale
                        let mut ds = softmax_bwd_rows(p, &dp, s, s);
                        for v in ds.iter_mut() {
                            *v *= scale;
                        }
                        // dQ = dS @ K ; dK = dS^T @ Q
                        let dqh = matmul(&ds, &kh, s, s, hd);
                        let dkh = matmul_tn(&ds, &qh, s, s, hd);
                        for t in 0..s {
                            let dst = (b * s + t) * d + off;
                            tensor::axpy(1.0, &dqh[t * hd..(t + 1) * hd], &mut gq[dst..dst + hd]);
                            tensor::axpy(1.0, &dkh[t * hd..(t + 1) * hd], &mut gk[dst..dst + hd]);
                            tensor::axpy(1.0, &dvh[t * hd..(t + 1) * hd], &mut gv[dst..dst + hd]);
                        }
                    }
                }
                acc!(node.inputs[0], gq);
                acc!(node.inputs[1], gk);
                acc!(node.inputs[2], gv);
            }
            OpKind::PatchMerge { side } => {
                let (bsz, dim4) = (node.shape[0], node.shape[2]);
                let dim = dim4 / 4;
                let half = side / 2;
                let mut g = vec![0.0f32; bsz * side * side * dim];
                for b in 0..bsz {
                    for i2 in 0..half {
                        for j2 in 0..half {
                            let o = (b * half * half + i2 * half + j2) * dim4;
                            for (slot, (di, dj)) in
                                [(0, 0), (1, 0), (0, 1), (1, 1)].iter().enumerate()
                            {
                                let dst = (b * side * side
                                    + (2 * i2 + di) * side
                                    + (2 * j2 + dj))
                                    * dim;
                                g[dst..dst + dim]
                                    .copy_from_slice(&cot[o + slot * dim..o + (slot + 1) * dim]);
                            }
                        }
                    }
                }
                acc!(node.inputs[0], g);
            }
            OpKind::TokenPoolCls => {
                let is = &nodes[node.inputs[0]].shape;
                let (bsz, t, dim) = (is[0], is[1], is[2]);
                let mut g = vec![0.0f32; bsz * t * dim];
                for b in 0..bsz {
                    g[b * t * dim..b * t * dim + dim].copy_from_slice(&cot[b * dim..(b + 1) * dim]);
                }
                acc!(node.inputs[0], g);
            }
            OpKind::TokenPoolMean => {
                let is = &nodes[node.inputs[0]].shape;
                let (bsz, t, dim) = (is[0], is[1], is[2]);
                let scale = 1.0 / t as f32;
                let mut g = vec![0.0f32; bsz * t * dim];
                for b in 0..bsz {
                    for tok in 0..t {
                        for j in 0..dim {
                            g[(b * t + tok) * dim + j] = cot[b * dim + j] * scale;
                        }
                    }
                }
                acc!(node.inputs[0], g);
            }
        }
    }

    Ok(RunOut {
        loss,
        metric,
        extra,
        logits: std::mem::take(&mut vals[out_id]),
        grads: Some((grads, qgrads)),
    })
}

type LossOut = (f32, f32, Vec<Vec<f32>>, Option<Vec<f32>>);

/// Softmax cross-entropy over `[B, ncls]` logits; metric = correct count.
fn image_loss(logits: &[f32], shape: &[usize], y: &HostArray, with_grads: bool) -> Result<LossOut> {
    let HostArray::I32(yv) = y else {
        anyhow::bail!("image_cls expects i32 labels")
    };
    let (bsz, ncls) = (shape[0], shape[1]);
    anyhow::ensure!(yv.len() == bsz, "label batch size mismatch");
    let mut probs = logits.to_vec();
    softmax_rows(&mut probs, bsz, ncls);
    let mut loss = 0.0f64;
    let mut correct = 0.0f32;
    for b in 0..bsz {
        let row = &probs[b * ncls..(b + 1) * ncls];
        let label = yv[b] as usize;
        anyhow::ensure!(label < ncls, "label {label} out of range");
        loss -= (row[label].max(1e-12) as f64).ln();
        if argmax(row) == label {
            correct += 1.0;
        }
    }
    let loss = (loss / bsz as f64) as f32;
    let cot = with_grads.then(|| {
        let scale = 1.0 / bsz as f32;
        for b in 0..bsz {
            probs[b * ncls + yv[b] as usize] -= 1.0;
        }
        for v in probs.iter_mut() {
            *v *= scale;
        }
        probs
    });
    Ok((loss, correct, Vec::new(), cot))
}

/// Start+end span cross-entropy over `[B, S, 2]` logits (python
/// `bert_loss`); metric = correct starts + correct ends; eval extras =
/// (pred_start, pred_end).
fn span_loss(logits: &[f32], shape: &[usize], y: &HostArray, with_grads: bool) -> Result<LossOut> {
    let HostArray::I32(yv) = y else {
        anyhow::bail!("span_qa expects i32 labels")
    };
    let (bsz, seq) = (shape[0], shape[1]);
    anyhow::ensure!(shape[2] == 2, "span head emits 2 logit columns");
    anyhow::ensure!(yv.len() == bsz * 2, "span labels are [B, 2]");
    let mut loss = 0.0f64;
    let mut metric = 0.0f32;
    let mut cot = with_grads.then(|| vec![0.0f32; logits.len()]);
    let mut preds: Vec<Vec<f32>> = vec![Vec::with_capacity(bsz), Vec::with_capacity(bsz)];
    for col in 0..2 {
        let mut lg = vec![0.0f32; bsz * seq];
        for b in 0..bsz {
            for s in 0..seq {
                lg[b * seq + s] = logits[(b * seq + s) * 2 + col];
            }
        }
        softmax_rows(&mut lg, bsz, seq);
        for b in 0..bsz {
            let row = &lg[b * seq..(b + 1) * seq];
            let label = yv[b * 2 + col] as usize;
            anyhow::ensure!(label < seq, "span label {label} out of range");
            loss -= (row[label].max(1e-12) as f64).ln() / bsz as f64;
            let am = argmax(row);
            if am == label {
                metric += 1.0;
            }
            preds[col].push(am as f32);
        }
        if let Some(cot) = cot.as_mut() {
            let scale = 1.0 / bsz as f32;
            for b in 0..bsz {
                for s in 0..seq {
                    let mut g = lg[b * seq + s];
                    if s == yv[b * 2 + col] as usize {
                        g -= 1.0;
                    }
                    cot[(b * seq + s) * 2 + col] = g * scale;
                }
            }
        }
    }
    let extra = if with_grads { Vec::new() } else { preds };
    Ok((loss as f32, metric, extra, cot))
}

/// Masked next-token cross-entropy over `[B, S, V]` logits (python
/// `lm_loss`); metric = correct unmasked predictions; eval extra =
/// [mask_count].
fn lm_loss(logits: &[f32], shape: &[usize], y: &HostArray, with_grads: bool) -> Result<LossOut> {
    let HostArray::I32(yv) = y else {
        anyhow::bail!("lm expects i32 labels")
    };
    let (bsz, seq, vocab) = (shape[0], shape[1], shape[2]);
    anyhow::ensure!(yv.len() == bsz * seq, "lm labels are [B, S]");
    let mut probs = logits.to_vec();
    softmax_rows(&mut probs, bsz * seq, vocab);
    let mask_count = yv.iter().filter(|&&t| t >= 0).count();
    let denom = (mask_count as f64).max(1.0);
    let mut loss = 0.0f64;
    let mut metric = 0.0f32;
    for r in 0..bsz * seq {
        let t = yv[r];
        if t < 0 {
            continue;
        }
        let label = t as usize;
        anyhow::ensure!(label < vocab, "lm label {label} out of range");
        let row = &probs[r * vocab..(r + 1) * vocab];
        loss -= (row[label].max(1e-12) as f64).ln();
        if argmax(row) == label {
            metric += 1.0;
        }
    }
    let loss = (loss / denom) as f32;
    let cot = with_grads.then(|| {
        let scale = (1.0 / denom) as f32;
        for r in 0..bsz * seq {
            let row = &mut probs[r * vocab..(r + 1) * vocab];
            let t = yv[r];
            if t < 0 {
                tensor::zero(row);
                continue;
            }
            row[t as usize] -= 1.0;
            for v in row.iter_mut() {
                *v *= scale;
            }
        }
        probs
    });
    let extra = if with_grads {
        Vec::new()
    } else {
        vec![vec![mask_count as f32]]
    };
    Ok((loss, metric, extra, cot))
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for j in 1..row.len() {
        if row[j] > row[best] {
            best = j;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::super::native::NativeEngine;
    use super::super::Backend;
    use crate::data::SynthData;
    use crate::quant::QParams;
    use crate::util::json;

    /// Tiny per-family configs: small enough that central differences over
    /// the full engine are cheap, structurally complete enough to cover
    /// every op the family lowers to.
    fn tiny(family: &str) -> &'static str {
        match family {
            "vgg" => r#"{"name": "t_vgg", "family": "vgg", "task": "image_cls",
                "image": {"size": 8, "channels": 2}, "conv_channels": [4, 4],
                "pool_every": 2, "fc_dims": [6], "num_classes": 3,
                "quant": {"weight": true, "act": true}}"#,
            "resnet" => r#"{"name": "t_res", "family": "resnet", "task": "image_cls",
                "image": {"size": 8, "channels": 2}, "stem_channels": 4,
                "stage_channels": [4, 6], "blocks_per_stage": 1, "num_classes": 3,
                "quant": {"weight": true, "act": false}}"#,
            // span_qa synthesis needs seq_len > 8 (delimiter placement)
            "bert" => r#"{"name": "t_bert", "family": "bert", "task": "span_qa",
                "vocab": 16, "seq_len": 12, "dim": 8, "heads": 2, "blocks": 1,
                "mlp_ratio": 2, "quant": {"weight": true, "act": false}}"#,
            "gpt" => r#"{"name": "t_gpt", "family": "gpt", "task": "lm",
                "vocab": 16, "seq_len": 6, "dim": 8, "heads": 2, "blocks": 1,
                "mlp_ratio": 2, "quant": {"weight": true, "act": false}}"#,
            "vit" => r#"{"name": "t_vit", "family": "vit", "task": "image_cls",
                "image": {"size": 8, "channels": 2}, "dim": 8, "heads": 2,
                "blocks": 1, "mlp_ratio": 2, "patch": 4, "pool": "cls",
                "num_classes": 3, "quant": {"weight": true, "act": false}}"#,
            "vit_mean" => r#"{"name": "t_vitm", "family": "vit", "task": "image_cls",
                "image": {"size": 8, "channels": 2}, "dim": 8, "heads": 2,
                "blocks": 1, "mlp_ratio": 2, "patch": 4, "pool": "mean",
                "num_classes": 3, "quant": {"weight": true, "act": false}}"#,
            "swin" => r#"{"name": "t_swin", "family": "swin", "task": "image_cls",
                "image": {"size": 8, "channels": 2}, "stage_dims": [8, 12],
                "stage_blocks": [1, 1], "heads": 2, "mlp_ratio": 2, "patch": 2,
                "num_classes": 3, "quant": {"weight": true, "act": false}}"#,
            other => panic!("no tiny config for {other}"),
        }
    }

    fn engine(family: &str) -> NativeEngine {
        NativeEngine::from_config(&json::parse(tiny(family)).unwrap()).unwrap()
    }

    fn batch(e: &NativeEngine, seed: u64) -> (super::HostArray, super::HostArray) {
        let m = e.manifest();
        let (train, _) = SynthData::for_model(&m.config, 64, 32, seed);
        let idxs: Vec<usize> = (0..m.batch.batch_size()).collect();
        train.batch(&idxs)
    }

    /// Central-difference check of d(loss)/d(param) across every tensor of
    /// a tiny model. 24-bit quantizers keep the fake-quant staircase far
    /// below the probe step, so the STE gradient is the smooth slope; the
    /// few probes that land inside h of a clip boundary are skipped (the
    /// STE legitimately disagrees there).
    fn fd_check(family: &str, seed: u64) {
        let e = engine(family);
        let params = e.init_params(seed);
        let q = e.init_qparams(&params, 24.0);
        let (x, y) = batch(&e, seed + 1);
        let out = e.train_step(&params, &q, &x, &y).unwrap();
        assert!(out.loss.is_finite(), "{family}: loss {}", out.loss);
        let h = 1e-3f32;
        let mut checked = 0;
        for (ti, t) in params.tensors.iter().enumerate() {
            let site = e
                .manifest()
                .qsites
                .iter()
                .position(|s| s.param.as_deref() == Some(t.name.as_str()));
            for &ei in &[0usize, t.data.len() - 1] {
                if let Some(s) = site {
                    if t.data[ei].abs() + h >= q[s].qm {
                        continue;
                    }
                }
                let mut p1 = params.clone();
                p1.tensors[ti].data[ei] += h;
                let l1 = e.eval_step(&p1, &q, &x, &y).unwrap().loss;
                let mut p2 = params.clone();
                p2.tensors[ti].data[ei] -= h;
                let l2 = e.eval_step(&p2, &q, &x, &y).unwrap().loss;
                let fd = (l1 - l2) / (2.0 * h);
                let an = out.grads.tensors[ti].data[ei];
                assert!(
                    (an - fd).abs() < 0.02 + 0.1 * an.abs().max(fd.abs()),
                    "{family} {}[{ei}]: analytic {an} vs fd {fd}",
                    t.name
                );
                checked += 1;
            }
        }
        assert!(checked >= 12, "{family}: only {checked} probes ran");
    }

    #[test]
    fn vgg_gradients_match_finite_differences() {
        fd_check("vgg", 3);
    }

    #[test]
    fn resnet_gradients_match_finite_differences() {
        fd_check("resnet", 5);
    }

    #[test]
    fn bert_gradients_match_finite_differences() {
        fd_check("bert", 7);
    }

    #[test]
    fn gpt_gradients_match_finite_differences() {
        fd_check("gpt", 9);
    }

    #[test]
    fn vit_gradients_match_finite_differences() {
        fd_check("vit", 11);
        fd_check("vit_mean", 13);
    }

    #[test]
    fn swin_gradients_match_finite_differences() {
        fd_check("swin", 15);
    }

    #[test]
    fn conv_families_sgd_reduces_loss() {
        for family in ["vgg", "resnet", "vit"] {
            let e = engine(family);
            let mut params = e.init_params(0);
            let q = e.init_qparams(&params, 16.0);
            let (x, y) = batch(&e, 21);
            let mut first = None;
            let mut last = 0.0;
            for _ in 0..8 {
                let out = e.train_step(&params, &q, &x, &y).unwrap();
                first.get_or_insert(out.loss);
                last = out.loss;
                for (ti, t) in out.grads.tensors.iter().enumerate() {
                    for (i, g) in t.data.iter().enumerate() {
                        params.tensors[ti].data[i] -= 0.05 * g;
                    }
                }
            }
            assert!(last < first.unwrap(), "{family}: {first:?} -> {last}");
        }
    }

    #[test]
    fn quant_sites_are_live_on_conv_and_attention_families() {
        for family in ["vgg", "resnet", "bert", "vit", "swin"] {
            let e = engine(family);
            let params = e.init_params(1);
            // coarse quantizer => large rounding residuals => live d-grads
            let q = e.init_qparams(&params, 4.0);
            let (x, y) = batch(&e, 31);
            let out = e.train_step(&params, &q, &x, &y).unwrap();
            assert_eq!(out.qgrads.len(), e.manifest().qsites.len(), "{family}");
            let live = out
                .qgrads
                .iter()
                .any(|g| g.0.abs() + g.1.abs() + g.2.abs() > 0.0);
            assert!(live, "{family}: all quant-param gradients zero");
            // bits must change the loss
            let hi = e.init_qparams(&params, 16.0);
            let l_hi = e.eval_step(&params, &hi, &x, &y).unwrap().loss;
            let l_lo = e.eval_step(&params, &q, &x, &y).unwrap().loss;
            assert!((l_hi - l_lo).abs() > 1e-7, "{family}: {l_hi} vs {l_lo}");
        }
    }

    #[test]
    fn span_and_lm_heads_emit_eval_extras() {
        let e = engine("bert");
        let params = e.init_params(2);
        let q = e.init_qparams(&params, 8.0);
        let (x, y) = batch(&e, 41);
        let ev = e.eval_step(&params, &q, &x, &y).unwrap();
        assert_eq!(ev.extra.len(), 2); // pred_start, pred_end
        let bsz = e.manifest().batch.batch_size();
        let seq = e.manifest().config.usize_or("seq_len", 32) as f32;
        assert_eq!(ev.extra[0].len(), bsz);
        assert!(ev.extra[0].iter().all(|&p| p >= 0.0 && p < seq));

        let e = engine("gpt");
        let params = e.init_params(2);
        let q = e.init_qparams(&params, 8.0);
        let (x, y) = batch(&e, 43);
        let ev = e.eval_step(&params, &q, &x, &y).unwrap();
        assert_eq!(ev.extra.len(), 1); // mask_count
        let bsz = e.manifest().batch.batch_size();
        let seq = e.manifest().config.usize_or("seq_len", 32);
        assert_eq!(ev.extra[0][0], (bsz * (seq - 1)) as f32);
    }

    #[test]
    fn eval_is_deterministic_across_families() {
        for family in ["resnet", "bert", "swin"] {
            let e = engine(family);
            let params = e.init_params(6);
            let q = e.init_qparams(&params, 8.0);
            let (x, y) = batch(&e, 51);
            let a = e.eval_step(&params, &q, &x, &y).unwrap();
            let b = e.eval_step(&params, &q, &x, &y).unwrap();
            assert_eq!(a.loss, b.loss, "{family}");
            assert_eq!(a.metric, b.metric, "{family}");
        }
    }

    #[test]
    fn degenerate_qparams_keep_losses_finite() {
        for family in ["vgg", "bert"] {
            let e = engine(family);
            let params = e.init_params(4);
            let (x, y) = batch(&e, 61);
            for q in [
                QParams { d: 1e-8, t: 1.0, qm: 1.0 },
                QParams { d: 10.0, t: 1.0, qm: 1e-3 },
                QParams { d: 0.1, t: 2.0, qm: 4.0 },
            ] {
                let qs = vec![q; e.manifest().qsites.len()];
                let out = e.eval_step(&params, &qs, &x, &y).unwrap();
                assert!(out.loss.is_finite(), "{family} {q:?}");
            }
        }
    }
}
