//! Training interpreter for lowered programs: loss heads + backward over
//! the shared planned executor (`runtime/exec.rs`).
//!
//! The forward pass is [`exec::forward`] with a [`exec::TrainParams`]
//! source — the same core the deployment engine runs — so training and
//! serving can never drift apart op-by-op. This module owns what is
//! training-specific: the task loss heads (one shared softmax
//! cross-entropy core under image/span/lm), and the backward pass
//! producing clipped-STE parameter gradients plus the eq. (4)-(6) scalar
//! (d, t, q_m) gradients per site.
//!
//! The contract matches the PJRT engine exactly: weights are fake-quantized
//! at their sites on the forward pass, activation sites quantize in place,
//! losses are the zoo's task heads: softmax cross-entropy (image_cls),
//! start+end span cross-entropy (span_qa, python `bert_loss`) and masked
//! next-token cross-entropy (lm, python `lm_loss`).
//!
//! Numeric conventions: f32 storage, f64 accumulation in every contraction
//! (see `tensor/ops.rs` — tiled, multi-threaded, bitwise invariant across
//! thread counts), so results are deterministic and stable at the im2col
//! row counts the conv families produce.

use anyhow::{Context, Result};

use super::exec::{self, Arena, Aux, Plan};
use super::lowering::{OpKind, Program};
use super::HostArray;
use crate::quant::{self, QParams};
use crate::tensor::{
    self, batchnorm_bwd_rows, col2im_into, gelu_grad, im2col_into, layernorm_bwd_rows,
    matmul_into, matmul_nt_into, matmul_tn_into, softmax_bwd_rows, softmax_rows, ParamStore,
};

/// Everything one interpreter pass produces. `grads` is present only for
/// training passes; `extra` only for eval passes (task-dependent outputs
/// after loss+metric, in manifest `eval_outputs` order). `logits` is the
/// output node's raw values — the deployment path's parity reference
/// (compressed-engine output must match these on the masked model).
pub struct RunOut {
    pub loss: f32,
    pub metric: f32,
    pub extra: Vec<Vec<f32>>,
    pub logits: Vec<f32>,
    pub grads: Option<(ParamStore, Vec<(f32, f32, f32)>)>,
}

fn tensor_data<'a>(params: &'a ParamStore, name: &str) -> Result<&'a [f32]> {
    params
        .get(name)
        .map(|t| t.data.as_slice())
        .with_context(|| format!("missing parameter `{name}`"))
}

/// Accumulate eq. (4)-(6) site gradients from `values` (the quantizer
/// inputs) against `g` (the cotangent of the quantizer output), then apply
/// the clipped STE: zero the pass-through gradient outside the clip range.
fn ste_site_backward(values: &[f32], g: &mut [f32], qp: &QParams, acc: &mut (f32, f32, f32)) {
    debug_assert_eq!(values.len(), g.len());
    let (mut gd, mut gt, mut gqm) = (0.0f64, 0.0f64, 0.0f64);
    for (i, &v) in values.iter().enumerate() {
        let gi = g[i];
        gd += (gi * quant::grad_d(v, qp)) as f64;
        gt += (gi * quant::grad_t(v, qp)) as f64;
        gqm += (gi * quant::grad_qm(v, qp)) as f64;
        if v.abs() > qp.qm {
            g[i] = 0.0;
        }
    }
    acc.0 += gd as f32;
    acc.1 += gt as f32;
    acc.2 += gqm as f32;
}

/// Execute one batch through `prog` over `plan`-resolved shapes. `n_sites`
/// sizes the qgrad vector (= manifest qsites count; every node site index
/// lies below it). `arena` supplies the reusable forward/scratch buffers —
/// pass the same arena every step and the hot loop stops allocating.
#[allow(clippy::too_many_arguments)]
pub fn run(
    prog: &Program,
    plan: &Plan,
    n_sites: usize,
    params: &ParamStore,
    q: &[QParams],
    x: &HostArray,
    y: &HostArray,
    with_grads: bool,
    arena: &mut Arena,
) -> Result<RunOut> {
    anyhow::ensure!(q.len() == n_sites, "qparam count mismatch: {} vs {n_sites}", q.len());
    let nodes = &prog.nodes;
    let input = match x {
        HostArray::F32(v) => exec::Input::F32(v),
        HostArray::I32(v) => exec::Input::I32(v),
    };
    let src = exec::TrainParams { params, q };

    // ------------------------------------------------------------ forward
    let (mut vals, aux) = {
        let _fwd = crate::obs::span("train", "forward");
        exec::forward(prog, plan, &src, &input, with_grads, arena)?
    };

    let xi32: Option<&Vec<i32>> = match x {
        HostArray::I32(v) => Some(v),
        HostArray::F32(_) => None,
    };

    // --------------------------------------------------------- loss heads
    let out_id = prog.output();
    let out_shape = &plan.shapes[out_id];
    let loss_span = crate::obs::span("train", "loss");
    let (loss, metric, extra, mut out_cot) = match prog.task.as_str() {
        "image_cls" => image_loss(&vals[out_id], out_shape, y, with_grads)?,
        "span_qa" => span_loss(&vals[out_id], out_shape, y, with_grads)?,
        "lm" => lm_loss(&vals[out_id], out_shape, y, with_grads)?,
        other => anyhow::bail!("unknown task `{other}`"),
    };
    drop(loss_span);
    if !with_grads {
        let logits = std::mem::take(&mut vals[out_id]);
        arena.reclaim_all(vals);
        return Ok(RunOut {
            loss,
            metric,
            extra,
            logits,
            grads: None,
        });
    }

    // ----------------------------------------------------------- backward
    let mut grads = params.zeros_like();
    let mut qgrads = vec![(0.0f32, 0.0f32, 0.0f32); n_sites];
    let mut cots: Vec<Vec<f32>> = (0..nodes.len()).map(|_| Vec::new()).collect();
    cots[out_id] = out_cot.take().expect("training pass produced a cotangent");

    let trace_on = crate::obs::enabled();
    let bwd_span = crate::obs::span("train", "backward");
    for i in (0..nodes.len()).rev() {
        let cot = std::mem::take(&mut cots[i]);
        if cot.is_empty() {
            continue;
        }
        let node = &nodes[i];
        let t0 = if trace_on { Some(std::time::Instant::now()) } else { None };
        // accumulate into an input's cotangent buffer
        macro_rules! acc {
            ($j:expr, $g:expr) => {{
                let j: usize = $j;
                let g: Vec<f32> = $g;
                if cots[j].is_empty() {
                    cots[j] = g;
                } else {
                    tensor::axpy(1.0, &g, &mut cots[j]);
                    arena.reclaim(g);
                }
            }};
        }
        match &node.op {
            OpKind::Input => {}
            OpKind::Embed { tok, pos } => {
                let toks = xi32.context("token task expects i32 inputs")?;
                let sh = &plan.shapes[i];
                let (bsz, seq, dim) = (sh[0], sh[1], sh[2]);
                let gtok = &mut grads
                    .get_mut(tok)
                    .with_context(|| format!("grad store missing {tok}"))?
                    .data;
                for b in 0..bsz {
                    for s in 0..seq {
                        let id = toks[b * seq + s] as usize;
                        tensor::axpy(
                            1.0,
                            &cot[(b * seq + s) * dim..(b * seq + s + 1) * dim],
                            &mut gtok[id * dim..(id + 1) * dim],
                        );
                    }
                }
                let gpos = &mut grads
                    .get_mut(pos)
                    .with_context(|| format!("grad store missing {pos}"))?
                    .data;
                for b in 0..bsz {
                    tensor::axpy(1.0, &cot[b * seq * dim..(b + 1) * seq * dim], gpos);
                }
            }
            OpKind::Linear { w, site } => {
                let Aux::W(wqo) = &aux[i] else { unreachable!() };
                let raw = tensor_data(params, &format!("{w}.weight"))?;
                let wq: &[f32] = wqo.as_deref().unwrap_or(raw);
                let din = *plan.shapes[node.inputs[0]].last().unwrap();
                let dout = *plan.shapes[i].last().unwrap();
                let rows = cot.len() / dout;
                let xin = &vals[node.inputs[0]];
                let mut gw = arena.alloc_uninit(din * dout);
                matmul_tn_into(&mut gw, xin, &cot, rows, din, dout);
                if let Some(s) = site {
                    ste_site_backward(raw, &mut gw, &q[*s], &mut qgrads[*s]);
                }
                tensor::axpy(
                    1.0,
                    &gw,
                    &mut grads
                        .get_mut(&format!("{w}.weight"))
                        .with_context(|| format!("grad store missing {w}.weight"))?
                        .data,
                );
                arena.reclaim(gw);
                let gb = &mut grads
                    .get_mut(&format!("{w}.bias"))
                    .with_context(|| format!("grad store missing {w}.bias"))?
                    .data;
                for r in 0..rows {
                    tensor::axpy(1.0, &cot[r * dout..(r + 1) * dout], gb);
                }
                let mut gx = arena.alloc_uninit(rows * din);
                matmul_nt_into(&mut gx, &cot, wq, rows, dout, din);
                acc!(node.inputs[0], gx);
                arena.reclaim(cot);
            }
            OpKind::Conv2d { w, site, k, stride, pad } => {
                let Aux::W(wqo) = &aux[i] else { unreachable!() };
                let raw = tensor_data(params, &format!("{w}.weight"))?;
                let wq: &[f32] = wqo.as_deref().unwrap_or(raw);
                let is = &plan.shapes[node.inputs[0]];
                let (bsz, h, wd, cin) = (is[0], is[1], is[2], is[3]);
                let sh = &plan.shapes[i];
                let (ho, wo, cout) = (sh[1], sh[2], sh[3]);
                let rows = bsz * ho * wo;
                let kkc = k * k * cin;
                // cols are recomputed rather than kept from the forward:
                // one im2col is far cheaper than holding every conv's
                // column matrix across the whole step
                let mut cols = arena.alloc_uninit(plan.col_sizes[i]);
                im2col_into(
                    &mut cols,
                    &vals[node.inputs[0]],
                    bsz,
                    h,
                    wd,
                    cin,
                    *k,
                    *stride,
                    *pad,
                    ho,
                    wo,
                );
                let mut gw = arena.alloc_uninit(kkc * cout);
                matmul_tn_into(&mut gw, &cols, &cot, rows, kkc, cout);
                arena.reclaim(cols);
                if let Some(s) = site {
                    ste_site_backward(raw, &mut gw, &q[*s], &mut qgrads[*s]);
                }
                tensor::axpy(
                    1.0,
                    &gw,
                    &mut grads
                        .get_mut(&format!("{w}.weight"))
                        .with_context(|| format!("grad store missing {w}.weight"))?
                        .data,
                );
                arena.reclaim(gw);
                let gb = &mut grads
                    .get_mut(&format!("{w}.bias"))
                    .with_context(|| format!("grad store missing {w}.bias"))?
                    .data;
                for r in 0..rows {
                    tensor::axpy(1.0, &cot[r * cout..(r + 1) * cout], gb);
                }
                let mut gcols = arena.alloc_uninit(rows * kkc);
                matmul_nt_into(&mut gcols, &cot, wq, rows, cout, kkc);
                let mut gx = arena.alloc_uninit(bsz * h * wd * cin);
                col2im_into(&mut gx, &gcols, bsz, h, wd, cin, *k, *stride, *pad, ho, wo);
                acc!(node.inputs[0], gx);
                arena.reclaim(gcols);
                arena.reclaim(cot);
            }
            OpKind::BatchNorm { p } | OpKind::LayerNorm { p } => {
                let Aux::Norm(na) = &aux[i] else { unreachable!() };
                let gamma = tensor_data(params, &format!("{p}.gamma"))?;
                let c = *plan.shapes[i].last().unwrap();
                let rows = cot.len() / c;
                let (gx, gg, gb) = if matches!(node.op, OpKind::BatchNorm { .. }) {
                    batchnorm_bwd_rows(gamma, &cot, na, rows, c)
                } else {
                    layernorm_bwd_rows(gamma, &cot, na, rows, c)
                };
                tensor::axpy(
                    1.0,
                    &gg,
                    &mut grads
                        .get_mut(&format!("{p}.gamma"))
                        .with_context(|| format!("grad store missing {p}.gamma"))?
                        .data,
                );
                tensor::axpy(
                    1.0,
                    &gb,
                    &mut grads
                        .get_mut(&format!("{p}.beta"))
                        .with_context(|| format!("grad store missing {p}.beta"))?
                        .data,
                );
                acc!(node.inputs[0], gx);
                arena.reclaim(cot);
            }
            OpKind::Relu => {
                let mut g = cot;
                for (gi, &xi) in g.iter_mut().zip(&vals[node.inputs[0]]) {
                    if xi <= 0.0 {
                        *gi = 0.0;
                    }
                }
                acc!(node.inputs[0], g);
            }
            OpKind::Gelu => {
                let mut g = cot;
                for (gi, &xi) in g.iter_mut().zip(&vals[node.inputs[0]]) {
                    *gi *= gelu_grad(xi);
                }
                acc!(node.inputs[0], g);
            }
            OpKind::ActQuant { site } => {
                let mut g = cot;
                ste_site_backward(&vals[node.inputs[0]], &mut g, &q[*site], &mut qgrads[*site]);
                acc!(node.inputs[0], g);
            }
            OpKind::Add => {
                let mut g = arena.alloc_uninit(cot.len());
                g.copy_from_slice(&cot);
                acc!(node.inputs[0], g);
                acc!(node.inputs[1], cot);
            }
            OpKind::MaxPool2 => {
                let Aux::Pool(arg) = &aux[i] else { unreachable!() };
                let mut g = arena.alloc(vals[node.inputs[0]].len());
                for (o, &src_i) in arg.iter().enumerate() {
                    g[src_i] += cot[o];
                }
                acc!(node.inputs[0], g);
                arena.reclaim(cot);
            }
            OpKind::GlobalAvgPool => {
                let is = &plan.shapes[node.inputs[0]];
                let (bsz, h, wd, c) = (is[0], is[1], is[2], is[3]);
                let scale = 1.0 / (h * wd) as f32;
                let mut g = arena.alloc(bsz * h * wd * c);
                for b in 0..bsz {
                    for pix in 0..h * wd {
                        for ch in 0..c {
                            g[(b * h * wd + pix) * c + ch] = cot[b * c + ch] * scale;
                        }
                    }
                }
                acc!(node.inputs[0], g);
                arena.reclaim(cot);
            }
            OpKind::Reshape => {
                acc!(node.inputs[0], cot);
            }
            OpKind::ConcatCls { cls } => {
                let sh = &plan.shapes[i];
                let (bsz, t1, dim) = (sh[0], sh[1], sh[2]);
                let gcls = &mut grads
                    .get_mut(cls)
                    .with_context(|| format!("grad store missing {cls}"))?
                    .data;
                let mut g = arena.alloc(bsz * (t1 - 1) * dim);
                for b in 0..bsz {
                    tensor::axpy(1.0, &cot[b * t1 * dim..b * t1 * dim + dim], gcls);
                    g[b * (t1 - 1) * dim..(b + 1) * (t1 - 1) * dim]
                        .copy_from_slice(&cot[b * t1 * dim + dim..(b + 1) * t1 * dim]);
                }
                acc!(node.inputs[0], g);
                arena.reclaim(cot);
            }
            OpKind::AddPos { pos } => {
                let bsz = plan.shapes[i][0];
                let rest = cot.len() / bsz;
                let gpos = &mut grads
                    .get_mut(pos)
                    .with_context(|| format!("grad store missing {pos}"))?
                    .data;
                for b in 0..bsz {
                    tensor::axpy(1.0, &cot[b * rest..(b + 1) * rest], gpos);
                }
                acc!(node.inputs[0], cot);
            }
            OpKind::Attention { heads, .. } => {
                let Aux::Att(probs) = &aux[i] else { unreachable!() };
                let sh = &plan.shapes[i];
                let (bsz, s, d) = (sh[0], sh[1], sh[2]);
                let hd = d / heads;
                let scale = 1.0 / (hd as f32).sqrt();
                let (qv, kv, vv) = (
                    &vals[node.inputs[0]],
                    &vals[node.inputs[1]],
                    &vals[node.inputs[2]],
                );
                let mut gq = arena.alloc(qv.len());
                let mut gk = arena.alloc(kv.len());
                let mut gv = arena.alloc(vv.len());
                // per-head scratch: allocated once per node, fully
                // overwritten each head by the *_into kernels
                let mut qh = arena.alloc_uninit(s * hd);
                let mut kh = arena.alloc_uninit(s * hd);
                let mut vh = arena.alloc_uninit(s * hd);
                let mut dyh = arena.alloc_uninit(s * hd);
                let mut dp = arena.alloc_uninit(s * s);
                let mut dvh = arena.alloc_uninit(s * hd);
                let mut dqh = arena.alloc_uninit(s * hd);
                let mut dkh = arena.alloc_uninit(s * hd);
                for b in 0..bsz {
                    for head in 0..*heads {
                        let off = head * hd;
                        for t in 0..s {
                            let src_i = (b * s + t) * d + off;
                            qh[t * hd..(t + 1) * hd].copy_from_slice(&qv[src_i..src_i + hd]);
                            kh[t * hd..(t + 1) * hd].copy_from_slice(&kv[src_i..src_i + hd]);
                            vh[t * hd..(t + 1) * hd].copy_from_slice(&vv[src_i..src_i + hd]);
                            dyh[t * hd..(t + 1) * hd].copy_from_slice(&cot[src_i..src_i + hd]);
                        }
                        let p = &probs[(b * heads + head) * s * s..(b * heads + head + 1) * s * s];
                        // dP = dY @ V^T ; dV = P^T @ dY
                        matmul_nt_into(&mut dp, &dyh, &vh, s, hd, s);
                        matmul_tn_into(&mut dvh, p, &dyh, s, s, hd);
                        // dS = softmax'(P, dP) * scale
                        let mut ds = softmax_bwd_rows(p, &dp, s, s);
                        for v in ds.iter_mut() {
                            *v *= scale;
                        }
                        // dQ = dS @ K ; dK = dS^T @ Q
                        matmul_into(&mut dqh, &ds, &kh, s, s, hd);
                        matmul_tn_into(&mut dkh, &ds, &qh, s, s, hd);
                        arena.reclaim(ds);
                        for t in 0..s {
                            let dst = (b * s + t) * d + off;
                            tensor::axpy(1.0, &dqh[t * hd..(t + 1) * hd], &mut gq[dst..dst + hd]);
                            tensor::axpy(1.0, &dkh[t * hd..(t + 1) * hd], &mut gk[dst..dst + hd]);
                            tensor::axpy(1.0, &dvh[t * hd..(t + 1) * hd], &mut gv[dst..dst + hd]);
                        }
                    }
                }
                arena.reclaim_all([qh, kh, vh, dyh, dp, dvh, dqh, dkh]);
                acc!(node.inputs[0], gq);
                acc!(node.inputs[1], gk);
                acc!(node.inputs[2], gv);
                arena.reclaim(cot);
            }
            OpKind::PatchMerge { side } => {
                let sh = &plan.shapes[i];
                let (bsz, dim4) = (sh[0], sh[2]);
                let dim = dim4 / 4;
                let half = side / 2;
                let mut g = arena.alloc(bsz * side * side * dim);
                for b in 0..bsz {
                    for i2 in 0..half {
                        for j2 in 0..half {
                            let o = (b * half * half + i2 * half + j2) * dim4;
                            for (slot, (di, dj)) in
                                [(0, 0), (1, 0), (0, 1), (1, 1)].iter().enumerate()
                            {
                                let dst = (b * side * side
                                    + (2 * i2 + di) * side
                                    + (2 * j2 + dj))
                                    * dim;
                                g[dst..dst + dim]
                                    .copy_from_slice(&cot[o + slot * dim..o + (slot + 1) * dim]);
                            }
                        }
                    }
                }
                acc!(node.inputs[0], g);
                arena.reclaim(cot);
            }
            OpKind::TokenPoolCls => {
                let is = &plan.shapes[node.inputs[0]];
                let (bsz, t, dim) = (is[0], is[1], is[2]);
                let mut g = arena.alloc(bsz * t * dim);
                for b in 0..bsz {
                    g[b * t * dim..b * t * dim + dim].copy_from_slice(&cot[b * dim..(b + 1) * dim]);
                }
                acc!(node.inputs[0], g);
                arena.reclaim(cot);
            }
            OpKind::TokenPoolMean => {
                let is = &plan.shapes[node.inputs[0]];
                let (bsz, t, dim) = (is[0], is[1], is[2]);
                let scale = 1.0 / t as f32;
                let mut g = arena.alloc(bsz * t * dim);
                for b in 0..bsz {
                    for tok in 0..t {
                        for j in 0..dim {
                            g[(b * t + tok) * dim + j] = cot[b * dim + j] * scale;
                        }
                    }
                }
                acc!(node.inputs[0], g);
                arena.reclaim(cot);
            }
        }
        if let Some(t0) = t0 {
            crate::obs::trace::record("bwd", node.op.label().to_string(), t0);
        }
    }
    drop(bwd_span);

    let logits = std::mem::take(&mut vals[out_id]);
    arena.reclaim_all(vals);
    for ax in aux {
        exec::reclaim_aux(arena, ax);
    }

    Ok(RunOut {
        loss,
        metric,
        extra,
        logits,
        grads: Some((grads, qgrads)),
    })
}

type LossOut = (f32, f32, Vec<Vec<f32>>, Option<Vec<f32>>);

/// Shared softmax-cross-entropy core over flat `[rows, n]` logits with one
/// i32 label per row (negative = masked out of loss and metric). Returns
/// (summed loss over unmasked rows, correct count, per-row argmax, and —
/// when `with_grad` — the **unscaled** cotangent `softmax(row) -
/// onehot(label)`, zeroed on masked rows). Callers apply their own
/// 1/denominator scale; this is the one place the softmax + log +
/// argmax + one-hot-subtract math lives for all three task heads.
fn softmax_xent_rows(
    logits: &[f32],
    rows: usize,
    n: usize,
    labels: &[i32],
    with_grad: bool,
) -> Result<(f64, f32, Vec<u32>, Option<Vec<f32>>)> {
    assert_eq!(logits.len(), rows * n);
    anyhow::ensure!(labels.len() == rows, "label count mismatch: {} vs {rows}", labels.len());
    let mut probs = logits.to_vec();
    softmax_rows(&mut probs, rows, n);
    let mut loss = 0.0f64;
    let mut correct = 0.0f32;
    let mut amax = vec![0u32; rows];
    for r in 0..rows {
        let row = &probs[r * n..(r + 1) * n];
        let am = argmax(row);
        amax[r] = am as u32;
        let t = labels[r];
        if t < 0 {
            continue;
        }
        let label = t as usize;
        anyhow::ensure!(label < n, "label {label} out of range (n = {n})");
        loss -= (row[label].max(1e-12) as f64).ln();
        if am == label {
            correct += 1.0;
        }
    }
    let cot = with_grad.then(|| {
        for r in 0..rows {
            let row = &mut probs[r * n..(r + 1) * n];
            let t = labels[r];
            if t < 0 {
                tensor::zero(row);
                continue;
            }
            row[t as usize] -= 1.0;
        }
        probs
    });
    Ok((loss, correct, amax, cot))
}

/// Softmax cross-entropy over `[B, ncls]` logits; metric = correct count.
fn image_loss(logits: &[f32], shape: &[usize], y: &HostArray, with_grads: bool) -> Result<LossOut> {
    let HostArray::I32(yv) = y else {
        anyhow::bail!("image_cls expects i32 labels")
    };
    let (bsz, ncls) = (shape[0], shape[1]);
    anyhow::ensure!(yv.len() == bsz, "label batch size mismatch");
    for &l in yv {
        // negative would silently mask the row in the shared core
        anyhow::ensure!(l >= 0, "image label {l} negative");
    }
    let (loss, correct, _amax, mut cot) = softmax_xent_rows(logits, bsz, ncls, yv, with_grads)?;
    if let Some(c) = cot.as_mut() {
        let scale = 1.0 / bsz as f32;
        for v in c.iter_mut() {
            *v *= scale;
        }
    }
    Ok(((loss / bsz as f64) as f32, correct, Vec::new(), cot))
}

/// Start+end span cross-entropy over `[B, S, 2]` logits (python
/// `bert_loss`); metric = correct starts + correct ends; eval extras =
/// (pred_start, pred_end). Each logit column is one `[B, S]` problem for
/// the shared core; the cotangent is scattered back to the interleaved
/// layout.
fn span_loss(logits: &[f32], shape: &[usize], y: &HostArray, with_grads: bool) -> Result<LossOut> {
    let HostArray::I32(yv) = y else {
        anyhow::bail!("span_qa expects i32 labels")
    };
    let (bsz, seq) = (shape[0], shape[1]);
    anyhow::ensure!(shape[2] == 2, "span head emits 2 logit columns");
    anyhow::ensure!(yv.len() == bsz * 2, "span labels are [B, 2]");
    let mut loss = 0.0f64;
    let mut metric = 0.0f32;
    let mut cot = with_grads.then(|| vec![0.0f32; logits.len()]);
    let mut preds: Vec<Vec<f32>> = vec![Vec::with_capacity(bsz), Vec::with_capacity(bsz)];
    for col in 0..2 {
        let mut lg = vec![0.0f32; bsz * seq];
        for b in 0..bsz {
            for s in 0..seq {
                lg[b * seq + s] = logits[(b * seq + s) * 2 + col];
            }
        }
        let labels: Vec<i32> = (0..bsz).map(|b| yv[b * 2 + col]).collect();
        for &l in &labels {
            // negative would silently mask the row in the shared core
            anyhow::ensure!(l >= 0, "span label {l} negative");
        }
        let (lsum, correct, amax, ccol) = softmax_xent_rows(&lg, bsz, seq, &labels, with_grads)?;
        loss += lsum / bsz as f64;
        metric += correct;
        preds[col].extend(amax.iter().map(|&a| a as f32));
        if let (Some(cot), Some(ccol)) = (cot.as_mut(), ccol) {
            let scale = 1.0 / bsz as f32;
            for b in 0..bsz {
                for s in 0..seq {
                    cot[(b * seq + s) * 2 + col] = ccol[b * seq + s] * scale;
                }
            }
        }
    }
    let extra = if with_grads { Vec::new() } else { preds };
    Ok((loss as f32, metric, extra, cot))
}

/// Masked next-token cross-entropy over `[B, S, V]` logits (python
/// `lm_loss`); metric = correct unmasked predictions; eval extra =
/// [mask_count]. Masking (label < 0) is handled inside the shared core.
fn lm_loss(logits: &[f32], shape: &[usize], y: &HostArray, with_grads: bool) -> Result<LossOut> {
    let HostArray::I32(yv) = y else {
        anyhow::bail!("lm expects i32 labels")
    };
    let (bsz, seq, vocab) = (shape[0], shape[1], shape[2]);
    anyhow::ensure!(yv.len() == bsz * seq, "lm labels are [B, S]");
    let mask_count = yv.iter().filter(|&&t| t >= 0).count();
    let denom = (mask_count as f64).max(1.0);
    let (lsum, metric, _amax, mut cot) =
        softmax_xent_rows(logits, bsz * seq, vocab, yv, with_grads)?;
    if let Some(c) = cot.as_mut() {
        let scale = (1.0 / denom) as f32;
        for v in c.iter_mut() {
            *v *= scale;
        }
    }
    let extra = if with_grads {
        Vec::new()
    } else {
        vec![vec![mask_count as f32]]
    };
    Ok(((lsum / denom) as f32, metric, extra, cot))
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for j in 1..row.len() {
        if row[j] > row[best] {
            best = j;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::super::native::NativeEngine;
    use super::super::Backend;
    use crate::data::SynthData;
    use crate::quant::QParams;
    use crate::util::json;

    /// Tiny per-family configs: small enough that central differences over
    /// the full engine are cheap, structurally complete enough to cover
    /// every op the family lowers to.
    fn tiny(family: &str) -> &'static str {
        match family {
            "vgg" => r#"{"name": "t_vgg", "family": "vgg", "task": "image_cls",
                "image": {"size": 8, "channels": 2}, "conv_channels": [4, 4],
                "pool_every": 2, "fc_dims": [6], "num_classes": 3,
                "quant": {"weight": true, "act": true}}"#,
            "resnet" => r#"{"name": "t_res", "family": "resnet", "task": "image_cls",
                "image": {"size": 8, "channels": 2}, "stem_channels": 4,
                "stage_channels": [4, 6], "blocks_per_stage": 1, "num_classes": 3,
                "quant": {"weight": true, "act": false}}"#,
            // span_qa synthesis needs seq_len > 8 (delimiter placement)
            "bert" => r#"{"name": "t_bert", "family": "bert", "task": "span_qa",
                "vocab": 16, "seq_len": 12, "dim": 8, "heads": 2, "blocks": 1,
                "mlp_ratio": 2, "quant": {"weight": true, "act": false}}"#,
            "gpt" => r#"{"name": "t_gpt", "family": "gpt", "task": "lm",
                "vocab": 16, "seq_len": 6, "dim": 8, "heads": 2, "blocks": 1,
                "mlp_ratio": 2, "quant": {"weight": true, "act": false}}"#,
            "vit" => r#"{"name": "t_vit", "family": "vit", "task": "image_cls",
                "image": {"size": 8, "channels": 2}, "dim": 8, "heads": 2,
                "blocks": 1, "mlp_ratio": 2, "patch": 4, "pool": "cls",
                "num_classes": 3, "quant": {"weight": true, "act": false}}"#,
            "vit_mean" => r#"{"name": "t_vitm", "family": "vit", "task": "image_cls",
                "image": {"size": 8, "channels": 2}, "dim": 8, "heads": 2,
                "blocks": 1, "mlp_ratio": 2, "patch": 4, "pool": "mean",
                "num_classes": 3, "quant": {"weight": true, "act": false}}"#,
            "swin" => r#"{"name": "t_swin", "family": "swin", "task": "image_cls",
                "image": {"size": 8, "channels": 2}, "stage_dims": [8, 12],
                "stage_blocks": [1, 1], "heads": 2, "mlp_ratio": 2, "patch": 2,
                "num_classes": 3, "quant": {"weight": true, "act": false}}"#,
            other => panic!("no tiny config for {other}"),
        }
    }

    fn engine(family: &str) -> NativeEngine {
        NativeEngine::from_config(&json::parse(tiny(family)).unwrap()).unwrap()
    }

    fn batch(e: &NativeEngine, seed: u64) -> (super::HostArray, super::HostArray) {
        let m = e.manifest();
        let (train, _) = SynthData::for_model(&m.config, 64, 32, seed);
        let idxs: Vec<usize> = (0..m.batch.batch_size()).collect();
        train.batch(&idxs)
    }

    /// Central-difference check of d(loss)/d(param) across every tensor of
    /// a tiny model. 24-bit quantizers keep the fake-quant staircase far
    /// below the probe step, so the STE gradient is the smooth slope; the
    /// few probes that land inside h of a clip boundary are skipped (the
    /// STE legitimately disagrees there).
    fn fd_check(family: &str, seed: u64) {
        let e = engine(family);
        let params = e.init_params(seed);
        let q = e.init_qparams(&params, 24.0);
        let (x, y) = batch(&e, seed + 1);
        let out = e.train_step(&params, &q, &x, &y).unwrap();
        assert!(out.loss.is_finite(), "{family}: loss {}", out.loss);
        let h = 1e-3f32;
        let mut checked = 0;
        for (ti, t) in params.tensors.iter().enumerate() {
            let site = e
                .manifest()
                .qsites
                .iter()
                .position(|s| s.param.as_deref() == Some(t.name.as_str()));
            for &ei in &[0usize, t.data.len() - 1] {
                if let Some(s) = site {
                    if t.data[ei].abs() + h >= q[s].qm {
                        continue;
                    }
                }
                let mut p1 = params.clone();
                p1.tensors[ti].data[ei] += h;
                let l1 = e.eval_step(&p1, &q, &x, &y).unwrap().loss;
                let mut p2 = params.clone();
                p2.tensors[ti].data[ei] -= h;
                let l2 = e.eval_step(&p2, &q, &x, &y).unwrap().loss;
                let fd = (l1 - l2) / (2.0 * h);
                let an = out.grads.tensors[ti].data[ei];
                assert!(
                    (an - fd).abs() < 0.02 + 0.1 * an.abs().max(fd.abs()),
                    "{family} {}[{ei}]: analytic {an} vs fd {fd}",
                    t.name
                );
                checked += 1;
            }
        }
        assert!(checked >= 12, "{family}: only {checked} probes ran");
    }

    #[test]
    fn vgg_gradients_match_finite_differences() {
        fd_check("vgg", 3);
    }

    #[test]
    fn resnet_gradients_match_finite_differences() {
        fd_check("resnet", 5);
    }

    #[test]
    fn bert_gradients_match_finite_differences() {
        fd_check("bert", 7);
    }

    #[test]
    fn gpt_gradients_match_finite_differences() {
        fd_check("gpt", 9);
    }

    #[test]
    fn vit_gradients_match_finite_differences() {
        fd_check("vit", 11);
        fd_check("vit_mean", 13);
    }

    #[test]
    fn swin_gradients_match_finite_differences() {
        fd_check("swin", 15);
    }

    #[test]
    fn conv_families_sgd_reduces_loss() {
        for family in ["vgg", "resnet", "vit"] {
            let e = engine(family);
            let mut params = e.init_params(0);
            let q = e.init_qparams(&params, 16.0);
            let (x, y) = batch(&e, 21);
            let mut first = None;
            let mut last = 0.0;
            for _ in 0..8 {
                let out = e.train_step(&params, &q, &x, &y).unwrap();
                first.get_or_insert(out.loss);
                last = out.loss;
                for (ti, t) in out.grads.tensors.iter().enumerate() {
                    for (i, g) in t.data.iter().enumerate() {
                        params.tensors[ti].data[i] -= 0.05 * g;
                    }
                }
            }
            assert!(last < first.unwrap(), "{family}: {first:?} -> {last}");
        }
    }

    #[test]
    fn quant_sites_are_live_on_conv_and_attention_families() {
        for family in ["vgg", "resnet", "bert", "vit", "swin"] {
            let e = engine(family);
            let params = e.init_params(1);
            // coarse quantizer => large rounding residuals => live d-grads
            let q = e.init_qparams(&params, 4.0);
            let (x, y) = batch(&e, 31);
            let out = e.train_step(&params, &q, &x, &y).unwrap();
            assert_eq!(out.qgrads.len(), e.manifest().qsites.len(), "{family}");
            let live = out
                .qgrads
                .iter()
                .any(|g| g.0.abs() + g.1.abs() + g.2.abs() > 0.0);
            assert!(live, "{family}: all quant-param gradients zero");
            // bits must change the loss
            let hi = e.init_qparams(&params, 16.0);
            let l_hi = e.eval_step(&params, &hi, &x, &y).unwrap().loss;
            let l_lo = e.eval_step(&params, &q, &x, &y).unwrap().loss;
            assert!((l_hi - l_lo).abs() > 1e-7, "{family}: {l_hi} vs {l_lo}");
        }
    }

    #[test]
    fn span_and_lm_heads_emit_eval_extras() {
        let e = engine("bert");
        let params = e.init_params(2);
        let q = e.init_qparams(&params, 8.0);
        let (x, y) = batch(&e, 41);
        let ev = e.eval_step(&params, &q, &x, &y).unwrap();
        assert_eq!(ev.extra.len(), 2); // pred_start, pred_end
        let bsz = e.manifest().batch.batch_size();
        let seq = e.manifest().config.usize_or("seq_len", 32) as f32;
        assert_eq!(ev.extra[0].len(), bsz);
        assert!(ev.extra[0].iter().all(|&p| p >= 0.0 && p < seq));

        let e = engine("gpt");
        let params = e.init_params(2);
        let q = e.init_qparams(&params, 8.0);
        let (x, y) = batch(&e, 43);
        let ev = e.eval_step(&params, &q, &x, &y).unwrap();
        assert_eq!(ev.extra.len(), 1); // mask_count
        let bsz = e.manifest().batch.batch_size();
        let seq = e.manifest().config.usize_or("seq_len", 32);
        assert_eq!(ev.extra[0][0], (bsz * (seq - 1)) as f32);
    }

    #[test]
    fn eval_is_deterministic_across_families() {
        for family in ["resnet", "bert", "swin"] {
            let e = engine(family);
            let params = e.init_params(6);
            let q = e.init_qparams(&params, 8.0);
            let (x, y) = batch(&e, 51);
            let a = e.eval_step(&params, &q, &x, &y).unwrap();
            let b = e.eval_step(&params, &q, &x, &y).unwrap();
            assert_eq!(a.loss, b.loss, "{family}");
            assert_eq!(a.metric, b.metric, "{family}");
        }
    }

    #[test]
    fn degenerate_qparams_keep_losses_finite() {
        for family in ["vgg", "bert"] {
            let e = engine(family);
            let params = e.init_params(4);
            let (x, y) = batch(&e, 61);
            for q in [
                QParams { d: 1e-8, t: 1.0, qm: 1.0 },
                QParams { d: 10.0, t: 1.0, qm: 1e-3 },
                QParams { d: 0.1, t: 2.0, qm: 4.0 },
            ] {
                let qs = vec![q; e.manifest().qsites.len()];
                let out = e.eval_step(&params, &qs, &x, &y).unwrap();
                assert!(out.loss.is_finite(), "{family} {q:?}");
            }
        }
    }
}
