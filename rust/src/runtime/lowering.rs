//! Config -> typed op IR lowering for the native interpreter.
//!
//! Each model family's JSON config lowers to a flat, topologically ordered
//! list of [`Node`]s — the same layer sequence the JAX apply functions in
//! `python/compile/models/` execute and the trace-graph builders
//! (`graph/builders.rs`) mirror node-for-node. The lowering is the single
//! source of real per-op shapes: the interpreter (`runtime/interp.rs`)
//! executes it, and BOPs accounting ([`layer_costs`]) reads MAC counts off
//! the same shapes instead of re-deriving spatial bookkeeping per family.
//!
//! Quantization sites are resolved here: every weight-carrying node stores
//! the q-row index of its weight site (plan order, from
//! `graph::builders::quant_site_specs`), and activation-quant sites lower
//! to explicit [`OpKind::ActQuant`] nodes.

use anyhow::{Context, Result};

use crate::graph::builders;
use crate::metrics::bops::LayerCost;
use crate::optim::qasso::SiteSpec;
use crate::tensor::conv_out_dim;
use crate::util::json::Json;

/// Model families the native interpreter can lower and execute. A model
/// whose family appears here may never self-skip in the test suites.
pub fn lowered_families() -> &'static [&'static str] {
    &["mlp", "vgg", "resnet", "bert", "gpt", "vit", "swin"]
}

/// One interpreter op. Weight-carrying ops name their parameter prefix
/// (`<w>.weight` / `<w>.bias` in the `ParamStore`); `site` is the q-row of
/// the weight's quant site when the config quantizes weights.
#[derive(Debug, Clone)]
pub enum OpKind {
    /// The raw f32 image batch `[B,H,W,C]` (image tasks only).
    Input,
    /// Token + positional embedding lookup: i32 `[B,S]` -> `[B,S,D]`.
    Embed { tok: String, pos: String },
    /// `x @ W + b` over the last axis.
    Linear { w: String, site: Option<usize> },
    /// NHWC conv via im2col (`pad` = low-side padding; high side implied).
    Conv2d {
        w: String,
        site: Option<usize>,
        k: usize,
        stride: usize,
        pad: usize,
    },
    /// Per-channel batch-statistics normalization (`<p>.gamma`/`.beta`).
    BatchNorm { p: String },
    /// Per-row last-axis normalization (`<p>.gamma`/`.beta`).
    LayerNorm { p: String },
    Relu,
    Gelu,
    /// Fake-quantize activations at q-row `site`.
    ActQuant { site: usize },
    /// Elementwise sum of two inputs (residual join).
    Add,
    /// 2x2/stride-2 max pool (VALID).
    MaxPool2,
    /// Mean over H,W: `[B,H,W,C] -> [B,C]`.
    GlobalAvgPool,
    /// Pure shape change (flatten / NHWC->tokens); data is shared.
    Reshape,
    /// Prepend the broadcast `cls_token` parameter: `[B,T,D] -> [B,T+1,D]`.
    ConcatCls { cls: String },
    /// Add a `[T,D]` positional table broadcast over the batch.
    AddPos { pos: String },
    /// Fused multi-head self-attention over (q, k, v) inputs `[B,S,D]`.
    Attention { heads: usize, causal: bool },
    /// Swin 2x2 patch merging: `[B,side²,D] -> [B,(side/2)²,4D]`.
    PatchMerge { side: usize },
    /// Take token 0: `[B,T,D] -> [B,D]`.
    TokenPoolCls,
    /// Mean over tokens: `[B,T,D] -> [B,D]`.
    TokenPoolMean,
}

impl OpKind {
    /// Stable op-kind label for telemetry span names and the
    /// `geta profile` per-op table.
    pub fn label(&self) -> &'static str {
        match self {
            OpKind::Input => "Input",
            OpKind::Embed { .. } => "Embed",
            OpKind::Linear { .. } => "Linear",
            OpKind::Conv2d { .. } => "Conv2d",
            OpKind::BatchNorm { .. } => "BatchNorm",
            OpKind::LayerNorm { .. } => "LayerNorm",
            OpKind::Relu => "Relu",
            OpKind::Gelu => "Gelu",
            OpKind::ActQuant { .. } => "ActQuant",
            OpKind::Add => "Add",
            OpKind::MaxPool2 => "MaxPool2",
            OpKind::GlobalAvgPool => "GlobalAvgPool",
            OpKind::Reshape => "Reshape",
            OpKind::ConcatCls { .. } => "ConcatCls",
            OpKind::AddPos { .. } => "AddPos",
            OpKind::Attention { .. } => "Attention",
            OpKind::PatchMerge { .. } => "PatchMerge",
            OpKind::TokenPoolCls => "TokenPoolCls",
            OpKind::TokenPoolMean => "TokenPoolMean",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub op: OpKind,
    /// Indices of producer nodes (always earlier in the list).
    pub inputs: Vec<usize>,
    /// Output shape including the batch dim.
    pub shape: Vec<usize>,
}

/// A lowered model: nodes in execution order; the last node emits the
/// task logits (`[B,ncls]`, `[B,S,2]` or `[B,S,V]`).
#[derive(Debug, Clone)]
pub struct Program {
    pub family: String,
    pub task: String,
    pub batch: usize,
    pub nodes: Vec<Node>,
}

impl Program {
    pub fn output(&self) -> usize {
        self.nodes.len() - 1
    }
}

struct Lower<'a> {
    nodes: Vec<Node>,
    sites: &'a [SiteSpec],
}

impl<'a> Lower<'a> {
    fn site(&self, name: &str) -> Option<usize> {
        self.sites.iter().position(|s| s.name == name)
    }

    fn push(&mut self, name: &str, op: OpKind, inputs: Vec<usize>, shape: Vec<usize>) -> usize {
        self.nodes.push(Node {
            name: name.to_string(),
            op,
            inputs,
            shape,
        });
        self.nodes.len() - 1
    }

    fn shape(&self, id: usize) -> &Vec<usize> {
        &self.nodes[id].shape
    }

    /// Shape-preserving unary op.
    fn unary(&mut self, prev: usize, name: &str, op: OpKind) -> usize {
        let shape = self.shape(prev).clone();
        self.push(name, op, vec![prev], shape)
    }

    fn linear(&mut self, prev: usize, name: &str, dout: usize) -> usize {
        let mut shape = self.shape(prev).clone();
        *shape.last_mut().expect("linear input has a last dim") = dout;
        let site = self.site(&format!("{name}.weight"));
        self.push(
            name,
            OpKind::Linear {
                w: name.to_string(),
                site,
            },
            vec![prev],
            shape,
        )
    }

    fn conv(
        &mut self,
        prev: usize,
        name: &str,
        cout: usize,
        k: usize,
        stride: usize,
        same: bool,
    ) -> usize {
        let in_shape = self.shape(prev).clone();
        let (b, h, w) = (in_shape[0], in_shape[1], in_shape[2]);
        let (ho, pad) = conv_out_dim(h, k, stride, same);
        let (wo, _) = conv_out_dim(w, k, stride, same);
        let site = self.site(&format!("{name}.weight"));
        self.push(
            name,
            OpKind::Conv2d {
                w: name.to_string(),
                site,
                k,
                stride,
                pad,
            },
            vec![prev],
            vec![b, ho, wo, cout],
        )
    }

    fn act_quant(&mut self, prev: usize, site_name: &str) -> usize {
        match self.site(site_name) {
            Some(site) => self.unary(prev, site_name, OpKind::ActQuant { site }),
            None => prev,
        }
    }

    /// Pre-LN transformer block (mirrors `common.transformer_block`).
    fn block(&mut self, x: usize, name: &str, heads: usize, ratio: usize, causal: bool) -> usize {
        let dim = *self.shape(x).last().unwrap();
        let ln1 = self.unary(x, &format!("{name}.ln1"), OpKind::LayerNorm { p: format!("{name}.ln1") });
        let wq = self.linear(ln1, &format!("{name}.attn.wq"), dim);
        let wk = self.linear(ln1, &format!("{name}.attn.wk"), dim);
        let wv = self.linear(ln1, &format!("{name}.attn.wv"), dim);
        let shape = self.shape(wq).clone();
        let att = self.push(
            &format!("{name}.attn"),
            OpKind::Attention { heads, causal },
            vec![wq, wk, wv],
            shape,
        );
        let wo = self.linear(att, &format!("{name}.attn.wo"), dim);
        let add1 = {
            let shape = self.shape(x).clone();
            self.push(&format!("{name}.add1"), OpKind::Add, vec![x, wo], shape)
        };
        let ln2 = self.unary(add1, &format!("{name}.ln2"), OpKind::LayerNorm { p: format!("{name}.ln2") });
        let fc1 = self.linear(ln2, &format!("{name}.fc1"), dim * ratio);
        let gelu = self.unary(fc1, &format!("{name}.gelu"), OpKind::Gelu);
        let fc2 = self.linear(gelu, &format!("{name}.fc2"), dim);
        let shape = self.shape(add1).clone();
        self.push(&format!("{name}.add2"), OpKind::Add, vec![add1, fc2], shape)
    }
}

/// Attention requires `dim % heads == 0`; the interpreter's per-head
/// slicing would otherwise silently drop the trailing channels.
fn check_heads(model: &str, dim: usize, heads: usize) -> Result<()> {
    anyhow::ensure!(
        heads > 0 && dim % heads == 0,
        "model `{model}`: attention dim {dim} not divisible by heads {heads}"
    );
    Ok(())
}

/// Lower `cfg` into an executable [`Program`] for batch size `batch`.
/// `sites` is the plan-order quant-site list (the manifest's `qsites`).
pub fn lower(cfg: &Json, sites: &[SiteSpec], batch: usize) -> Result<Program> {
    let family = cfg.req("family")?.as_str().unwrap_or_default().to_string();
    let task = cfg.str_or("task", "image_cls");
    let model = cfg.str_or("name", "<unnamed>");
    let img = |key: &str, default: usize| -> usize {
        cfg.get("image").map(|i| i.usize_or(key, default)).unwrap_or(default)
    };
    let ncls = cfg.usize_or("num_classes", 10);
    let mut lo = Lower {
        nodes: Vec::new(),
        sites,
    };
    match family.as_str() {
        "mlp" => {
            let (s, c) = (img("size", 8), img("channels", 3));
            let inp = lo.push("input", OpKind::Input, vec![], vec![batch, s, s, c]);
            let mut prev = lo.push("flatten", OpKind::Reshape, vec![inp], vec![batch, s * s * c]);
            for (i, &dout) in cfg.usize_arr("hidden").iter().enumerate() {
                prev = lo.linear(prev, &format!("fc{i}"), dout);
                prev = lo.unary(prev, &format!("fc{i}.relu"), OpKind::Relu);
                prev = lo.act_quant(prev, &format!("fc{i}.act"));
            }
            lo.linear(prev, "head", ncls);
        }
        "vgg" => {
            let (s, c) = (img("size", 16), img("channels", 3));
            let pool_every = cfg.usize_or("pool_every", 2);
            let mut prev = lo.push("input", OpKind::Input, vec![], vec![batch, s, s, c]);
            for (i, &cout) in cfg.usize_arr("conv_channels").iter().enumerate() {
                prev = lo.conv(prev, &format!("features.{i}"), cout, 3, 1, true);
                prev = lo.unary(
                    prev,
                    &format!("features.{i}.bn"),
                    OpKind::BatchNorm { p: format!("features.{i}.bn") },
                );
                prev = lo.unary(prev, &format!("features.{i}.relu"), OpKind::Relu);
                prev = lo.act_quant(prev, &format!("features.{i}.act"));
                if (i + 1) % pool_every == 0 {
                    let sh = lo.shape(prev).clone();
                    prev = lo.push(
                        &format!("pool{i}"),
                        OpKind::MaxPool2,
                        vec![prev],
                        vec![sh[0], sh[1] / 2, sh[2] / 2, sh[3]],
                    );
                }
            }
            let flat: usize = lo.shape(prev)[1..].iter().product();
            prev = lo.push("flatten", OpKind::Reshape, vec![prev], vec![batch, flat]);
            for (i, &dout) in cfg.usize_arr("fc_dims").iter().enumerate() {
                prev = lo.linear(prev, &format!("fc{i}"), dout);
                prev = lo.unary(prev, &format!("fc{i}.relu"), OpKind::Relu);
                prev = lo.act_quant(prev, &format!("fc{i}.act"));
            }
            lo.linear(prev, "head", ncls);
        }
        "resnet" => {
            let (s, c) = (img("size", 16), img("channels", 3));
            let stem_c = cfg.usize_or("stem_channels", 8);
            let blocks = cfg.usize_or("blocks_per_stage", 2);
            let inp = lo.push("input", OpKind::Input, vec![], vec![batch, s, s, c]);
            let mut prev = lo.conv(inp, "stem", stem_c, 3, 1, true);
            prev = lo.unary(prev, "stem.bn", OpKind::BatchNorm { p: "stem.bn".into() });
            prev = lo.unary(prev, "stem.relu", OpKind::Relu);
            let mut cin = stem_c;
            for (si, &cout) in cfg.usize_arr("stage_channels").iter().enumerate() {
                let stage_stride = if si == 0 { 1 } else { 2 };
                for b in 0..blocks {
                    let stride = if b == 0 { stage_stride } else { 1 };
                    let n = format!("stage{si}.{b}");
                    let mut y = lo.conv(prev, &format!("{n}.conv1"), cout, 3, stride, true);
                    y = lo.unary(y, &format!("{n}.bn1"), OpKind::BatchNorm { p: format!("{n}.bn1") });
                    y = lo.unary(y, &format!("{n}.relu1"), OpKind::Relu);
                    y = lo.conv(y, &format!("{n}.conv2"), cout, 3, 1, true);
                    y = lo.unary(y, &format!("{n}.bn2"), OpKind::BatchNorm { p: format!("{n}.bn2") });
                    let skip = if stride != 1 || cin != cout {
                        let p = lo.conv(prev, &format!("{n}.proj"), cout, 1, stride, true);
                        lo.unary(p, &format!("{n}.bnp"), OpKind::BatchNorm { p: format!("{n}.bnp") })
                    } else {
                        prev
                    };
                    let shape = lo.shape(y).clone();
                    let add = lo.push(&format!("{n}.add"), OpKind::Add, vec![y, skip], shape);
                    prev = lo.unary(add, &format!("{n}.relu2"), OpKind::Relu);
                    cin = cout;
                }
            }
            let sh = lo.shape(prev).clone();
            prev = lo.push("gap", OpKind::GlobalAvgPool, vec![prev], vec![sh[0], sh[3]]);
            lo.linear(prev, "head", ncls);
        }
        "bert" | "gpt" => {
            let dim = cfg.usize_or("dim", 64);
            let seq = cfg.usize_or("seq_len", 32);
            let heads = cfg.usize_or("heads", 4);
            let ratio = cfg.usize_or("mlp_ratio", 4);
            check_heads(&model, dim, heads)?;
            let mut prev = lo.push(
                "embed",
                OpKind::Embed {
                    tok: "embed.tok".into(),
                    pos: "embed.pos".into(),
                },
                vec![],
                vec![batch, seq, dim],
            );
            if family == "bert" {
                prev = lo.unary(prev, "embed.ln", OpKind::LayerNorm { p: "embed.ln".into() });
            }
            for b in 0..cfg.usize_or("blocks", 2) {
                prev = lo.block(prev, &format!("block{b}"), heads, ratio, family == "gpt");
            }
            prev = lo.unary(prev, "final.ln", OpKind::LayerNorm { p: "final.ln".into() });
            if family == "bert" {
                lo.linear(prev, "span_head", 2);
            } else {
                lo.linear(prev, "lm_head", cfg.usize_or("vocab", 128));
            }
        }
        "vit" => {
            let (s, c) = (img("size", 16), img("channels", 3));
            let dim = cfg.usize_or("dim", 48);
            let patch = cfg.usize_or("patch", 4);
            let heads = cfg.usize_or("heads", 4);
            let ratio = cfg.usize_or("mlp_ratio", 4);
            check_heads(&model, dim, heads)?;
            let inp = lo.push("input", OpKind::Input, vec![], vec![batch, s, s, c]);
            let mut prev = lo.conv(inp, "patch_embed", dim, patch, patch, false);
            let grid = lo.shape(prev)[1] * lo.shape(prev)[2];
            prev = lo.push("tokens", OpKind::Reshape, vec![prev], vec![batch, grid, dim]);
            if cfg.str_or("pool", "cls") == "cls" {
                prev = lo.push(
                    "cls",
                    OpKind::ConcatCls { cls: "cls_token".into() },
                    vec![prev],
                    vec![batch, grid + 1, dim],
                );
            }
            prev = lo.unary(prev, "pos", OpKind::AddPos { pos: "pos_embed".into() });
            for b in 0..cfg.usize_or("blocks", 2) {
                prev = lo.block(prev, &format!("block{b}"), heads, ratio, false);
            }
            prev = lo.unary(prev, "final.ln", OpKind::LayerNorm { p: "final.ln".into() });
            let pool_op = if cfg.str_or("pool", "cls") == "cls" {
                OpKind::TokenPoolCls
            } else {
                OpKind::TokenPoolMean
            };
            prev = lo.push("pool", pool_op, vec![prev], vec![batch, dim]);
            lo.linear(prev, "head", ncls);
        }
        "swin" => {
            let (s, c) = (img("size", 16), img("channels", 3));
            let dims = cfg.usize_arr("stage_dims");
            let stage_blocks = cfg.usize_arr("stage_blocks");
            let patch = cfg.usize_or("patch", 2);
            let heads = cfg.usize_or("heads", 4);
            let ratio = cfg.usize_or("mlp_ratio", 2);
            anyhow::ensure!(
                dims.len() == stage_blocks.len() && !dims.is_empty(),
                "swin config needs matching stage_dims/stage_blocks"
            );
            for &dim in &dims {
                check_heads(&model, dim, heads)?;
            }
            let inp = lo.push("input", OpKind::Input, vec![], vec![batch, s, s, c]);
            let mut prev = lo.conv(inp, "patch_embed", dims[0], patch, patch, false);
            let mut side = lo.shape(prev)[1];
            prev = lo.push("tokens", OpKind::Reshape, vec![prev], vec![batch, side * side, dims[0]]);
            prev = lo.unary(prev, "pos", OpKind::AddPos { pos: "pos_embed".into() });
            for (si, &dim) in dims.iter().enumerate() {
                for b in 0..stage_blocks[si] {
                    prev = lo.block(prev, &format!("stage{si}.block{b}"), heads, ratio, false);
                }
                if si + 1 < dims.len() {
                    prev = lo.push(
                        &format!("merge{si}.cat"),
                        OpKind::PatchMerge { side },
                        vec![prev],
                        vec![batch, (side / 2) * (side / 2), dim * 4],
                    );
                    side /= 2;
                    prev = lo.unary(
                        prev,
                        &format!("merge{si}.ln"),
                        OpKind::LayerNorm { p: format!("merge{si}.ln") },
                    );
                    prev = lo.linear(prev, &format!("merge{si}"), dims[si + 1]);
                }
            }
            prev = lo.unary(prev, "final.ln", OpKind::LayerNorm { p: "final.ln".into() });
            let dim = *dims.last().unwrap();
            prev = lo.push("pool", OpKind::TokenPoolMean, vec![prev], vec![batch, dim]);
            lo.linear(prev, "head", ncls);
        }
        other => anyhow::bail!(
            "no native lowering for model family `{other}` (model `{model}`); \
             lowered families: {:?}",
            lowered_families()
        ),
    }
    Ok(Program {
        family,
        task,
        batch,
        nodes: lo.nodes,
    })
}

/// Per-layer MAC costs derived from the lowered program's real op shapes
/// (batch-1 lowering), replacing the per-family spatial bookkeeping that
/// used to live in `metrics/bops.rs`. Conv MACs use the interpreter's own
/// output dims (`ho*wo*k²*cin*cout`); linear MACs scale by the true token
/// count of their input. `act_in_site` walks back through shape-only ops
/// to the activation-quant site feeding the layer, if any.
pub fn layer_costs(cfg: &Json) -> Result<Vec<LayerCost>> {
    let sites = builders::quant_site_specs(cfg)?;
    let prog = lower(cfg, &sites, 1)?;
    let mut out = Vec::new();
    for node in &prog.nodes {
        let (w, macs, cin, cout) = match &node.op {
            OpKind::Linear { w, .. } => {
                let in_shape = &prog.nodes[node.inputs[0]].shape;
                let din = *in_shape.last().context("linear input shape")?;
                let dout = *node.shape.last().context("linear output shape")?;
                let tokens: usize = node.shape[1..node.shape.len() - 1].iter().product();
                (w.clone(), (tokens * din * dout) as f64, din, dout)
            }
            OpKind::Conv2d { w, k, .. } => {
                let in_shape = &prog.nodes[node.inputs[0]].shape;
                let cin = *in_shape.last().context("conv input shape")?;
                let (ho, wo, cout) = (node.shape[1], node.shape[2], node.shape[3]);
                (w.clone(), (ho * wo * k * k * cin * cout) as f64, cin, cout)
            }
            _ => continue,
        };
        // trace back through shape-only / pooling ops to an act-quant site
        let mut src = node.inputs[0];
        let act_in_site = loop {
            match &prog.nodes[src].op {
                OpKind::Reshape | OpKind::MaxPool2 | OpKind::GlobalAvgPool => {
                    src = prog.nodes[src].inputs[0];
                }
                OpKind::ActQuant { site } => break Some(sites[*site].name.clone()),
                _ => break None,
            }
        };
        out.push(LayerCost {
            param: format!("{w}.weight"),
            macs,
            cin,
            cout,
            act_in_site,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn cfg(name: &str) -> Json {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("configs/models")
            .join(format!("{name}.json"));
        json::parse_file(&path).unwrap()
    }

    fn lower_model(name: &str, batch: usize) -> Program {
        let c = cfg(name);
        let sites = builders::quant_site_specs(&c).unwrap();
        lower(&c, &sites, batch).unwrap()
    }

    #[test]
    fn all_nine_configs_lower() {
        for name in [
            "mlp_tiny", "vgg7_mini", "resnet_mini", "resnet_mini_l",
            "bert_mini", "gpt_mini", "vit_mini", "simplevit_mini", "swin_mini",
        ] {
            let p = lower_model(name, 4);
            assert!(p.nodes.len() > 3, "{name}");
            // inputs always reference earlier nodes (topological order)
            for (i, n) in p.nodes.iter().enumerate() {
                for &j in &n.inputs {
                    assert!(j < i, "{name}: node {} input {j} not earlier", n.name);
                }
                assert!(!n.shape.is_empty(), "{name}: {}", n.name);
                assert_eq!(n.shape[0], 4, "{name}: {} batch dim", n.name);
            }
        }
    }

    #[test]
    fn unknown_family_error_names_the_family() {
        let c = json::parse(r#"{"name": "x", "family": "tcn", "task": "image_cls"}"#).unwrap();
        let err = lower(&c, &[], 2).unwrap_err().to_string();
        assert!(err.contains("tcn"), "{err}");
        assert!(err.contains("no native lowering"), "{err}");
    }

    #[test]
    fn indivisible_head_count_is_rejected() {
        // dim 48, heads 5: the per-head slicing would drop channels 45..48
        let c = json::parse(
            r#"{"name": "x", "family": "gpt", "task": "lm", "vocab": 32,
                "seq_len": 8, "dim": 48, "heads": 5, "blocks": 1,
                "mlp_ratio": 2, "quant": {"weight": true, "act": false}}"#,
        )
        .unwrap();
        let err = lower(&c, &[], 4).unwrap_err().to_string();
        assert!(err.contains("not divisible"), "{err}");
    }

    #[test]
    fn vgg_shapes_follow_pools() {
        let p = lower_model("vgg7_mini", 2);
        let pool_shapes: Vec<Vec<usize>> = p
            .nodes
            .iter()
            .filter(|n| matches!(n.op, OpKind::MaxPool2))
            .map(|n| n.shape.clone())
            .collect();
        assert_eq!(pool_shapes, vec![
            vec![2, 8, 8, 16],
            vec![2, 4, 4, 32],
            vec![2, 2, 2, 64],
        ]);
    }

    #[test]
    fn resnet_strided_convs_halve_spatial_dims() {
        let p = lower_model("resnet_mini", 1);
        let c1 = p.nodes.iter().find(|n| n.name == "stage1.0.conv1").unwrap();
        assert_eq!(c1.shape, vec![1, 8, 8, 16]);
        let proj = p.nodes.iter().find(|n| n.name == "stage1.0.proj").unwrap();
        assert_eq!(proj.shape, vec![1, 8, 8, 16]);
        let add = p.nodes.iter().find(|n| n.name == "stage2.1.add").unwrap();
        assert_eq!(add.shape, vec![1, 4, 4, 32]);
        assert_eq!(add.inputs.len(), 2);
    }

    #[test]
    fn vit_token_count_includes_cls() {
        let p = lower_model("vit_mini", 1);
        let pos = p.nodes.iter().find(|n| n.name == "pos").unwrap();
        assert_eq!(pos.shape, vec![1, 17, 48]); // 4x4 grid + cls
        let p2 = lower_model("simplevit_mini", 1);
        let pos2 = p2.nodes.iter().find(|n| n.name == "pos").unwrap();
        assert_eq!(pos2.shape, vec![1, 16, 48]); // mean pool: no cls token
    }

    #[test]
    fn swin_merge_halves_tokens_and_grows_channels() {
        let p = lower_model("swin_mini", 1);
        let cat = p.nodes.iter().find(|n| n.name == "merge0.cat").unwrap();
        assert_eq!(cat.shape, vec![1, 16, 128]); // 8x8 -> 4x4, 32 -> 128
        let merge = p.nodes.iter().find(|n| n.name == "merge0").unwrap();
        assert_eq!(merge.shape, vec![1, 16, 64]);
    }

    #[test]
    fn weight_sites_resolved_in_plan_order() {
        let p = lower_model("vgg7_mini", 1);
        let c = cfg("vgg7_mini");
        let sites = builders::quant_site_specs(&c).unwrap();
        for n in &p.nodes {
            let (w, site) = match &n.op {
                OpKind::Linear { w, site } | OpKind::Conv2d { w, site, .. } => (w, site),
                OpKind::ActQuant { site } => {
                    assert_eq!(sites[*site].name, n.name);
                    continue;
                }
                _ => continue,
            };
            let site = site.expect("vgg quantizes every weight");
            assert_eq!(sites[site].name, format!("{w}.weight"));
        }
    }

    #[test]
    fn layer_costs_use_interpreter_shapes() {
        // conv0 of vgg7: 16x16 output, 3x3 kernel, 3 -> 16 channels
        let costs = layer_costs(&cfg("vgg7_mini")).unwrap();
        assert_eq!(costs[0].param, "features.0.weight");
        assert_eq!(costs[0].macs, (16 * 16 * 9 * 3 * 16) as f64);
        // the layer after the first pool sees 8x8 inputs
        let c2 = costs.iter().find(|c| c.param == "features.2.weight").unwrap();
        assert_eq!(c2.macs, (8 * 8 * 9 * 16 * 32) as f64);
        // act site feeding features.1 is features.0.act (through no pool)
        let c1 = costs.iter().find(|c| c.param == "features.1.weight").unwrap();
        assert_eq!(c1.act_in_site.as_deref(), Some("features.0.act"));
        // ...and through a pool for features.2
        assert_eq!(c2.act_in_site.as_deref(), Some("features.1.act"));
    }
}
