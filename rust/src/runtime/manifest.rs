//! AOT manifest loader — the contract between `python/compile/aot.py` and
//! the Rust runtime/coordinator.

use anyhow::{Context, Result};

use crate::optim::qasso::SiteSpec;
use crate::util::json::{self, Json};

#[derive(Debug, Clone)]
pub struct BatchSpec {
    pub x_shape: Vec<usize>,
    pub x_dtype: String,
    pub y_shape: Vec<usize>,
    pub y_dtype: String,
}

impl BatchSpec {
    pub fn batch_size(&self) -> usize {
        *self.x_shape.first().unwrap_or(&1)
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: String,
    /// The model config embedded at lowering time.
    pub config: Json,
    pub task: String,
    pub train_hlo: String,
    pub eval_hlo: String,
    /// (name, shape) in HLO input order.
    pub params: Vec<(String, Vec<usize>)>,
    pub qsites: Vec<SiteSpec>,
    /// Rows of the q input array (max(n_sites, 1)).
    pub q_rows: usize,
    pub batch: BatchSpec,
    pub eval_outputs: Vec<String>,
    pub param_count: usize,
}

impl Manifest {
    pub fn load(art_dir: &std::path::Path, model: &str) -> Result<Manifest> {
        let path = art_dir.join(format!("{model}.manifest.json"));
        let j = json::parse_file(&path)?;
        Self::from_json(&j).with_context(|| format!("manifest {}", path.display()))
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let params = j
            .req("params")?
            .as_arr()
            .context("params array")?
            .iter()
            .map(|p| {
                Ok((
                    p.req("name")?.as_str().context("name")?.to_string(),
                    p.req("shape")?
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect(),
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let qsites: Vec<SiteSpec> = j
            .req("qsites")?
            .as_arr()
            .context("qsites")?
            .iter()
            .map(|s| SiteSpec {
                name: s.str_or("name", ""),
                param: s.get("param").and_then(|p| p.as_str()).map(String::from),
            })
            .collect();
        let batch = j.req("batch")?;
        let bspec = |key: &str| -> Result<(Vec<usize>, String)> {
            let b = batch.req(key)?;
            Ok((
                b.req("shape")?
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect(),
                b.str_or("dtype", "f32"),
            ))
        };
        let (x_shape, x_dtype) = bspec("x")?;
        let (y_shape, y_dtype) = bspec("y")?;
        let config = j.req("config")?.clone();
        Ok(Manifest {
            model: j.str_or("model", ""),
            task: config.str_or("task", ""),
            config,
            train_hlo: j.str_or("train_hlo", ""),
            eval_hlo: j.str_or("eval_hlo", ""),
            params,
            qsites,
            q_rows: j
                .req("q_shape")?
                .as_arr()
                .and_then(|a| a.first())
                .and_then(|v| v.as_usize())
                .unwrap_or(1),
            batch: BatchSpec {
                x_shape,
                x_dtype,
                y_shape,
                y_dtype,
            },
            eval_outputs: j
                .req("eval_outputs")?
                .as_arr()
                .context("eval_outputs")?
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect(),
            param_count: j.usize_or("param_count", 0),
        })
    }

    /// All models listed in artifacts/index.json.
    pub fn list_models(art_dir: &std::path::Path) -> Result<Vec<String>> {
        let idx = json::parse_file(&art_dir.join("index.json"))?;
        Ok(idx
            .req("models")?
            .as_arr()
            .context("models")?
            .iter()
            .filter_map(|m| m.get("model").and_then(|v| v.as_str()).map(String::from))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        art_dir().join("index.json").exists()
    }

    #[test]
    fn loads_every_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let models = Manifest::list_models(&art_dir()).unwrap();
        assert!(models.len() >= 9, "{models:?}");
        for m in &models {
            let man = Manifest::load(&art_dir(), m).unwrap();
            assert_eq!(&man.model, m);
            assert!(!man.params.is_empty());
            assert!(man.param_count > 0);
            assert!(art_dir().join(&man.train_hlo).exists());
            assert!(art_dir().join(&man.eval_hlo).exists());
            let total: usize = man.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
            assert_eq!(total, man.param_count, "{m}");
        }
    }

    #[test]
    fn qsites_align_with_rust_graph() {
        if !have_artifacts() {
            return;
        }
        // site order in the manifest must equal the Rust builders' order
        for m in Manifest::list_models(&art_dir()).unwrap() {
            let man = Manifest::load(&art_dir(), &m).unwrap();
            let sites = crate::graph::builders::quant_sites(&man.config).unwrap();
            assert_eq!(man.qsites.len(), sites.len(), "{m}");
            for (a, (bname, _)) in man.qsites.iter().zip(&sites) {
                assert_eq!(&a.name, bname, "{m}");
            }
        }
    }
}
