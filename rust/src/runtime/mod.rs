//! Execution backends: the contract between the Layer-3 coordinator and
//! whatever actually runs the model's forward/backward pass.
//!
//! Two implementations exist:
//!
//! * [`native::NativeEngine`] — a pure-Rust manifest-driven interpreter
//!   covering **every zoo family** (mlp, vgg, resnet, bert, gpt, vit,
//!   swin). Each config is lowered to a typed op IR
//!   ([`lowering`]: linear, conv-as-im2col, batch/layer norm, residual
//!   add, multi-head attention, gelu/relu, patch embed/merge, pooling) and
//!   executed by [`interp`] with per-site fake-quantization and STE
//!   gradients for (d, t, q_m). It synthesizes its own in-memory
//!   [`Manifest`] and needs no Python, JAX or XLA, which is what makes
//!   `cargo test` hermetic — CNN and transformer e2e runs included — on a
//!   clean machine.
//! * `pjrt::Engine` (behind the `pjrt` cargo feature) — loads the AOT
//!   artifacts produced by `make artifacts` (python/compile/aot.py) and
//!   executes the compiled HLO through a PJRT CPU client.
//!
//! The native forward pass itself lives in [`exec`] — the **planned
//! executor**: a shape-resolved [`exec::Plan`] built once per model, a
//! buffer [`exec::Arena`] reused across steps/micro-batches, and a
//! [`exec::ParamSource`] seam that lets the *same* op kernels serve both
//! training (dense fake-quant parameters) and `.geta` deployment
//! (dequantized packed weights — see `deploy::GetaEngine`). [`interp`]
//! adds the loss heads and backward pass on top.
//!
//! The coordinator, QASSO, subnet construction and BOPs accounting all run
//! on the [`Backend`] trait and cannot tell the two apart: the manifest is
//! the single interface in both directions. BOPs accounting additionally
//! reads per-layer MAC counts off the lowered program's real op shapes
//! (`lowering::layer_costs`).

pub mod exec;
pub mod interp;
pub mod lowering;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use manifest::{BatchSpec, Manifest};
pub use native::NativeEngine;
#[cfg(feature = "pjrt")]
pub use pjrt::Engine;

use anyhow::Result;

use crate::optim::qasso::SiteSpec;
use crate::quant::QParams;
use crate::tensor::{ParamStore, Tensor};
use crate::util::rng::Rng;

/// A batch in host memory, matching the manifest's x/y specs.
#[derive(Debug, Clone)]
pub enum HostArray {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostArray {
    pub fn len(&self) -> usize {
        match self {
            HostArray::F32(v) => v.len(),
            HostArray::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Debug)]
pub struct TrainOut {
    pub loss: f32,
    pub grads: ParamStore,
    /// (∂d, ∂t, ∂q_m) per site.
    pub qgrads: Vec<(f32, f32, f32)>,
    pub metric: f32,
}

#[derive(Debug)]
pub struct EvalOut {
    pub loss: f32,
    pub metric: f32,
    /// Task-specific extra outputs (span predictions / LM mask count),
    /// in manifest order after loss+metric.
    pub extra: Vec<Vec<f32>>,
}

/// One model's execution engine: everything the coordinator needs to run
/// train/eval steps and to set up parameters and quantizers.
///
/// Deliberately NOT `Send`-bounded: real PJRT client handles may be
/// thread-confined, so worker pools construct their backend inside each
/// thread instead of moving one across (examples/compression_service).
pub trait Backend {
    /// The manifest driving input packing and search-space construction.
    fn manifest(&self) -> &Manifest;

    /// Human-readable execution platform (e.g. "cpu" under PJRT, "native").
    fn platform(&self) -> String;

    /// Execute one training step: loss, per-param grads, per-site quant
    /// grads, task metric.
    fn train_step(
        &self,
        params: &ParamStore,
        q: &[QParams],
        x: &HostArray,
        y: &HostArray,
    ) -> Result<TrainOut>;

    /// Execute one evaluation step.
    fn eval_step(
        &self,
        params: &ParamStore,
        q: &[QParams],
        x: &HostArray,
        y: &HostArray,
    ) -> Result<EvalOut>;

    /// Raw output-node logits of an eval forward pass — the deployment
    /// path's parity reference (`deploy::GetaEngine` must reproduce these
    /// on the masked model). Backends that cannot expose logits (compiled
    /// HLO returns only loss/metric) keep the default error.
    fn eval_logits(
        &self,
        _params: &ParamStore,
        _q: &[QParams],
        _x: &HostArray,
        _y: &HostArray,
    ) -> Result<Vec<f32>> {
        anyhow::bail!("backend `{}` does not expose eval logits", self.platform())
    }

    /// Initialize parameters per the layer-name conventions shared with the
    /// JAX zoo (he for conv, glorot for linear, 0.02-normal embeddings,
    /// ones/zeros for norms and biases). Distribution-faithful rather than
    /// bit-identical to the numpy init — all experiments train from this.
    fn init_params(&self, seed: u64) -> ParamStore {
        init_params_for(self.manifest(), seed)
    }

    /// Quantizer init (paper Appendix C): weight sites from max|w| at the
    /// configured bit width; activation sites with q_m = 4 (post-ReLU
    /// scale; learned thereafter).
    fn init_qparams(&self, params: &ParamStore, init_bits: f32) -> Vec<QParams> {
        init_qparams_for(self.manifest(), params, init_bits)
    }

    fn site_specs(&self) -> Vec<SiteSpec> {
        self.manifest().qsites.clone()
    }

    /// Downcast to the native interpreter engine, when that is what this
    /// backend is. The shrink-as-you-train re-planner needs the lowered
    /// program to rebuild a Plan on the sliced subnet; backends that can't
    /// expose one (compiled HLO) keep the default `None` and train dense.
    fn as_native(&self) -> Option<&native::NativeEngine> {
        None
    }
}

/// Shared parameter initialization (see [`Backend::init_params`]).
pub fn init_params_for(manifest: &Manifest, seed: u64) -> ParamStore {
    let mut rng = Rng::new(seed);
    let mut store = ParamStore::new();
    for (name, shape) in &manifest.params {
        let n: usize = shape.iter().product();
        let mut data = vec![0.0f32; n];
        if name.ends_with(".bias") || name.ends_with(".beta") || name == "cls_token" {
            // zeros
        } else if name.ends_with(".gamma") {
            data.iter_mut().for_each(|v| *v = 1.0);
        } else if name.contains("embed.tok") || name.contains("embed.pos") || name.contains("pos_embed") {
            rng.fill_normal(&mut data, 0.02);
        } else if shape.len() == 4 {
            // conv HWIO: He with fan_in = kh*kw*cin
            let fan_in = (shape[0] * shape[1] * shape[2]) as f32;
            rng.fill_normal(&mut data, (2.0 / fan_in).sqrt());
        } else if shape.len() == 2 {
            let std = (2.0 / (shape[0] + shape[1]) as f32).sqrt();
            rng.fill_normal(&mut data, std);
        } else {
            rng.fill_normal(&mut data, 0.02);
        }
        store.push(Tensor::from_vec(name, shape, data));
    }
    store
}

/// Shared quantizer initialization (see [`Backend::init_qparams`]).
pub fn init_qparams_for(manifest: &Manifest, params: &ParamStore, init_bits: f32) -> Vec<QParams> {
    manifest
        .qsites
        .iter()
        .map(|s| match &s.param {
            Some(p) => {
                let w = params
                    .get(p)
                    .map(|t| crate::tensor::max_abs(&t.data))
                    .unwrap_or(1.0);
                QParams::init(w, init_bits)
            }
            None => QParams::init(4.0, init_bits),
        })
        .collect()
}

/// Pick the best available backend for `model`.
///
/// With the `pjrt` feature and AOT artifacts present, the compiled-HLO
/// engine wins; otherwise the native interpreter serves the model (it
/// lowers every zoo family). Unknown models or families outside
/// [`native::lowered_families`] produce an error naming the family.
pub fn load_backend(art_dir: &std::path::Path, model: &str) -> Result<Box<dyn Backend>> {
    // per-model gate, matching `manifest_for`: a partial artifacts dir
    // (subset `make artifacts` run) must not shadow natively served models
    let have_artifacts = has_artifact(art_dir, model);
    #[cfg(feature = "pjrt")]
    {
        if have_artifacts {
            match pjrt::Engine::load(art_dir, model) {
                Ok(e) => return Ok(Box::new(e)),
                // a failing PJRT engine (e.g. the vendored xla stub is
                // linked) falls back to the native backend when it can
                // serve the model; otherwise surface the PJRT error
                Err(err) => match native::NativeEngine::new(model) {
                    Ok(e) => {
                        eprintln!(
                            "pjrt engine unavailable ({err}); using the native backend for {model}"
                        );
                        return Ok(Box::new(e));
                    }
                    Err(_) => return Err(err),
                },
            }
        }
    }
    match native::NativeEngine::new(model) {
        Ok(e) => Ok(Box::new(e)),
        Err(e) if have_artifacts => Err(e.context(
            "AOT artifacts exist but this build omits the `pjrt` feature \
             (rebuild with `cargo build --features pjrt`)",
        )),
        Err(e) => Err(e),
    }
}

/// True when `model` has a usable AOT artifact (index + its own manifest).
pub fn has_artifact(art_dir: &std::path::Path, model: &str) -> bool {
    art_dir.join("index.json").exists()
        && art_dir.join(format!("{model}.manifest.json")).exists()
}

/// True when this build would actually *use* `model`'s AOT artifact —
/// the single decision point behind [`load_backend`], [`manifest_for`]
/// and the `geta models` provenance label.
pub fn uses_artifact(art_dir: &std::path::Path, model: &str) -> bool {
    cfg!(feature = "pjrt") && has_artifact(art_dir, model)
}

/// Load a model's manifest from the source [`load_backend`] would use:
/// the AOT export only when a `pjrt` build would run it, the native
/// synthesis otherwise — so the manifest and the engine always describe
/// the same model plan. Artifact manifests still serve as a fallback for
/// models missing from the embedded config set.
pub fn manifest_for(art_dir: &std::path::Path, model: &str) -> Result<Manifest> {
    if uses_artifact(art_dir, model) {
        return Manifest::load(art_dir, model);
    }
    match native::synth_manifest_for(model) {
        Ok(m) => Ok(m),
        Err(_) if has_artifact(art_dir, model) => Manifest::load(art_dir, model),
        Err(e) => Err(e),
    }
}

/// Every model this build can describe: the artifact index (when present)
/// unioned with the embedded config set, so a partial artifacts dir does
/// not hide natively describable models.
pub fn available_models(art_dir: &std::path::Path) -> Vec<String> {
    let mut models = if art_dir.join("index.json").exists() {
        Manifest::list_models(art_dir).unwrap_or_default()
    } else {
        Vec::new()
    };
    for m in native::model_names() {
        if !models.contains(&m) {
            models.push(m);
        }
    }
    models
}
