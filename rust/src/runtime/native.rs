//! Native reference backend: pure-Rust forward/backward with per-site
//! fake-quantization, no Python/JAX/XLA anywhere.
//!
//! Two capabilities live here:
//!
//! 1. **Manifest synthesis** for every model family. The model configs
//!    under `configs/models/` are embedded into the binary at compile time
//!    and expanded into [`Manifest`]s by mirroring the plan functions of
//!    `python/compile/models/` name-for-name and shape-for-shape (the same
//!    contract `python/compile/aot.py` exports). This lets the graph /
//!    search-space / BOPs contract tests run with zero artifacts.
//! 2. **`NativeEngine`** — a reference implementation of the `mlp` family
//!    (dense layers + ReLU + softmax cross-entropy) matching
//!    `python/compile/models/cnn.py::make_apply_mlp`: weights fake-quantized
//!    at their sites on the forward pass, activations quantized after each
//!    ReLU, and the backward pass producing clipped-STE weight gradients
//!    plus the eq. (4)-(6) scalar (d, t, q_m) gradients per site — exactly
//!    the `TrainOut` contract of the PJRT engine, so QASSO, subnet
//!    construction and BOPs accounting run unchanged on top of it.

use anyhow::{Context, Result};

use super::{Backend, BatchSpec, EvalOut, HostArray, Manifest, TrainOut};
use crate::graph::builders;
use crate::optim::qasso::SiteSpec;
use crate::quant::{self, QParams};
use crate::tensor::{ParamStore, Tensor};
use crate::util::json::{self, Json};

/// Batch sizes per task, mirroring python/compile/models/__init__.py BATCH.
fn batch_size_for(task: &str) -> usize {
    match task {
        "image_cls" => 32,
        _ => 16, // span_qa, lm
    }
}

/// Model configs embedded at compile time (configs/models/*.json).
const EMBEDDED_CONFIGS: &[(&str, &str)] = &[
    ("bert_mini", include_str!("../../../configs/models/bert_mini.json")),
    ("gpt_mini", include_str!("../../../configs/models/gpt_mini.json")),
    ("mlp_tiny", include_str!("../../../configs/models/mlp_tiny.json")),
    ("resnet_mini", include_str!("../../../configs/models/resnet_mini.json")),
    ("resnet_mini_l", include_str!("../../../configs/models/resnet_mini_l.json")),
    ("simplevit_mini", include_str!("../../../configs/models/simplevit_mini.json")),
    ("swin_mini", include_str!("../../../configs/models/swin_mini.json")),
    ("vgg7_mini", include_str!("../../../configs/models/vgg7_mini.json")),
    ("vit_mini", include_str!("../../../configs/models/vit_mini.json")),
];

/// Names of all embedded model configs.
pub fn model_names() -> Vec<String> {
    EMBEDDED_CONFIGS.iter().map(|(n, _)| n.to_string()).collect()
}

/// Parse the embedded config of `model`.
pub fn embedded_config(model: &str) -> Option<Json> {
    EMBEDDED_CONFIGS
        .iter()
        .find(|(n, _)| *n == model)
        .and_then(|(_, text)| json::parse(text).ok())
}

// ------------------------------------------------------- manifest synthesis

/// Ordered (name, shape) collector mirroring python's `Plan`.
struct PlanParams {
    specs: Vec<(String, Vec<usize>)>,
}

impl PlanParams {
    fn new() -> PlanParams {
        PlanParams { specs: Vec::new() }
    }

    fn param(&mut self, name: &str, shape: &[usize]) {
        self.specs.push((name.to_string(), shape.to_vec()));
    }

    fn linear(&mut self, name: &str, din: usize, dout: usize) {
        self.param(&format!("{name}.weight"), &[din, dout]);
        self.param(&format!("{name}.bias"), &[dout]);
    }

    fn conv(&mut self, name: &str, cin: usize, cout: usize, k: usize) {
        self.param(&format!("{name}.weight"), &[k, k, cin, cout]);
        self.param(&format!("{name}.bias"), &[cout]);
    }

    fn norm(&mut self, name: &str, c: usize) {
        self.param(&format!("{name}.gamma"), &[c]);
        self.param(&format!("{name}.beta"), &[c]);
    }

    fn block(&mut self, name: &str, dim: usize, ratio: usize) {
        self.norm(&format!("{name}.ln1"), dim);
        for p in ["wq", "wk", "wv", "wo"] {
            self.linear(&format!("{name}.attn.{p}"), dim, dim);
        }
        self.norm(&format!("{name}.ln2"), dim);
        self.linear(&format!("{name}.fc1"), dim, dim * ratio);
        self.linear(&format!("{name}.fc2"), dim * ratio, dim);
    }
}

/// Parameter specs of a config, in the python plan order (the HLO input
/// order the AOT manifests export).
fn param_specs(cfg: &Json) -> Result<Vec<(String, Vec<usize>)>> {
    let fam = cfg.req("family")?.as_str().unwrap_or_default();
    let img = |key: &str, default: usize| -> usize {
        cfg.get("image").map(|i| i.usize_or(key, default)).unwrap_or(default)
    };
    let ncls = cfg.usize_or("num_classes", 10);
    let mut p = PlanParams::new();
    match fam {
        "mlp" => {
            let mut din = img("size", 8) * img("size", 8) * img("channels", 3);
            for (i, &dout) in cfg.usize_arr("hidden").iter().enumerate() {
                p.linear(&format!("fc{i}"), din, dout);
                din = dout;
            }
            p.linear("head", din, ncls);
        }
        "vgg" => {
            let channels = cfg.usize_arr("conv_channels");
            let mut cin = img("channels", 3);
            for (i, &cout) in channels.iter().enumerate() {
                p.conv(&format!("features.{i}"), cin, cout, 3);
                p.norm(&format!("features.{i}.bn"), cout);
                cin = cout;
            }
            let npool = channels.len() / cfg.usize_or("pool_every", 2);
            let fmap = img("size", 16) >> npool;
            let mut din = cin * fmap * fmap;
            for (i, &dout) in cfg.usize_arr("fc_dims").iter().enumerate() {
                p.linear(&format!("fc{i}"), din, dout);
                din = dout;
            }
            p.linear("head", din, ncls);
        }
        "resnet" => {
            let stem = cfg.usize_or("stem_channels", 8);
            p.conv("stem", img("channels", 3), stem, 3);
            p.norm("stem.bn", stem);
            let mut cin = stem;
            for (si, &cout) in cfg.usize_arr("stage_channels").iter().enumerate() {
                let stride = if si == 0 { 1 } else { 2 };
                for b in 0..cfg.usize_or("blocks_per_stage", 2) {
                    let s = if b == 0 { stride } else { 1 };
                    let name = format!("stage{si}.{b}");
                    p.conv(&format!("{name}.conv1"), cin, cout, 3);
                    p.norm(&format!("{name}.bn1"), cout);
                    p.conv(&format!("{name}.conv2"), cout, cout, 3);
                    p.norm(&format!("{name}.bn2"), cout);
                    if s != 1 || cin != cout {
                        p.conv(&format!("{name}.proj"), cin, cout, 1);
                        p.norm(&format!("{name}.bnp"), cout);
                    }
                    cin = cout;
                }
            }
            p.linear("head", cin, ncls);
        }
        "bert" | "gpt" => {
            let dim = cfg.usize_or("dim", 64);
            p.param("embed.tok", &[cfg.usize_or("vocab", 128), dim]);
            p.param("embed.pos", &[cfg.usize_or("seq_len", 32), dim]);
            if fam == "bert" {
                p.norm("embed.ln", dim);
            }
            for b in 0..cfg.usize_or("blocks", 2) {
                p.block(&format!("block{b}"), dim, cfg.usize_or("mlp_ratio", 4));
            }
            p.norm("final.ln", dim);
            if fam == "bert" {
                p.linear("span_head", dim, 2);
            } else {
                p.linear("lm_head", dim, cfg.usize_or("vocab", 128));
            }
        }
        "vit" => {
            let dim = cfg.usize_or("dim", 48);
            let patch = cfg.usize_or("patch", 4);
            p.conv("patch_embed", img("channels", 3), dim, patch);
            let mut ntok = (img("size", 16) / patch).pow(2);
            if cfg.str_or("pool", "cls") == "cls" {
                p.param("cls_token", &[1, 1, dim]);
                ntok += 1;
            }
            p.param("pos_embed", &[ntok, dim]);
            for b in 0..cfg.usize_or("blocks", 2) {
                p.block(&format!("block{b}"), dim, cfg.usize_or("mlp_ratio", 4));
            }
            p.norm("final.ln", dim);
            p.linear("head", dim, ncls);
        }
        "swin" => {
            let dims = cfg.usize_arr("stage_dims");
            let stage_blocks = cfg.usize_arr("stage_blocks");
            let patch = cfg.usize_or("patch", 2);
            p.conv("patch_embed", img("channels", 3), dims[0], patch);
            let side = img("size", 16) / patch;
            p.param("pos_embed", &[side * side, dims[0]]);
            for (si, &dim) in dims.iter().enumerate() {
                for b in 0..stage_blocks[si] {
                    p.block(&format!("stage{si}.block{b}"), dim, cfg.usize_or("mlp_ratio", 2));
                }
                if si + 1 < dims.len() {
                    p.linear(&format!("merge{si}"), dim * 4, dims[si + 1]);
                    p.norm(&format!("merge{si}.ln"), dim * 4);
                }
            }
            p.norm("final.ln", *dims.last().unwrap());
            p.linear("head", *dims.last().unwrap(), ncls);
        }
        other => anyhow::bail!("unknown family {other}"),
    }
    Ok(p.specs)
}

/// Synthesize the manifest the AOT pipeline would export for `cfg`,
/// without running Python: params from the plan mirror above, quant sites
/// from the Rust trace-graph builders, batch/eval specs from the task.
pub fn synth_manifest(cfg: &Json) -> Result<Manifest> {
    let task = cfg.str_or("task", "image_cls");
    let params = param_specs(cfg)?;
    let qsites: Vec<SiteSpec> = builders::quant_sites(cfg)?
        .into_iter()
        .map(|(name, kind)| SiteSpec {
            param: (kind == "weight").then(|| name.clone()),
            name,
        })
        .collect();
    let bsz = batch_size_for(&task);
    let seq = cfg.usize_or("seq_len", 32);
    let (x_shape, x_dtype, y_shape, y_dtype) = match task.as_str() {
        "image_cls" => {
            let img = cfg.req("image")?;
            let s = img.usize_or("size", 8);
            let c = img.usize_or("channels", 3);
            (vec![bsz, s, s, c], "f32", vec![bsz], "i32")
        }
        "span_qa" => (vec![bsz, seq], "i32", vec![bsz, 2], "i32"),
        "lm" => (vec![bsz, seq], "i32", vec![bsz, seq], "i32"),
        other => anyhow::bail!("unknown task {other}"),
    };
    let eval_outputs: Vec<String> = match task.as_str() {
        "image_cls" => vec!["loss", "correct"],
        "span_qa" => vec!["loss", "correct", "pred_start", "pred_end"],
        "lm" => vec!["loss", "correct", "mask_count"],
        _ => unreachable!(),
    }
    .into_iter()
    .map(String::from)
    .collect();
    let param_count = params.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    Ok(Manifest {
        model: cfg.str_or("name", ""),
        task,
        config: cfg.clone(),
        train_hlo: String::new(),
        eval_hlo: String::new(),
        q_rows: qsites.len().max(1),
        params,
        qsites,
        batch: BatchSpec {
            x_shape,
            x_dtype: x_dtype.to_string(),
            y_shape,
            y_dtype: y_dtype.to_string(),
        },
        eval_outputs,
        param_count,
    })
}

/// [`synth_manifest`] for an embedded config by model name.
pub fn synth_manifest_for(model: &str) -> Result<Manifest> {
    let cfg = embedded_config(model)
        .with_context(|| format!("no embedded config for model `{model}`"))?;
    synth_manifest(&cfg)
}

// ------------------------------------------------------------ NativeEngine

fn param_shape<'m>(manifest: &'m Manifest, name: &str) -> Result<&'m Vec<usize>> {
    manifest
        .params
        .iter()
        .find(|(p, _)| p == name)
        .map(|(_, s)| s)
        .with_context(|| format!("manifest missing {name}"))
}

/// Pure-Rust MLP engine (see module docs). One instance per model.
pub struct NativeEngine {
    manifest: Manifest,
    /// Layer widths `[din, hidden..., num_classes]`.
    dims: Vec<usize>,
    /// Per linear layer (incl. head): quant-site row of its weight.
    weight_site: Vec<Option<usize>>,
    /// Per hidden layer: quant-site row of its post-ReLU activation.
    act_site: Vec<Option<usize>>,
    /// Per linear layer: parameter names ("fcN"/"head").
    layer_names: Vec<String>,
}

impl NativeEngine {
    pub fn new(model: &str) -> Result<NativeEngine> {
        let cfg = embedded_config(model)
            .with_context(|| format!("no embedded config for model `{model}`"))?;
        let family = cfg.str_or("family", "");
        anyhow::ensure!(
            family == "mlp",
            "native backend implements family `mlp` only (got `{family}` for `{model}`); \
             run `make artifacts` and build with `--features pjrt` for the full zoo"
        );
        let manifest = synth_manifest(&cfg)?;
        let mut layer_names: Vec<String> = (0..cfg.usize_arr("hidden").len())
            .map(|i| format!("fc{i}"))
            .collect();
        layer_names.push("head".to_string());
        // derive the layer widths from the manifest's own weight shapes so
        // the engine cannot desync from the params it just planned
        let mut dims = vec![param_shape(&manifest, &format!("{}.weight", layer_names[0]))?[0]];
        for n in &layer_names {
            dims.push(param_shape(&manifest, &format!("{n}.weight"))?[1]);
        }
        let site_idx = |name: &str| -> Option<usize> {
            manifest.qsites.iter().position(|s| s.name == name)
        };
        let weight_site = layer_names
            .iter()
            .map(|n| site_idx(&format!("{n}.weight")))
            .collect();
        let act_site = (0..layer_names.len() - 1)
            .map(|i| site_idx(&format!("fc{i}.act")))
            .collect();
        Ok(NativeEngine {
            manifest,
            dims,
            weight_site,
            act_site,
            layer_names,
        })
    }

    fn weight<'a>(&self, params: &'a ParamStore, layer: usize) -> Result<&'a Tensor> {
        params
            .get(&format!("{}.weight", self.layer_names[layer]))
            .with_context(|| format!("missing weight for layer {}", self.layer_names[layer]))
    }

    fn bias<'a>(&self, params: &'a ParamStore, layer: usize) -> Result<&'a Tensor> {
        params
            .get(&format!("{}.bias", self.layer_names[layer]))
            .with_context(|| format!("missing bias for layer {}", self.layer_names[layer]))
    }

    /// Forward (and optionally backward) over one batch.
    fn run(
        &self,
        params: &ParamStore,
        q: &[QParams],
        x: &HostArray,
        y: &HostArray,
        with_grads: bool,
    ) -> Result<(f32, f32, Option<(ParamStore, Vec<(f32, f32, f32)>)>)> {
        let m = &self.manifest;
        let nl = self.dims.len() - 1; // linear layers incl. head
        let b = m.batch.batch_size();
        let ncls = self.dims[nl];
        let HostArray::F32(xv) = x else {
            anyhow::bail!("mlp expects f32 inputs")
        };
        let HostArray::I32(yv) = y else {
            anyhow::bail!("mlp expects i32 labels")
        };
        anyhow::ensure!(xv.len() == b * self.dims[0], "x size mismatch");
        anyhow::ensure!(yv.len() == b, "y size mismatch");
        anyhow::ensure!(q.len() == m.qsites.len(), "qparam count mismatch");

        // ---- fake-quantized weights per site (eq. 1-2 on the fwd pass)
        let mut wq: Vec<Vec<f32>> = Vec::with_capacity(nl);
        for l in 0..nl {
            let w = &self.weight(params, l)?.data;
            wq.push(match self.weight_site[l] {
                Some(s) => w.iter().map(|&v| quant::fake_quant(v, &q[s])).collect(),
                None => w.clone(),
            });
        }

        // ---- forward
        // inputs[l] = the (quantized) activations feeding layer l
        let mut inputs: Vec<Vec<f32>> = Vec::with_capacity(nl);
        inputs.push(xv.clone());
        // post-ReLU, pre-act-quant activations of each hidden layer
        let mut relu_out: Vec<Vec<f32>> = Vec::with_capacity(nl - 1);
        for l in 0..nl - 1 {
            let bias = &self.bias(params, l)?.data;
            let mut z = affine(&inputs[l], &wq[l], bias, b, self.dims[l], self.dims[l + 1]);
            for v in z.iter_mut() {
                *v = v.max(0.0);
            }
            let aq = match self.act_site[l] {
                Some(s) => z.iter().map(|&v| quant::fake_quant(v, &q[s])).collect(),
                None => z.clone(),
            };
            relu_out.push(z);
            inputs.push(aq);
        }
        let head_bias = &self.bias(params, nl - 1)?.data;
        let logits = affine(
            &inputs[nl - 1],
            &wq[nl - 1],
            head_bias,
            b,
            self.dims[nl - 1],
            ncls,
        );

        // ---- softmax cross-entropy + correct count
        let mut probs = logits;
        let mut loss = 0.0f64;
        let mut correct = 0.0f32;
        for i in 0..b {
            let row = &mut probs[i * ncls..(i + 1) * ncls];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f64;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                sum += *v as f64;
            }
            for v in row.iter_mut() {
                *v = (*v as f64 / sum) as f32;
            }
            let mut argmax = 0;
            for j in 1..ncls {
                if row[j] > row[argmax] {
                    argmax = j;
                }
            }
            let label = yv[i] as usize;
            anyhow::ensure!(label < ncls, "label {label} out of range");
            loss -= (row[label].max(1e-12) as f64).ln();
            if argmax == label {
                correct += 1.0;
            }
        }
        let loss = (loss / b as f64) as f32;
        if !with_grads {
            return Ok((loss, correct, None));
        }

        // ---- backward
        let mut grads = params.zeros_like();
        let mut qgrads = vec![(0.0f32, 0.0f32, 0.0f32); m.qsites.len()];
        // d loss / d logits
        let mut cot = probs;
        for i in 0..b {
            cot[i * ncls + yv[i] as usize] -= 1.0;
        }
        let scale = 1.0 / b as f32;
        for v in cot.iter_mut() {
            *v *= scale;
        }
        for l in (0..nl).rev() {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            // grads wrt the *quantized* weight, then STE back to the raw one
            let mut gw = grad_weights(&inputs[l], &cot, b, din, dout);
            if let Some(s) = self.weight_site[l] {
                let w = &self.weight(params, l)?.data;
                let qg = &mut qgrads[s];
                for (i, &wi) in w.iter().enumerate() {
                    let g = gw[i];
                    qg.0 += g * quant::grad_d(wi, &q[s]);
                    qg.1 += g * quant::grad_t(wi, &q[s]);
                    qg.2 += g * quant::grad_qm(wi, &q[s]);
                    // clipped STE: pass-through inside the clip range only
                    if wi.abs() > q[s].qm {
                        gw[i] = 0.0;
                    }
                }
            }
            let name = &self.layer_names[l];
            grads
                .get_mut(&format!("{name}.weight"))
                .with_context(|| format!("grad store missing {name}.weight"))?
                .data
                .copy_from_slice(&gw);
            let gb = &mut grads
                .get_mut(&format!("{name}.bias"))
                .with_context(|| format!("grad store missing {name}.bias"))?
                .data;
            for i in 0..b {
                for j in 0..dout {
                    gb[j] += cot[i * dout + j];
                }
            }
            if l == 0 {
                break;
            }
            // propagate to the layer input: cot @ wq^T
            let mut gh = matmul_nt(&cot, &wq[l], b, dout, din);
            // through the activation fake-quant (contract before masking:
            // the site grads use the cotangent wrt the quantizer *output*)
            if let Some(s) = self.act_site[l - 1] {
                let a = &relu_out[l - 1];
                let qg = &mut qgrads[s];
                for (i, &ai) in a.iter().enumerate() {
                    let g = gh[i];
                    qg.0 += g * quant::grad_d(ai, &q[s]);
                    qg.1 += g * quant::grad_t(ai, &q[s]);
                    qg.2 += g * quant::grad_qm(ai, &q[s]);
                    if ai.abs() > q[s].qm {
                        gh[i] = 0.0;
                    }
                }
            }
            // through the ReLU
            for (i, &ai) in relu_out[l - 1].iter().enumerate() {
                if ai <= 0.0 {
                    gh[i] = 0.0;
                }
            }
            cot = gh;
        }
        Ok((loss, correct, Some((grads, qgrads))))
    }
}

impl Backend for NativeEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn platform(&self) -> String {
        "native".to_string()
    }

    fn train_step(
        &self,
        params: &ParamStore,
        q: &[QParams],
        x: &HostArray,
        y: &HostArray,
    ) -> Result<TrainOut> {
        let (loss, metric, g) = self.run(params, q, x, y, true)?;
        let (grads, qgrads) = g.expect("grads requested");
        Ok(TrainOut {
            loss,
            grads,
            qgrads,
            metric,
        })
    }

    fn eval_step(
        &self,
        params: &ParamStore,
        q: &[QParams],
        x: &HostArray,
        y: &HostArray,
    ) -> Result<EvalOut> {
        let (loss, metric, _) = self.run(params, q, x, y, false)?;
        Ok(EvalOut {
            loss,
            metric,
            extra: Vec::new(),
        })
    }
}

// ----------------------------------------------------------- dense kernels

/// `x[b, din] @ w[din, dout] + bias[dout]` (row-major flat buffers).
fn affine(x: &[f32], w: &[f32], bias: &[f32], b: usize, din: usize, dout: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), b * din);
    debug_assert_eq!(w.len(), din * dout);
    let mut out = vec![0.0f32; b * dout];
    for i in 0..b {
        let xrow = &x[i * din..(i + 1) * din];
        let orow = &mut out[i * dout..(i + 1) * dout];
        orow.copy_from_slice(bias);
        for (k, &xk) in xrow.iter().enumerate() {
            if xk == 0.0 {
                continue;
            }
            let wrow = &w[k * dout..(k + 1) * dout];
            crate::tensor::axpy(xk, wrow, orow);
        }
    }
    out
}

/// `x[b, din]^T @ cot[b, dout]` -> grads `[din, dout]`.
fn grad_weights(x: &[f32], cot: &[f32], b: usize, din: usize, dout: usize) -> Vec<f32> {
    let mut gw = vec![0.0f32; din * dout];
    for i in 0..b {
        let xrow = &x[i * din..(i + 1) * din];
        let crow = &cot[i * dout..(i + 1) * dout];
        for (k, &xk) in xrow.iter().enumerate() {
            if xk == 0.0 {
                continue;
            }
            crate::tensor::axpy(xk, crow, &mut gw[k * dout..(k + 1) * dout]);
        }
    }
    gw
}

/// `cot[b, dout] @ w[din, dout]^T` -> `[b, din]`.
fn matmul_nt(cot: &[f32], w: &[f32], b: usize, dout: usize, din: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; b * din];
    for i in 0..b {
        let crow = &cot[i * dout..(i + 1) * dout];
        let orow = &mut out[i * din..(i + 1) * din];
        for k in 0..din {
            orow[k] = crate::tensor::dot(crow, &w[k * dout..(k + 1) * dout]) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Backend;

    fn engine() -> NativeEngine {
        NativeEngine::new("mlp_tiny").unwrap()
    }

    fn batch(e: &NativeEngine, seed: u64) -> (HostArray, HostArray) {
        let m = e.manifest();
        let (train, _) = crate::data::SynthData::for_model(&m.config, 64, 32, seed);
        let idxs: Vec<usize> = (0..m.batch.batch_size()).collect();
        train.batch(&idxs)
    }

    #[test]
    fn synth_manifests_match_aot_contract() {
        for model in model_names() {
            let man = synth_manifest_for(&model).unwrap();
            assert_eq!(man.model, model);
            assert!(!man.params.is_empty(), "{model}");
            assert!(man.param_count > 0, "{model}");
            let total: usize = man.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
            assert_eq!(total, man.param_count, "{model}");
            // site order must equal the Rust builders' order (the same
            // invariant the AOT manifests are tested for)
            let sites = builders::quant_sites(&man.config).unwrap();
            assert_eq!(man.qsites.len(), sites.len(), "{model}");
            for (a, (bname, kind)) in man.qsites.iter().zip(&sites) {
                assert_eq!(&a.name, bname, "{model}");
                assert_eq!(a.param.is_some(), kind == "weight", "{model}");
            }
        }
    }

    #[test]
    fn native_gradients_match_finite_differences() {
        let e = engine();
        let params = e.init_params(3);
        // 16-bit quantizers: d is tiny, so central differences spanning many
        // rounding steps recover the smooth slope the STE gradient models
        let q = e.init_qparams(&params, 16.0);
        let (x, y) = batch(&e, 5);
        let out = e.train_step(&params, &q, &x, &y).unwrap();
        let h = 1e-2f32;
        let mut checked = 0;
        for (ti, t) in params.tensors.iter().enumerate() {
            let site = e
                .manifest()
                .qsites
                .iter()
                .position(|s| s.param.as_deref() == Some(t.name.as_str()));
            for &ei in &[0usize, t.data.len() / 2, t.data.len() - 1] {
                // near the clip boundary the STE and the true slope
                // legitimately disagree — skip those probes
                if let Some(s) = site {
                    if t.data[ei].abs() + h >= q[s].qm {
                        continue;
                    }
                }
                let mut p1 = params.clone();
                p1.tensors[ti].data[ei] += h;
                let l1 = e.eval_step(&p1, &q, &x, &y).unwrap().loss;
                let mut p2 = params.clone();
                p2.tensors[ti].data[ei] -= h;
                let l2 = e.eval_step(&p2, &q, &x, &y).unwrap().loss;
                let fd = (l1 - l2) / (2.0 * h);
                let an = out.grads.tensors[ti].data[ei];
                assert!(
                    (an - fd).abs() < 0.02 + 0.1 * an.abs().max(fd.abs()),
                    "{}[{ei}]: analytic {an} vs fd {fd}",
                    t.name
                );
                checked += 1;
            }
        }
        assert!(checked >= 12, "only {checked} probes ran");
    }

    #[test]
    fn native_sgd_reduces_loss() {
        // mirror of python/tests/test_models.py::test_sgd_reduces_loss
        let e = engine();
        let mut params = e.init_params(0);
        let q = e.init_qparams(&params, 16.0);
        let (x, y) = batch(&e, 7);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..6 {
            let out = e.train_step(&params, &q, &x, &y).unwrap();
            first.get_or_insert(out.loss);
            last = out.loss;
            for (ti, t) in out.grads.tensors.iter().enumerate() {
                for (i, g) in t.data.iter().enumerate() {
                    params.tensors[ti].data[i] -= 0.05 * g;
                }
            }
        }
        assert!(last < first.unwrap(), "{first:?} -> {last}");
    }

    #[test]
    fn quant_param_gradients_are_live() {
        let e = engine();
        let params = e.init_params(1);
        // coarse quantizer => large rounding residuals => nonzero d-grads
        let q = e.init_qparams(&params, 4.0);
        let (x, y) = batch(&e, 9);
        let out = e.train_step(&params, &q, &x, &y).unwrap();
        assert_eq!(out.qgrads.len(), e.manifest().qsites.len());
        let live = out
            .qgrads
            .iter()
            .any(|g| g.0.abs() + g.1.abs() + g.2.abs() > 0.0);
        assert!(live, "all quant-param gradients zero: {:?}", out.qgrads);
    }

    #[test]
    fn bits_change_the_loss() {
        let e = engine();
        let params = e.init_params(2);
        let (x, y) = batch(&e, 11);
        let hi = e.init_qparams(&params, 16.0);
        let lo = e.init_qparams(&params, 2.0);
        let l_hi = e.eval_step(&params, &hi, &x, &y).unwrap().loss;
        let l_lo = e.eval_step(&params, &lo, &x, &y).unwrap().loss;
        assert!((l_hi - l_lo).abs() > 1e-6, "{l_hi} vs {l_lo}");
    }

    #[test]
    fn unsupported_family_reports_fix() {
        let err = NativeEngine::new("bert_mini").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        assert!(NativeEngine::new("nope").is_err());
    }
}
