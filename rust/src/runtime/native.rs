//! Native reference backend: pure-Rust forward/backward with per-site
//! fake-quantization, no Python/JAX/XLA anywhere.
//!
//! Two capabilities live here:
//!
//! 1. **Manifest synthesis** for every model family. The model configs
//!    under `configs/models/` are embedded into the binary at compile time
//!    and expanded into [`Manifest`]s by mirroring the plan functions of
//!    `python/compile/models/` name-for-name and shape-for-shape (the same
//!    contract `python/compile/aot.py` exports). This lets the graph /
//!    search-space / BOPs contract tests run with zero artifacts.
//! 2. **`NativeEngine`** — a manifest-driven interpreter covering every
//!    zoo family (conv *and* attention). The model config is lowered to a
//!    typed op IR (`runtime/lowering.rs`: linear, conv-as-im2col,
//!    batch/layer norm, residual add, multi-head attention, gelu/relu,
//!    patch embed/merge, pooling) and executed by `runtime/interp.rs`:
//!    weights fake-quantized at their sites on the forward pass,
//!    activation sites quantized in place, and the backward pass producing
//!    clipped-STE weight gradients plus the eq. (4)-(6) scalar (d, t, q_m)
//!    gradients per site — exactly the `TrainOut` contract of the PJRT
//!    engine, so QASSO, subnet construction and BOPs accounting run
//!    unchanged on top of it.

use anyhow::{Context, Result};

use super::exec;
use super::lowering::{self, Program};
use super::{interp, Backend, BatchSpec, EvalOut, HostArray, Manifest, TrainOut};
use crate::graph::builders;
use crate::quant::QParams;
use crate::tensor::ParamStore;
use crate::util::json::{self, Json};

pub use super::lowering::lowered_families;

/// Batch sizes per task, mirroring python/compile/models/__init__.py BATCH.
/// Public: the deployment engine uses it as the default inference
/// micro-batch (normalization-statistics granularity).
pub fn batch_size_for(task: &str) -> usize {
    match task {
        "image_cls" => 32,
        _ => 16, // span_qa, lm
    }
}

/// Model configs embedded at compile time (configs/models/*.json).
const EMBEDDED_CONFIGS: &[(&str, &str)] = &[
    ("bert_mini", include_str!("../../../configs/models/bert_mini.json")),
    ("gpt_mini", include_str!("../../../configs/models/gpt_mini.json")),
    ("mlp_tiny", include_str!("../../../configs/models/mlp_tiny.json")),
    ("resnet_mini", include_str!("../../../configs/models/resnet_mini.json")),
    ("resnet_mini_l", include_str!("../../../configs/models/resnet_mini_l.json")),
    ("simplevit_mini", include_str!("../../../configs/models/simplevit_mini.json")),
    ("swin_mini", include_str!("../../../configs/models/swin_mini.json")),
    ("vgg7_mini", include_str!("../../../configs/models/vgg7_mini.json")),
    ("vit_mini", include_str!("../../../configs/models/vit_mini.json")),
];

/// Names of all embedded model configs.
pub fn model_names() -> Vec<String> {
    EMBEDDED_CONFIGS.iter().map(|(n, _)| n.to_string()).collect()
}

/// Parse the embedded config of `model`.
pub fn embedded_config(model: &str) -> Option<Json> {
    EMBEDDED_CONFIGS
        .iter()
        .find(|(n, _)| *n == model)
        .and_then(|(_, text)| json::parse(text).ok())
}

// ------------------------------------------------------- manifest synthesis

/// Ordered (name, shape) collector mirroring python's `Plan`.
struct PlanParams {
    specs: Vec<(String, Vec<usize>)>,
}

impl PlanParams {
    fn new() -> PlanParams {
        PlanParams { specs: Vec::new() }
    }

    fn param(&mut self, name: &str, shape: &[usize]) {
        self.specs.push((name.to_string(), shape.to_vec()));
    }

    fn linear(&mut self, name: &str, din: usize, dout: usize) {
        self.param(&format!("{name}.weight"), &[din, dout]);
        self.param(&format!("{name}.bias"), &[dout]);
    }

    fn conv(&mut self, name: &str, cin: usize, cout: usize, k: usize) {
        self.param(&format!("{name}.weight"), &[k, k, cin, cout]);
        self.param(&format!("{name}.bias"), &[cout]);
    }

    fn norm(&mut self, name: &str, c: usize) {
        self.param(&format!("{name}.gamma"), &[c]);
        self.param(&format!("{name}.beta"), &[c]);
    }

    fn block(&mut self, name: &str, dim: usize, ratio: usize) {
        self.norm(&format!("{name}.ln1"), dim);
        for p in ["wq", "wk", "wv", "wo"] {
            self.linear(&format!("{name}.attn.{p}"), dim, dim);
        }
        self.norm(&format!("{name}.ln2"), dim);
        self.linear(&format!("{name}.fc1"), dim, dim * ratio);
        self.linear(&format!("{name}.fc2"), dim * ratio, dim);
    }
}

/// Parameter specs of a config, in the python plan order (the HLO input
/// order the AOT manifests export).
fn param_specs(cfg: &Json) -> Result<Vec<(String, Vec<usize>)>> {
    let fam = cfg.req("family")?.as_str().unwrap_or_default();
    let img = |key: &str, default: usize| -> usize {
        cfg.get("image").map(|i| i.usize_or(key, default)).unwrap_or(default)
    };
    let ncls = cfg.usize_or("num_classes", 10);
    let mut p = PlanParams::new();
    match fam {
        "mlp" => {
            let mut din = img("size", 8) * img("size", 8) * img("channels", 3);
            for (i, &dout) in cfg.usize_arr("hidden").iter().enumerate() {
                p.linear(&format!("fc{i}"), din, dout);
                din = dout;
            }
            p.linear("head", din, ncls);
        }
        "vgg" => {
            let channels = cfg.usize_arr("conv_channels");
            let mut cin = img("channels", 3);
            for (i, &cout) in channels.iter().enumerate() {
                p.conv(&format!("features.{i}"), cin, cout, 3);
                p.norm(&format!("features.{i}.bn"), cout);
                cin = cout;
            }
            let npool = channels.len() / cfg.usize_or("pool_every", 2);
            let fmap = img("size", 16) >> npool;
            let mut din = cin * fmap * fmap;
            for (i, &dout) in cfg.usize_arr("fc_dims").iter().enumerate() {
                p.linear(&format!("fc{i}"), din, dout);
                din = dout;
            }
            p.linear("head", din, ncls);
        }
        "resnet" => {
            let stem = cfg.usize_or("stem_channels", 8);
            p.conv("stem", img("channels", 3), stem, 3);
            p.norm("stem.bn", stem);
            let mut cin = stem;
            for (si, &cout) in cfg.usize_arr("stage_channels").iter().enumerate() {
                let stride = if si == 0 { 1 } else { 2 };
                for b in 0..cfg.usize_or("blocks_per_stage", 2) {
                    let s = if b == 0 { stride } else { 1 };
                    let name = format!("stage{si}.{b}");
                    p.conv(&format!("{name}.conv1"), cin, cout, 3);
                    p.norm(&format!("{name}.bn1"), cout);
                    p.conv(&format!("{name}.conv2"), cout, cout, 3);
                    p.norm(&format!("{name}.bn2"), cout);
                    if s != 1 || cin != cout {
                        p.conv(&format!("{name}.proj"), cin, cout, 1);
                        p.norm(&format!("{name}.bnp"), cout);
                    }
                    cin = cout;
                }
            }
            p.linear("head", cin, ncls);
        }
        "bert" | "gpt" => {
            let dim = cfg.usize_or("dim", 64);
            p.param("embed.tok", &[cfg.usize_or("vocab", 128), dim]);
            p.param("embed.pos", &[cfg.usize_or("seq_len", 32), dim]);
            if fam == "bert" {
                p.norm("embed.ln", dim);
            }
            for b in 0..cfg.usize_or("blocks", 2) {
                p.block(&format!("block{b}"), dim, cfg.usize_or("mlp_ratio", 4));
            }
            p.norm("final.ln", dim);
            if fam == "bert" {
                p.linear("span_head", dim, 2);
            } else {
                p.linear("lm_head", dim, cfg.usize_or("vocab", 128));
            }
        }
        "vit" => {
            let dim = cfg.usize_or("dim", 48);
            let patch = cfg.usize_or("patch", 4);
            p.conv("patch_embed", img("channels", 3), dim, patch);
            let mut ntok = (img("size", 16) / patch).pow(2);
            if cfg.str_or("pool", "cls") == "cls" {
                p.param("cls_token", &[1, 1, dim]);
                ntok += 1;
            }
            p.param("pos_embed", &[ntok, dim]);
            for b in 0..cfg.usize_or("blocks", 2) {
                p.block(&format!("block{b}"), dim, cfg.usize_or("mlp_ratio", 4));
            }
            p.norm("final.ln", dim);
            p.linear("head", dim, ncls);
        }
        "swin" => {
            let dims = cfg.usize_arr("stage_dims");
            let stage_blocks = cfg.usize_arr("stage_blocks");
            let patch = cfg.usize_or("patch", 2);
            p.conv("patch_embed", img("channels", 3), dims[0], patch);
            let side = img("size", 16) / patch;
            p.param("pos_embed", &[side * side, dims[0]]);
            for (si, &dim) in dims.iter().enumerate() {
                for b in 0..stage_blocks[si] {
                    p.block(&format!("stage{si}.block{b}"), dim, cfg.usize_or("mlp_ratio", 2));
                }
                if si + 1 < dims.len() {
                    p.linear(&format!("merge{si}"), dim * 4, dims[si + 1]);
                    p.norm(&format!("merge{si}.ln"), dim * 4);
                }
            }
            p.norm("final.ln", *dims.last().unwrap());
            p.linear("head", *dims.last().unwrap(), ncls);
        }
        other => anyhow::bail!("unknown family {other}"),
    }
    Ok(p.specs)
}

/// Synthesize the manifest the AOT pipeline would export for `cfg`,
/// without running Python: params from the plan mirror above, quant sites
/// from the Rust trace-graph builders, batch/eval specs from the task.
pub fn synth_manifest(cfg: &Json) -> Result<Manifest> {
    let task = cfg.str_or("task", "image_cls");
    let params = param_specs(cfg)?;
    let qsites = builders::quant_site_specs(cfg)?;
    let bsz = batch_size_for(&task);
    let seq = cfg.usize_or("seq_len", 32);
    let (x_shape, x_dtype, y_shape, y_dtype) = match task.as_str() {
        "image_cls" => {
            let img = cfg.req("image")?;
            let s = img.usize_or("size", 8);
            let c = img.usize_or("channels", 3);
            (vec![bsz, s, s, c], "f32", vec![bsz], "i32")
        }
        "span_qa" => (vec![bsz, seq], "i32", vec![bsz, 2], "i32"),
        "lm" => (vec![bsz, seq], "i32", vec![bsz, seq], "i32"),
        other => anyhow::bail!("unknown task {other}"),
    };
    let eval_outputs: Vec<String> = match task.as_str() {
        "image_cls" => vec!["loss", "correct"],
        "span_qa" => vec!["loss", "correct", "pred_start", "pred_end"],
        "lm" => vec!["loss", "correct", "mask_count"],
        _ => unreachable!(),
    }
    .into_iter()
    .map(String::from)
    .collect();
    let param_count = params.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    Ok(Manifest {
        model: cfg.str_or("name", ""),
        task,
        config: cfg.clone(),
        train_hlo: String::new(),
        eval_hlo: String::new(),
        q_rows: qsites.len().max(1),
        params,
        qsites,
        batch: BatchSpec {
            x_shape,
            x_dtype: x_dtype.to_string(),
            y_shape,
            y_dtype: y_dtype.to_string(),
        },
        eval_outputs,
        param_count,
    })
}

/// [`synth_manifest`] for an embedded config by model name.
pub fn synth_manifest_for(model: &str) -> Result<Manifest> {
    let cfg = embedded_config(model)
        .with_context(|| format!("no embedded config for model `{model}`"))?;
    synth_manifest(&cfg)
}

// ------------------------------------------------------------ NativeEngine

/// Manifest-driven interpreter engine (see module docs). One instance per
/// model; covers every family in [`lowered_families`]. The shape-resolved
/// execution [`exec::Plan`] is built once here and reused by every step,
/// and the buffer arena carries forward/scratch allocations across steps
/// (`RefCell`: the [`Backend`] trait is deliberately not thread-shared —
/// worker pools construct one engine per thread).
pub struct NativeEngine {
    manifest: Manifest,
    program: Program,
    plan: exec::Plan,
    arena: std::cell::RefCell<exec::Arena>,
}

impl NativeEngine {
    pub fn new(model: &str) -> Result<NativeEngine> {
        let cfg = embedded_config(model)
            .with_context(|| format!("no embedded config for model `{model}`"))?;
        NativeEngine::from_config(&cfg)
    }

    /// Build an engine for an arbitrary config (tests drive tiny custom
    /// configs through the full synth-manifest + lowering pipeline).
    pub fn from_config(cfg: &Json) -> Result<NativeEngine> {
        let manifest = synth_manifest(cfg)?;
        let bsz = manifest.batch.batch_size();
        let program = lowering::lower(cfg, &manifest.qsites, bsz)?;
        let plan = exec::Plan::new(&program, bsz);
        Ok(NativeEngine {
            manifest,
            program,
            plan,
            arena: std::cell::RefCell::new(exec::Arena::new()),
        })
    }

    /// Build an engine around an already-lowered program — the
    /// shrink-as-you-train re-planner hands in the sliced program from
    /// `subnet::propagate_slices` with the original manifest (batch specs
    /// and quant-site order are slicing-invariant). A fresh Plan and Arena
    /// are built for the shrunken shapes.
    pub fn with_program(manifest: Manifest, program: Program) -> NativeEngine {
        let plan = exec::Plan::new(&program, manifest.batch.batch_size());
        NativeEngine {
            manifest,
            program,
            plan,
            arena: std::cell::RefCell::new(exec::Arena::new()),
        }
    }

    /// The lowered op program this engine executes.
    pub fn program(&self) -> &Program {
        &self.program
    }

    /// The shape-resolved execution plan (built once at construction).
    pub fn plan(&self) -> &exec::Plan {
        &self.plan
    }
}

impl Backend for NativeEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn as_native(&self) -> Option<&NativeEngine> {
        Some(self)
    }

    fn platform(&self) -> String {
        "native".to_string()
    }

    fn train_step(
        &self,
        params: &ParamStore,
        q: &[QParams],
        x: &HostArray,
        y: &HostArray,
    ) -> Result<TrainOut> {
        let mut arena = self.arena.borrow_mut();
        let out = interp::run(
            &self.program,
            &self.plan,
            self.manifest.qsites.len(),
            params,
            q,
            x,
            y,
            true,
            &mut arena,
        )?;
        let (grads, qgrads) = out.grads.expect("training pass produces gradients");
        Ok(TrainOut {
            loss: out.loss,
            grads,
            qgrads,
            metric: out.metric,
        })
    }

    fn eval_step(
        &self,
        params: &ParamStore,
        q: &[QParams],
        x: &HostArray,
        y: &HostArray,
    ) -> Result<EvalOut> {
        let mut arena = self.arena.borrow_mut();
        let out = interp::run(
            &self.program,
            &self.plan,
            self.manifest.qsites.len(),
            params,
            q,
            x,
            y,
            false,
            &mut arena,
        )?;
        Ok(EvalOut {
            loss: out.loss,
            metric: out.metric,
            extra: out.extra,
        })
    }

    fn eval_logits(
        &self,
        params: &ParamStore,
        q: &[QParams],
        x: &HostArray,
        y: &HostArray,
    ) -> Result<Vec<f32>> {
        let mut arena = self.arena.borrow_mut();
        let out = interp::run(
            &self.program,
            &self.plan,
            self.manifest.qsites.len(),
            params,
            q,
            x,
            y,
            false,
            &mut arena,
        )?;
        Ok(out.logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Backend;

    fn engine() -> NativeEngine {
        NativeEngine::new("mlp_tiny").unwrap()
    }

    fn batch(e: &NativeEngine, seed: u64) -> (HostArray, HostArray) {
        let m = e.manifest();
        let (train, _) = crate::data::SynthData::for_model(&m.config, 64, 32, seed);
        let idxs: Vec<usize> = (0..m.batch.batch_size()).collect();
        train.batch(&idxs)
    }

    #[test]
    fn synth_manifests_match_aot_contract() {
        for model in model_names() {
            let man = synth_manifest_for(&model).unwrap();
            assert_eq!(man.model, model);
            assert!(!man.params.is_empty(), "{model}");
            assert!(man.param_count > 0, "{model}");
            let total: usize = man.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
            assert_eq!(total, man.param_count, "{model}");
            // site order must equal the Rust builders' order (the same
            // invariant the AOT manifests are tested for)
            let sites = builders::quant_sites(&man.config).unwrap();
            assert_eq!(man.qsites.len(), sites.len(), "{model}");
            for (a, (bname, kind)) in man.qsites.iter().zip(&sites) {
                assert_eq!(&a.name, bname, "{model}");
                assert_eq!(a.param.is_some(), kind == "weight", "{model}");
            }
        }
    }

    #[test]
    fn native_gradients_match_finite_differences() {
        let e = engine();
        let params = e.init_params(3);
        // 16-bit quantizers: d is tiny, so central differences spanning many
        // rounding steps recover the smooth slope the STE gradient models
        let q = e.init_qparams(&params, 16.0);
        let (x, y) = batch(&e, 5);
        let out = e.train_step(&params, &q, &x, &y).unwrap();
        let h = 1e-2f32;
        let mut checked = 0;
        for (ti, t) in params.tensors.iter().enumerate() {
            let site = e
                .manifest()
                .qsites
                .iter()
                .position(|s| s.param.as_deref() == Some(t.name.as_str()));
            for &ei in &[0usize, t.data.len() / 2, t.data.len() - 1] {
                // near the clip boundary the STE and the true slope
                // legitimately disagree — skip those probes
                if let Some(s) = site {
                    if t.data[ei].abs() + h >= q[s].qm {
                        continue;
                    }
                }
                let mut p1 = params.clone();
                p1.tensors[ti].data[ei] += h;
                let l1 = e.eval_step(&p1, &q, &x, &y).unwrap().loss;
                let mut p2 = params.clone();
                p2.tensors[ti].data[ei] -= h;
                let l2 = e.eval_step(&p2, &q, &x, &y).unwrap().loss;
                let fd = (l1 - l2) / (2.0 * h);
                let an = out.grads.tensors[ti].data[ei];
                assert!(
                    (an - fd).abs() < 0.02 + 0.1 * an.abs().max(fd.abs()),
                    "{}[{ei}]: analytic {an} vs fd {fd}",
                    t.name
                );
                checked += 1;
            }
        }
        assert!(checked >= 12, "only {checked} probes ran");
    }

    #[test]
    fn native_sgd_reduces_loss() {
        // mirror of python/tests/test_models.py::test_sgd_reduces_loss
        let e = engine();
        let mut params = e.init_params(0);
        let q = e.init_qparams(&params, 16.0);
        let (x, y) = batch(&e, 7);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..6 {
            let out = e.train_step(&params, &q, &x, &y).unwrap();
            first.get_or_insert(out.loss);
            last = out.loss;
            for (ti, t) in out.grads.tensors.iter().enumerate() {
                for (i, g) in t.data.iter().enumerate() {
                    params.tensors[ti].data[i] -= 0.05 * g;
                }
            }
        }
        assert!(last < first.unwrap(), "{first:?} -> {last}");
    }

    #[test]
    fn quant_param_gradients_are_live() {
        let e = engine();
        let params = e.init_params(1);
        // coarse quantizer => large rounding residuals => nonzero d-grads
        let q = e.init_qparams(&params, 4.0);
        let (x, y) = batch(&e, 9);
        let out = e.train_step(&params, &q, &x, &y).unwrap();
        assert_eq!(out.qgrads.len(), e.manifest().qsites.len());
        let live = out
            .qgrads
            .iter()
            .any(|g| g.0.abs() + g.1.abs() + g.2.abs() > 0.0);
        assert!(live, "all quant-param gradients zero: {:?}", out.qgrads);
    }

    #[test]
    fn bits_change_the_loss() {
        let e = engine();
        let params = e.init_params(2);
        let (x, y) = batch(&e, 11);
        let hi = e.init_qparams(&params, 16.0);
        let lo = e.init_qparams(&params, 2.0);
        let l_hi = e.eval_step(&params, &hi, &x, &y).unwrap().loss;
        let l_lo = e.eval_step(&params, &lo, &x, &y).unwrap().loss;
        assert!((l_hi - l_lo).abs() > 1e-6, "{l_hi} vs {l_lo}");
    }

    #[test]
    fn every_embedded_model_constructs_an_engine() {
        // the interpreter covers the whole zoo: no family may fall back to
        // "needs pjrt" errors anymore
        for model in model_names() {
            let e = NativeEngine::new(&model).unwrap();
            assert_eq!(e.manifest().model, model);
            assert!(!e.program().nodes.is_empty(), "{model}");
        }
    }

    #[test]
    fn unknown_family_error_names_the_family() {
        let cfg = json::parse(
            r#"{"name": "mystery", "family": "capsule", "task": "image_cls",
                "image": {"size": 8, "channels": 3},
                "quant": {"weight": true, "act": false}}"#,
        )
        .unwrap();
        let err = NativeEngine::from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("capsule"), "{err}");
        assert!(NativeEngine::new("nope").is_err());
    }
}
