//! PJRT runtime: load AOT artifacts, execute train/eval steps.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire runtime bridge. An `Engine` owns one PJRT CPU client plus the
//! compiled train/eval executables of one model, and the manifest emitted
//! by `python/compile/aot.py` drives all input packing / output unpacking
//! — the Rust side has zero hardcoded model knowledge.
//!
//! Interchange is HLO **text** (xla_extension 0.5.1 rejects jax>=0.5
//! serialized protos with 64-bit instruction ids; the text parser
//! reassigns ids — see /opt/xla-example/README.md).
//!
//! Gated behind the `pjrt` cargo feature: default builds use the native
//! reference backend instead (`super::native`), so a machine without the
//! XLA toolchain still builds and tests the full pipeline.

use anyhow::{Context, Result};

use super::{Backend, EvalOut, HostArray, Manifest, TrainOut};
use crate::quant::QParams;
use crate::tensor::{ParamStore, Tensor};

fn to_literal(arr: &HostArray, shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let lit = match arr {
        HostArray::F32(v) => xla::Literal::vec1(v),
        HostArray::I32(v) => xla::Literal::vec1(v),
    };
    Ok(lit.reshape(&dims)?)
}

pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
}

impl Engine {
    /// Load and compile the artifacts of `model` from `art_dir`.
    pub fn load(art_dir: &std::path::Path, model: &str) -> Result<Engine> {
        let manifest = Manifest::load(art_dir, model)?;
        let client = xla::PjRtClient::cpu().context("PJRT cpu client")?;
        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = art_dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path utf-8")?,
            )
            .with_context(|| format!("parse {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let train_exe = compile(&manifest.train_hlo)?;
        let eval_exe = compile(&manifest.eval_hlo)?;
        Ok(Engine {
            manifest,
            client,
            train_exe,
            eval_exe,
        })
    }

    // ------------------------------------------------------------ stepping
    fn pack_inputs(
        &self,
        params: &ParamStore,
        q: &[QParams],
        x: &HostArray,
        y: &HostArray,
    ) -> Result<Vec<xla::Literal>> {
        let m = &self.manifest;
        anyhow::ensure!(params.len() == m.params.len(), "param count mismatch");
        let mut lits = Vec::with_capacity(params.len() + 3);
        for (t, (name, shape)) in params.tensors.iter().zip(&m.params) {
            debug_assert_eq!(&t.name, name);
            lits.push(to_literal(&HostArray::F32(t.data.clone()), shape)?);
        }
        // q array [max(nsites,1), 3]
        let rows = m.q_rows.max(1);
        let mut qdata = vec![0.0f32; rows * 3];
        for (i, s) in q.iter().enumerate() {
            qdata[i * 3] = s.d;
            qdata[i * 3 + 1] = s.t;
            qdata[i * 3 + 2] = s.qm;
        }
        lits.push(to_literal(&HostArray::F32(qdata), &[rows, 3])?);
        lits.push(to_literal(x, &m.batch.x_shape)?);
        lits.push(to_literal(y, &m.batch.y_shape)?);
        Ok(lits)
    }

    fn scalar(lit: &xla::Literal) -> Result<f32> {
        Ok(lit.to_vec::<f32>()?.first().copied().unwrap_or(f32::NAN))
    }
}

impl Backend for Engine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn train_step(
        &self,
        params: &ParamStore,
        q: &[QParams],
        x: &HostArray,
        y: &HostArray,
    ) -> Result<TrainOut> {
        let inputs = self.pack_inputs(params, q, x, y)?;
        let result = self.train_exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        let m = &self.manifest;
        anyhow::ensure!(
            outs.len() == 1 + m.params.len() + 2,
            "train outputs: got {}, want {}",
            outs.len(),
            1 + m.params.len() + 2
        );
        let loss = Self::scalar(&outs[0])?;
        let mut grads = ParamStore::new();
        for (i, (name, shape)) in m.params.iter().enumerate() {
            let data = outs[1 + i].to_vec::<f32>()?;
            grads.push(Tensor::from_vec(name, shape, data));
        }
        let qflat = outs[1 + m.params.len()].to_vec::<f32>()?;
        let qgrads = (0..m.qsites.len())
            .map(|i| (qflat[i * 3], qflat[i * 3 + 1], qflat[i * 3 + 2]))
            .collect();
        let metric = Self::scalar(&outs[1 + m.params.len() + 1])?;
        Ok(TrainOut {
            loss,
            grads,
            qgrads,
            metric,
        })
    }

    fn eval_step(
        &self,
        params: &ParamStore,
        q: &[QParams],
        x: &HostArray,
        y: &HostArray,
    ) -> Result<EvalOut> {
        let inputs = self.pack_inputs(params, q, x, y)?;
        let result = self.eval_exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == self.manifest.eval_outputs.len(), "eval arity");
        let loss = Self::scalar(&outs[0])?;
        let metric = Self::scalar(&outs[1])?;
        let mut extra = Vec::new();
        for o in outs.iter().skip(2) {
            // predictions may be i32 (span argmax) or f32 (mask counts)
            let v = o.to_vec::<f32>().or_else(|_| {
                o.to_vec::<i32>()
                    .map(|iv| iv.into_iter().map(|x| x as f32).collect())
            })?;
            extra.push(v);
        }
        Ok(EvalOut { loss, metric, extra })
    }
}
