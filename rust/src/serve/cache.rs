//! Shared, load-once model cache for the serving path.
//!
//! A server process fronts one or more `.geta` artifacts with many
//! workers. The expensive part of an engine — the unpacked (and, on the
//! int8 kernel, weight-stationary i8-resident) parameter panels plus the
//! shape-resolved plan — must exist **once per model**, not once per
//! worker: every worker holds the same `Arc<GetaEngine>` and the engine's
//! own arena pool keeps their scratch spaces from contending. The cache
//! lock is held across a miss's load, which is exactly the single-load
//! guarantee: two racing first requests for one model cannot both pay the
//! unpack.
//!
//! Engines are cached with `threads = 1`: a server parallelizes across
//! requests (workers) rather than within one request, so per-call
//! micro-batch sharding would only oversubscribe the worker pool.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::deploy::{GetaEngine, KernelKind};

/// Load-once cache of [`GetaEngine`]s keyed by artifact path (or any
/// caller-chosen key via [`put`](ModelCache::put)).
pub struct ModelCache {
    kernel: KernelKind,
    engines: Mutex<BTreeMap<String, Arc<GetaEngine>>>,
}

impl ModelCache {
    /// A cache whose misses load with the given compute kernel
    /// ([`KernelKind::Int8`] is the serving default: resident i8 panels,
    /// integer GEMMs, f32 fallback per oversized site).
    pub fn new(kernel: KernelKind) -> ModelCache {
        ModelCache {
            kernel,
            engines: Mutex::new(BTreeMap::new()),
        }
    }

    /// The engine for a `.geta` artifact — loaded on first request,
    /// shared on every later one.
    ///
    /// A **failed** load is never cached: the `?` below returns before
    /// anything is inserted, so the next `get_or_load` for the same path
    /// retries from disk — a model that was mid-export (or being repaired)
    /// becomes servable the moment a valid artifact lands, with no
    /// process restart. `test_faults.rs` pins this.
    pub fn get_or_load(&self, path: &std::path::Path) -> Result<Arc<GetaEngine>> {
        let key = path.display().to_string();
        let mut engines = self.engines.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(e) = engines.get(&key) {
            return Ok(Arc::clone(e));
        }
        let mut engine = GetaEngine::load_kernel(path, self.kernel)
            .with_context(|| format!("loading serving model from {key}"))?;
        engine.threads = 1;
        let engine = Arc::new(engine);
        engines.insert(key, Arc::clone(&engine));
        Ok(engine)
    }

    /// Drop the cached engine for `key` (e.g. after its artifact was
    /// replaced on disk, or a health check condemned it); the next
    /// `get_or_load` reloads fresh. Returns the evicted engine, which
    /// in-flight requests may still hold via their own `Arc`s — eviction
    /// never invalidates a request already being served.
    pub fn evict(&self, key: &str) -> Option<Arc<GetaEngine>> {
        self.engines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(key)
    }

    /// Seed the cache with an already-built engine (a server that trains
    /// or exports in-process). Replaces any previous entry for `key`.
    pub fn put(&self, key: &str, engine: Arc<GetaEngine>) {
        self.engines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(key.to_string(), engine);
    }

    /// The cached engine for `key`, if present (no load on miss).
    pub fn get(&self, key: &str) -> Option<Arc<GetaEngine>> {
        self.engines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(key)
            .map(Arc::clone)
    }

    pub fn len(&self) -> usize {
        self.engines.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
