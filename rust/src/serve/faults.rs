//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a *schedule*, not a dice roll: each request's fate
//! is a pure function of `(seed, arrival_index)` through a private
//! xorshift mix — no wall clock, no global RNG — so the same seed and
//! the same request count mark exactly the same requests with exactly
//! the same faults on every run, at any worker count. That is what lets
//! CI run a chaos soak twice and byte-diff the summaries.
//!
//! Four fault classes, mirroring how real serving stacks fail:
//!
//! | class     | spec key | injected as                                | request outcome          |
//! |-----------|----------|--------------------------------------------|--------------------------|
//! | panic     | `panic`  | `panic!` inside the model call (every try) | `ServeError::WorkerPanic`|
//! | slow      | `slow`   | one-shot sleep before the model call       | completes (late)         |
//! | poison    | `poison` | input kind corrupted at admission          | `ServeError::Model`      |
//! | transient | `err`    | one-shot `Err` from the model call         | completes (after retry)  |
//!
//! The plan is threaded through [`Server`](super::Server) as an
//! `Option<Arc<FaultPlan>>`; `None` (the default) adds no branch beyond
//! one `Option` check per admission and per batch, and the served bits
//! are identical to a build that never heard of faults. `test_serve.rs`
//! continues to pin the disarmed path.
//!
//! At most one class marks a given request: the unit interval is split
//! into disjoint probability bands (`panic`, then `slow`, then `poison`,
//! then `err`), so outcome accounting is exact — under a plan, the soak
//! in [`chaos_soak`] *knows* how many requests must fail with each typed
//! error and asserts the server delivered precisely that.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::runtime::HostArray;

use super::loadgen::Backoff;
use super::{BatchModel, ServeConfig, ServeError, Server, Ticket};

/// The fault classes a [`FaultPlan`] can pin on a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The model call panics while this request is in the batch.
    Panic,
    /// The model call sleeps `slow_us` first (once).
    Slow,
    /// The request's input is corrupted at admission (wrong dtype).
    Poison,
    /// The model call returns `Err` once; the retry succeeds.
    Transient,
}

/// Parsed `--faults` spec: per-class probabilities plus the latency-spike
/// size. Grammar: comma-separated `class:prob[:param]`, e.g.
/// `panic:0.05,slow:0.1:2000,poison:0.02,err:0.1` (`slow`'s optional
/// third field is the spike in microseconds, default 2000).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    pub panic_p: f64,
    pub slow_p: f64,
    pub poison_p: f64,
    pub transient_p: f64,
    pub slow_us: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            panic_p: 0.0,
            slow_p: 0.0,
            poison_p: 0.0,
            transient_p: 0.0,
            slow_us: 2000,
        }
    }
}

impl FaultSpec {
    /// Parse the `--faults` grammar. Errors on unknown classes, bad
    /// numbers, or probabilities that don't fit in the unit interval
    /// (classes are disjoint, so they must *sum* to ≤ 1).
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec::default();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() < 2 {
                bail!("fault spec `{part}`: expected class:prob[:param]");
            }
            let p: f64 = fields[1]
                .parse()
                .map_err(|_| anyhow::anyhow!("fault spec `{part}`: bad probability `{}`", fields[1]))?;
            if !(0.0..=1.0).contains(&p) {
                bail!("fault spec `{part}`: probability {p} outside [0, 1]");
            }
            match fields[0] {
                "panic" => spec.panic_p = p,
                "slow" => {
                    spec.slow_p = p;
                    if let Some(us) = fields.get(2) {
                        spec.slow_us = us
                            .parse()
                            .map_err(|_| anyhow::anyhow!("fault spec `{part}`: bad microseconds `{us}`"))?;
                    }
                }
                "poison" => spec.poison_p = p,
                "err" => spec.transient_p = p,
                other => bail!("fault spec `{part}`: unknown class `{other}` (panic|slow|poison|err)"),
            }
        }
        let total = spec.panic_p + spec.slow_p + spec.poison_p + spec.transient_p;
        if total > 1.0 {
            bail!("fault spec `{s}`: class probabilities sum to {total} > 1 (bands are disjoint)");
        }
        Ok(spec)
    }
}

/// xorshift64* — the plan's private generator. One mix per request index;
/// no state is carried between requests, so marking is order-independent.
fn mix(seed: u64, idx: u64) -> u64 {
    let mut x = seed ^ idx.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x5DEE_CE66_D1CE_4E5B;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    x = x.wrapping_mul(0x2545_F491_4F6C_DD1D);
    x ^= x >> 33;
    x
}

/// Top 53 bits of a mixed word as a unit-interval f64.
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// Count of injection events per class, accumulated at admission time
/// (which is deterministic) — not at fire time (which depends on how
/// requests happened to coalesce into batches).
#[derive(Debug, Default)]
struct Injected {
    panic: AtomicU64,
    slow: AtomicU64,
    poison: AtomicU64,
    transient: AtomicU64,
}

/// A seeded, schedule-driven fault injector. See the module docs for the
/// determinism contract; see [`Server::start_faulted`](super::Server::start_faulted)
/// for arming one.
pub struct FaultPlan {
    seed: u64,
    spec: FaultSpec,
    /// One-shot classes (slow, transient) record which request indices
    /// have already fired, so an isolation retry of a marked request does
    /// not re-fire the fault. `panic` is intentionally *not* one-shot: a
    /// panic-marked request brings down every call it rides in, which is
    /// what forces the typed `WorkerPanic` outcome.
    spent: Mutex<HashSet<u64>>,
    injected: Injected,
}

impl FaultPlan {
    pub fn new(seed: u64, spec: FaultSpec) -> FaultPlan {
        FaultPlan {
            seed,
            spec,
            spent: Mutex::new(HashSet::new()),
            injected: Injected::default(),
        }
    }

    /// `FaultPlan::new` over a parsed `--faults` string.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        Ok(FaultPlan::new(seed, FaultSpec::parse(spec)?))
    }

    /// The fault (if any) this plan pins on arrival index `idx` — a pure
    /// function, same answer every call.
    pub fn fault_for(&self, idx: u64) -> Option<FaultKind> {
        let u = unit(mix(self.seed, idx));
        let bands = [
            (FaultKind::Panic, self.spec.panic_p),
            (FaultKind::Slow, self.spec.slow_p),
            (FaultKind::Poison, self.spec.poison_p),
            (FaultKind::Transient, self.spec.transient_p),
        ];
        let mut lo = 0.0;
        for (kind, p) in bands {
            if u >= lo && u < lo + p {
                return Some(kind);
            }
            lo += p;
        }
        None
    }

    /// Admission hook: count the mark and, for `Poison`, corrupt the
    /// input in place (dtype swap — the engine's per-request validation
    /// rejects it with a typed error, exactly like a malformed client
    /// payload would be rejected in production).
    pub(super) fn admit(&self, idx: u64, x: &mut HostArray) {
        match self.fault_for(idx) {
            Some(FaultKind::Panic) => {
                self.injected.panic.fetch_add(1, Ordering::Relaxed);
            }
            Some(FaultKind::Slow) => {
                self.injected.slow.fetch_add(1, Ordering::Relaxed);
            }
            Some(FaultKind::Poison) => {
                self.injected.poison.fetch_add(1, Ordering::Relaxed);
                *x = match x {
                    HostArray::F32(_) => HostArray::I32(vec![i32::MIN]),
                    HostArray::I32(_) => HostArray::F32(vec![f32::NAN]),
                };
            }
            Some(FaultKind::Transient) => {
                self.injected.transient.fetch_add(1, Ordering::Relaxed);
            }
            None => {}
        }
    }

    /// Model-call hook, run inside the worker's `catch_unwind` just
    /// before `infer_many`. Sleeps for unfired `Slow` marks, then either
    /// panics (any `Panic` mark present) or bails (any unfired
    /// `Transient` mark — all of them are spent by the one failure, so
    /// the per-request retry goes through clean).
    pub(super) fn before_call<I: IntoIterator<Item = u64>>(&self, reqs: I) -> Result<()> {
        let mut boom: Option<u64> = None;
        let mut flaky: Option<u64> = None;
        for idx in reqs {
            match self.fault_for(idx) {
                Some(FaultKind::Slow) => {
                    if self.take_once(idx) {
                        std::thread::sleep(Duration::from_micros(self.spec.slow_us));
                    }
                }
                Some(FaultKind::Panic) => boom = boom.or(Some(idx)),
                Some(FaultKind::Transient) => {
                    if self.take_once(idx) {
                        flaky = flaky.or(Some(idx));
                    }
                }
                _ => {}
            }
        }
        if let Some(idx) = boom {
            panic!("injected worker panic (request #{idx})");
        }
        if let Some(idx) = flaky {
            bail!("injected transient model error (request #{idx})");
        }
        Ok(())
    }

    /// Record a one-shot fault as fired; true exactly once per index.
    fn take_once(&self, idx: u64) -> bool {
        self.spent.lock().unwrap_or_else(|e| e.into_inner()).insert(idx)
    }

    /// Injection counts `[panic, slow, poison, transient]` so far —
    /// admission-time, hence deterministic for a fixed request count.
    pub fn injected(&self) -> [u64; 4] {
        [
            self.injected.panic.load(Ordering::Relaxed),
            self.injected.slow.load(Ordering::Relaxed),
            self.injected.poison.load(Ordering::Relaxed),
            self.injected.transient.load(Ordering::Relaxed),
        ]
    }
}

/// What one chaos soak observed. Every field is a deterministic function
/// of `(model artifact, seed, spec, requests)` — counters that depend on
/// thread scheduling (shed totals, batch shapes, restart *counts*) are
/// deliberately reduced to booleans or left out, so two same-seed runs
/// serialize byte-identically (the CI chaos-smoke contract).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChaosReport {
    pub model: String,
    pub seed: u64,
    pub spec: String,
    pub requests: usize,
    /// Requests answered with logits.
    pub completed: usize,
    pub failed_worker_panic: usize,
    pub failed_model: usize,
    pub failed_deadline: usize,
    /// Any other typed failure (`Dropped`, admission errors surfacing at
    /// wait time). Zero under every plan — a nonzero value is a bug.
    pub failed_other: usize,
    pub injected_panic: u64,
    pub injected_slow: u64,
    pub injected_poison: u64,
    pub injected_transient: u64,
    /// Successful replies whose logits differed bitwise from the
    /// fault-free reference. Must be zero: faults may fail a request,
    /// never corrupt a surviving one.
    pub mismatched_logits: usize,
    /// Tickets that neither replied nor failed within the harvest
    /// timeout. Must be zero: no ticket leaks.
    pub unresolved: usize,
    /// True iff the worker restart counter ended positive — implied by
    /// `injected_panic > 0`, stated as a bool because the raw count
    /// depends on batching.
    pub worker_restarts_positive: bool,
    /// True iff a probe request submitted *after* the fault storm still
    /// resolved (reply or typed error — either proves liveness).
    pub server_live_after: bool,
}

/// Run `requests` requests against a fresh fault-armed [`Server`] and
/// check every robustness promise at once: liveness, typed per-request
/// failure, zero ticket leaks, and bitwise parity of surviving logits
/// against the fault-free `expected` logits (one per entry of `inputs`,
/// applied round-robin like the submission order).
///
/// `clients` threads submit in pressure mode (retry-with-backoff on
/// `QueueFull`), so arrival indices are exactly `0..requests` and the
/// plan's marking is reproducible run to run.
pub fn chaos_soak(
    model: Arc<dyn BatchModel>,
    inputs: &[HostArray],
    expected: &[Vec<f32>],
    cfg: ServeConfig,
    plan: Arc<FaultPlan>,
    requests: usize,
    clients: usize,
) -> ChaosReport {
    assert!(!inputs.is_empty() && inputs.len() == expected.len());
    let seed = plan.seed;
    let spec = plan.spec;
    let server = Server::start_faulted(model, cfg, Some(Arc::clone(&plan)));
    let clients = clients.max(1);

    // (completed, panic, model, deadline, other, mismatched, unresolved)
    let mut tally = [0usize; 7];
    let per_client: Vec<[usize; 7]> = std::thread::scope(|sc| {
        let server = &server;
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                sc.spawn(move || {
                    let mut out = [0usize; 7];
                    let mut tickets: Vec<(usize, Ticket)> = Vec::new();
                    let mut backoff = Backoff::new(0xC4A0_5EED ^ c as u64);
                    let mut i = c;
                    while i < requests {
                        let x = inputs[i % inputs.len()].clone();
                        loop {
                            match server.submit(x.clone()) {
                                Ok(t) => {
                                    tickets.push((i % inputs.len(), t));
                                    backoff.reset();
                                    break;
                                }
                                Err(ServeError::QueueFull { .. }) => {
                                    std::thread::sleep(backoff.pause());
                                }
                                Err(_) => {
                                    // shutdown mid-soak: counts as unresolved
                                    out[6] += 1;
                                    break;
                                }
                            }
                        }
                        i += clients;
                    }
                    for (input_idx, t) in tickets {
                        match t.wait_timeout_typed(Duration::from_secs(60)) {
                            Some(Ok(reply)) => {
                                out[0] += 1;
                                let want = &expected[input_idx];
                                let same = reply.logits.len() == want.len()
                                    && reply
                                        .logits
                                        .iter()
                                        .zip(want)
                                        .all(|(a, b)| a.to_bits() == b.to_bits());
                                if !same {
                                    out[5] += 1;
                                }
                            }
                            Some(Err(ServeError::WorkerPanic { .. })) => out[1] += 1,
                            Some(Err(ServeError::Model { .. })) => out[2] += 1,
                            Some(Err(ServeError::DeadlineExceeded { .. })) => out[3] += 1,
                            Some(Err(_)) => out[4] += 1,
                            None => out[6] += 1,
                        }
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("chaos client panicked"))
            .collect()
    });
    for row in per_client {
        for (t, v) in tally.iter_mut().zip(row) {
            *t += v;
        }
    }

    // Snapshot injection counts before the probe so they cover exactly
    // the soak's `requests` arrival indices.
    let [inj_panic, inj_slow, inj_poison, inj_transient] = plan.injected();

    // Liveness probe: one more request after the storm. Any resolution —
    // logits or a typed error — proves the server is still answering.
    let live = match server.submit(inputs[0].clone()) {
        Ok(t) => t.wait_timeout_typed(Duration::from_secs(60)).is_some(),
        Err(_) => false,
    };
    let report = server.shutdown();

    ChaosReport {
        model: String::new(),
        seed,
        spec: format!(
            "panic:{}:slow:{}:poison:{}:err:{}:slow_us:{}",
            spec.panic_p, spec.slow_p, spec.poison_p, spec.transient_p, spec.slow_us
        ),
        requests,
        completed: tally[0],
        failed_worker_panic: tally[1],
        failed_model: tally[2],
        failed_deadline: tally[3],
        failed_other: tally[4],
        injected_panic: inj_panic,
        injected_slow: inj_slow,
        injected_poison: inj_poison,
        injected_transient: inj_transient,
        mismatched_logits: tally[5],
        unresolved: tally[6],
        worker_restarts_positive: report.stats.worker_restarts > 0,
        server_live_after: live,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marking_is_a_pure_function_of_seed_and_index() {
        let spec = FaultSpec::parse("panic:0.1,slow:0.2,poison:0.1,err:0.2").unwrap();
        let a = FaultPlan::new(7, spec);
        let b = FaultPlan::new(7, spec);
        for idx in 0..2000 {
            assert_eq!(a.fault_for(idx), b.fault_for(idx));
            assert_eq!(a.fault_for(idx), a.fault_for(idx), "re-asking must not drift");
        }
        let c = FaultPlan::new(8, spec);
        let diverges = (0..2000).any(|i| a.fault_for(i) != c.fault_for(i));
        assert!(diverges, "different seeds must mark differently");
    }

    #[test]
    fn bands_hit_every_class_and_roughly_match_probabilities() {
        let spec = FaultSpec::parse("panic:0.1,slow:0.1,poison:0.1,err:0.1").unwrap();
        let plan = FaultPlan::new(42, spec);
        let n = 20_000u64;
        let mut counts = [0usize; 5];
        for i in 0..n {
            match plan.fault_for(i) {
                Some(FaultKind::Panic) => counts[0] += 1,
                Some(FaultKind::Slow) => counts[1] += 1,
                Some(FaultKind::Poison) => counts[2] += 1,
                Some(FaultKind::Transient) => counts[3] += 1,
                None => counts[4] += 1,
            }
        }
        for (i, &c) in counts[..4].iter().enumerate() {
            let p = c as f64 / n as f64;
            assert!((p - 0.1).abs() < 0.02, "class {i} rate {p} far from 0.1");
        }
        assert!(counts[4] > 0, "most requests stay unmarked");
    }

    #[test]
    fn spec_parser_accepts_the_grammar_and_rejects_garbage() {
        let s = FaultSpec::parse("panic:0.05,slow:0.1:2500,err:0.2").unwrap();
        assert_eq!(s.panic_p, 0.05);
        assert_eq!(s.slow_p, 0.1);
        assert_eq!(s.slow_us, 2500);
        assert_eq!(s.transient_p, 0.2);
        assert_eq!(s.poison_p, 0.0);
        assert!(FaultSpec::parse("panic").is_err(), "missing probability");
        assert!(FaultSpec::parse("explode:0.5").is_err(), "unknown class");
        assert!(FaultSpec::parse("panic:1.5").is_err(), "probability > 1");
        assert!(FaultSpec::parse("panic:nope").is_err(), "non-numeric");
        assert!(
            FaultSpec::parse("panic:0.6,slow:0.6").is_err(),
            "bands must fit in the unit interval"
        );
        assert_eq!(FaultSpec::parse("").unwrap(), FaultSpec::default());
    }

    #[test]
    fn one_shot_classes_fire_once_panic_fires_always() {
        // A spec that marks everything Transient: band [0, 1).
        let plan = FaultPlan::new(3, FaultSpec::parse("err:1.0").unwrap());
        assert!(plan.before_call([5u64]).is_err(), "first call trips the fault");
        assert!(plan.before_call([5u64]).is_ok(), "retry goes through clean");
        let boom = FaultPlan::new(3, FaultSpec::parse("panic:1.0").unwrap());
        for _ in 0..2 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                boom.before_call([5u64])
            }));
            assert!(r.is_err(), "panic marks fire on every call");
        }
    }

    #[test]
    fn poison_swaps_the_input_kind() {
        let plan = FaultPlan::new(1, FaultSpec::parse("poison:1.0").unwrap());
        let mut x = HostArray::F32(vec![1.0, 2.0]);
        plan.admit(0, &mut x);
        assert!(matches!(x, HostArray::I32(_)));
        assert_eq!(plan.injected(), [0, 0, 1, 0]);
    }
}
