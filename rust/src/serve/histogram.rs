//! Log-bucketed latency histograms for the serving path.
//!
//! Fixed geometric buckets (×1.25 per bucket from 1 µs to beyond 2
//! minutes, ~80 buckets) so recording is O(log buckets) with no
//! allocation, and quantiles are read without storing per-request
//! samples — the histogram costs the same whether it absorbed a hundred
//! requests or a hundred million. Quantile answers are the upper bound of
//! the bucket holding the requested rank (clamped to the observed
//! maximum), so their resolution is the bucket growth factor: within
//! +25% of the true value, which is the right fidelity for p50/p95/p99
//! dashboard numbers and for the `BENCH_serve.json` trajectory.

use std::time::Duration;

/// Geometric growth per bucket. Smaller = finer quantiles, more buckets.
const GROWTH: f64 = 1.25;
/// Upper bound of the first bucket, in microseconds.
const FIRST_US: f64 = 1.0;
/// Everything at or beyond this lands in the final catch-all bucket.
const LAST_US: f64 = 180e6; // 3 minutes

/// A mergeable log-bucketed histogram of request latencies.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Upper bound (µs) of each bucket; the final bucket is a catch-all.
    bounds_us: Vec<f64>,
    counts: Vec<u64>,
    count: u64,
    sum_us: f64,
    min_us: f64,
    max_us: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        let mut bounds_us = Vec::new();
        let mut b = FIRST_US;
        while b < LAST_US {
            bounds_us.push(b);
            b *= GROWTH;
        }
        bounds_us.push(f64::INFINITY);
        let counts = vec![0u64; bounds_us.len()];
        LatencyHistogram {
            bounds_us,
            counts,
            count: 0,
            sum_us: 0.0,
            min_us: f64::INFINITY,
            max_us: 0.0,
        }
    }

    pub fn record(&mut self, latency: Duration) {
        let us = latency.as_secs_f64() * 1e6;
        let i = self.bounds_us.partition_point(|&b| b < us);
        self.counts[i.min(self.counts.len() - 1)] += 1;
        self.count += 1;
        self.sum_us += us;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    /// Recorded request count.
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    pub fn min_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min_us
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// The `q`-quantile (`0.0..=1.0`) in microseconds: the upper bound of
    /// the bucket holding the `ceil(q * count)`-th recorded latency,
    /// clamped to the observed maximum. 0 when nothing was recorded.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return self.bounds_us[i].min(self.max_us);
            }
        }
        self.max_us
    }

    pub fn p50_us(&self) -> f64 {
        self.quantile_us(0.50)
    }

    pub fn p95_us(&self) -> f64 {
        self.quantile_us(0.95)
    }

    pub fn p99_us(&self) -> f64 {
        self.quantile_us(0.99)
    }

    /// Fold another histogram into this one (same fixed bucketing by
    /// construction).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        debug_assert_eq!(self.bounds_us.len(), other.bounds_us.len());
        for (c, &o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    /// One-line human summary (`geta serve` output).
    pub fn summary(&self) -> String {
        format!(
            "n {}  p50 {:.1}us  p95 {:.1}us  p99 {:.1}us  mean {:.1}us  max {:.1}us",
            self.count,
            self.p50_us(),
            self.p95_us(),
            self.p99_us(),
            self.mean_us(),
            self.max_us()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn us(v: u64) -> Duration {
        Duration::from_micros(v)
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50_us(), 0.0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.min_us(), 0.0);
    }

    #[test]
    fn quantiles_track_recorded_values_within_bucket_resolution() {
        let mut h = LatencyHistogram::new();
        for v in 1..=1000u64 {
            h.record(us(v));
        }
        assert_eq!(h.count(), 1000);
        // bucket upper bounds over-report by at most the growth factor
        let p50 = h.p50_us();
        assert!((500.0..=500.0 * GROWTH).contains(&p50), "p50 {p50}");
        let p99 = h.p99_us();
        assert!((990.0..=990.0 * GROWTH).contains(&p99), "p99 {p99}");
        // max is exact, and quantiles never exceed it
        assert_eq!(h.max_us(), 1000.0);
        assert!(h.quantile_us(1.0) <= h.max_us());
        assert!((h.mean_us() - 500.5).abs() < 1.0);
    }

    #[test]
    fn out_of_range_latencies_land_in_edge_buckets() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_nanos(1)); // below the first bound
        h.record(Duration::from_secs(600)); // beyond the last bound
        assert_eq!(h.count(), 2);
        assert!(h.max_us() >= 600e6);
        assert!(h.quantile_us(1.0) <= h.max_us());
    }

    #[test]
    fn merge_is_equivalent_to_recording_into_one() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut whole = LatencyHistogram::new();
        for v in [3u64, 17, 250, 4000, 90_000] {
            a.record(us(v));
            whole.record(us(v));
        }
        for v in [8u64, 120, 55_000] {
            b.record(us(v));
            whole.record(us(v));
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.p50_us(), whole.p50_us());
        assert_eq!(a.p99_us(), whole.p99_us());
        assert_eq!(a.max_us(), whole.max_us());
    }

    #[test]
    fn single_sample_pins_every_statistic() {
        let mut h = LatencyHistogram::new();
        h.record(us(1234));
        assert_eq!(h.count(), 1);
        assert_eq!(h.min_us(), 1234.0);
        assert_eq!(h.max_us(), 1234.0);
        assert_eq!(h.mean_us(), 1234.0);
        // every quantile of a one-sample histogram is that sample
        // (bucket bounds clamp to the observed max)
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.quantile_us(q), 1234.0, "quantile {q}");
        }
    }

    #[test]
    fn merge_with_disjoint_bucket_ranges() {
        // a: all sub-10µs; b: all beyond 1s — no shared buckets
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut whole = LatencyHistogram::new();
        for v in [2u64, 3, 5, 7, 9] {
            a.record(us(v));
            whole.record(us(v));
        }
        for v in [1_500_000u64, 2_500_000, 9_000_000] {
            b.record(us(v));
            whole.record(us(v));
        }
        a.merge(&b);
        assert_eq!(a.count(), 8);
        assert_eq!(a.min_us(), whole.min_us());
        assert_eq!(a.max_us(), whole.max_us());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile_us(q), whole.quantile_us(q), "quantile {q}");
        }
        // the low half still dominates p50; the high tail owns p99
        assert!(a.p50_us() < 100.0, "p50 {}", a.p50_us());
        assert!(a.p99_us() > 1e6, "p99 {}", a.p99_us());
        // empty-into-full and full-into-empty merges are identities
        let mut empty = LatencyHistogram::new();
        empty.merge(&a);
        assert_eq!(empty.count(), a.count());
        assert_eq!(empty.p95_us(), a.p95_us());
        a.merge(&LatencyHistogram::new());
        assert_eq!(a.count(), 8);
    }

    #[test]
    fn quantiles_are_monotone_under_random_input() {
        let mut rng = crate::util::rng::Rng::new(20260808);
        let mut h = LatencyHistogram::new();
        for _ in 0..5000 {
            // log-uniform over ~7 decades, hitting many buckets plus both
            // edge buckets
            let exp = rng.range(-1.0, 6.5);
            let us_f = 10f64.powf(exp);
            h.record(Duration::from_secs_f64(us_f / 1e6));
        }
        assert_eq!(h.count(), 5000);
        let mut prev = 0.0f64;
        for q in [0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999, 1.0] {
            let v = h.quantile_us(q);
            assert!(v >= prev, "quantile {q}: {v} < previous {prev}");
            prev = v;
        }
        assert!(h.p50_us() <= h.p95_us());
        assert!(h.p95_us() <= h.p99_us());
        assert!(h.p99_us() <= h.max_us());
        assert!(h.min_us() <= h.mean_us() && h.mean_us() <= h.max_us());
    }
}
