//! Open-loop synthetic load generation for `geta serve` / `geta
//! bench-serve`.
//!
//! Open-loop means requests are submitted on a fixed schedule (`rps`)
//! regardless of how fast the server answers — the standard way to
//! surface queueing delay, which closed-loop clients (submit → wait →
//! submit) structurally hide. At saturation an open-loop generator sheds:
//! rejected requests are counted, not retried, so the measured latencies
//! describe the requests the server actually admitted.
//!
//! `rps <= 0` flips to **pressure mode**: a closed-loop saturation probe
//! that retries each rejected submission until admitted. This measures
//! the server's sustainable throughput under backpressure-aware clients —
//! the number `bench-serve` compares batched vs unbatched on.

use std::time::{Duration, Instant};

use crate::runtime::HostArray;

use super::{ServeError, Server, Ticket};

/// One load-generation run's shape.
#[derive(Debug, Clone, Copy)]
pub struct LoadSpec {
    /// Target submissions per second across all clients (`> 0`:
    /// open-loop, shed on `QueueFull`). `<= 0`: pressure mode (retry
    /// until admitted).
    pub rps: f64,
    /// Total requests to submit.
    pub requests: usize,
    /// Concurrent submitter threads. Open-loop interleaves the schedule
    /// across clients; pressure mode uses them to keep the queue full
    /// past a single submitter's syscall rate.
    pub clients: usize,
}

/// What a load run observed, client-side.
#[derive(Debug, Clone, Copy, Default)]
pub struct LoadReport {
    /// Requests the generator attempted (unique requests, not retries).
    pub submitted: usize,
    /// Admissions rejected with `QueueFull` (open-loop: lost requests;
    /// pressure mode: retried attempts).
    pub shed: usize,
    /// Requests answered with logits.
    pub completed: usize,
    /// Requests answered with a model error.
    pub failed: usize,
    /// First submission to last harvested completion.
    pub wall: Duration,
    /// `completed / wall` — the throughput the clients actually got.
    pub achieved_rps: f64,
}

/// Drive `server` with `spec.requests` requests drawn round-robin from
/// `inputs`, then wait for every admitted request. Latency histograms
/// accumulate server-side; this returns the client-side accounting.
pub fn run(server: &Server, inputs: &[HostArray], spec: &LoadSpec) -> LoadReport {
    assert!(!inputs.is_empty(), "load generator needs at least one input");
    let clients = spec.clients.max(1);
    let interval = if spec.rps > 0.0 {
        Duration::from_secs_f64(1.0 / spec.rps)
    } else {
        Duration::ZERO
    };
    let t0 = Instant::now();
    let per_client: Vec<(usize, usize, usize, usize)> = std::thread::scope(|sc| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                sc.spawn(move || {
                    let mut tickets: Vec<Ticket> = Vec::new();
                    let mut submitted = 0usize;
                    let mut shed = 0usize;
                    let mut i = c;
                    'submit: while i < spec.requests {
                        let x = inputs[i % inputs.len()].clone();
                        if spec.rps > 0.0 {
                            // open-loop: submit at the scheduled instant,
                            // shed means lost
                            let due = t0 + interval.mul_f64(i as f64);
                            let now = Instant::now();
                            if due > now {
                                std::thread::sleep(due - now);
                            }
                            submitted += 1;
                            match server.submit(x) {
                                Ok(t) => tickets.push(t),
                                Err(ServeError::QueueFull { .. }) => shed += 1,
                                Err(ServeError::ShuttingDown) => break 'submit,
                            }
                        } else {
                            // pressure mode: this request *will* be
                            // admitted; rejections just mean "queue full
                            // right now"
                            submitted += 1;
                            loop {
                                match server.submit(x.clone()) {
                                    Ok(t) => {
                                        tickets.push(t);
                                        break;
                                    }
                                    Err(ServeError::QueueFull { .. }) => {
                                        shed += 1;
                                        std::thread::yield_now();
                                    }
                                    Err(ServeError::ShuttingDown) => break 'submit,
                                }
                            }
                        }
                        i += clients;
                    }
                    let mut completed = 0usize;
                    let mut failed = 0usize;
                    for t in tickets {
                        match t.wait() {
                            Ok(_) => completed += 1,
                            Err(_) => failed += 1,
                        }
                    }
                    (submitted, shed, completed, failed)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("load client panicked"))
            .collect()
    });
    let wall = t0.elapsed();
    let mut r = LoadReport {
        wall,
        ..Default::default()
    };
    for (submitted, shed, completed, failed) in per_client {
        r.submitted += submitted;
        r.shed += shed;
        r.completed += completed;
        r.failed += failed;
    }
    r.achieved_rps = r.completed as f64 / wall.as_secs_f64().max(1e-9);
    r
}

/// `n` single-sample request payloads drawn from a dataset — the unit of
/// work a serving client sends (the coalescer is what builds batches).
pub fn single_sample_inputs(data: &crate::data::SynthData, n: usize) -> Vec<HostArray> {
    (0..n.max(1))
        .map(|i| data.batch(&[i % data.len().max(1)]).0)
        .collect()
}
