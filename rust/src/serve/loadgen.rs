//! Open-loop synthetic load generation for `geta serve` / `geta
//! bench-serve`.
//!
//! Open-loop means requests are submitted on a fixed schedule (`rps`)
//! regardless of how fast the server answers — the standard way to
//! surface queueing delay, which closed-loop clients (submit → wait →
//! submit) structurally hide. At saturation an open-loop generator sheds:
//! rejected requests are counted, not retried, so the measured latencies
//! describe the requests the server actually admitted.
//!
//! `rps <= 0` flips to **pressure mode**: a closed-loop saturation probe
//! that retries each rejected submission until admitted. Retries pause
//! under bounded exponential [`Backoff`] with deterministic jitter — a
//! hot spin would burn a core per client fighting the very workers it is
//! measuring, and unjittered retries resynchronize into admission
//! stampedes. This measures the server's sustainable throughput under
//! backpressure-aware clients — the number `bench-serve` compares
//! batched vs unbatched on.
//!
//! Accounting separates **attempts** (every `submit` call, retries
//! included) from **submitted** (unique requests) from **completed**
//! (requests answered with logits): `achieved_rps` is completions per
//! second, never inflated by retry traffic.

use std::time::{Duration, Instant};

use crate::runtime::HostArray;

use super::{Priority, ServeError, Server, Ticket};

/// Bounded exponential backoff with deterministic jitter for retrying
/// shed submissions. The pause sequence is a pure function of the seed
/// (private xorshift, no global RNG): pauses are drawn uniformly from
/// `[next/2, next]` and `next` doubles per rejection from
/// `GETA_BACKOFF_BASE_US` (default 50) up to `GETA_BACKOFF_MAX_US`
/// (default 5000); an admission resets the ladder.
#[derive(Debug, Clone)]
pub struct Backoff {
    next_us: u64,
    base_us: u64,
    max_us: u64,
    rng: u64,
}

fn env_us(key: &str, default: u64) -> u64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(default)
}

impl Backoff {
    pub fn new(seed: u64) -> Backoff {
        let base_us = env_us("GETA_BACKOFF_BASE_US", 50).max(1);
        let max_us = env_us("GETA_BACKOFF_MAX_US", 5_000).max(base_us);
        Backoff {
            next_us: base_us,
            base_us,
            max_us,
            // xorshift has one absorbing state; keep seeds off it
            rng: seed | 1,
        }
    }

    fn rng_next(&mut self) -> u64 {
        let mut x = self.rng;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// The pause to take after one more rejection (and double the ladder).
    pub fn pause(&mut self) -> Duration {
        let span = self.next_us / 2;
        let jitter = if span == 0 { 0 } else { self.rng_next() % (span + 1) };
        let sleep_us = (self.next_us - span) + jitter;
        self.next_us = (self.next_us * 2).min(self.max_us);
        Duration::from_micros(sleep_us)
    }

    /// Back to the base pause — call after a successful admission.
    pub fn reset(&mut self) {
        self.next_us = self.base_us;
    }
}

/// One load-generation run's shape.
#[derive(Debug, Clone, Copy)]
pub struct LoadSpec {
    /// Target submissions per second across all clients (`> 0`:
    /// open-loop, shed on `QueueFull`). `<= 0`: pressure mode (retry
    /// until admitted, pausing under [`Backoff`]).
    pub rps: f64,
    /// Total requests to submit.
    pub requests: usize,
    /// Concurrent submitter threads. Open-loop interleaves the schedule
    /// across clients; pressure mode uses them to keep the queue full
    /// past a single submitter's syscall rate.
    pub clients: usize,
    /// Per-request deadline passed to `submit_with` (None = no deadline).
    pub deadline: Option<Duration>,
    /// Seeds the per-client backoff jitter streams.
    pub seed: u64,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            rps: 0.0,
            requests: 0,
            clients: 1,
            deadline: None,
            seed: 0x10AD_6E4E,
        }
    }
}

/// What a load run observed, client-side.
#[derive(Debug, Clone, Copy, Default)]
pub struct LoadReport {
    /// Unique requests the generator drove (each counted once, however
    /// many submission attempts it took).
    pub submitted: usize,
    /// Every `submit` call, retries included. `attempts - submitted` =
    /// retry traffic (pressure mode only; open-loop never retries).
    pub attempts: usize,
    /// Admissions rejected with `QueueFull` (open-loop: lost requests;
    /// pressure mode: retried attempts).
    pub shed: usize,
    /// Requests answered with logits.
    pub completed: usize,
    /// Requests answered with a typed error (sum of the classes below).
    pub failed: usize,
    /// … because their queue deadline passed.
    pub failed_deadline: usize,
    /// … because the model call panicked with them in the batch.
    pub failed_panic: usize,
    /// … because the model call errored (after the bounded retry).
    pub failed_model: usize,
    /// … any other typed resolution (`Dropped`; zero in healthy runs).
    pub failed_other: usize,
    /// First submission to last harvested completion.
    pub wall: Duration,
    /// `completed / wall` — the throughput the clients actually got
    /// (completions only; retry attempts never inflate this).
    pub achieved_rps: f64,
}

#[derive(Debug, Clone, Copy, Default)]
struct Tally {
    submitted: usize,
    attempts: usize,
    shed: usize,
    completed: usize,
    failed_deadline: usize,
    failed_panic: usize,
    failed_model: usize,
    failed_other: usize,
}

/// Drive `server` with `spec.requests` requests drawn round-robin from
/// `inputs`, then wait for every admitted request. Latency histograms
/// accumulate server-side; this returns the client-side accounting.
pub fn run(server: &Server, inputs: &[HostArray], spec: &LoadSpec) -> LoadReport {
    assert!(!inputs.is_empty(), "load generator needs at least one input");
    let clients = spec.clients.max(1);
    let interval = if spec.rps > 0.0 {
        Duration::from_secs_f64(1.0 / spec.rps)
    } else {
        Duration::ZERO
    };
    let t0 = Instant::now();
    let per_client: Vec<Tally> = std::thread::scope(|sc| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                sc.spawn(move || {
                    let mut t = Tally::default();
                    let mut tickets: Vec<Ticket> = Vec::new();
                    let mut backoff = Backoff::new(spec.seed ^ (c as u64).wrapping_mul(0x9E37));
                    let mut i = c;
                    'submit: while i < spec.requests {
                        let x = inputs[i % inputs.len()].clone();
                        if spec.rps > 0.0 {
                            // open-loop: submit at the scheduled instant,
                            // shed means lost
                            let due = t0 + interval.mul_f64(i as f64);
                            let now = Instant::now();
                            if due > now {
                                std::thread::sleep(due - now);
                            }
                            t.submitted += 1;
                            t.attempts += 1;
                            match server.submit_with(x, Priority::Normal, spec.deadline) {
                                Ok(tk) => tickets.push(tk),
                                Err(ServeError::QueueFull { .. }) => t.shed += 1,
                                Err(_) => break 'submit,
                            }
                        } else {
                            // pressure mode: this request *will* be
                            // admitted; rejections just mean "queue full
                            // right now" — pause and come back
                            t.submitted += 1;
                            loop {
                                t.attempts += 1;
                                match server.submit_with(x.clone(), Priority::Normal, spec.deadline)
                                {
                                    Ok(tk) => {
                                        tickets.push(tk);
                                        backoff.reset();
                                        break;
                                    }
                                    Err(ServeError::QueueFull { .. }) => {
                                        t.shed += 1;
                                        std::thread::sleep(backoff.pause());
                                    }
                                    Err(_) => break 'submit,
                                }
                            }
                        }
                        i += clients;
                    }
                    for tk in tickets {
                        match tk.wait_typed() {
                            Ok(_) => t.completed += 1,
                            Err(ServeError::DeadlineExceeded { .. }) => t.failed_deadline += 1,
                            Err(ServeError::WorkerPanic { .. }) => t.failed_panic += 1,
                            Err(ServeError::Model { .. }) => t.failed_model += 1,
                            Err(_) => t.failed_other += 1,
                        }
                    }
                    t
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("load client panicked"))
            .collect()
    });
    let wall = t0.elapsed();
    let mut r = LoadReport {
        wall,
        ..Default::default()
    };
    for t in per_client {
        r.submitted += t.submitted;
        r.attempts += t.attempts;
        r.shed += t.shed;
        r.completed += t.completed;
        r.failed_deadline += t.failed_deadline;
        r.failed_panic += t.failed_panic;
        r.failed_model += t.failed_model;
        r.failed_other += t.failed_other;
    }
    r.failed = r.failed_deadline + r.failed_panic + r.failed_model + r.failed_other;
    r.achieved_rps = r.completed as f64 / wall.as_secs_f64().max(1e-9);
    r
}

/// `n` single-sample request payloads drawn from a dataset — the unit of
/// work a serving client sends (the coalescer is what builds batches).
pub fn single_sample_inputs(data: &crate::data::SynthData, n: usize) -> Vec<HostArray> {
    (0..n.max(1))
        .map(|i| data.batch(&[i % data.len().max(1)]).0)
        .collect()
}
