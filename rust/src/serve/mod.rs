//! Batched, back-pressured inference serving on top of
//! [`deploy::GetaEngine`](crate::deploy::GetaEngine).
//!
//! ```text
//!              submit()                 coalesce (≤ batch_window,
//!   clients ─────────────▶ bounded ────▶ ≤ max_batch)        ┌─────────┐
//!                          queue        worker threads ─────▶│ engine  │
//!              ServeError::QueueFull      │                  │ (shared,│
//!   clients ◀───────────── at capacity    │ infer_many       │ Arc)    │
//!                                         ▼                  └─────────┘
//!                          per-request latency ──▶ LatencyHistogram
//! ```
//!
//! The pieces, each its own module:
//!
//! * [`ModelCache`] (`cache`) — loads each `.geta` artifact **once** into
//!   an `Arc<GetaEngine>` shared read-only by every worker; the
//!   weight-stationary i8 panels are resident exactly once per model, not
//!   once per worker.
//! * [`Server`] (this module) — a bounded request queue with explicit
//!   load-shedding ([`ServeError::QueueFull`] at capacity, never an
//!   unbounded block), a request coalescer that merges queued requests
//!   into one [`BatchModel::infer_many`] call under a configurable
//!   latency budget (`batch_window`), a worker pool, and per-request
//!   latency recording into a [`LatencyHistogram`]. Shutdown drains: every
//!   accepted request completes before [`Server::shutdown`] returns.
//! * [`loadgen`] — an open-loop synthetic load generator (`geta serve` /
//!   `geta bench-serve`) that submits on a fixed schedule regardless of
//!   completion, the standard way to surface queueing delay that
//!   closed-loop clients hide.
//!
//! Determinism: coalescing does **not** change results. The engine's
//! `infer_many` keeps each request's micro-batch chunk boundaries exactly
//! as a solo `infer` call would produce them, so batch-statistics
//! normalization — and therefore every logit — is bitwise identical
//! whether a request was served alone or merged into a batch, at any
//! (workers, batch_window) setting. `test_serve.rs` pins this.
//!
//! Threading: with more than one worker the server pins the shared tiled
//! kernels to one thread per worker (`tensor::serial_scope`), so worker
//! parallelism and kernel parallelism never multiply into
//! oversubscription; a single-worker server lets the engine keep its full
//! kernel thread budget.

pub mod cache;
pub mod histogram;
pub mod loadgen;

pub use cache::ModelCache;
pub use histogram::LatencyHistogram;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::obs;
use crate::runtime::HostArray;
use crate::tensor;

/// Anything a [`Server`] can put behind its queue: answers a coalesced
/// batch of independent requests with one logits vector per request, in
/// request order. Implemented by `GetaEngine` (the real thing) and by
/// test doubles with controlled timing.
pub trait BatchModel: Send + Sync + 'static {
    fn infer_many(&self, xs: &[&HostArray]) -> Result<Vec<Vec<f32>>>;
}

impl BatchModel for crate::deploy::GetaEngine {
    fn infer_many(&self, xs: &[&HostArray]) -> Result<Vec<Vec<f32>>> {
        crate::deploy::GetaEngine::infer_many(self, xs)
    }
}

/// Typed admission errors — the explicit alternative to blocking the
/// caller when the service is saturated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// The bounded queue is at capacity: the request was **shed**, not
    /// enqueued. Callers retry, back off, or drop — their choice, made
    /// with full information.
    QueueFull { depth: usize },
    /// The server is draining for shutdown and admits no new requests.
    ShuttingDown,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueFull { depth } => {
                write!(f, "request shed: queue at capacity ({depth})")
            }
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Server tuning knobs. The defaults serve single requests immediately
/// (no added latency) with a small queue; `geta serve` exposes each as a
/// CLI flag.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads pulling batches off the queue.
    pub workers: usize,
    /// Bounded queue capacity; submissions beyond it are shed with
    /// [`ServeError::QueueFull`].
    pub queue_depth: usize,
    /// How long a worker may hold the oldest queued request back waiting
    /// for more requests to coalesce with. Zero = serve immediately.
    pub batch_window: Duration,
    /// Most requests merged into one `infer_many` call.
    pub max_batch: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            queue_depth: 64,
            batch_window: Duration::from_micros(500),
            max_batch: 8,
        }
    }
}

/// Counters a [`Server`] accumulates over its lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests admitted to the queue.
    pub accepted: u64,
    /// Requests rejected with [`ServeError::QueueFull`].
    pub shed: u64,
    /// Requests answered (successfully or with a model error).
    pub completed: u64,
    /// `infer_many` calls issued (completed ÷ batches = achieved batch).
    pub batches: u64,
}

/// The live form of [`ServeStats`]: relaxed atomics, so the shed path —
/// which runs exactly when the service is overloaded — never takes a
/// lock, and readers assemble a snapshot without stopping writers.
#[derive(Debug, Default)]
struct AtomicStats {
    accepted: AtomicU64,
    shed: AtomicU64,
    completed: AtomicU64,
    batches: AtomicU64,
}

impl AtomicStats {
    fn snapshot(&self) -> ServeStats {
        ServeStats {
            accepted: self.accepted.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
        }
    }
}

/// Handles into the global [`obs::metrics`] registry mirroring the
/// server's counters (plus the live queue-depth gauge and the latency
/// summary), so `geta serve --metrics-every` and metric snapshots see
/// the same numbers [`Server::stats`] reports.
struct RegistryMirror {
    accepted: obs::metrics::Counter,
    shed: obs::metrics::Counter,
    completed: obs::metrics::Counter,
    batches: obs::metrics::Counter,
    queue_depth: obs::metrics::Gauge,
    latency: obs::metrics::Hist,
}

impl RegistryMirror {
    fn new() -> RegistryMirror {
        let r = obs::metrics::global();
        RegistryMirror {
            accepted: r.counter("geta_serve_accepted_total"),
            shed: r.counter("geta_serve_shed_total"),
            completed: r.counter("geta_serve_completed_total"),
            batches: r.counter("geta_serve_batches_total"),
            queue_depth: r.gauge("geta_serve_queue_depth"),
            latency: r.histogram("geta_serve_latency_us"),
        }
    }
}

/// A served request's answer plus its measured queue-to-completion
/// latency (the number the histograms aggregate).
#[derive(Debug, Clone)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub latency: Duration,
}

/// One-shot completion slot a worker fulfills and a [`Ticket`] waits on.
#[derive(Debug)]
struct ResponseSlot {
    done: Mutex<Option<Result<Reply, String>>>,
    cv: Condvar,
}

impl ResponseSlot {
    fn new() -> ResponseSlot {
        ResponseSlot {
            done: Mutex::new(None),
            cv: Condvar::new(),
        }
    }

    fn fulfill(&self, r: Result<Reply, String>) {
        let mut done = self.done.lock().unwrap_or_else(|e| e.into_inner());
        debug_assert!(done.is_none(), "response slot fulfilled twice");
        *done = Some(r);
        self.cv.notify_all();
    }
}

/// Handle for an **accepted** request; [`wait`](Ticket::wait) blocks until
/// a worker answers. Drain-on-shutdown guarantees every ticket is
/// eventually fulfilled.
#[derive(Debug)]
pub struct Ticket {
    slot: Arc<ResponseSlot>,
}

impl Ticket {
    pub fn wait(self) -> Result<Reply> {
        let mut done = self.slot.done.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(r) = done.take() {
                return r.map_err(|e| anyhow::anyhow!(e));
            }
            done = self.slot.cv.wait(done).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Like [`wait`](Self::wait) but gives up after `timeout`, returning
    /// `None` (the request remains in flight and its latency is still
    /// recorded server-side).
    pub fn wait_timeout(self, timeout: Duration) -> Option<Result<Reply>> {
        let deadline = Instant::now() + timeout;
        let mut done = self.slot.done.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(r) = done.take() {
                return Some(r.map_err(|e| anyhow::anyhow!(e)));
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (d, _) = self
                .slot
                .cv
                .wait_timeout(done, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            done = d;
        }
    }
}

struct Pending {
    x: HostArray,
    enq: Instant,
    slot: Arc<ResponseSlot>,
}

struct Queue {
    items: VecDeque<Pending>,
    /// False once shutdown begins: no new admissions, workers drain what
    /// remains and exit.
    open: bool,
}

struct Inner {
    model: Arc<dyn BatchModel>,
    cfg: ServeConfig,
    /// Pin kernels to one thread inside each worker (workers > 1).
    serial_workers: bool,
    q: Mutex<Queue>,
    cv: Condvar,
    hist: Mutex<LatencyHistogram>,
    stats: AtomicStats,
    mirror: RegistryMirror,
}

impl Inner {
    /// Block until a batch is ready (coalescing up to `batch_window` /
    /// `max_batch`), or return `None` when the queue is closed and empty.
    fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut q = self.q.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if q.items.is_empty() {
                if !q.open {
                    return None;
                }
                q = self.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                continue;
            }
            // Coalesce: the latency budget runs from the *oldest* queued
            // request, so the window bounds added latency per request, not
            // per wait. A closing queue serves immediately.
            let deadline = q.items[0].enq + self.cfg.batch_window;
            while q.open && q.items.len() < self.cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (qq, timeout) = self
                    .cv
                    .wait_timeout(q, deadline - now)
                    .unwrap_or_else(|e| e.into_inner());
                q = qq;
                if q.items.is_empty() || timeout.timed_out() {
                    break;
                }
            }
            if q.items.is_empty() {
                // another worker drained the queue while we coalesced
                continue;
            }
            let take = q.items.len().min(self.cfg.max_batch.max(1));
            let batch: Vec<Pending> = q.items.drain(..take).collect();
            self.mirror.queue_depth.set(q.items.len() as i64);
            if !q.items.is_empty() {
                // leftover work: hand it to a sibling before we go compute
                self.cv.notify_one();
            }
            return Some(batch);
        }
    }

    fn run_batch(&self, batch: Vec<Pending>) {
        // picked = end of each request's queue wait, start of batch compute
        let picked = obs::enabled().then(Instant::now);
        let xs: Vec<&HostArray> = batch.iter().map(|p| &p.x).collect();
        let result = if self.serial_workers {
            tensor::serial_scope(|| self.model.infer_many(&xs))
        } else {
            self.model.infer_many(&xs)
        };
        let done = Instant::now();
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        self.stats.completed.fetch_add(batch.len() as u64, Ordering::Relaxed);
        self.mirror.batches.inc();
        self.mirror.completed.add(batch.len() as u64);
        if let Some(picked) = picked {
            for p in &batch {
                obs::trace::record_between("serve", "wait".to_string(), p.enq, picked);
            }
            obs::trace::record_between(
                "serve",
                format!("infer[{}]", batch.len()),
                picked,
                done,
            );
        }
        match result {
            Ok(outs) if outs.len() == batch.len() => {
                let mut hist = self.hist.lock().unwrap_or_else(|e| e.into_inner());
                for (p, logits) in batch.into_iter().zip(outs) {
                    let latency = done.saturating_duration_since(p.enq);
                    hist.record(latency);
                    self.mirror.latency.record(latency);
                    p.slot.fulfill(Ok(Reply { logits, latency }));
                }
                if picked.is_some() {
                    obs::trace::record_between("serve", "reply".to_string(), done, Instant::now());
                }
            }
            Ok(outs) => {
                let msg = format!(
                    "model returned {} outputs for a batch of {}",
                    outs.len(),
                    batch.len()
                );
                for p in batch {
                    p.slot.fulfill(Err(msg.clone()));
                }
            }
            Err(e) => {
                // a failed batch fails its requests, never the server
                let msg = format!("{e:#}");
                for p in batch {
                    p.slot.fulfill(Err(msg.clone()));
                }
            }
        }
    }

    fn worker_loop(&self) {
        while let Some(batch) = self.next_batch() {
            self.run_batch(batch);
        }
    }
}

/// Final accounting returned by [`Server::shutdown`].
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub stats: ServeStats,
    pub histogram: LatencyHistogram,
}

/// The serving front end: bounded admission, request coalescing, a worker
/// pool over one shared [`BatchModel`], per-request latency histograms.
/// See the module docs for the architecture.
pub struct Server {
    inner: Arc<Inner>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    pub fn start(model: Arc<dyn BatchModel>, cfg: ServeConfig) -> Server {
        let nworkers = cfg.workers.max(1);
        let inner = Arc::new(Inner {
            model,
            serial_workers: nworkers > 1,
            q: Mutex::new(Queue {
                items: VecDeque::new(),
                open: true,
            }),
            cv: Condvar::new(),
            hist: Mutex::new(LatencyHistogram::new()),
            stats: AtomicStats::default(),
            mirror: RegistryMirror::new(),
            cfg,
        });
        let workers = (0..nworkers)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("geta-serve-{i}"))
                    .spawn(move || inner.worker_loop())
                    .expect("spawn serve worker")
            })
            .collect();
        Server { inner, workers }
    }

    /// Admit one request. `Ok(Ticket)` means the request **will** be
    /// answered (drain-on-shutdown included); `Err` is immediate, typed,
    /// and never blocks.
    pub fn submit(&self, x: HostArray) -> Result<Ticket, ServeError> {
        let mut q = self.inner.q.lock().unwrap_or_else(|e| e.into_inner());
        if !q.open {
            return Err(ServeError::ShuttingDown);
        }
        if q.items.len() >= self.inner.cfg.queue_depth.max(1) {
            drop(q);
            // lock-free on purpose: shedding happens under overload
            self.inner.stats.shed.fetch_add(1, Ordering::Relaxed);
            self.inner.mirror.shed.inc();
            return Err(ServeError::QueueFull {
                depth: self.inner.cfg.queue_depth.max(1),
            });
        }
        let slot = Arc::new(ResponseSlot::new());
        q.items.push_back(Pending {
            x,
            enq: Instant::now(),
            slot: Arc::clone(&slot),
        });
        let depth = q.items.len();
        drop(q);
        self.inner.stats.accepted.fetch_add(1, Ordering::Relaxed);
        self.inner.mirror.accepted.inc();
        self.inner.mirror.queue_depth.set(depth as i64);
        self.inner.cv.notify_one();
        Ok(Ticket { slot })
    }

    /// Snapshot of the lifetime counters.
    pub fn stats(&self) -> ServeStats {
        self.inner.stats.snapshot()
    }

    /// Snapshot of the latency histogram so far.
    pub fn histogram(&self) -> LatencyHistogram {
        self.inner.hist.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Number of requests currently queued (not yet picked up).
    pub fn queued(&self) -> usize {
        self.inner.q.lock().unwrap_or_else(|e| e.into_inner()).items.len()
    }

    /// Stop admissions, **drain every accepted request**, join the
    /// workers, and return the final accounting. No accepted request is
    /// lost: tickets taken before shutdown all resolve.
    pub fn shutdown(self) -> ServeReport {
        {
            let mut q = self.inner.q.lock().unwrap_or_else(|e| e.into_inner());
            q.open = false;
        }
        self.inner.cv.notify_all();
        for h in self.workers {
            h.join().expect("serve worker panicked");
        }
        ServeReport {
            stats: self.inner.stats.snapshot(),
            histogram: self.inner.hist.lock().unwrap_or_else(|e| e.into_inner()).clone(),
        }
    }
}
