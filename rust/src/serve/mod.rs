//! Batched, back-pressured, **fault-tolerant** inference serving on top
//! of [`deploy::GetaEngine`](crate::deploy::GetaEngine).
//!
//! ```text
//!          submit_with(prio,              coalesce (≤ batch_window,
//!   clients ──deadline)──▶ 3-lane ───────▶ ≤ max_batch)        ┌─────────┐
//!                          bounded        worker threads ─────▶│ engine  │
//!          ServeError::    queue            │ catch_unwind     │ (shared,│
//!   clients ◀─QueueFull─── at capacity      │ + supervision    │ Arc)    │
//!          ◀─DeadlineExceeded─ on expiry    │ infer_many       └─────────┘
//!          ◀─WorkerPanic/Model─ per request ▼
//!                          per-request latency ──▶ LatencyHistogram
//! ```
//!
//! The pieces, each its own module:
//!
//! * [`ModelCache`] (`cache`) — loads each `.geta` artifact **once** into
//!   an `Arc<GetaEngine>` shared read-only by every worker; a failed load
//!   is never cached (and [`ModelCache::evict`] can drop a entry whose
//!   artifact was replaced on disk).
//! * [`Server`] (this module) — a bounded priority queue with explicit
//!   load-shedding ([`ServeError::QueueFull`] at capacity, never an
//!   unbounded block), per-request deadlines (entries that expire while
//!   queued are failed with [`ServeError::DeadlineExceeded`] *before*
//!   wasting an `infer_many` slot), a request coalescer that merges
//!   queued requests into one [`BatchModel::infer_many`] call under a
//!   configurable latency budget (`batch_window`), a **supervised**
//!   worker pool, and per-request latency recording into a
//!   [`LatencyHistogram`]. Shutdown drains: every accepted request
//!   resolves before [`Server::shutdown`] returns — with a reply, a typed
//!   error, or (backstop) [`ServeError::Dropped`].
//! * [`faults`] — a seeded, schedule-driven fault injector
//!   ([`FaultPlan`]) armed via [`Server::start_faulted`]; `None` keeps
//!   the hot path bitwise identical to an unarmed build.
//! * [`loadgen`] — an open-loop synthetic load generator (`geta serve` /
//!   `geta bench-serve`); its pressure mode retries shed submissions
//!   under bounded exponential [`Backoff`](loadgen::Backoff) with
//!   deterministic jitter.
//!
//! **Failure containment.** The model call runs under
//! `std::panic::catch_unwind`: a panicking request fails *its own ticket*
//! with [`ServeError::WorkerPanic`] — batchmates are re-served solo
//! (bitwise identical results, see below) and the server stays up. A
//! worker thread that caught a panic is retired after resolving its
//! batch — panicking mid-kernel can strand thread-local state (e.g. the
//! [`tensor::serial_scope`] pin) — and a supervisor respawn takes its
//! place (`ServeStats::worker_restarts`, `geta_serve_worker_restarts`
//! metric). A model call that returns `Err` gets one bounded solo retry
//! (transient faults recover; persistent ones fail typed as
//! [`ServeError::Model`]).
//!
//! Determinism: coalescing does **not** change results. The engine's
//! `infer_many` keeps each request's micro-batch chunk boundaries exactly
//! as a solo `infer` call would produce them, so batch-statistics
//! normalization — and therefore every logit — is bitwise identical
//! whether a request was served alone, merged into a batch, or re-served
//! solo after a batchmate's fault, at any (workers, batch_window)
//! setting. `test_serve.rs` pins the clean path; `test_faults.rs` pins
//! survivor parity under every injected fault class.
//!
//! Threading: with more than one worker the server pins the shared tiled
//! kernels to one thread per worker (`tensor::serial_scope`), so worker
//! parallelism and kernel parallelism never multiply into
//! oversubscription; a single-worker server lets the engine keep its full
//! kernel thread budget.

pub mod cache;
pub mod faults;
pub mod histogram;
pub mod loadgen;

pub use cache::ModelCache;
pub use faults::{ChaosReport, FaultKind, FaultPlan, FaultSpec};
pub use histogram::LatencyHistogram;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::obs;
use crate::runtime::HostArray;
use crate::tensor;

/// Anything a [`Server`] can put behind its queue: answers a coalesced
/// batch of independent requests with one logits vector per request, in
/// request order. Implemented by `GetaEngine` (the real thing) and by
/// test doubles with controlled timing.
pub trait BatchModel: Send + Sync + 'static {
    fn infer_many(&self, xs: &[&HostArray]) -> Result<Vec<Vec<f32>>>;
}

impl BatchModel for crate::deploy::GetaEngine {
    fn infer_many(&self, xs: &[&HostArray]) -> Result<Vec<Vec<f32>>> {
        crate::deploy::GetaEngine::infer_many(self, xs)
    }
}

/// Typed request outcomes other than a reply. Admission errors
/// (`QueueFull`, `ShuttingDown`) come back from [`Server::submit`]
/// immediately; the rest resolve a [`Ticket`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The bounded queue is at capacity: the request was **shed**, not
    /// enqueued. Callers retry, back off, or drop — their choice, made
    /// with full information.
    QueueFull { depth: usize },
    /// The server is draining for shutdown and admits no new requests.
    ShuttingDown,
    /// The request's deadline passed while it sat in the queue; it was
    /// expired without spending an `infer_many` slot on it.
    DeadlineExceeded { waited_us: u64 },
    /// The model call panicked with this request in the batch. The
    /// worker was supervised: batchmates were re-served, the thread was
    /// respawned, only this request fails.
    WorkerPanic { msg: String },
    /// The model call returned an error for this request (after one
    /// bounded retry).
    Model { msg: String },
    /// Backstop: the request was dropped without a worker answering —
    /// only reachable if a worker died outside the supervised model call.
    Dropped,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueFull { depth } => {
                write!(f, "request shed: queue at capacity ({depth})")
            }
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::DeadlineExceeded { waited_us } => {
                write!(f, "deadline exceeded after {waited_us}us in queue")
            }
            ServeError::WorkerPanic { msg } => {
                write!(f, "worker panicked serving this request: {msg}")
            }
            // bare message: callers see exactly what the model reported
            ServeError::Model { msg } => f.write_str(msg),
            ServeError::Dropped => {
                write!(f, "request dropped without an answer (unsupervised worker death)")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Queue lane a request is admitted to. Workers always drain the highest
/// non-empty lane first; within a lane, FIFO. There is no aging — a
/// saturated `High` lane starves `Low` by design (shed, don't reorder).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Low,
}

impl Priority {
    const COUNT: usize = 3;

    fn lane(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

/// Server tuning knobs. The defaults serve single requests immediately
/// (no added latency) with a small queue; `geta serve` exposes each as a
/// CLI flag.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads pulling batches off the queue.
    pub workers: usize,
    /// Bounded queue capacity (all lanes combined); submissions beyond
    /// it are shed with [`ServeError::QueueFull`].
    pub queue_depth: usize,
    /// How long a worker may hold the oldest queued request back waiting
    /// for more requests to coalesce with. Zero = serve immediately.
    pub batch_window: Duration,
    /// Most requests merged into one `infer_many` call.
    pub max_batch: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            queue_depth: 64,
            batch_window: Duration::from_micros(500),
            max_batch: 8,
        }
    }
}

/// Counters a [`Server`] accumulates over its lifetime. Invariant after
/// shutdown: `accepted == completed + expired` (+ any `Dropped`
/// backstops, which only an unsupervised worker death can produce).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests admitted to the queue.
    pub accepted: u64,
    /// Requests rejected with [`ServeError::QueueFull`].
    pub shed: u64,
    /// Requests answered by a worker (with logits **or** a typed
    /// failure).
    pub completed: u64,
    /// Subset of `completed` answered with a typed failure
    /// ([`ServeError::Model`] / [`ServeError::WorkerPanic`]).
    pub failed: u64,
    /// Requests expired in-queue with [`ServeError::DeadlineExceeded`].
    pub expired: u64,
    /// `infer_many` calls issued for whole batches (isolation re-serves
    /// and retries not included; completed ÷ batches = achieved batch).
    pub batches: u64,
    /// Bounded solo retries after a model-call `Err`.
    pub retries: u64,
    /// Model-call panics caught by the supervisor.
    pub worker_panics: u64,
    /// Worker threads retired after a caught panic and respawned.
    pub worker_restarts: u64,
}

/// The live form of [`ServeStats`]: relaxed atomics, so the shed path —
/// which runs exactly when the service is overloaded — never takes a
/// lock, and readers assemble a snapshot without stopping writers.
#[derive(Debug, Default)]
struct AtomicStats {
    accepted: AtomicU64,
    shed: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    expired: AtomicU64,
    batches: AtomicU64,
    retries: AtomicU64,
    worker_panics: AtomicU64,
    worker_restarts: AtomicU64,
}

impl AtomicStats {
    fn snapshot(&self) -> ServeStats {
        ServeStats {
            accepted: self.accepted.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
        }
    }
}

/// Handles into the global [`obs::metrics`] registry mirroring the
/// server's counters (plus the live queue-depth gauge and the latency
/// summary), so `geta serve --metrics-every` and metric snapshots see
/// the same numbers [`Server::stats`] reports.
struct RegistryMirror {
    accepted: obs::metrics::Counter,
    shed: obs::metrics::Counter,
    completed: obs::metrics::Counter,
    failed: obs::metrics::Counter,
    expired: obs::metrics::Counter,
    batches: obs::metrics::Counter,
    panics: obs::metrics::Counter,
    restarts: obs::metrics::Counter,
    queue_depth: obs::metrics::Gauge,
    latency: obs::metrics::Hist,
}

impl RegistryMirror {
    fn new() -> RegistryMirror {
        let r = obs::metrics::global();
        RegistryMirror {
            accepted: r.counter("geta_serve_accepted_total"),
            shed: r.counter("geta_serve_shed_total"),
            completed: r.counter("geta_serve_completed_total"),
            failed: r.counter("geta_serve_failed_total"),
            expired: r.counter("geta_serve_deadline_expired_total"),
            batches: r.counter("geta_serve_batches_total"),
            panics: r.counter("geta_serve_worker_panics_total"),
            restarts: r.counter("geta_serve_worker_restarts"),
            queue_depth: r.gauge("geta_serve_queue_depth"),
            latency: r.histogram("geta_serve_latency_us"),
        }
    }
}

/// A served request's answer plus its measured queue-to-completion
/// latency (the number the histograms aggregate).
#[derive(Debug, Clone)]
pub struct Reply {
    pub logits: Vec<f32>,
    pub latency: Duration,
}

/// One-shot completion slot a worker fulfills and a [`Ticket`] waits on.
/// `answered` tracks fulfillment independently of `done` because the
/// waiter *takes* the value out — `Pending`'s drop backstop must not
/// re-fulfill a slot whose answer was already consumed.
#[derive(Debug)]
struct ResponseSlot {
    done: Mutex<Option<Result<Reply, ServeError>>>,
    answered: AtomicBool,
    cv: Condvar,
}

impl ResponseSlot {
    fn new() -> ResponseSlot {
        ResponseSlot {
            done: Mutex::new(None),
            answered: AtomicBool::new(false),
            cv: Condvar::new(),
        }
    }

    fn fulfill(&self, r: Result<Reply, ServeError>) {
        let mut done = self.done.lock().unwrap_or_else(|e| e.into_inner());
        let was = self.answered.swap(true, Ordering::SeqCst);
        debug_assert!(!was, "response slot fulfilled twice");
        *done = Some(r);
        self.cv.notify_all();
    }
}

/// Handle for an **accepted** request; [`wait`](Ticket::wait) blocks until
/// a worker answers. Drain-on-shutdown guarantees every ticket is
/// eventually fulfilled.
#[derive(Debug)]
pub struct Ticket {
    slot: Arc<ResponseSlot>,
}

impl Ticket {
    /// Block for the typed outcome — the variant callers use to account
    /// per error class (deadline vs panic vs model error).
    pub fn wait_typed(self) -> Result<Reply, ServeError> {
        let mut done = self.slot.done.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(r) = done.take() {
                return r;
            }
            done = self.slot.cv.wait(done).unwrap_or_else(|e| e.into_inner());
        }
    }

    pub fn wait(self) -> Result<Reply> {
        self.wait_typed().map_err(anyhow::Error::new)
    }

    /// Like [`wait_typed`](Self::wait_typed) but gives up after
    /// `timeout`, returning `None` (the request remains in flight and
    /// its latency is still recorded server-side).
    pub fn wait_timeout_typed(self, timeout: Duration) -> Option<Result<Reply, ServeError>> {
        let deadline = Instant::now() + timeout;
        let mut done = self.slot.done.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(r) = done.take() {
                return Some(r);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (d, _) = self
                .slot
                .cv
                .wait_timeout(done, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            done = d;
        }
    }

    pub fn wait_timeout(self, timeout: Duration) -> Option<Result<Reply>> {
        self.wait_timeout_typed(timeout)
            .map(|r| r.map_err(anyhow::Error::new))
    }
}

struct Pending {
    x: HostArray,
    enq: Instant,
    /// Absolute expiry instant, from `submit_with`'s relative deadline.
    deadline: Option<Instant>,
    /// Admission-order index — the coordinate a [`FaultPlan`] marks on.
    arrival: u64,
    slot: Arc<ResponseSlot>,
}

impl Drop for Pending {
    /// Backstop for the no-ticket-leaks guarantee: a `Pending` that dies
    /// unfulfilled (worker death outside the supervised call, future
    /// logic bug) still resolves its ticket, as [`ServeError::Dropped`].
    fn drop(&mut self) {
        if !self.slot.answered.load(Ordering::SeqCst) {
            self.slot.fulfill(Err(ServeError::Dropped));
        }
    }
}

struct Queue {
    /// One FIFO per [`Priority`], drained highest-priority-first.
    lanes: [VecDeque<Pending>; Priority::COUNT],
    /// Admission counter; assigns each accepted request its arrival
    /// index (dense, in admission order — what fault plans key on).
    arrivals: u64,
    /// False once shutdown begins: no new admissions, workers drain what
    /// remains and exit.
    open: bool,
}

impl Queue {
    fn total(&self) -> usize {
        self.lanes.iter().map(VecDeque::len).sum()
    }

    /// Enqueue instant of the oldest entry across all lanes (each lane
    /// is FIFO, so lane fronts are lane-oldest).
    fn oldest_enq(&self) -> Option<Instant> {
        self.lanes.iter().filter_map(|l| l.front().map(|p| p.enq)).min()
    }

    /// Next request in priority order.
    fn pop_next(&mut self) -> Option<Pending> {
        self.lanes.iter_mut().find_map(VecDeque::pop_front)
    }
}

/// A model-call attempt's outcome, with panics reified as values.
enum Call {
    Ok(Vec<Vec<f32>>),
    Err(String),
    Panic(String),
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

struct Inner {
    model: Arc<dyn BatchModel>,
    cfg: ServeConfig,
    /// Pin kernels to one thread inside each worker (workers > 1).
    serial_workers: bool,
    /// Armed fault injector; `None` (the default) costs one branch per
    /// admission and per model call and changes no served bit.
    faults: Option<Arc<FaultPlan>>,
    q: Mutex<Queue>,
    cv: Condvar,
    hist: Mutex<LatencyHistogram>,
    stats: AtomicStats,
    mirror: RegistryMirror,
    /// Live worker threads; respawned replacements are pushed here, and
    /// shutdown joins until it drains.
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Monotonic spawn counter (names respawned threads distinctly).
    spawn_gen: AtomicU64,
}

impl Inner {
    /// Block until a batch of live (non-expired) requests is ready
    /// (coalescing up to `batch_window` / `max_batch`), or return `None`
    /// when the queue is closed and empty. Entries whose deadline passed
    /// while queued are expired here — typed, without spending an
    /// `infer_many` slot.
    fn next_batch(&self) -> Option<Vec<Pending>> {
        loop {
            let (batch, expired) = {
                let mut q = self.q.lock().unwrap_or_else(|e| e.into_inner());
                loop {
                    if q.total() == 0 {
                        if !q.open {
                            return None;
                        }
                        q = self.cv.wait(q).unwrap_or_else(|e| e.into_inner());
                        continue;
                    }
                    // Coalesce: the latency budget runs from the *oldest*
                    // queued request, so the window bounds added latency
                    // per request, not per wait. A closing queue serves
                    // immediately.
                    let window_end = q.oldest_enq().expect("non-empty queue has an oldest entry")
                        + self.cfg.batch_window;
                    while q.open && q.total() < self.cfg.max_batch {
                        let now = Instant::now();
                        if now >= window_end {
                            break;
                        }
                        let (qq, timeout) = self
                            .cv
                            .wait_timeout(q, window_end - now)
                            .unwrap_or_else(|e| e.into_inner());
                        q = qq;
                        if q.total() == 0 || timeout.timed_out() {
                            break;
                        }
                    }
                    if q.total() == 0 {
                        // another worker drained the queue while we coalesced
                        continue;
                    }
                    break;
                }
                let now = Instant::now();
                let mut batch = Vec::new();
                let mut expired = Vec::new();
                while batch.len() < self.cfg.max_batch.max(1) {
                    let Some(p) = q.pop_next() else { break };
                    if p.deadline.is_some_and(|d| now >= d) {
                        expired.push(p);
                    } else {
                        batch.push(p);
                    }
                }
                self.mirror.queue_depth.set(q.total() as i64);
                if q.total() > 0 {
                    // leftover work: hand it to a sibling before we go compute
                    self.cv.notify_one();
                }
                (batch, expired)
            };
            // queue lock released: resolve the dead-on-arrival entries
            let now = Instant::now();
            for p in expired {
                self.expire(p, now);
            }
            if !batch.is_empty() {
                return Some(batch);
            }
            // everything popped had already expired — wait for live work
        }
    }

    /// One model-call attempt over `batch`, with the armed fault hook and
    /// the panic boundary. Worker panics become [`Call::Panic`] values
    /// (and count into `worker_panics`); they never unwind further.
    fn invoke(&self, batch: &[Pending]) -> Call {
        let run = || -> Result<Vec<Vec<f32>>> {
            if let Some(plan) = &self.faults {
                plan.before_call(batch.iter().map(|p| p.arrival))?;
            }
            let xs: Vec<&HostArray> = batch.iter().map(|p| &p.x).collect();
            if self.serial_workers {
                tensor::serial_scope(|| self.model.infer_many(&xs))
            } else {
                self.model.infer_many(&xs)
            }
        };
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(run)) {
            Ok(Ok(outs)) => Call::Ok(outs),
            Ok(Err(e)) => Call::Err(format!("{e:#}")),
            Err(payload) => {
                self.stats.worker_panics.fetch_add(1, Ordering::Relaxed);
                self.mirror.panics.inc();
                Call::Panic(panic_message(payload.as_ref()))
            }
        }
    }

    fn fulfill_ok(&self, p: Pending, logits: Vec<f32>, done_at: Instant) {
        let latency = done_at.saturating_duration_since(p.enq);
        self.hist.lock().unwrap_or_else(|e| e.into_inner()).record(latency);
        self.mirror.latency.record(latency);
        self.stats.completed.fetch_add(1, Ordering::Relaxed);
        self.mirror.completed.inc();
        p.slot.fulfill(Ok(Reply { logits, latency }));
    }

    /// Resolve a request with a typed failure. Failed requests count as
    /// completed (the ticket is answered) but never enter the latency
    /// histogram, which describes successful replies only.
    fn fail(&self, p: Pending, e: ServeError) {
        self.stats.completed.fetch_add(1, Ordering::Relaxed);
        self.stats.failed.fetch_add(1, Ordering::Relaxed);
        self.mirror.completed.inc();
        self.mirror.failed.inc();
        p.slot.fulfill(Err(e));
    }

    fn expire(&self, p: Pending, now: Instant) {
        let waited = now.saturating_duration_since(p.enq);
        self.stats.expired.fetch_add(1, Ordering::Relaxed);
        self.mirror.expired.inc();
        p.slot.fulfill(Err(ServeError::DeadlineExceeded {
            waited_us: waited.as_micros() as u64,
        }));
    }

    /// Resolve one request given its first solo call outcome. `Err` gets
    /// one bounded retry (transient faults recover); a panic fails typed
    /// with no retry. Returns true if a panic was caught here.
    fn resolve_solo(&self, p: Pending, call: Call) -> bool {
        match call {
            Call::Ok(mut outs) if outs.len() == 1 => {
                self.fulfill_ok(p, outs.pop().expect("length checked"), Instant::now());
                false
            }
            Call::Ok(outs) => {
                self.fail(
                    p,
                    ServeError::Model {
                        msg: format!("model returned {} outputs for 1 request", outs.len()),
                    },
                );
                false
            }
            Call::Err(_) => {
                self.stats.retries.fetch_add(1, Ordering::Relaxed);
                match self.invoke(std::slice::from_ref(&p)) {
                    Call::Ok(mut outs) if outs.len() == 1 => {
                        self.fulfill_ok(p, outs.pop().expect("length checked"), Instant::now());
                        false
                    }
                    Call::Ok(outs) => {
                        self.fail(
                            p,
                            ServeError::Model {
                                msg: format!("model returned {} outputs for 1 request", outs.len()),
                            },
                        );
                        false
                    }
                    Call::Err(second) => {
                        self.fail(p, ServeError::Model { msg: second });
                        false
                    }
                    Call::Panic(msg) => {
                        self.fail(p, ServeError::WorkerPanic { msg });
                        true
                    }
                }
            }
            Call::Panic(msg) => {
                self.fail(p, ServeError::WorkerPanic { msg });
                true
            }
        }
    }

    /// Serve one coalesced batch to resolution. Returns true if any model
    /// call panicked under this thread (the caller retires it).
    fn run_batch(&self, batch: Vec<Pending>) -> bool {
        // picked = end of each request's queue wait, start of batch compute
        let picked = obs::enabled().then(Instant::now);
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        self.mirror.batches.inc();
        let call = self.invoke(&batch);
        let done = Instant::now();
        if let Some(picked) = picked {
            for p in &batch {
                obs::trace::record_between("serve", "wait".to_string(), p.enq, picked);
            }
            obs::trace::record_between("serve", format!("infer[{}]", batch.len()), picked, done);
        }
        match call {
            Call::Ok(outs) if outs.len() == batch.len() => {
                for (p, logits) in batch.into_iter().zip(outs) {
                    self.fulfill_ok(p, logits, done);
                }
                if picked.is_some() {
                    obs::trace::record_between("serve", "reply".to_string(), done, Instant::now());
                }
                false
            }
            first => {
                let mut tainted = matches!(first, Call::Panic(_));
                if batch.len() == 1 {
                    let p = batch.into_iter().next().expect("length checked");
                    tainted |= self.resolve_solo(p, first);
                } else {
                    // A coalesced batch failed as a unit. Re-serve each
                    // request alone so one bad request cannot take down its
                    // batchmates — solo logits are bitwise identical to
                    // coalesced ones, so survivors lose nothing.
                    drop(first);
                    for p in batch {
                        let call = self.invoke(std::slice::from_ref(&p));
                        tainted |= self.resolve_solo(p, call);
                    }
                }
                tainted
            }
        }
    }

    fn spawn_worker(inner: &Arc<Inner>, id: usize) {
        let nth = inner.spawn_gen.fetch_add(1, Ordering::Relaxed);
        let name = if nth < inner.cfg.workers.max(1) as u64 {
            format!("geta-serve-{id}")
        } else {
            format!("geta-serve-{id}r{nth}")
        };
        let me = Arc::clone(inner);
        match std::thread::Builder::new().name(name).spawn(move || Inner::worker_loop(&me, id)) {
            Ok(h) => inner.handles.lock().unwrap_or_else(|e| e.into_inner()).push(h),
            // Out of threads: degraded but safe — remaining workers (or
            // the shutdown backstop drain) still resolve every ticket.
            Err(e) => eprintln!("[serve] could not spawn worker {id}: {e}"),
        }
    }

    fn worker_loop(inner: &Arc<Inner>, id: usize) {
        while let Some(batch) = inner.next_batch() {
            if inner.run_batch(batch) {
                // The model call panicked under this thread. Its batch is
                // fully resolved (typed), but the unwind may have stranded
                // thread-local state — serial_scope's kernel pin restores
                // non-guarded, for one — so retire the thread and hand the
                // loop to a fresh replacement.
                inner.stats.worker_restarts.fetch_add(1, Ordering::Relaxed);
                inner.mirror.restarts.inc();
                Inner::spawn_worker(inner, id);
                return;
            }
        }
    }
}

/// Final accounting returned by [`Server::shutdown`].
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub stats: ServeStats,
    pub histogram: LatencyHistogram,
    /// Worker threads that died *outside* the supervised model call
    /// (join error at shutdown). Always 0 unless serving code itself —
    /// not the model — panicked; reported, never re-raised.
    pub dead_workers: usize,
}

/// The serving front end: bounded admission with priorities and
/// deadlines, request coalescing, a supervised worker pool over one
/// shared [`BatchModel`], per-request latency histograms. See the module
/// docs for the architecture and the failure-containment contract.
pub struct Server {
    inner: Arc<Inner>,
}

impl Server {
    pub fn start(model: Arc<dyn BatchModel>, cfg: ServeConfig) -> Server {
        Server::start_faulted(model, cfg, None)
    }

    /// [`start`](Self::start) with an armed fault injector. `None` is
    /// the production path: beyond one `Option` check per admission and
    /// per model call, the server is bit-for-bit the unarmed one.
    pub fn start_faulted(
        model: Arc<dyn BatchModel>,
        cfg: ServeConfig,
        faults: Option<Arc<FaultPlan>>,
    ) -> Server {
        let nworkers = cfg.workers.max(1);
        let inner = Arc::new(Inner {
            model,
            serial_workers: nworkers > 1,
            faults,
            q: Mutex::new(Queue {
                lanes: std::array::from_fn(|_| VecDeque::new()),
                arrivals: 0,
                open: true,
            }),
            cv: Condvar::new(),
            hist: Mutex::new(LatencyHistogram::new()),
            stats: AtomicStats::default(),
            mirror: RegistryMirror::new(),
            handles: Mutex::new(Vec::with_capacity(nworkers)),
            spawn_gen: AtomicU64::new(0),
            cfg,
        });
        for i in 0..nworkers {
            Inner::spawn_worker(&inner, i);
        }
        Server { inner }
    }

    /// Admit one request at [`Priority::Normal`] with no deadline.
    /// `Ok(Ticket)` means the request **will** be answered
    /// (drain-on-shutdown included); `Err` is immediate, typed, and
    /// never blocks.
    pub fn submit(&self, x: HostArray) -> Result<Ticket, ServeError> {
        self.submit_with(x, Priority::Normal, None)
    }

    /// Admit one request into a priority lane, optionally with a
    /// deadline relative to now. A request still queued when its
    /// deadline passes is failed with [`ServeError::DeadlineExceeded`]
    /// instead of occupying an `infer_many` slot; once a worker picks it
    /// up it runs to completion regardless.
    pub fn submit_with(
        &self,
        x: HostArray,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<Ticket, ServeError> {
        let mut x = x;
        let mut q = self.inner.q.lock().unwrap_or_else(|e| e.into_inner());
        if !q.open {
            return Err(ServeError::ShuttingDown);
        }
        if q.total() >= self.inner.cfg.queue_depth.max(1) {
            drop(q);
            // lock-free on purpose: shedding happens under overload
            self.inner.stats.shed.fetch_add(1, Ordering::Relaxed);
            self.inner.mirror.shed.inc();
            return Err(ServeError::QueueFull {
                depth: self.inner.cfg.queue_depth.max(1),
            });
        }
        let arrival = q.arrivals;
        q.arrivals += 1;
        if let Some(plan) = &self.inner.faults {
            plan.admit(arrival, &mut x);
        }
        let slot = Arc::new(ResponseSlot::new());
        let now = Instant::now();
        q.lanes[priority.lane()].push_back(Pending {
            x,
            enq: now,
            deadline: deadline.map(|d| now + d),
            arrival,
            slot: Arc::clone(&slot),
        });
        let depth = q.total();
        drop(q);
        self.inner.stats.accepted.fetch_add(1, Ordering::Relaxed);
        self.inner.mirror.accepted.inc();
        self.inner.mirror.queue_depth.set(depth as i64);
        self.inner.cv.notify_one();
        Ok(Ticket { slot })
    }

    /// Snapshot of the lifetime counters.
    pub fn stats(&self) -> ServeStats {
        self.inner.stats.snapshot()
    }

    /// Snapshot of the latency histogram so far.
    pub fn histogram(&self) -> LatencyHistogram {
        self.inner.hist.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Number of requests currently queued (not yet picked up).
    pub fn queued(&self) -> usize {
        self.inner.q.lock().unwrap_or_else(|e| e.into_inner()).total()
    }

    /// Stop admissions, **drain every accepted request**, join the
    /// workers (including any respawned mid-drain), and return the final
    /// accounting. No accepted request is lost: tickets taken before
    /// shutdown all resolve — a dead worker is reported in
    /// [`ServeReport::dead_workers`], never re-raised as a panic.
    pub fn shutdown(self) -> ServeReport {
        {
            let mut q = self.inner.q.lock().unwrap_or_else(|e| e.into_inner());
            q.open = false;
        }
        self.inner.cv.notify_all();
        let mut dead_workers = 0usize;
        // Joined one at a time because a supervised respawn can push a new
        // handle while we drain: a retiring worker pushes its replacement
        // before exiting, so its join implies the replacement is visible.
        // The guard must drop inside the closure: on edition 2021 a
        // `while let` scrutinee keeps its temporaries alive through the
        // body, which would hold the handles lock across `join()` and
        // deadlock against a respawning worker pushing its handle.
        let pop_handle = || self.inner.handles.lock().unwrap_or_else(|e| e.into_inner()).pop();
        while let Some(h) = pop_handle() {
            if h.join().is_err() {
                dead_workers += 1;
            }
        }
        // Backstop: if workers died unsupervised they may have stranded
        // queued requests; dropping them resolves each ticket with
        // `ServeError::Dropped` (see `Pending::drop`).
        let stranded: Vec<Pending> = {
            let mut q = self.inner.q.lock().unwrap_or_else(|e| e.into_inner());
            let mut v = Vec::new();
            while let Some(p) = q.pop_next() {
                v.push(p);
            }
            v
        };
        drop(stranded);
        ServeReport {
            stats: self.inner.stats.snapshot(),
            histogram: self.inner.hist.lock().unwrap_or_else(|e| e.into_inner()).clone(),
            dead_workers,
        }
    }
}
