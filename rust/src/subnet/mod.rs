//! Subnet construction — `geta.construct_subnet()` of the paper's usage
//! sketch: turn a trained, group-zeroed, quantized model into a compressed
//! deliverable.
//!
//! Produces (1) per-tensor retained-channel maps (the slicing plan), (2)
//! packed integer weights at the learned bit widths, and (3) the size /
//! BOPs report. Training-time pruning only *zeroes* groups (forward-
//! equivalent to slicing — proven by `slicing_equivalence` tests); this
//! module performs the physical removal.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::graph::PruneGroup;
use crate::metrics::bops::{self, LayerCost};
use crate::optim::qasso::SiteSpec;
use crate::quant::{self, QParams};
use crate::runtime::lowering::{Node, OpKind, Program};
use crate::tensor::ParamStore;

/// Per-tensor axis retention after pruning.
#[derive(Debug, Clone, Default)]
pub struct KeptMap {
    /// tensor -> axis -> sorted removed indices
    pub removed: BTreeMap<String, BTreeMap<usize, Vec<usize>>>,
}

impl KeptMap {
    pub fn from_groups(groups: &[PruneGroup], pruned: &[bool]) -> KeptMap {
        let mut removed: BTreeMap<String, BTreeMap<usize, Vec<usize>>> = BTreeMap::new();
        for (g, grp) in groups.iter().enumerate() {
            if !pruned[g] {
                continue;
            }
            for m in &grp.members {
                let e = removed
                    .entry(m.tensor.clone())
                    .or_default()
                    .entry(m.axis)
                    .or_default();
                e.extend(&m.indices);
            }
        }
        for axes in removed.values_mut() {
            for idx in axes.values_mut() {
                idx.sort_unstable();
                idx.dedup();
            }
        }
        KeptMap { removed }
    }

    /// (input fraction, output fraction) retained for a weight tensor.
    pub fn frac(&self, tensor: &str, shape: &[usize]) -> (f64, f64) {
        let out_axis = shape.len() - 1;
        let in_axis = out_axis.saturating_sub(1);
        let f = |axis: usize| -> f64 {
            let total = shape[axis] as f64;
            let gone = self
                .removed
                .get(tensor)
                .and_then(|m| m.get(&axis))
                .map(|v| v.len())
                .unwrap_or(0) as f64;
            (total - gone) / total
        };
        if shape.len() < 2 {
            return (1.0, f(0));
        }
        (f(in_axis), f(out_axis))
    }

    /// Physically slice a tensor: drop the removed indices on each axis.
    pub fn slice(&self, t: &crate::tensor::Tensor) -> crate::tensor::Tensor {
        let Some(axes) = self.removed.get(&t.name) else {
            return t.clone();
        };
        let mut shape = t.shape.clone();
        let mut data = t.data.clone();
        // remove axes one at a time, highest axis first (strides stay valid)
        let mut order: Vec<_> = axes.keys().copied().collect();
        order.sort_unstable_by(|a, b| b.cmp(a));
        for axis in order {
            let rm = &axes[&axis];
            let keep: Vec<usize> = (0..shape[axis]).filter(|i| !rm.contains(i)).collect();
            let inner: usize = shape[axis + 1..].iter().product();
            let outer: usize = shape[..axis].iter().product();
            let mut out = Vec::with_capacity(outer * keep.len() * inner);
            for o in 0..outer {
                for &k in &keep {
                    let base = o * shape[axis] * inner + k * inner;
                    out.extend_from_slice(&data[base..base + inner]);
                }
            }
            shape[axis] = keep.len();
            data = out;
        }
        crate::tensor::Tensor::from_vec(&t.name, &shape, data)
    }

    /// Inverse of [`KeptMap::slice`]: re-insert the removed indices as
    /// zero rows/columns, restoring the original dense shape. Values at
    /// kept positions are copied bit-for-bit; removed positions are 0.0.
    /// `expand(slice(t))` equals `t` wherever `t` was zero at the removed
    /// positions (the QASSO invariant for pruned groups).
    pub fn expand(&self, t: &crate::tensor::Tensor) -> crate::tensor::Tensor {
        let Some(axes) = self.removed.get(&t.name) else {
            return t.clone();
        };
        let mut shape = t.shape.clone();
        let mut data = t.data.clone();
        // grow axes one at a time, lowest axis first (the mirror of
        // slice()'s highest-first order), recomputing strides each pass
        let mut order: Vec<_> = axes.keys().copied().collect();
        order.sort_unstable();
        for axis in order {
            let rm = &axes[&axis];
            let newlen = shape[axis] + rm.len();
            let keep: Vec<usize> = (0..newlen).filter(|i| !rm.contains(i)).collect();
            debug_assert_eq!(keep.len(), shape[axis]);
            let inner: usize = shape[axis + 1..].iter().product();
            let outer: usize = shape[..axis].iter().product();
            let mut out = vec![0.0f32; outer * newlen * inner];
            for o in 0..outer {
                for (ki, &k) in keep.iter().enumerate() {
                    let src = o * shape[axis] * inner + ki * inner;
                    let dst = o * newlen * inner + k * inner;
                    out[dst..dst + inner].copy_from_slice(&data[src..src + inner]);
                }
            }
            shape[axis] = newlen;
            data = out;
        }
        crate::tensor::Tensor::from_vec(&t.name, &shape, data)
    }
}

/// One packed, quantized weight tensor.
#[derive(Debug)]
pub struct PackedTensor {
    pub name: String,
    pub bits: u32,
    pub numel: usize,
    /// Signed quantization levels round(sgn·clip/d) (carrier i32; the
    /// size accounting uses `bits`).
    pub levels: Vec<i32>,
    pub q: QParams,
}

impl PackedTensor {
    pub fn size_bytes(&self) -> usize {
        (self.numel * self.bits as usize).div_ceil(8)
    }

    /// Reconstruct the fake-quantized values (levels * d).
    pub fn dequantize(&self) -> Vec<f32> {
        self.levels.iter().map(|&l| l as f32 * self.q.d).collect()
    }
}

#[derive(Debug)]
pub struct CompressedModel {
    pub kept: KeptMap,
    pub sliced: ParamStore,
    pub packed: Vec<PackedTensor>,
    pub params_before: usize,
    pub params_after: usize,
    pub size_fp32_before: usize,
    pub size_after: usize,
    pub avg_bits: f32,
    pub bops: bops::BopsReport,
}

impl CompressedModel {
    pub fn param_sparsity(&self) -> f64 {
        1.0 - self.params_after as f64 / self.params_before.max(1) as f64
    }
}

/// Re-zero every pruned group's output-side members. QASSO keeps pruned
/// groups at zero during training, but the deployment path re-asserts it
/// so masked-eval parity never depends on optimizer drift.
pub fn zero_pruned(params: &mut ParamStore, groups: &[PruneGroup], pruned: &[bool]) {
    let gi = crate::optim::saliency::GroupIndex::build(groups, params);
    for (g, &p) in pruned.iter().enumerate() {
        if p {
            gi.zero_group(g, params);
        }
    }
}

/// Propagate kept-channel slicing through a lowered program: rebuild every
/// node's shape from the **sliced** parameter store so conv/linear/norm/
/// attention shapes shrink coherently along the QADG groups instead of
/// merely carrying zeroed channels. Spatial extents never change (channel
/// pruning only), attention head counts shrink in whole heads, and every
/// producer/consumer channel mismatch is a hard error naming the node.
///
/// Caveat: this function sees only the sliced *shapes*, not which channel
/// indices were removed, so for attention it can check divisibility
/// (`dim % head_dim == 0`) but not that the removed channels align to
/// whole-head boundaries. Whole-head alignment is guaranteed by the QADG's
/// head-granular prune groups (`graph::depgraph` raises the space
/// granularity to `head_dim` at every `AttentionJoin`); callers slicing by
/// any other scheme must enforce it themselves.
pub fn propagate_slices(prog: &Program, sliced: &ParamStore) -> Result<Program> {
    let dim_of = |name: &str, axis: usize| -> Result<usize> {
        let t = sliced
            .get(name)
            .with_context(|| format!("sliced store missing `{name}`"))?;
        anyhow::ensure!(axis < t.shape.len(), "`{name}`: axis {axis} of {:?}", t.shape);
        Ok(t.shape[axis])
    };
    let numel_of = |name: &str| -> Result<usize> {
        Ok(sliced
            .get(name)
            .with_context(|| format!("sliced store missing `{name}`"))?
            .numel())
    };
    let mut nodes: Vec<Node> = Vec::with_capacity(prog.nodes.len());
    for node in &prog.nodes {
        let in_shape = |k: usize| -> &Vec<usize> { &nodes[node.inputs[k]].shape };
        let (shape, op) = match &node.op {
            OpKind::Input => (node.shape.clone(), node.op.clone()),
            OpKind::Embed { tok, pos } => {
                let dim = dim_of(tok, 1)?;
                anyhow::ensure!(
                    dim_of(pos, 1)? == dim,
                    "{}: pos table dim {} vs embedding dim {dim}",
                    node.name,
                    dim_of(pos, 1)?
                );
                (vec![node.shape[0], node.shape[1], dim], node.op.clone())
            }
            OpKind::Linear { w, .. } => {
                let wname = format!("{w}.weight");
                let din = dim_of(&wname, 0)?;
                let dout = dim_of(&wname, 1)?;
                anyhow::ensure!(
                    dout > 0,
                    "{}: fully pruned (zero kept output units) — cannot build a \
                     degenerate 0-dim linear",
                    node.name
                );
                let got = *in_shape(0).last().unwrap();
                anyhow::ensure!(
                    got == din,
                    "{}: input dim {got} vs sliced weight rows {din}",
                    node.name
                );
                anyhow::ensure!(
                    dim_of(&format!("{w}.bias"), 0)? == dout,
                    "{}: bias/weight out mismatch",
                    node.name
                );
                let mut shape = in_shape(0).clone();
                *shape.last_mut().unwrap() = dout;
                (shape, node.op.clone())
            }
            OpKind::Conv2d { w, .. } => {
                let wname = format!("{w}.weight");
                let cin = dim_of(&wname, 2)?;
                let cout = dim_of(&wname, 3)?;
                anyhow::ensure!(
                    cout > 0,
                    "{}: fully pruned (zero kept output channels) — cannot build a \
                     degenerate 0-channel conv",
                    node.name
                );
                let got = *in_shape(0).last().unwrap();
                anyhow::ensure!(
                    got == cin,
                    "{}: input channels {got} vs sliced weight cin {cin}",
                    node.name
                );
                anyhow::ensure!(
                    dim_of(&format!("{w}.bias"), 0)? == cout,
                    "{}: bias/weight cout mismatch",
                    node.name
                );
                // spatial extent is pruning-invariant: keep ho/wo
                (
                    vec![node.shape[0], node.shape[1], node.shape[2], cout],
                    node.op.clone(),
                )
            }
            OpKind::BatchNorm { p } | OpKind::LayerNorm { p } => {
                let shape = in_shape(0).clone();
                let c = *shape.last().unwrap();
                anyhow::ensure!(
                    c > 0,
                    "{}: fully pruned (zero surviving channels reach this norm)",
                    node.name
                );
                anyhow::ensure!(
                    numel_of(&format!("{p}.gamma"))? == c && numel_of(&format!("{p}.beta"))? == c,
                    "{}: norm params not sliced to {c} channels",
                    node.name
                );
                (shape, node.op.clone())
            }
            OpKind::Relu | OpKind::Gelu | OpKind::ActQuant { .. } => {
                (in_shape(0).clone(), node.op.clone())
            }
            OpKind::Add => {
                let a = in_shape(0).clone();
                anyhow::ensure!(
                    &a == in_shape(1),
                    "{}: add over mismatched shapes {a:?} vs {:?}",
                    node.name,
                    in_shape(1)
                );
                (a, node.op.clone())
            }
            OpKind::MaxPool2 => {
                let s = in_shape(0);
                (
                    vec![s[0], node.shape[1], node.shape[2], s[3]],
                    node.op.clone(),
                )
            }
            OpKind::GlobalAvgPool => {
                let s = in_shape(0);
                (vec![s[0], s[3]], node.op.clone())
            }
            OpKind::Reshape => {
                let s = in_shape(0);
                let shape = if node.shape.len() == 3 {
                    // NHWC -> tokens: [b, h*w, c]
                    vec![s[0], s[1] * s[2], s[3]]
                } else {
                    vec![s[0], s[1..].iter().product()]
                };
                (shape, node.op.clone())
            }
            OpKind::ConcatCls { cls } => {
                let s = in_shape(0);
                let dim = s[2];
                anyhow::ensure!(
                    numel_of(cls)? == dim,
                    "{}: cls token not sliced to dim {dim}",
                    node.name
                );
                (vec![s[0], s[1] + 1, dim], node.op.clone())
            }
            OpKind::AddPos { pos } => {
                let s = in_shape(0).clone();
                let rest: usize = s[1..].iter().product();
                anyhow::ensure!(
                    numel_of(pos)? == rest,
                    "{}: pos table not sliced to {rest} entries",
                    node.name
                );
                (s, node.op.clone())
            }
            OpKind::Attention { heads, causal } => {
                let orig_dim = *node.shape.last().unwrap();
                let hd = orig_dim / heads;
                let s = in_shape(0).clone();
                anyhow::ensure!(
                    &s == in_shape(1) && &s == in_shape(2),
                    "{}: q/k/v shapes diverge after slicing",
                    node.name
                );
                let dim = *s.last().unwrap();
                anyhow::ensure!(
                    dim > 0,
                    "{}: fully pruned (zero kept heads) — attention needs at least \
                     one surviving head",
                    node.name
                );
                anyhow::ensure!(
                    hd > 0 && dim % hd == 0,
                    "{}: sliced attention dim {dim} not a whole number of {hd}-wide heads \
                     (QADG groups must prune whole heads)",
                    node.name
                );
                (
                    s,
                    OpKind::Attention {
                        heads: dim / hd,
                        causal: *causal,
                    },
                )
            }
            OpKind::PatchMerge { side } => {
                let s = in_shape(0);
                let dim = s[2];
                let half = side / 2;
                (vec![s[0], half * half, dim * 4], node.op.clone())
            }
            OpKind::TokenPoolCls | OpKind::TokenPoolMean => {
                let s = in_shape(0);
                (vec![s[0], s[2]], node.op.clone())
            }
        };
        nodes.push(Node {
            name: node.name.clone(),
            op,
            inputs: node.inputs.clone(),
            shape,
        });
    }
    Ok(Program {
        family: prog.family.clone(),
        task: prog.task.clone(),
        batch: prog.batch,
        nodes,
    })
}

/// Build the compressed deliverable.
pub fn construct(
    params: &ParamStore,
    groups: &[PruneGroup],
    pruned: &[bool],
    costs: &[LayerCost],
    sites: &[SiteSpec],
    q: &[QParams],
) -> CompressedModel {
    let kept = KeptMap::from_groups(groups, pruned);
    let mut sliced = ParamStore::new();
    for t in &params.tensors {
        sliced.push(kept.slice(t));
    }
    // pack quantized weight sites from the sliced tensors
    let mut packed = Vec::new();
    let mut wbits: BTreeMap<String, f32> = BTreeMap::new();
    let mut abits: BTreeMap<String, f32> = BTreeMap::new();
    for (i, s) in sites.iter().enumerate() {
        let qp = q[i];
        let b = qp.bit_width().round().max(2.0);
        match &s.param {
            Some(pname) => {
                wbits.insert(pname.clone(), b);
                if let Some(t) = sliced.get(pname) {
                    let levels = t.data.iter().map(|&x| quant::quantize_level(x, &qp)).collect();
                    packed.push(PackedTensor {
                        name: pname.clone(),
                        bits: b as u32,
                        numel: t.numel(),
                        levels,
                        q: qp,
                    });
                }
            }
            None => {
                abits.insert(s.name.clone(), b);
            }
        }
    }
    let mut kept_fracs = BTreeMap::new();
    for t in &params.tensors {
        kept_fracs.insert(t.name.clone(), kept.frac(&t.name, &t.shape));
    }
    let bops_report = bops::bops(costs, &kept_fracs, &wbits, &abits, 1.0);
    let params_before = params.total_params();
    let params_after = sliced.total_params();
    // compressed size: packed sites at learned bits + the rest fp32
    let packed_names: Vec<&str> = packed.iter().map(|p| p.name.as_str()).collect();
    let rest_fp32: usize = sliced
        .tensors
        .iter()
        .filter(|t| !packed_names.contains(&t.name.as_str()))
        .map(|t| t.numel() * 4)
        .sum();
    let size_after = rest_fp32 + packed.iter().map(|p| p.size_bytes()).sum::<usize>();
    let avg_bits = if q.is_empty() {
        32.0
    } else {
        q.iter().map(|s| s.bit_width()).sum::<f32>() / q.len() as f32
    };
    CompressedModel {
        kept,
        sliced,
        packed,
        params_before,
        params_after,
        size_fp32_before: params_before * 4,
        size_after,
        avg_bits,
        bops: bops_report,
    }
}

// ----------------------------------------------------------------- tests
#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Member, Side};
    use crate::tensor::Tensor;

    /// Plain dense MLP forward (rust-native) used to prove zeroing ≡ slicing.
    fn mlp_forward(w1: &Tensor, w2: &Tensor, x: &[f32]) -> Vec<f32> {
        let (din, dh) = (w1.shape[0], w1.shape[1]);
        let dout = w2.shape[1];
        let mut h = vec![0.0f32; dh];
        for j in 0..dh {
            let mut s = 0.0;
            for i in 0..din {
                s += x[i] * w1.data[i * dh + j];
            }
            h[j] = s.max(0.0); // relu
        }
        let mut y = vec![0.0f32; dout];
        for j in 0..dout {
            let mut s = 0.0;
            for i in 0..dh {
                s += h[i] * w2.data[i * dout + j];
            }
            y[j] = s;
        }
        y
    }

    fn toy_mlp() -> (ParamStore, Vec<PruneGroup>) {
        let mut rng = crate::util::rng::Rng::new(17);
        let mut w1 = vec![0.0f32; 4 * 6];
        let mut w2 = vec![0.0f32; 6 * 3];
        rng.fill_normal(&mut w1, 1.0);
        rng.fill_normal(&mut w2, 1.0);
        let mut params = ParamStore::new();
        params.push(Tensor::from_vec("fc1.weight", &[4, 6], w1));
        params.push(Tensor::from_vec("fc2.weight", &[6, 3], w2));
        // groups: hidden neurons — out col of fc1 + in row of fc2
        let groups = (0..6)
            .map(|j| PruneGroup {
                id: j,
                label: format!("h{j}"),
                members: vec![
                    Member {
                        tensor: "fc1.weight".into(),
                        axis: 1,
                        indices: vec![j],
                        side: Side::Out,
                    },
                    Member {
                        tensor: "fc2.weight".into(),
                        axis: 0,
                        indices: vec![j],
                        side: Side::In,
                    },
                ],
            })
            .collect();
        (params, groups)
    }

    #[test]
    fn slicing_equivalence_zeroed_vs_sliced_forward() {
        let (mut params, groups) = toy_mlp();
        let pruned = vec![false, true, false, true, true, false];
        // zero the pruned groups' OUT members (training-time behaviour)
        let gi = crate::optim::saliency::GroupIndex::build(&groups, &params);
        for (g, &p) in pruned.iter().enumerate() {
            if p {
                gi.zero_group(g, &mut params);
            }
        }
        let x = [0.3f32, -0.7, 1.1, 0.5];
        let y_zeroed = mlp_forward(
            params.get("fc1.weight").unwrap(),
            params.get("fc2.weight").unwrap(),
            &x,
        );
        let kept = KeptMap::from_groups(&groups, &pruned);
        let w1s = kept.slice(params.get("fc1.weight").unwrap());
        let w2s = kept.slice(params.get("fc2.weight").unwrap());
        assert_eq!(w1s.shape, vec![4, 3]);
        assert_eq!(w2s.shape, vec![3, 3]);
        let y_sliced = mlp_forward(&w1s, &w2s, &x);
        for (a, b) in y_zeroed.iter().zip(&y_sliced) {
            assert!((a - b).abs() < 1e-5, "{y_zeroed:?} vs {y_sliced:?}");
        }
    }

    #[test]
    fn kept_fractions() {
        let (_, groups) = toy_mlp();
        let pruned = vec![false, true, false, true, true, false];
        let kept = KeptMap::from_groups(&groups, &pruned);
        let (fin, fout) = kept.frac("fc1.weight", &[4, 6]);
        assert_eq!((fin, fout), (1.0, 0.5));
        let (fin, fout) = kept.frac("fc2.weight", &[6, 3]);
        assert_eq!((fin, fout), (0.5, 1.0));
    }

    #[test]
    fn construct_reports_compression() {
        let (mut params, groups) = toy_mlp();
        let pruned = vec![false, true, false, true, true, false];
        let gi = crate::optim::saliency::GroupIndex::build(&groups, &params);
        for (g, &p) in pruned.iter().enumerate() {
            if p {
                gi.zero_group(g, &mut params);
            }
        }
        let costs = vec![
            LayerCost {
                param: "fc1.weight".into(),
                macs: 24.0,
                cin: 4,
                cout: 6,
                act_in_site: None,
            },
            LayerCost {
                param: "fc2.weight".into(),
                macs: 18.0,
                cin: 6,
                cout: 3,
                act_in_site: None,
            },
        ];
        let sites = vec![
            SiteSpec {
                name: "fc1.weight".into(),
                param: Some("fc1.weight".into()),
            },
            SiteSpec {
                name: "fc2.weight".into(),
                param: Some("fc2.weight".into()),
            },
        ];
        let q = vec![QParams::init(1.0, 8.0), QParams::init(1.0, 8.0)];
        let cm = construct(&params, &groups, &pruned, &costs, &sites, &q);
        assert_eq!(cm.params_before, 42);
        assert_eq!(cm.params_after, 4 * 3 + 3 * 3);
        assert!(cm.param_sparsity() > 0.4);
        // 50% pruned + 8/32 bits => rel bops = 0.5 * 0.25 = 12.5%
        assert!((cm.bops.rel_percent() - 12.5).abs() < 1e-6);
        assert!(cm.size_after < cm.size_fp32_before / 4);
        assert_eq!(cm.packed.len(), 2);
        // packed levels fit in the bit budget
        for p in &cm.packed {
            let cap = 1i64 << (p.bits - 1);
            assert!(p.levels.iter().all(|&l| (l as i64).abs() <= cap));
        }
    }

    #[test]
    fn slice_noop_without_pruning() {
        let (params, groups) = toy_mlp();
        let kept = KeptMap::from_groups(&groups, &[false; 6]);
        let t = params.get("fc1.weight").unwrap();
        let s = kept.slice(t);
        assert_eq!(s.shape, t.shape);
        assert_eq!(s.data, t.data);
    }

    #[test]
    fn zero_pruned_matches_group_index_zeroing() {
        let (mut a, groups) = toy_mlp();
        let mut b = a.clone();
        let pruned = vec![true, false, true, false, false, true];
        let gi = crate::optim::saliency::GroupIndex::build(&groups, &a);
        for (g, &p) in pruned.iter().enumerate() {
            if p {
                gi.zero_group(g, &mut a);
            }
        }
        zero_pruned(&mut b, &groups, &pruned);
        for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
            assert_eq!(ta.data, tb.data, "{}", ta.name);
        }
    }

    #[test]
    fn prop_packed_dequantize_error_bounded_by_quant_step() {
        // At the Appendix-C init (t = 1) the quantizer is a uniform grid of
        // step d inside the clip range, so the reconstruction error of any
        // in-range weight is at most d/2.
        crate::util::prop::check(
            100,
            |g| {
                let qm = g.f32_in(0.2, 2.0);
                let bits = g.f32_in(2.0, 8.0).round();
                let n = 4 + g.size(24);
                let w = g.vec_normal(n, qm * 0.4);
                (qm, bits, w)
            },
            |(qm, bits, w)| {
                let qp = QParams::init(*qm, *bits); // t = 1
                let levels: Vec<i32> = w.iter().map(|&x| quant::quantize_level(x, &qp)).collect();
                let p = PackedTensor {
                    name: "w".into(),
                    bits: *bits as u32,
                    numel: w.len(),
                    levels,
                    q: qp,
                };
                for (i, &x) in w.iter().enumerate() {
                    if x.abs() > qp.qm {
                        continue; // clipped: error is |x| - qm, unbounded by d
                    }
                    let err = (p.dequantize()[i] - x).abs();
                    if err > qp.d * 0.5 + 1e-6 {
                        return Err(format!(
                            "w[{i}]={x}: dequant error {err} > d/2 = {}",
                            qp.d * 0.5
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_slice_preserves_kept_channel_order() {
        crate::util::prop::check(
            80,
            |g| {
                let rows = g.size(6);
                let cols = 2 + g.size(8);
                let data = g.vec_normal(rows * cols, 1.0);
                // remove a random strict subset of columns
                let n_rm = g.rng.below(cols);
                let mut removed: Vec<usize> = (0..n_rm).map(|_| g.rng.below(cols)).collect();
                removed.sort_unstable();
                removed.dedup();
                if removed.len() == cols {
                    removed.pop();
                }
                (rows, cols, data, removed)
            },
            |(rows, cols, data, removed)| {
                let mut kept = KeptMap::default();
                kept.removed
                    .entry("w".to_string())
                    .or_default()
                    .insert(1, removed.clone());
                let t = Tensor::from_vec("w", &[*rows, *cols], data.clone());
                let s = kept.slice(&t);
                let keep: Vec<usize> =
                    (0..*cols).filter(|c| !removed.contains(c)).collect();
                if s.shape != vec![*rows, keep.len()] {
                    return Err(format!("shape {:?}", s.shape));
                }
                for r in 0..*rows {
                    for (k, &c) in keep.iter().enumerate() {
                        let got = s.data[r * keep.len() + k];
                        let want = data[r * cols + c];
                        if got != want {
                            return Err(format!(
                                "[{r},{k}] = {got}, want original column {c} = {want} \
                                 (kept-channel order violated)"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_expand_is_inverse_of_slice() {
        crate::util::prop::check(
            80,
            |g| {
                let rows = 1 + g.size(5);
                let cols = 2 + g.size(8);
                let data = g.vec_normal(rows * cols, 1.0);
                let n_rm_r = g.rng.below(rows);
                let mut rm_rows: Vec<usize> = (0..n_rm_r).map(|_| g.rng.below(rows)).collect();
                rm_rows.sort_unstable();
                rm_rows.dedup();
                if rm_rows.len() == rows {
                    rm_rows.pop();
                }
                let n_rm_c = g.rng.below(cols);
                let mut rm_cols: Vec<usize> = (0..n_rm_c).map(|_| g.rng.below(cols)).collect();
                rm_cols.sort_unstable();
                rm_cols.dedup();
                if rm_cols.len() == cols {
                    rm_cols.pop();
                }
                (rows, cols, data, rm_rows, rm_cols)
            },
            |(rows, cols, data, rm_rows, rm_cols)| {
                let mut kept = KeptMap::default();
                let e = kept.removed.entry("w".to_string()).or_default();
                e.insert(0, rm_rows.clone());
                e.insert(1, rm_cols.clone());
                // zero the removed positions so expand(slice(t)) == t exactly
                let mut z = data.clone();
                for r in 0..*rows {
                    for c in 0..*cols {
                        if rm_rows.contains(&r) || rm_cols.contains(&c) {
                            z[r * cols + c] = 0.0;
                        }
                    }
                }
                let t = Tensor::from_vec("w", &[*rows, *cols], z.clone());
                let back = kept.expand(&kept.slice(&t));
                if back.shape != t.shape {
                    return Err(format!("shape {:?} vs {:?}", back.shape, t.shape));
                }
                for (i, (a, b)) in back.data.iter().zip(&t.data).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("[{i}] expand∘slice = {a}, want {b}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn propagate_slices_rejects_fully_pruned_site() {
        use crate::graph::builders;
        use crate::runtime::lowering;
        use crate::util::json;
        let cfg = json::parse(
            r#"{"name": "t", "family": "mlp", "task": "image_cls",
                "image": {"size": 4, "channels": 1}, "hidden": [6, 4],
                "num_classes": 3, "quant": {"weight": true, "act": false}}"#,
        )
        .unwrap();
        let sites = builders::quant_site_specs(&cfg).unwrap();
        let prog = lowering::lower(&cfg, &sites, 2).unwrap();
        let space = crate::graph::search_space_for(&cfg).unwrap();
        let params = crate::runtime::init_params_for(
            &crate::runtime::native::synth_manifest(&cfg).unwrap(),
            0,
        );
        // prune EVERY fc0 hidden unit: zero kept outputs at that site
        let pruned: Vec<bool> = space
            .groups
            .iter()
            .map(|g| g.label.starts_with("fc0"))
            .collect();
        assert!(pruned.iter().any(|&p| p));
        let kept = KeptMap::from_groups(&space.groups, &pruned);
        let mut sliced = ParamStore::new();
        for t in &params.tensors {
            sliced.push(kept.slice(t));
        }
        let err = propagate_slices(&prog, &sliced).unwrap_err().to_string();
        assert!(err.contains("fc0"), "error should name the node: {err}");
        assert!(err.contains("fully pruned"), "{err}");
    }

    #[test]
    fn propagate_slices_shrinks_mlp_program() {
        use crate::graph::builders;
        use crate::runtime::lowering;
        use crate::util::json;
        let cfg = json::parse(
            r#"{"name": "t", "family": "mlp", "task": "image_cls",
                "image": {"size": 4, "channels": 1}, "hidden": [6, 4],
                "num_classes": 3, "quant": {"weight": true, "act": false}}"#,
        )
        .unwrap();
        let sites = builders::quant_site_specs(&cfg).unwrap();
        let prog = lowering::lower(&cfg, &sites, 2).unwrap();
        let space = crate::graph::search_space_for(&cfg).unwrap();
        let params = crate::runtime::init_params_for(
            &crate::runtime::native::synth_manifest(&cfg).unwrap(),
            0,
        );
        // prune half of fc0's hidden units
        let pruned: Vec<bool> = space
            .groups
            .iter()
            .map(|g| g.label.starts_with("fc0") && g.id % 2 == 0)
            .collect();
        let kept = KeptMap::from_groups(&space.groups, &pruned);
        let mut sliced = ParamStore::new();
        for t in &params.tensors {
            sliced.push(kept.slice(t));
        }
        let p2 = propagate_slices(&prog, &sliced).unwrap();
        let fc0 = p2.nodes.iter().find(|n| n.name == "fc0").unwrap();
        assert_eq!(*fc0.shape.last().unwrap(), 3); // 6 -> 3
        // downstream fc1 input rows shrank coherently; its output did not
        let fc1 = p2.nodes.iter().find(|n| n.name == "fc1").unwrap();
        assert_eq!(*fc1.shape.last().unwrap(), 4);
        let head = p2.nodes.iter().find(|n| n.name == "head").unwrap();
        assert_eq!(*head.shape.last().unwrap(), 3);
        // incoherent stores are rejected with the node name
        let mut bad = sliced.clone();
        bad.get_mut("fc1.weight").unwrap().shape = vec![6, 4];
        let err = propagate_slices(&prog, &bad).unwrap_err().to_string();
        assert!(err.contains("fc1"), "{err}");
    }
}
